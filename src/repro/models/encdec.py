"""Encoder-decoder backbone (SeamlessM4T-class).

Encoder input is the modality-frontend STUB output: precomputed frame
embeddings [B, S_enc, d] (per the assignment the frontend itself is not
modeled). Decoder is a standard causal LM with cross-attention; decode keeps
a self-attn ring cache plus static cross K/V computed once at prefill.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.layers import (
    embed_tokens, embedding_spec, lm_logits, mlp_apply, mlp_spec, norm_spec,
    rms_norm, unembed_spec,
)
from repro.models.params import stack_spec
from repro.models.transformer import _remat, ce_loss, padded_vocab
from repro.parallel import constrain


def enc_block_spec(cfg):
    return {
        "ln1": norm_spec(cfg.d_model),
        "attn": attn.attn_spec(cfg),
        "ln2": norm_spec(cfg.d_model),
        "mlp": mlp_spec(cfg, cfg.d_ff),
    }


def dec_block_spec(cfg):
    return {
        "ln1": norm_spec(cfg.d_model),
        "self_attn": attn.attn_spec(cfg),
        "ln2": norm_spec(cfg.d_model),
        "cross_attn": attn.attn_spec(cfg, cross=True),
        "ln3": norm_spec(cfg.d_model),
        "mlp": mlp_spec(cfg, cfg.d_ff),
    }


def encdec_param_spec(cfg):
    pv = padded_vocab(cfg)
    spec = {
        "embed": embedding_spec(cfg, pv),
        "enc_layers": stack_spec(enc_block_spec(cfg), cfg.num_layers),
        "dec_layers": stack_spec(dec_block_spec(cfg), cfg.num_decoder_layers),
        "ln_enc": norm_spec(cfg.d_model),
        "ln_f": norm_spec(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        spec["unembed"] = unembed_spec(cfg, pv)
    return spec


def encode(cfg, params, enc_embeds):
    x = enc_embeds.astype(jnp.dtype(cfg.dtype))
    x = constrain(x, ("batch", None, None))
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    from repro.models.transformer import rope_tables_for
    rope = rope_tables_for(cfg, S)

    def body(h, lyr):
        hh = rms_norm(h, lyr["ln1"], cfg.norm_eps)
        h = h + attn.self_attention(cfg, lyr["attn"], hh, positions,
                                    causal=False, rope=rope)
        hh = rms_norm(h, lyr["ln2"], cfg.norm_eps)
        h = h + mlp_apply(cfg, lyr["mlp"], hh)
        return constrain(h, ("batch", None, None)), None

    x, _ = jax.lax.scan(_remat(cfg, body), x, params["enc_layers"])
    return rms_norm(x, params["ln_enc"], cfg.norm_eps)


def dec_block(cfg, p, x, positions, enc_out, rope=None):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    x = x + attn.self_attention(cfg, p["self_attn"], h, positions, causal=True,
                                rope=rope)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + attn.cross_attention(cfg, p["cross_attn"], h, enc_out)
    h = rms_norm(x, p["ln3"], cfg.norm_eps)
    x = x + mlp_apply(cfg, p["mlp"], h)
    return constrain(x, ("batch", None, None))


def encdec_loss(cfg, params, batch):
    enc_out = encode(cfg, params, batch["enc_embeds"])
    tokens = batch["dec_tokens"]
    x = embed_tokens(cfg, params["embed"]["table"], tokens, jnp.dtype(cfg.dtype))
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    from repro.models.transformer import rope_tables_for
    rope = rope_tables_for(cfg, S)
    body = _remat(cfg, lambda h, lyr: (dec_block(cfg, lyr, h, positions,
                                                 enc_out, rope), None))
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    loss, metrics = ce_loss(cfg, params, x[:, :-1], tokens[:, 1:])
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------- prefill / decode ----

def encdec_cache_spec(cfg, batch, max_len, enc_len, dtype):
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim()
    L = cfg.num_decoder_layers
    self_spec = attn.init_cache_spec(cfg, batch, max_len, dtype)
    return {
        "self": {k: jax.ShapeDtypeStruct((L,) + v.shape, v.dtype)
                 for k, v in self_spec.items()},
        "cross_k": jax.ShapeDtypeStruct((L, batch, enc_len, KV, hd), dtype),
        "cross_v": jax.ShapeDtypeStruct((L, batch, enc_len, KV, hd), dtype),
    }


def encdec_cache_axes(cfg):
    ax = {k: ("layer",) + v for k, v in attn.cache_logical_axes().items()}
    return {
        "self": ax,
        "cross_k": ("layer", "batch", None, "kv_heads", None),
        "cross_v": ("layer", "batch", None, "kv_heads", None),
    }


def encdec_prefill(cfg, params, batch, max_len):
    """Encode source; consume decoder prompt; return (caches, last logits)."""
    dtype = jnp.dtype(cfg.dtype)
    enc_out = encode(cfg, params, batch["enc_embeds"])
    tokens = batch["dec_tokens"]
    x = embed_tokens(cfg, params["embed"]["table"], tokens, dtype)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(h, lyr):
        hh = rms_norm(h, lyr["ln1"], cfg.norm_eps)
        self_cache = attn.prefill_cache(cfg, lyr["self_attn"], hh, positions,
                                        max_len, dtype)
        h = h + attn.self_attention(cfg, lyr["self_attn"], hh, positions,
                                    causal=True)
        hh = rms_norm(h, lyr["ln2"], cfg.norm_eps)
        h = h + attn.cross_attention(cfg, lyr["cross_attn"], hh, enc_out)
        ck = jnp.einsum("bsd,dnh->bsnh", enc_out,
                        lyr["cross_attn"]["wk"].astype(dtype))
        cv = jnp.einsum("bsd,dnh->bsnh", enc_out,
                        lyr["cross_attn"]["wv"].astype(dtype))
        hh = rms_norm(h, lyr["ln3"], cfg.norm_eps)
        h = h + mlp_apply(cfg, lyr["mlp"], hh)
        return h, (self_cache, ck, cv)

    x, (self_c, ck, cv) = jax.lax.scan(body, x, params["dec_layers"])
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    pv = padded_vocab(cfg)
    logits = lm_logits(cfg, params, x[:, -1:], pv)
    caches = {"self": self_c, "cross_k": ck, "cross_v": cv}
    return caches, logits[:, 0, : cfg.vocab_size]


def _cross_decode(cfg, p, x, ck, cv):
    """Single-query cross attention against static enc K/V."""
    import numpy as np
    hd = cfg.resolved_head_dim()
    scale = 1.0 / np.sqrt(hd)
    q = attn._project_q(cfg, p, x)                    # [B,1,KV,G,hd]
    s = jnp.einsum("bqngh,bknh->bngqk", q.astype(jnp.float32),
                   ck.astype(jnp.float32)) * scale
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngqk,bknh->bqngh", w, cv.astype(jnp.float32)).astype(x.dtype)
    return attn._out_proj(cfg, p, o)


def encdec_decode(cfg, params, caches, tokens, pos):
    dtype = jnp.dtype(cfg.dtype)
    x = embed_tokens(cfg, params["embed"]["table"], tokens, dtype)

    def body(h, xs):
        lyr, sc, ck, cv = xs
        hh = rms_norm(h, lyr["ln1"], cfg.norm_eps)
        out, sc2 = attn.decode_attention(cfg, lyr["self_attn"], hh, sc, pos)
        h = h + out
        hh = rms_norm(h, lyr["ln2"], cfg.norm_eps)
        h = h + _cross_decode(cfg, lyr["cross_attn"], hh, ck, cv)
        hh = rms_norm(h, lyr["ln3"], cfg.norm_eps)
        h = h + mlp_apply(cfg, lyr["mlp"], hh)
        return h, sc2

    x, self_c = jax.lax.scan(
        body, x, (params["dec_layers"], caches["self"],
                  caches["cross_k"], caches["cross_v"]))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    pv = padded_vocab(cfg)
    logits = lm_logits(cfg, params, x, pv)
    new_caches = {"self": self_c, "cross_k": caches["cross_k"],
                  "cross_v": caches["cross_v"]}
    return logits[:, 0, : cfg.vocab_size], new_caches
