"""Shared layers: RMSNorm, RoPE, activations, embeddings, spec helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.params import ParamSpec
from repro.parallel import constrain


def dense_spec(shape, axes, fan_in=None, scale=1.0):
    """ParamSpec for a projection with 1/sqrt(fan_in) init."""
    if fan_in is None:
        fan_in = shape[0]
    return ParamSpec(shape, axes, init="normal", scale=scale / max(fan_in, 1) ** 0.5)


def norm_spec(dim):
    return ParamSpec((dim,), (None,), init="ones")


def rms_norm(x, gamma, eps=1e-5, dtype=None):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32)).astype(dtype or dt)


def activation(name: str):
    if name == "swiglu" or name == "silu":
        return jax.nn.silu
    if name == "geglu" or name == "gelu":
        return jax.nn.gelu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "relu":
        return jax.nn.relu
    raise ValueError(name)


def is_gated(name: str) -> bool:
    return name in ("swiglu", "geglu")


# ---------------------------------------------------------------- RoPE ----

def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) * 2.0 / head_dim))


def rope_tables(positions_1d, head_dim: int, theta: float):
    """cos/sin tables [S, half] (f32). Computed ONCE per forward and passed
    into the layer scan as closure constants — hoisting them out of the loop
    removed ~8% of HBM traffic on the train cells (EXPERIMENTS.md Perf)."""
    freqs = jnp.asarray(rope_freqs(head_dim, theta))
    ang = positions_1d.astype(jnp.float32)[:, None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float, tables=None):
    """x: [..., S, H?, head_dim] with positions [..., S] or [S]. Rotates pairs
    (x[..., :half], x[..., half:]) — the 'split-half' convention. `tables`
    (cos, sin) of shape [S, half] short-circuits the trig."""
    head_dim = x.shape[-1]
    if tables is None:
        freqs = jnp.asarray(rope_freqs(head_dim, theta))        # [half]
        ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, half]
        while ang.ndim < x.ndim:
            ang = ang[..., None, :]
        cos, sin = jnp.cos(ang), jnp.sin(ang)
    else:
        cos, sin = tables                                       # [S, half]
        # align the S axis: x is [..., S, (heads...), hd]
        extra = x.ndim - 2 - cos.ndim + 1                       # head axes
        for _ in range(max(extra, 0)):
            cos = cos[..., None, :]
            sin = sin[..., None, :]
    half = head_dim // 2
    x1, x2 = x[..., :half], x[..., half:]
    x32_1, x32_2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x32_1 * cos - x32_2 * sin,
                           x32_2 * cos + x32_1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------- embedding ----

def embedding_spec(cfg, padded_vocab: int):
    return {
        "table": ParamSpec((padded_vocab, cfg.d_model), ("vocab", "embed"),
                           init="normal", scale=0.02),
    }


def padded_vocab_size(vocab: int, multiple: int = 512) -> int:
    return -(-vocab // multiple) * multiple


def batch_axis(cfg) -> str:
    return "batch_dp3" if cfg.dense_layout == "dp" else "batch"


def embed_tokens(cfg, table, tokens, compute_dtype):
    x = jnp.take(table, tokens, axis=0).astype(compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), compute_dtype)
    return constrain(x, (batch_axis(cfg), None, None))


def lm_logits(cfg, params, x, padded_vocab: int):
    """Final logits. Uses tied embedding transpose or a separate unembed."""
    if cfg.tie_embeddings:
        w = params["embed"]["table"]
        logits = jnp.einsum("bsd,vd->bsv", x, w.astype(x.dtype))
    else:
        w = params["unembed"]["table"]
        logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    # mask padded vocab entries out of the softmax
    if padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, jnp.asarray(-1e9, logits.dtype), logits)
    return constrain(logits, (batch_axis(cfg), None, "act_vocab"))


def unembed_spec(cfg, padded_vocab: int):
    return {"table": dense_spec((cfg.d_model, padded_vocab), ("embed", "vocab"),
                                fan_in=cfg.d_model)}


# ----------------------------------------------------------------- MLP ----

def mlp_spec(cfg, d_ff: int, d_model=None):
    d = d_model or cfg.d_model
    fax = "mlp" if cfg.dense_layout == "tp" else None
    spec = {
        "wi": dense_spec((d, d_ff), ("embed", fax)),
        "wo": dense_spec((d_ff, d), (fax, "embed"), fan_in=d_ff),
    }
    if is_gated(cfg.ffn_activation):
        spec["wg"] = dense_spec((d, d_ff), ("embed", fax))
    return spec


def mlp_apply(cfg, p, x):
    act = activation(cfg.ffn_activation)
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
    if is_gated(cfg.ffn_activation):
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype))
        h = act(g) * h
    else:
        h = act(h)
    h = constrain(h, (batch_axis(cfg), None, "act_mlp"))
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype))
