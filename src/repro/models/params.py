"""Parameter-spec trees: one definition drives init, eval_shape and sharding.

A model builds a nested dict of ParamSpec leaves. From that single tree we
derive (a) materialized params (`init_params`), (b) ShapeDtypeStruct stand-ins
for the dry-run (`shape_tree` — never allocates), and (c) per-leaf logical
axes for the sharding resolver (`axes_tree`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple                      # logical axis names (len == ndim)
    init: str = "fan_in"             # fan_in | normal | zeros | ones | const
    scale: float = 1.0
    dtype: Optional[str] = None      # override model param_dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _map_specs(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_spec)


def stack_spec(tree, n: int):
    """Prepend a scanned 'layer' dimension of size n to every leaf."""
    def f(s: ParamSpec):
        return dataclasses.replace(s, shape=(n,) + s.shape, axes=("layer",) + s.axes)
    return _map_specs(f, tree)


def shape_tree(tree, default_dtype):
    def f(s: ParamSpec):
        return jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype or default_dtype))
    return _map_specs(f, tree)


def axes_tree(tree):
    return _map_specs(lambda s: s.axes, tree)


def init_params(tree, key, default_dtype):
    """Materialize params. Deterministic per-leaf keys derived by path hash so
    the result is independent of tree iteration order."""
    import hashlib
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_spec)
    leaves = []
    for path, spec in flat:
        pstr = "/".join(str(p) for p in path)
        # blake2, NOT hash(): Python string hashing is salted per process and
        # replay workers must derive bit-identical init keys
        digest = hashlib.blake2b(pstr.encode(), digest_size=4).digest()
        k = jax.random.fold_in(key, int.from_bytes(digest, "little"))
        dtype = jnp.dtype(spec.dtype or default_dtype)
        if spec.init == "zeros":
            leaf = jnp.zeros(spec.shape, dtype)
        elif spec.init == "ones":
            leaf = jnp.ones(spec.shape, dtype)
        elif spec.init == "const":
            leaf = jnp.full(spec.shape, spec.scale, dtype)
        elif spec.init == "normal":
            leaf = (spec.scale * jax.random.normal(k, spec.shape)).astype(dtype)
        elif spec.init == "fan_in":
            fan_in = spec.shape[0] if len(spec.shape) == 1 else int(np.prod(spec.shape[:-1]))
            if len(spec.shape) >= 3 and spec.axes and spec.axes[0] in ("layer", "expert"):
                fan_in = int(np.prod(spec.shape[1:-1])) or 1
            std = spec.scale / max(fan_in, 1) ** 0.5
            leaf = (std * jax.random.normal(k, spec.shape)).astype(dtype)
        else:
            raise ValueError(f"unknown init {spec.init!r}")
        leaves.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, leaves)
