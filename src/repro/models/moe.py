"""Mixture-of-Experts with explicit expert parallelism.

Dispatch is sort-based (capacity-dropping, GShard-style) and runs INSIDE a
shard_map so the scatter/gather stay local to each device:

  * tokens are sharded over ("pod","data") and replicated over "model";
  * EP mode (num_experts % model_axis == 0): each model shard owns E/ms
    experts; it filters the (token, choice) pairs that route to its experts,
    builds its local [E_local, C, d] buffer, runs its experts, and psums the
    partial combine over "model". No all-to-all: replicated-dispatch EP.
  * TP mode (small expert counts, e.g. Mixtral's 8 on a 16-way axis): every
    shard holds all experts but only d_ff/ms of each; partial outputs psum.

The capacity C is per data-shard, so dispatch memory is O(topk * T_local * d).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import activation, dense_spec, is_gated
from repro.parallel import current_mesh


def moe_spec(cfg):
    mo = cfg.moe
    d, E, f = cfg.d_model, mo.num_experts, mo.d_ff_expert
    spec = {
        "router": dense_spec((d, E), ("embed", None)),
        "experts": {
            "wi": dense_spec((E, d, f), ("expert", "embed", "mlp"), fan_in=d),
            "wo": dense_spec((E, f, d), ("expert", "mlp", "embed"), fan_in=f),
        },
    }
    if is_gated(cfg.ffn_activation):
        spec["experts"]["wg"] = dense_spec((E, d, f), ("expert", "embed", "mlp"),
                                           fan_in=d)
    if mo.num_shared_experts:
        fs = f * mo.num_shared_experts
        spec["shared"] = {
            "wi": dense_spec((d, fs), ("embed", "mlp")),
            "wo": dense_spec((fs, d), ("mlp", "embed"), fan_in=fs),
        }
        if is_gated(cfg.ffn_activation):
            spec["shared"]["wg"] = dense_spec((d, fs), ("embed", "mlp"))
    return spec


def _route(cfg, router_w, x_flat):
    """Router logits -> (topk weights [T,k], topk ids [T,k], aux_loss)."""
    mo = cfg.moe
    logits = jnp.einsum("td,de->te", x_flat, router_w.astype(x_flat.dtype))
    logits = logits.astype(jnp.float32)
    if mo.router == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        w, ids = jax.lax.top_k(scores, mo.top_k)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
        probs = scores / jnp.maximum(scores.sum(-1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, ids = jax.lax.top_k(probs, mo.top_k)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    E = logits.shape[-1]
    hot = jax.nn.one_hot(ids[:, 0], E, dtype=jnp.float32)
    f_e = hot.mean(0)
    p_e = probs.mean(0)
    aux = E * jnp.sum(f_e * p_e)
    return w, ids, aux


def _expert_ffn(cfg, pe, buf):
    """buf [E_l, C, d] through per-expert (possibly ff-sliced) MLP."""
    act = activation(cfg.ffn_activation)
    h = jnp.einsum("ecd,edf->ecf", buf, pe["wi"].astype(buf.dtype))
    if "wg" in pe:
        g = jnp.einsum("ecd,edf->ecf", buf, pe["wg"].astype(buf.dtype))
        h = act(g) * h
    else:
        h = act(h)
    return jnp.einsum("ecf,efd->ecd", h, pe["wo"].astype(buf.dtype))


def _moe_local(cfg, p, x_flat, e_offset: int, e_local: int, capacity: int):
    """Per-device dispatch/compute/combine over local experts [e_offset,
    e_offset+e_local). Returns (partial_out [T,d], aux, dropped_frac)."""
    mo = cfg.moe
    T, d = x_flat.shape
    k = mo.top_k
    w, ids, aux = _route(cfg, p["router"], x_flat)

    ids_f = ids.reshape(-1)                                    # [T*k]
    w_f = w.reshape(-1)
    tok_f = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)

    local = ids_f - e_offset
    mine = (local >= 0) & (local < e_local)
    sort_key = jnp.where(mine, local, e_local)                 # sentinel last
    order = jnp.argsort(sort_key, stable=True)
    s_local = sort_key[order]
    s_tok = tok_f[order]
    s_w = w_f[order]
    # position within the expert's segment
    seg_start = jnp.searchsorted(s_local, s_local, side="left")
    pos = jnp.arange(T * k, dtype=jnp.int32) - seg_start.astype(jnp.int32)
    keep = (s_local < e_local) & (pos < capacity)
    dropped = jnp.sum((s_local < e_local) & ~keep) / jnp.maximum(
        jnp.sum(s_local < e_local), 1)

    # scatter into [E_l, C, d]; invalid rows get an out-of-bounds expert index
    # and are dropped by scatter mode="drop"
    e_idx = jnp.where(keep, s_local, e_local)
    buf = jnp.zeros((e_local, capacity, d), x_flat.dtype)
    buf = buf.at[e_idx, jnp.clip(pos, 0, capacity - 1)].set(
        x_flat[s_tok], mode="drop")

    out_buf = _expert_ffn(cfg, p["experts"], buf)

    gathered = out_buf[jnp.clip(e_idx, 0, e_local - 1),
                       jnp.clip(pos, 0, capacity - 1)]         # [T*k, d]
    contrib = gathered * (s_w * keep).astype(gathered.dtype)[:, None]
    out = jnp.zeros((T, d), x_flat.dtype).at[s_tok].add(contrib, mode="drop")
    return out, aux, dropped


def moe_apply(cfg, p, x):
    """x [B,S,d] -> (y [B,S,d], metrics dict). Shared experts added outside
    the shard_map (plain GSPMD tensor-parallel MLP)."""
    from jax.sharding import PartitionSpec as P

    mo = cfg.moe
    B, S, d = x.shape
    mesh = current_mesh()
    x_flat = x.reshape(B * S, d)

    if mesh is not None and "model" in mesh.shape:
        from repro.parallel.sharding import physical_spec

        ms = mesh.shape["model"]
        dp = cfg.dense_layout == "dp"
        # divisibility-aware token sharding (decode with B*S==1 replicates)
        tok_spec = physical_spec(("batch_dp3" if dp else "batch", None),
                                 (B * S, d), mesh)
        tok_axes = ()
        if tok_spec and tok_spec[0] is not None:
            tok_axes = (tok_spec[0] if isinstance(tok_spec[0], tuple)
                        else (tok_spec[0],))
        t_shards = int(np.prod([mesh.shape[a] for a in tok_axes])) if tok_axes else 1
        t_local = (B * S) // t_shards
        ep = mo.num_experts % ms == 0
        e_local = mo.num_experts // ms if ep else mo.num_experts
        t_dispatch = t_local * (ms if (dp and tok_axes and "model" in tok_axes)
                                else 1)
        capacity = int(np.ceil(mo.top_k * t_dispatch / mo.num_experts
                               * mo.capacity_factor))
        capacity = max(capacity, 4)
        if ep:
            expert_specs = jax.tree_util.tree_map(
                lambda _: P("model", None, None), p["experts"])
        else:
            expert_specs = jax.tree_util.tree_map(
                lambda _: P(None, None, "model"), p["experts"])
            # wo is [E, f, d]: slice f (dim 1), not d
            expert_specs["wo"] = P(None, "model", None)
        in_specs = (tok_spec, P(None, None), expert_specs)
        out_specs = (tok_spec, P(), P())

        model_in_tok = dp and tok_axes and "model" in tok_axes

        def shard_fn(xl, router_w, experts_l):
            idx = jax.lax.axis_index("model")
            off = idx * e_local if ep else 0
            pl = {"router": router_w, "experts": experts_l}
            if model_in_tok:
                # dp layout: tokens are sharded over "model" too — gather
                # them for dispatch, reduce-scatter the combined outputs
                xg = jax.lax.all_gather(xl, "model", axis=0, tiled=True)
                out, aux, drop = _moe_local(cfg, pl, xg, off, e_local,
                                            capacity)
                out = jax.lax.psum_scatter(out, "model", scatter_dimension=0,
                                           tiled=True)
            else:
                out, aux, drop = _moe_local(cfg, pl, xl, off, e_local,
                                            capacity)
                out = jax.lax.psum(out, "model")
            # metrics differ across token shards: average them so the
            # replicated out_specs is semantically true
            mean_axes = tuple(a for a in tok_axes if a != "model") or None
            if mean_axes:
                aux = jax.lax.pmean(aux, mean_axes)
                drop = jax.lax.pmean(drop, mean_axes)
            return out, aux, drop

        y_flat, aux, dropped = jax.shard_map(
            shard_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False)(x_flat, p["router"], p["experts"])
    else:
        capacity = int(np.ceil(mo.top_k * (B * S) / mo.num_experts
                               * mo.capacity_factor))
        capacity = max(capacity, 4)
        y_flat, aux, dropped = _moe_local(cfg, p, x_flat, 0, mo.num_experts,
                                          capacity)

    y = y_flat.reshape(B, S, d)
    if "shared" in p:
        from repro.models.layers import mlp_apply
        y = y + mlp_apply(cfg, p["shared"], x)
    metrics = {"moe_aux": aux, "moe_dropped": dropped}
    return y, metrics
