"""DeepSeek-V3 Multi-head Latent Attention.

Train path expands the latent to per-head K/V and reuses the generic chunked
softmax. Decode uses the ABSORBED form: the cache holds only the compressed
latent c_kv [B,S,r_kv] + shared rope key k_r [B,S,r_rope] — the paper-relevant
KV-compression trick — and W_uk/W_uv are absorbed into the query/output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import _chunked_sdpa, _mask, NEG_INF
from repro.models.layers import apply_rope, dense_spec, rms_norm
from repro.models.params import ParamSpec
from repro.parallel import constrain


def mla_spec(cfg):
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    hax = "heads" if cfg.dense_layout == "tp" else None
    return {
        "w_dq": dense_spec((d, m.q_lora_rank), ("embed", None)),
        "q_ln": ParamSpec((m.q_lora_rank,), (None,), init="ones"),
        "w_uq": dense_spec((m.q_lora_rank, H, qk_hd), (None, hax, None),
                           fan_in=m.q_lora_rank),
        "w_dkv": dense_spec((d, m.kv_lora_rank), ("embed", None)),
        "kv_ln": ParamSpec((m.kv_lora_rank,), (None,), init="ones"),
        "w_kr": dense_spec((d, m.qk_rope_head_dim), ("embed", None)),
        "w_uk": dense_spec((m.kv_lora_rank, H, m.qk_nope_head_dim),
                           (None, hax, None), fan_in=m.kv_lora_rank),
        "w_uv": dense_spec((m.kv_lora_rank, H, m.v_head_dim),
                           (None, hax, None), fan_in=m.kv_lora_rank),
        "wo": dense_spec((H, m.v_head_dim, d), (hax, None, "embed"),
                         fan_in=H * m.v_head_dim),
    }


def _latents(cfg, p, x, positions, rope=None):
    """Shared q / kv latent computation. Returns (q_nope, q_rope, c_kv, k_r)."""
    m = cfg.mla
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"].astype(x.dtype)),
                  p["q_ln"], cfg.norm_eps)
    q = jnp.einsum("bsr,rnh->bsnh", cq, p["w_uq"].astype(x.dtype))
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions[:, :, None],
                        cfg.rope_theta, tables=rope)
    c_kv = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(x.dtype)),
                    p["kv_ln"], cfg.norm_eps)
    k_r = apply_rope(jnp.einsum("bsd,dr->bsr", x, p["w_kr"].astype(x.dtype)),
                     positions, cfg.rope_theta, tables=rope)
    return q_nope, q_rope, c_kv, k_r


def mla_attention(cfg, p, x, positions, rope=None):
    """Training/prefill forward (expanded form + chunked softmax)."""
    m = cfg.mla
    H = cfg.num_heads
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    scale = 1.0 / np.sqrt(qk_hd)
    q_nope, q_rope, c_kv, k_r = _latents(cfg, p, x, positions, rope=rope)
    k_nope = jnp.einsum("bsr,rnh->bsnh", c_kv, p["w_uk"].astype(x.dtype))
    v = jnp.einsum("bsr,rnh->bsnh", c_kv, p["w_uv"].astype(x.dtype))
    B, S = x.shape[:2]
    # assemble effective q/k with heads as the "KV" axis (G=1) so we can reuse
    # the generic chunked online-softmax
    q_eff = jnp.concatenate([q_nope, q_rope], axis=-1).reshape(B, S, H, 1, qk_hd)
    k_eff = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_r[:, :, None, :], (B, S, H, m.qk_rope_head_dim))],
        axis=-1)
    # pad v up to qk_hd so k/v share a head_dim (cheap: zero-pad, slice after)
    q_eff = constrain(q_eff, ("batch", None, "act_heads", None, None))
    k_eff = constrain(k_eff, ("batch", None, "act_heads", None))
    o = _chunked_sdpa(q_eff, k_eff,
                      jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_hd - m.v_head_dim))),
                      positions[0], positions[0], True, None, scale,
                      cfg.attention_chunk,
                      probs_dtype=cfg.attention_probs_dtype,
                      remat_chunk=cfg.attention_remat_chunk,
                      seq_sharded=cfg.seq_shard)
    o = o.reshape(B, S, H, qk_hd)[..., : m.v_head_dim]
    return jnp.einsum("bsnh,nhd->bsd", o, p["wo"].astype(x.dtype))


# ------------------------------------------------------------- decode -----

def mla_cache_spec(cfg, batch: int, max_len: int, dtype):
    m = cfg.mla
    return {
        "c_kv": jax.ShapeDtypeStruct((batch, max_len, m.kv_lora_rank), dtype),
        "k_r": jax.ShapeDtypeStruct((batch, max_len, m.qk_rope_head_dim), dtype),
        "slot_pos": jax.ShapeDtypeStruct((max_len,), jnp.int32),
    }


def mla_cache_axes():
    return {"c_kv": ("batch", "cache_seq", None),
            "k_r": ("batch", "cache_seq", None),
            "slot_pos": (None,)}


def mla_init_cache(cfg, batch, max_len, dtype):
    spec = mla_cache_spec(cfg, batch, max_len, dtype)
    c = {k: jnp.zeros(v.shape, v.dtype) for k, v in spec.items()}
    c["slot_pos"] = jnp.full((max_len,), -1, jnp.int32)
    return c


def mla_decode(cfg, p, x, cache, pos):
    """Absorbed-form one-token decode against the compressed latent cache."""
    m = cfg.mla
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    scale = 1.0 / np.sqrt(qk_hd)
    B = x.shape[0]
    posv = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope, c_kv_new, k_r_new = _latents(cfg, p, x, posv)

    ckv = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), (0, pos, 0))
    kr = jax.lax.dynamic_update_slice(
        cache["k_r"], k_r_new.astype(cache["k_r"].dtype), (0, pos, 0))
    slot_pos = jax.lax.dynamic_update_slice(
        cache["slot_pos"], jnp.full((1,), pos, jnp.int32), (pos,))
    ckv = constrain(ckv, ("batch", "cache_seq", None))

    # absorb W_uk into q: q_abs [B,1,H,r_kv]
    q_abs = jnp.einsum("bqnh,rnh->bqnr", q_nope, p["w_uk"].astype(x.dtype))
    s = (jnp.einsum("bqnr,bkr->bnqk", q_abs.astype(jnp.float32),
                    ckv.astype(jnp.float32))
         + jnp.einsum("bqnh,bkh->bnqk", q_rope.astype(jnp.float32),
                      kr.astype(jnp.float32))) * scale
    keep = _mask(jnp.full((1,), pos, jnp.int32), slot_pos, True, None)
    s = jnp.where(keep[None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bnqk,bkr->bqnr", w, ckv.astype(jnp.float32))
    o = jnp.einsum("bqnr,rnh->bqnh", ctx.astype(x.dtype), p["w_uv"].astype(x.dtype))
    out = jnp.einsum("bqnh,nhd->bqd", o, p["wo"].astype(x.dtype))
    return out, {"c_kv": ckv, "k_r": kr, "slot_pos": slot_pos}


def mla_prefill_cache(cfg, p, x, positions, max_len, dtype, rope=None):
    m = cfg.mla
    c_kv = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(x.dtype)),
                    p["kv_ln"], cfg.norm_eps)
    k_r = apply_rope(jnp.einsum("bsd,dr->bsr", x, p["w_kr"].astype(x.dtype)),
                     positions, cfg.rope_theta, tables=rope)
    B, S = x.shape[:2]
    pad = max_len - S
    return {
        "c_kv": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))).astype(dtype),
        "k_r": jnp.pad(k_r, ((0, 0), (0, pad), (0, 0))).astype(dtype),
        "slot_pos": jnp.concatenate(
            [jnp.arange(S, dtype=jnp.int32), jnp.full((pad,), -1, jnp.int32)]),
    }
