"""Decoder-only LM assembly for dense / moe / ssm / hybrid / vlm families.

Layer stacks are jax.lax.scan'd over stacked params (small HLO, GSPMD-sliced
FSDP gathers per iteration) with per-block jax.checkpoint (remat). The loss
is sequence-chunked so [B,S,vocab] logits never materialize for large-vocab
archs.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn
from repro.models import mamba
from repro.models import mla
from repro.models import moe as moe_mod
from repro.models.layers import (
    embed_tokens,
    embedding_spec,
    lm_logits,
    mlp_apply,
    mlp_spec,
    norm_spec,
    padded_vocab_size,
    unembed_spec,
)
from repro.models.params import stack_spec
from repro.models.layers import rms_norm
from repro.parallel import constrain


def _remat(cfg, fn):
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def padded_vocab(cfg) -> int:
    v = cfg.vocab_size
    return v if v < 512 else padded_vocab_size(v, 512)


# ------------------------------------------------------------- blocks -----

def dense_block_spec(cfg):
    spec = {
        "ln1": norm_spec(cfg.d_model),
        "attn": mla.mla_spec(cfg) if cfg.mla else attn.attn_spec(cfg),
        "ln2": norm_spec(cfg.d_model),
        "mlp": mlp_spec(cfg, cfg.d_ff),
    }
    return spec


def moe_block_spec(cfg):
    return {
        "ln1": norm_spec(cfg.d_model),
        "attn": mla.mla_spec(cfg) if cfg.mla else attn.attn_spec(cfg),
        "ln2": norm_spec(cfg.d_model),
        "moe": moe_mod.moe_spec(cfg),
    }


def _attention(cfg, p, x, positions, window, rope=None):
    if cfg.mla:
        return mla.mla_attention(cfg, p, x, positions, rope=rope)
    return attn.self_attention(cfg, p, x, positions, causal=True,
                               window=window, rope=rope)


def rope_tables_for(cfg, S: int):
    """Hoisted (cos, sin) rope tables — computed ONCE per forward and closed
    over by the layer scan (loop-invariant; saves ~8% HBM traffic)."""
    from repro.models.layers import rope_tables
    if cfg.family == "ssm":
        return None
    dim = cfg.mla.qk_rope_head_dim if cfg.mla else cfg.resolved_head_dim()
    return rope_tables(jnp.arange(S, dtype=jnp.int32), dim, cfg.rope_theta)


def res_axes(cfg):
    """Residual-stream logical axes. With cfg.seq_shard the sequence dim is
    sharded over 'model' (sequence parallelism) — the layout of choice when
    head counts don't divide the model axis and attention would replicate.
    With dense_layout='dp' the batch dim spreads over all mesh axes."""
    from repro.models.layers import batch_axis
    return (batch_axis(cfg), "seq_mp" if cfg.seq_shard else None, None)


def dense_block(cfg, p, x, positions, window=None, rope=None):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    x = x + _attention(cfg, p["attn"], h, positions, window, rope)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + mlp_apply(cfg, p["mlp"], h)
    return constrain(x, res_axes(cfg))


def moe_block(cfg, p, x, positions, window=None, rope=None):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    x = x + _attention(cfg, p["attn"], h, positions, window, rope)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    y, metrics = moe_mod.moe_apply(cfg, p["moe"], h)
    x = x + y
    return constrain(x, res_axes(cfg)), metrics


# -------------------------------------------------------------- specs -----

def lm_param_spec(cfg):
    pv = padded_vocab(cfg)
    spec = {"embed": embedding_spec(cfg, pv), "ln_f": norm_spec(cfg.d_model)}
    if not cfg.tie_embeddings:
        spec["unembed"] = unembed_spec(cfg, pv)
    fam = cfg.family
    if fam in ("dense", "vlm"):
        spec["layers"] = stack_spec(dense_block_spec(cfg), cfg.num_layers)
    elif fam == "moe":
        nd = cfg.moe.first_dense_layers
        if nd:
            spec["dense_layers"] = stack_spec(dense_block_spec(cfg), nd)
        spec["layers"] = stack_spec(moe_block_spec(cfg), cfg.num_layers - nd)
    elif fam == "ssm":
        spec["layers"] = stack_spec(mamba.mamba1_spec(cfg), cfg.num_layers)
    elif fam == "hybrid":
        g = cfg.num_layers // cfg.attn_period
        per = cfg.attn_period - 1
        tail = cfg.num_layers - g * cfg.attn_period
        spec["groups"] = stack_spec(stack_spec(mamba.mamba2_spec(cfg), per), g)
        spec["shared_attn"] = dense_block_spec(cfg)
        if tail:
            spec["tail"] = stack_spec(mamba.mamba2_spec(cfg), tail)
    else:
        raise ValueError(fam)
    return spec


# ------------------------------------------------------------ forward -----

def _mamba_fwd(cfg):
    return mamba.mamba1_forward if cfg.ssm.version == 1 else mamba.mamba2_forward


def lm_forward(cfg, params, tokens=None, embeds=None):
    """Returns final hidden states [B, S_total, d]."""
    compute_dtype = jnp.dtype(cfg.dtype)
    parts = []
    if embeds is not None:
        parts.append(embeds.astype(compute_dtype))
    if tokens is not None:
        parts.append(embed_tokens(cfg, params["embed"]["table"], tokens,
                                  compute_dtype))
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    x = constrain(x, res_axes(cfg))
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    window = cfg.sliding_window
    fam = cfg.family

    rope = rope_tables_for(cfg, S)
    if fam in ("dense", "vlm"):
        body = _remat(cfg, lambda h, lyr: (dense_block(cfg, lyr, h, positions,
                                                       window, rope), None))
        x, _ = jax.lax.scan(body, x, params["layers"])
        metrics = {}
    elif fam == "moe":
        if "dense_layers" in params:
            dbody = _remat(cfg, lambda h, lyr: (dense_block(cfg, lyr, h,
                                                            positions, window,
                                                            rope), None))
            x, _ = jax.lax.scan(dbody, x, params["dense_layers"])
        def mbody(h, lyr):
            h2, m = moe_block(cfg, lyr, h, positions, window, rope)
            return h2, (m["moe_aux"], m["moe_dropped"])
        x, (aux, drop) = jax.lax.scan(_remat(cfg, mbody), x, params["layers"])
        metrics = {"moe_aux": aux.mean(), "moe_dropped": drop.mean()}
    elif fam == "ssm":
        fwd = _mamba_fwd(cfg)
        body = _remat(cfg, lambda h, lyr: (h + fwd(cfg, lyr, h), None))
        x, _ = jax.lax.scan(body, x, params["layers"])
        metrics = {}
    elif fam == "hybrid":
        fwd = mamba.mamba2_forward
        mamba_body = _remat(cfg, lambda h, lyr: (h + fwd(cfg, lyr, h), None))
        shared = params["shared_attn"]
        rope = rope_tables_for(cfg, S)
        def group_body(h, glyr):
            h, _ = jax.lax.scan(mamba_body, h, glyr)
            h = _remat(cfg, lambda hh: dense_block(cfg, shared, hh, positions,
                                                   window, rope))(h)
            return h, None
        x, _ = jax.lax.scan(group_body, x, params["groups"])
        if "tail" in params:
            x, _ = jax.lax.scan(mamba_body, x, params["tail"])
        metrics = {}
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return x, metrics


# --------------------------------------------------------------- loss -----

def _loss_chunk_size(cfg, S):
    if cfg.loss_chunk:
        return min(cfg.loss_chunk, S)
    pv = padded_vocab(cfg)
    if S * pv > 64 * 1024 * 1024:
        return max(1, min(1024, S))
    return S


def ce_loss(cfg, params, hidden, labels, mask=None):
    """Chunked cross-entropy. hidden [B,T,d] aligned with labels [B,T]."""
    pv = padded_vocab(cfg)
    B, T, _ = hidden.shape
    if mask is None:
        mask = jnp.ones((B, T), jnp.float32)
    C = _loss_chunk_size(cfg, T)
    pad = (-T) % C
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nch = hidden.shape[1] // C

    def chunk_fn(h_c, y_c, m_c):
        logits = lm_logits(cfg, params, h_c, pv).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        hot = jax.nn.one_hot(y_c, pv, dtype=jnp.bfloat16)
        gold = jnp.einsum("bsv,bsv->bs", logits, hot,
                          preferred_element_type=jnp.float32)
        nll = (lse - gold) * m_c
        return nll.sum(), m_c.sum(), (jnp.square(lse) * m_c).sum()

    if nch == 1:
        tot, cnt, zsq = chunk_fn(hidden, labels, mask)
    else:
        hs = hidden.reshape(B, nch, C, -1).swapaxes(0, 1)
        ys = labels.reshape(B, nch, C).swapaxes(0, 1)
        ms = mask.reshape(B, nch, C).swapaxes(0, 1)
        def body(carry, xs):
            t, c, z = carry
            dt_, dc, dz = jax.checkpoint(chunk_fn)(*xs)
            return (t + dt_, c + dc, z + dz), None
        (tot, cnt, zsq), _ = jax.lax.scan(
            body, (jnp.zeros(()), jnp.zeros(()), jnp.zeros(())), (hs, ys, ms))
    cnt = jnp.maximum(cnt, 1.0)
    return tot / cnt, {"ce": tot / cnt, "z_loss": zsq / cnt}


def lm_loss(cfg, params, batch):
    """Next-token loss for decoder-only families. batch: tokens [B,S] and,
    for vlm, embeds [B,F,d] prefix."""
    tokens = batch["tokens"]
    embeds = batch.get("embeds")
    hidden, metrics = lm_forward(cfg, params, tokens, embeds)
    if embeds is not None:
        F = embeds.shape[1]
        St = tokens.shape[1]
        h = hidden[:, F - 1: F + St - 1]
        loss, lm = ce_loss(cfg, params, h, tokens)
    else:
        loss, lm = ce_loss(cfg, params, hidden[:, :-1], tokens[:, 1:])
    metrics.update(lm)
    if cfg.moe is not None and cfg.moe.router_aux_loss and "moe_aux" in metrics:
        loss = loss + cfg.moe.router_aux_loss * metrics["moe_aux"]
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------- prefill / decode ----

def _attn_prefill(cfg, p, x, positions, max_len, dtype, window, rope=None):
    """Run one attention block AND emit its primed cache."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla:
        out = mla.mla_attention(cfg, p["attn"], h, positions, rope=rope)
        cache = mla.mla_prefill_cache(cfg, p["attn"], h, positions, max_len,
                                      dtype, rope=rope)
    else:
        out = attn.self_attention(cfg, p["attn"], h, positions, causal=True,
                                  window=window, rope=rope)
        cache = attn.prefill_cache(cfg, p["attn"], h, positions, max_len,
                                   dtype, rope=rope)
    x = x + out
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, _ = moe_mod.moe_apply(cfg, p["moe"], h)
    else:
        y = mlp_apply(cfg, p["mlp"], h)
    return x + y, cache


def _mamba_prefill(cfg, p, x):
    """Mamba block forward + final state cache (for decode continuation)."""
    fwd = mamba.mamba1_forward if cfg.ssm.version == 1 else mamba.mamba2_forward
    out, cache = fwd(cfg, p, x, return_cache=True)
    return x + out, cache


def lm_prefill(cfg, params, batch, max_len):
    """Consume a prompt; return (primed caches, last-position logits)."""
    compute_dtype = jnp.dtype(cfg.dtype)
    tokens = batch.get("tokens")
    embeds = batch.get("embeds")
    parts = []
    if embeds is not None:
        parts.append(embeds.astype(compute_dtype))
    if tokens is not None:
        parts.append(embed_tokens(cfg, params["embed"]["table"], tokens,
                                  compute_dtype))
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    window = cfg.sliding_window
    fam = cfg.family
    caches = {}
    rope = rope_tables_for(cfg, S)
    if fam in ("dense", "vlm", "moe"):
        def body(h, lyr):
            return _attn_prefill(cfg, lyr, h, positions, max_len,
                                 compute_dtype, window, rope)
        if fam == "moe" and "dense_layers" in params:
            x, dc = jax.lax.scan(body, x, params["dense_layers"])
            caches["dense_layers"] = dc
        x, lc = jax.lax.scan(body, x, params["layers"])
        caches["layers"] = lc
    elif fam == "ssm":
        def body(h, lyr):
            return _mamba_prefill(cfg, lyr, h)
        x, lc = jax.lax.scan(body, x, params["layers"])
        caches["layers"] = lc
    elif fam == "hybrid":
        shared = params["shared_attn"]
        def mbody(h, lyr):
            return _mamba_prefill(cfg, lyr, h)
        def gbody(h, glyr):
            h, mc = jax.lax.scan(mbody, h, glyr)
            h, ac = _attn_prefill(cfg, shared, h, positions, max_len,
                                  compute_dtype, window, rope)
            return h, (mc, ac)
        x, (gmc, gac) = jax.lax.scan(gbody, x, params["groups"])
        caches["groups"] = gmc
        caches["shared_attn"] = gac
        if "tail" in params:
            x, tc = jax.lax.scan(mbody, x, params["tail"])
            caches["tail"] = tc
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    pv = padded_vocab(cfg)
    logits = lm_logits(cfg, params, x[:, -1:], pv)
    return caches, logits[:, 0, : cfg.vocab_size]


def _attn_decode_block(cfg, p, x, cache, pos):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla:
        out, c2 = mla.mla_decode(cfg, p["attn"], h, cache, pos)
    else:
        out, c2 = attn.decode_attention(cfg, p["attn"], h, cache, pos)
    x = x + out
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, _ = moe_mod.moe_apply(cfg, p["moe"], h)
    else:
        y = mlp_apply(cfg, p["mlp"], h)
    return x + y, c2


def _mamba_decode_block(cfg, p, x, cache):
    step = mamba.mamba1_decode if cfg.ssm.version == 1 else mamba.mamba2_decode
    out, c2 = step(cfg, p, x, cache)
    return x + out, c2


def lm_decode(cfg, params, caches, tokens, pos):
    """One decode step. tokens [B,1], pos scalar int32. Returns
    (logits [B, vocab], new caches)."""
    compute_dtype = jnp.dtype(cfg.dtype)
    x = embed_tokens(cfg, params["embed"]["table"], tokens, compute_dtype)
    fam = cfg.family
    new_caches = {}
    if fam in ("dense", "vlm", "moe"):
        def body(h, xs):
            lyr, c = xs
            return _attn_decode_block(cfg, lyr, h, c, pos)
        if fam == "moe" and "dense_layers" in params:
            x, dc = jax.lax.scan(body, x, (params["dense_layers"],
                                           caches["dense_layers"]))
            new_caches["dense_layers"] = dc
        x, lc = jax.lax.scan(body, x, (params["layers"], caches["layers"]))
        new_caches["layers"] = lc
    elif fam == "ssm":
        def body(h, xs):
            lyr, c = xs
            return _mamba_decode_block(cfg, lyr, h, c)
        x, lc = jax.lax.scan(body, x, (params["layers"], caches["layers"]))
        new_caches["layers"] = lc
    elif fam == "hybrid":
        shared = params["shared_attn"]
        def mbody(h, xs):
            lyr, c = xs
            return _mamba_decode_block(cfg, lyr, h, c)
        def gbody(h, xs):
            glyr, gmc, gac = xs
            h, mc = jax.lax.scan(mbody, h, (glyr, gmc))
            h, ac = _attn_decode_block(cfg, shared, h, gac, pos)
            return h, (mc, ac)
        x, (gmc, gac) = jax.lax.scan(
            gbody, x, (params["groups"], caches["groups"], caches["shared_attn"]))
        new_caches["groups"] = gmc
        new_caches["shared_attn"] = gac
        if "tail" in params:
            x, tc = jax.lax.scan(mbody, x, (params["tail"], caches["tail"]))
            new_caches["tail"] = tc
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    pv = padded_vocab(cfg)
    logits = lm_logits(cfg, params, x, pv)
    return logits[:, 0, : cfg.vocab_size], new_caches
