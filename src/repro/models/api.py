"""Unified model facade: params, loss, prefill/decode, caches, input specs.

Everything the launcher, Flor, and the dry-run need from a model goes through
``Model`` so that (arch x shape x mesh) cells are uniform.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import attention as attn
from repro.models import encdec as encdec_mod
from repro.models import mamba, mla
from repro.models import transformer as tfm
from repro.models.params import axes_tree, init_params, shape_tree

# encoder length used for enc-dec decode cells (≈30 s of audio frames after
# the frontend's subsampling; the frontend itself is a stub per assignment)
ENC_LEN_DECODE = 1536


def build_model(cfg: ModelConfig) -> "Model":
    return Model(cfg)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self._spec = (encdec_mod.encdec_param_spec(cfg) if cfg.family == "audio"
                      else tfm.lm_param_spec(cfg))

    # ------------------------------------------------------------ params --
    def param_spec(self):
        return self._spec

    def init(self, key):
        return init_params(self._spec, key, self.cfg.param_dtype)

    def param_shapes(self):
        return shape_tree(self._spec, self.cfg.param_dtype)

    def param_axes(self):
        return axes_tree(self._spec)

    # ----------------------------------------------------------- compute --
    def loss(self, params, batch):
        if self.cfg.family == "audio":
            return encdec_mod.encdec_loss(self.cfg, params, batch)
        return tfm.lm_loss(self.cfg, params, batch)

    def prefill(self, params, batch, max_len: int):
        if self.cfg.family == "audio":
            return encdec_mod.encdec_prefill(self.cfg, params, batch, max_len)
        return tfm.lm_prefill(self.cfg, params, batch, max_len)

    def decode(self, params, caches, tokens, pos):
        if self.cfg.family == "audio":
            return encdec_mod.encdec_decode(self.cfg, params, caches, tokens, pos)
        return tfm.lm_decode(self.cfg, params, caches, tokens, pos)

    # ------------------------------------------------------------ caches --
    def _attn_cache_spec(self, batch, max_len, dtype):
        cfg = self.cfg
        if cfg.mla:
            return mla.mla_cache_spec(cfg, batch, max_len, dtype)
        return attn.init_cache_spec(cfg, batch, max_len, dtype)

    def _attn_cache_axes(self):
        return mla.mla_cache_axes() if self.cfg.mla else attn.cache_logical_axes()

    def cache_spec(self, batch: int, max_len: int):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        fam = cfg.family

        def stack(spec, n):
            return jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), spec)

        if fam == "audio":
            return encdec_mod.encdec_cache_spec(cfg, batch, max_len,
                                                ENC_LEN_DECODE, dtype)
        if fam in ("dense", "vlm"):
            return {"layers": stack(self._attn_cache_spec(batch, max_len, dtype),
                                    cfg.num_layers)}
        if fam == "moe":
            nd = cfg.moe.first_dense_layers
            out = {"layers": stack(self._attn_cache_spec(batch, max_len, dtype),
                                   cfg.num_layers - nd)}
            if nd:
                out["dense_layers"] = stack(
                    self._attn_cache_spec(batch, max_len, dtype), nd)
            return out
        if fam == "ssm":
            return {"layers": stack(mamba.mamba1_cache_spec(cfg, batch, dtype),
                                    cfg.num_layers)}
        if fam == "hybrid":
            g = cfg.num_layers // cfg.attn_period
            per = cfg.attn_period - 1
            tail = cfg.num_layers - g * cfg.attn_period
            m = mamba.mamba2_cache_spec(cfg, batch, dtype)
            out = {
                "groups": stack(stack(m, per), g),
                "shared_attn": stack(attn.init_cache_spec(cfg, batch, max_len,
                                                          dtype), g),
            }
            if tail:
                out["tail"] = stack(m, tail)
            return out
        raise ValueError(fam)

    def cache_axes(self):
        cfg = self.cfg
        fam = cfg.family

        def stack(ax):
            return jax.tree_util.tree_map(lambda a: ("layer",) + a, ax,
                                          is_leaf=lambda x: isinstance(x, tuple))

        if fam == "audio":
            return encdec_mod.encdec_cache_axes(cfg)
        if fam in ("dense", "vlm"):
            return {"layers": stack(self._attn_cache_axes())}
        if fam == "moe":
            out = {"layers": stack(self._attn_cache_axes())}
            if cfg.moe.first_dense_layers:
                out["dense_layers"] = stack(self._attn_cache_axes())
            return out
        if fam == "ssm":
            return {"layers": stack(mamba.mamba1_cache_axes())}
        if fam == "hybrid":
            out = {
                "groups": stack(stack(mamba.mamba2_cache_axes())),
                "shared_attn": stack(attn.cache_logical_axes()),
            }
            g = cfg.num_layers // cfg.attn_period
            if cfg.num_layers - g * cfg.attn_period:
                out["tail"] = stack(mamba.mamba2_cache_axes())
            return out
        raise ValueError(fam)

    def init_cache(self, batch: int, max_len: int):
        spec = self.cache_spec(batch, max_len)

        def mk(path, s):
            name = str(path[-1].key) if hasattr(path[-1], "key") else ""
            if name == "slot_pos":
                return jnp.full(s.shape, -1, s.dtype)
            return jnp.zeros(s.shape, s.dtype)

        return jax.tree_util.tree_map_with_path(mk, spec)

    # ------------------------------------------------------------ inputs --
    def input_specs(self, shape: ShapeSpec) -> dict:
        """Global-shape ShapeDtypeStructs for the step function inputs."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        d = cfg.d_model
        adt = jnp.dtype(cfg.dtype)
        if shape.kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                    "pos": jax.ShapeDtypeStruct((), jnp.int32)}
        if cfg.family == "audio":
            half = S // 2
            return {"enc_embeds": jax.ShapeDtypeStruct((B, half, d), adt),
                    "dec_tokens": jax.ShapeDtypeStruct((B, half), jnp.int32)}
        if cfg.family == "vlm":
            F = cfg.frontend_tokens
            return {"embeds": jax.ShapeDtypeStruct((B, F, d), adt),
                    "tokens": jax.ShapeDtypeStruct((B, S - F), jnp.int32)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}

    def input_axes(self, shape: ShapeSpec) -> dict:
        cfg = self.cfg
        from repro.models.layers import batch_axis
        b = batch_axis(cfg)
        if shape.kind == "decode":
            return {"tokens": (b, None), "pos": ()}
        if cfg.family == "audio":
            return {"enc_embeds": (b, None, None), "dec_tokens": (b, None)}
        if cfg.family == "vlm":
            return {"embeds": (b, None, None), "tokens": (b, None)}
        return {"tokens": (b, None)}
