"""Attention: GQA/MQA, sliding-window, qk-norm, chunked (flash-style) softmax,
decode with ring-buffer KV cache, and cross-attention for encoder-decoder.

Layout conventions:
  q:      [B, S, KV, G, hd]   (G = num_heads // num_kv_heads; KV groups)
  k, v:   [B, S, KV, hd]
  cache k/v: [B, Smax, KV, hd] with slot_pos [Smax] (absolute position held by
  each slot; -1 = empty). SWA decode uses Smax == window and ring addressing,
  which bounds cache memory at long context.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import apply_rope, dense_spec, rms_norm
from repro.models.params import ParamSpec
from repro.parallel import constrain

NEG_INF = -1e30


def attn_spec(cfg, cross: bool = False):
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim()
    hax, kax = (("heads", "kv_heads") if cfg.dense_layout == "tp"
                else (None, None))        # dp: FSDP-only dense weights
    spec = {
        "wq": dense_spec((d, H, hd), ("embed", hax, None)),
        "wk": dense_spec((d, KV, hd), ("embed", kax, None)),
        "wv": dense_spec((d, KV, hd), ("embed", kax, None)),
        "wo": dense_spec((H, hd, d), (hax, None, "embed"), fan_in=H * hd),
    }
    if cfg.qk_norm and not cross:
        spec["q_norm"] = ParamSpec((hd,), (None,), init="ones")
        spec["k_norm"] = ParamSpec((hd,), (None,), init="ones")
    return spec


def _project_q(cfg, p, x):
    H, KV = cfg.num_heads, cfg.num_kv_heads
    G = H // KV
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(x.dtype))
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    B, S = x.shape[:2]
    return q.reshape(B, S, KV, G, q.shape[-1])


def _project_kv(cfg, p, x):
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"].astype(x.dtype))
    if "k_norm" in p:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return k, v


def _out_proj(cfg, p, o):
    B, S = o.shape[:2]
    o = o.reshape(B, S, cfg.num_heads, cfg.resolved_head_dim())
    return jnp.einsum("bsnh,nhd->bsd", o, p["wo"].astype(o.dtype))


def _mask(q_pos, k_pos, causal: bool, window):
    """[..., Sq, Sk] boolean keep-mask from absolute positions."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    keep = kp >= 0
    if causal:
        keep &= kp <= qp
    if window is not None:
        keep &= (qp - kp) < window
    return keep


def _sdpa(q, k, v, keep, scale):
    """q [B,Sq,KV,G,h], k/v [B,Sk,KV,h], keep [Sq,Sk] or [B,Sq,Sk]."""
    s = jnp.einsum("bqngh,bknh->bngqk", q, k).astype(jnp.float32) * scale
    if keep.ndim == 2:
        keep = keep[None, None, None]
    else:
        keep = keep[:, None, None]
    s = jnp.where(keep, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngqk,bknh->bqngh", w.astype(v.dtype), v)
    return o


def _chunked_sdpa(q, k, v, q_pos, k_pos, causal, window, scale, chunk,
                  probs_dtype=jnp.float32, remat_chunk=False,
                  seq_sharded=False):
    """Online-softmax attention, lax.scan over KV chunks. O(Sq*chunk) live.

    Positions must be contiguous aranges (q_pos/k_pos are [Sq]/[Sk] with
    q_pos[i] = q0+i): the per-chunk mask is rebuilt inside the scan body from
    the chunk INDEX so XLA cannot hoist a stacked [nc, ..., Sq, chunk] mask
    out of the loop (that hoist costs O(B*H*Sq*Sk) bytes of loop carry).

    probs_dtype=bfloat16 is the hillclimbed variant (EXPERIMENTS.md section
    Perf): scores and exp(p) tensors — the dominant HBM traffic of the train
    cells — are held in bf16; the row max/sum statistics and the output
    accumulator stay fp32, so softmax normalization keeps fp32 accuracy."""
    B, Sq, KV, G, hd = q.shape
    Sk = k.shape[1]
    q0 = q_pos[0]
    k0 = k_pos[0]
    n_chunks = -(-Sk // chunk)
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    k = k.reshape(B, n_chunks, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    v = v.reshape(B, n_chunks, chunk, KV, hd).transpose(1, 0, 2, 3, 4)

    # q stays in its compute dtype (bf16): the QK^T einsum accumulates in
    # f32 via preferred_element_type (flash-standard). Materializing an f32
    # copy of q doubled its traffic AND its all-gather under seq sharding.
    qp = q0 + jnp.arange(Sq, dtype=jnp.int32)

    def body(carry, xs):
        o, m, l = carry
        kc, vc, idx = xs
        s = jnp.einsum("bqngh,bknh->bngqk", q,
                       kc.astype(q.dtype),
                       preferred_element_type=jnp.float32) * scale
        kpc = k0 + idx * chunk + jnp.arange(chunk, dtype=jnp.int32)
        keep = jnp.broadcast_to(kpc[None, :] < (k0 + Sk), (Sq, chunk))
        if causal:
            keep &= kpc[None, :] <= qp[:, None]
        if window is not None:
            keep &= (qp[:, None] - kpc[None, :]) < window
        s = jnp.where(keep[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        # exp lands DIRECTLY in probs_dtype: with bf16 probs the f32 p tensor
        # never materializes (the first bf16 attempt kept it and only added a
        # convert — measured WORSE; see EXPERIMENTS.md Perf iteration A)
        p = jnp.exp(s - m_new[..., None]).astype(probs_dtype)
        l = l * corr + jnp.sum(p, axis=-1, dtype=jnp.float32)
        pv = jnp.einsum("bngqk,bknh->bngqh", p, vc.astype(p.dtype),
                        preferred_element_type=jnp.float32)
        o = o * corr[..., None] + pv
        return (o, m_new, l), None

    o0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    if seq_sharded:
        # the accumulators carry the q-sequence dim: without constraints the
        # replicated zeros-init makes GSPMD gather q to match (measured: 3x
        # full-seq f32 all-gathers per layer on qwen3)
        o0 = constrain(o0, ("batch", None, None, "seq_mp", None))
        m0 = constrain(m0, ("batch", None, None, "seq_mp"))
        l0 = constrain(l0, ("batch", None, None, "seq_mp"))
    body_fn = jax.checkpoint(body) if remat_chunk else body
    (o, m, l), _ = jax.lax.scan(
        body_fn, (o0, m0, l0), (k, v, jnp.arange(n_chunks, dtype=jnp.int32)))
    o = o / jnp.maximum(l[..., None], 1e-30)
    return o.transpose(0, 3, 1, 2, 4).astype(q.dtype)      # [B,Sq,KV,G,hd]


def self_attention(cfg, p, x, positions, *, causal=True, window=None,
                   rope=None):
    """Training/prefill self-attention over the full sequence. `rope` is the
    hoisted (cos, sin) table pair computed once per forward."""
    hd = cfg.resolved_head_dim()
    scale = 1.0 / np.sqrt(hd)
    q = _project_q(cfg, p, x)
    k, v = _project_kv(cfg, p, x)
    q = apply_rope(q, positions[:, :, None], cfg.rope_theta, tables=rope)
    k = apply_rope(k, positions[:, :, None], cfg.rope_theta, tables=rope)
    q = constrain(q, ("batch", None, "kv_heads", None, None))
    k = constrain(k, ("batch", None, "kv_heads", None))
    S = x.shape[1]
    impl = cfg.attention_impl
    if impl == "auto":
        impl = "chunked" if S > 2048 else "naive"
    if impl == "naive":
        # positions are the same across batch here (0..S)
        keep = _mask(positions[0], positions[0], causal, window)
        o = _sdpa(q, k, v, keep, scale)
    else:
        o = _chunked_sdpa(q, k, v, positions[0], positions[0], causal, window,
                          scale, cfg.attention_chunk,
                          probs_dtype=cfg.attention_probs_dtype,
                          remat_chunk=cfg.attention_remat_chunk,
                          seq_sharded=cfg.seq_shard)
    return _out_proj(cfg, p, o)


def cross_attention(cfg, p, x, enc_out):
    """Decoder->encoder attention (no mask, no rope)."""
    hd = cfg.resolved_head_dim()
    scale = 1.0 / np.sqrt(hd)
    q = _project_q(cfg, p, x)
    k, v = _project_kv(cfg, p, enc_out)
    Sk = enc_out.shape[1]
    keep = jnp.ones((x.shape[1], Sk), bool)
    o = _sdpa(q, k, v, keep, scale)
    return _out_proj(cfg, p, o)


# ------------------------------------------------------------- decode -----

def init_cache_spec(cfg, batch: int, max_len: int, dtype):
    """ShapeDtypeStructs for one layer's KV cache (window-bounded if SWA)."""
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim()
    smax = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    return {
        "k": jax.ShapeDtypeStruct((batch, smax, KV, hd), dtype),
        "v": jax.ShapeDtypeStruct((batch, smax, KV, hd), dtype),
        "slot_pos": jax.ShapeDtypeStruct((smax,), jnp.int32),
    }


def cache_logical_axes():
    return {
        "k": ("batch", "cache_seq", "kv_heads", None),
        "v": ("batch", "cache_seq", "kv_heads", None),
        "slot_pos": (None,),
    }


def init_cache(cfg, batch: int, max_len: int, dtype):
    spec = init_cache_spec(cfg, batch, max_len, dtype)
    c = {k: jnp.zeros(v.shape, v.dtype) for k, v in spec.items()}
    c["slot_pos"] = jnp.full(spec["slot_pos"].shape, -1, jnp.int32)
    return c


def decode_attention(cfg, p, x, cache, pos):
    """One-token decode. x [B,1,d]; pos scalar int32 (same across batch).
    Returns (out [B,1,d], new_cache)."""
    hd = cfg.resolved_head_dim()
    scale = 1.0 / np.sqrt(hd)
    B = x.shape[0]
    q = _project_q(cfg, p, x)                                  # [B,1,KV,G,hd]
    k, v = _project_kv(cfg, p, x)                              # [B,1,KV,hd]
    posv = jnp.full((B, 1), pos, jnp.int32)
    q = apply_rope(q, posv[:, :, None], cfg.rope_theta)
    k = apply_rope(k, posv[:, :, None], cfg.rope_theta)

    smax = cache["k"].shape[1]
    slot = (pos % smax).astype(jnp.int32)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    slot_pos = jax.lax.dynamic_update_slice(
        cache["slot_pos"], jnp.full((1,), pos, jnp.int32), (slot,))
    ck = constrain(ck, ("batch", "cache_seq", "kv_heads", None))
    cv = constrain(cv, ("batch", "cache_seq", "kv_heads", None))

    keep = _mask(jnp.full((1,), pos, jnp.int32), slot_pos, True,
                 cfg.sliding_window)                           # [1, smax]
    s = jnp.einsum("bqngh,bknh->bngqk", q.astype(jnp.float32),
                   ck.astype(jnp.float32)) * scale
    s = jnp.where(keep[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngqk,bknh->bqngh", w, cv.astype(jnp.float32)).astype(x.dtype)
    out = _out_proj(cfg, p, o)
    return out, {"k": ck, "v": cv, "slot_pos": slot_pos}


def prefill_cache(cfg, p, x, positions, max_len, dtype, rope=None):
    """Compute K/V for a full prompt and lay it into a fresh cache.
    Returns cache primed so decode can continue at pos = S."""
    k, v = _project_kv(cfg, p, x)
    k = apply_rope(k, positions[:, :, None], cfg.rope_theta, tables=rope)
    B, S = x.shape[:2]
    smax = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    if S >= smax:
        # keep the most recent smax positions, ring-addressed
        ktail = k[:, S - smax:]
        vtail = v[:, S - smax:]
        tail_pos = jnp.arange(S - smax, S)
        slots = tail_pos % smax
        order = jnp.argsort(slots)
        ck = ktail[:, order].astype(dtype)
        cv = vtail[:, order].astype(dtype)
        slot_pos = tail_pos[order]
    else:
        pad = smax - S
        ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(dtype)
        cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(dtype)
        slot_pos = jnp.concatenate([jnp.arange(S), jnp.full((pad,), -1, jnp.int32)])
    return {"k": ck, "v": cv, "slot_pos": slot_pos.astype(jnp.int32)}
