"""Mamba blocks: v1 (selective scan, Falcon-Mamba) and v2 (SSD, Zamba2).

Both use a CHUNKED scan: jax.lax.scan over sequence chunks carrying the SSM
state, with an associative scan (v1) or the SSD matmul form (v2) inside each
chunk. Live memory is O(B * chunk * d_inner * N) instead of O(B * S * ...),
which is what makes train_4k and long-context cells fit. Decode is a single
O(1)-state update (the reason these archs run the long_500k cell).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_spec, norm_spec, rms_norm
from repro.models.params import ParamSpec
from repro.parallel import constrain


# ------------------------------------------------------------ helpers -----

def _causal_conv(x, w, b):
    """Depthwise causal conv along axis 1. x [B,S,C], w [K,C], b [C]."""
    K = w.shape[0]
    out = jnp.zeros_like(x)
    for k in range(K):
        shift = K - 1 - k
        if shift == 0:
            xs = x
        else:
            xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xs * w[k].astype(x.dtype)
    return out + b.astype(x.dtype)


def _conv_step(x_t, conv_state, w, b):
    """Single-token conv. x_t [B,C]; conv_state [B,K-1,C] (oldest first)."""
    win = jnp.concatenate([conv_state, x_t[:, None]], axis=1)     # [B,K,C]
    out = jnp.einsum("bkc,kc->bc", win, w.astype(x_t.dtype)) + b.astype(x_t.dtype)
    return out, win[:, 1:]


def _pad_chunks(x, q, axis=1):
    s = x.shape[axis]
    pad = (-s) % q
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = jnp.pad(x, widths)
    return x, s


# ------------------------------------------------------------ Mamba 1 -----

def mamba1_spec(cfg):
    d, s = cfg.d_model, cfg.ssm
    din = s.expand * d
    dtr = s.dt_rank or -(-d // 16)
    return {
        "norm": norm_spec(d),
        "in_proj": dense_spec((d, 2 * din), ("embed", "dinner")),
        "conv_w": ParamSpec((s.conv_dim, din), (None, "dinner"), init="normal",
                            scale=1.0 / np.sqrt(s.conv_dim)),
        "conv_b": ParamSpec((din,), ("dinner",), init="zeros"),
        "x_proj": dense_spec((din, dtr + 2 * s.state_dim), ("dinner", None)),
        "dt_proj": dense_spec((dtr, din), (None, "dinner"), fan_in=dtr),
        "dt_bias": ParamSpec((din,), ("dinner",), init="const", scale=-4.0),
        "A_log": ParamSpec((din, s.state_dim), ("dinner", None), init="const",
                           scale=0.5),
        "D": ParamSpec((din,), ("dinner",), init="ones"),
        "out_proj": dense_spec((din, d), ("dinner", "embed"), fan_in=din),
    }


def _mamba1_inner(cfg, p, x1, z, return_state=False):
    """Chunked selective scan. x1, z: [B,S,din] (x1 already conv+silu)."""
    s = cfg.ssm
    B, S, din = x1.shape
    N = s.state_dim
    dtr = s.dt_rank or -(-cfg.d_model // 16)

    dbc = jnp.einsum("bsc,cr->bsr", x1, p["x_proj"].astype(x1.dtype))
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rc->bsc", dbc[..., :dtr], p["dt_proj"].astype(x1.dtype))
        .astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))    # [B,S,din]
    Bc = dbc[..., dtr:dtr + N].astype(jnp.float32)                  # [B,S,N]
    Cc = dbc[..., dtr + N:].astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                    # [din,N]

    Q = s.chunk
    x32, _ = _pad_chunks(x1.astype(jnp.float32), Q)
    dt, _ = _pad_chunks(dt, Q)
    Bc, _ = _pad_chunks(Bc, Q)
    Cc, _ = _pad_chunks(Cc, Q)
    # dt=0 on padded steps => identity state update (a=1, bx=0), so the final
    # carried state is exact for prefill
    valid = (jnp.arange(x32.shape[1]) < S).astype(jnp.float32)
    dt = dt * valid[None, :, None]
    nc = x32.shape[1] // Q

    def chunk(h, xs):
        xq, dtq, bq, cq = xs                     # [B,Q,din], [B,Q,din], [B,Q,N]x2
        dA = dtq[..., None] * A                  # [B,Q,din,N]  (log-decay, <=0)
        a = jnp.exp(dA)
        bx = (dtq * xq)[..., None] * bq[:, :, None, :]
        def comb(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br
        a_cum, b_scan = jax.lax.associative_scan(comb, (a, bx), axis=1)
        h_t = b_scan + a_cum * h[:, None]        # [B,Q,din,N]
        y = jnp.einsum("bqcn,bqn->bqc", h_t, cq)
        return h_t[:, -1], y

    xs = tuple(t.reshape(B, nc, Q, *t.shape[2:]).swapaxes(0, 1)
               for t in (x32, dt, Bc, Cc))
    h0 = jnp.zeros((B, din, N), jnp.float32)
    h_fin, ys = jax.lax.scan(chunk, h0, xs)
    y = ys.swapaxes(0, 1).reshape(B, nc * Q, din)[:, :S]
    y = y + x1.astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    if return_state:
        return y.astype(x1.dtype), h_fin
    return y.astype(x1.dtype)


def mamba1_forward(cfg, p, x, return_cache=False):
    """Full-sequence Mamba1 block (post in_proj->conv->scan->out_proj)."""
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    xz = jnp.einsum("bsd,dc->bsc", h, p["in_proj"].astype(x.dtype))
    din = xz.shape[-1] // 2
    x1, z = xz[..., :din], xz[..., din:]
    x1 = constrain(x1, ("batch", None, "act_mlp"))
    pre_conv = x1
    x1 = jax.nn.silu(_causal_conv(x1, p["conv_w"], p["conv_b"]))
    if return_cache:
        y, hst = _mamba1_inner(cfg, p, x1, z, return_state=True)
        out = jnp.einsum("bsc,cd->bsd", y, p["out_proj"].astype(x.dtype))
        return out, {"conv": _conv_tail(pre_conv, cfg.ssm.conv_dim), "ssm": hst}
    y = _mamba1_inner(cfg, p, x1, z)
    return jnp.einsum("bsc,cd->bsd", y, p["out_proj"].astype(x.dtype))


def _conv_tail(pre_conv, K):
    """Last K-1 pre-conv inputs (left-padded when S < K-1)."""
    B, S, C = pre_conv.shape
    if S >= K - 1:
        return pre_conv[:, S - (K - 1):]
    return jnp.pad(pre_conv, ((0, 0), (K - 1 - S, 0), (0, 0)))


def mamba1_cache_spec(cfg, batch, dtype):
    s = cfg.ssm
    din = s.expand * cfg.d_model
    return {
        "conv": jax.ShapeDtypeStruct((batch, s.conv_dim - 1, din), dtype),
        "ssm": jax.ShapeDtypeStruct((batch, din, s.state_dim), jnp.float32),
    }


def mamba1_cache_axes():
    return {"conv": ("batch", None, "dinner"), "ssm": ("batch", "dinner", None)}


def mamba1_decode(cfg, p, x, cache):
    """x [B,1,d] -> (out [B,1,d], new cache). O(1) state update."""
    s = cfg.ssm
    N = s.state_dim
    dtr = s.dt_rank or -(-cfg.d_model // 16)
    h = rms_norm(x, p["norm"], cfg.norm_eps)[:, 0]                 # [B,d]
    xz = jnp.einsum("bd,dc->bc", h, p["in_proj"].astype(x.dtype))
    din = xz.shape[-1] // 2
    x1, z = xz[..., :din], xz[..., din:]
    x1, conv_state = _conv_step(x1, cache["conv"].astype(x1.dtype),
                                p["conv_w"], p["conv_b"])
    x1 = jax.nn.silu(x1)
    dbc = jnp.einsum("bc,cr->br", x1, p["x_proj"].astype(x1.dtype))
    dt = jax.nn.softplus(
        jnp.einsum("br,rc->bc", dbc[..., :dtr], p["dt_proj"].astype(x1.dtype))
        .astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))   # [B,din]
    Bc = dbc[..., dtr:dtr + N].astype(jnp.float32)
    Cc = dbc[..., dtr + N:].astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    hst = cache["ssm"]
    hst = jnp.exp(dt[..., None] * A) * hst \
        + (dt * x1.astype(jnp.float32))[..., None] * Bc[:, None, :]
    y = jnp.einsum("bcn,bn->bc", hst, Cc) + x1.astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bc,cd->bd", y.astype(x.dtype), p["out_proj"].astype(x.dtype))
    return out[:, None], {"conv": conv_state.astype(cache["conv"].dtype), "ssm": hst}


# ------------------------------------------------------------ Mamba 2 -----

def mamba2_spec(cfg):
    d, s = cfg.d_model, cfg.ssm
    din = s.expand * d
    nh = din // s.head_dim
    N = s.state_dim
    return {
        "norm": norm_spec(d),
        "in_proj": dense_spec((d, 2 * din + 2 * N + nh), ("embed", "dinner")),
        "conv_w": ParamSpec((s.conv_dim, din + 2 * N), (None, "dinner"),
                            init="normal", scale=1.0 / np.sqrt(s.conv_dim)),
        "conv_b": ParamSpec((din + 2 * N,), ("dinner",), init="zeros"),
        "A_log": ParamSpec((nh,), (None,), init="const", scale=0.5),
        "D": ParamSpec((nh,), (None,), init="ones"),
        "dt_bias": ParamSpec((nh,), (None,), init="const", scale=-4.0),
        "gate_norm": ParamSpec((din,), ("dinner",), init="ones"),
        "out_proj": dense_spec((din, d), ("dinner", "embed"), fan_in=din),
    }


def _mamba2_split(cfg, zxbcdt):
    s = cfg.ssm
    din = s.expand * cfg.d_model
    N = s.state_dim
    nh = din // s.head_dim
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din:din + din + 2 * N]
    dt = zxbcdt[..., din + din + 2 * N:]
    assert dt.shape[-1] == nh
    return z, xbc, dt


def _ssd_chunk(cfg, xh, bq, cq, dtq, A, h_prev):
    """One SSD chunk. xh [B,Q,nh,p]; bq,cq [B,Q,N]; dtq [B,Q,nh]; A [nh];
    h_prev [B,nh,p,N]. Returns (y [B,Q,nh,p], h_next)."""
    dA = dtq * A                                   # [B,Q,nh] log-decay
    cA = jnp.cumsum(dA, axis=1)                    # inclusive cumsum
    # intra-chunk: W[t,s] = C_t.B_s * exp(cA_t - cA_s) * dt_s   (t >= s)
    scores = jnp.einsum("bqn,bsn->bqs", cq, bq)    # [B,Q,Q]
    ldiff = cA[:, :, None, :] - cA[:, None, :, :]  # [B,Q,Q,nh] t,s
    Q = dA.shape[1]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri[None, :, :, None], jnp.exp(ldiff), 0.0)
    W = scores[..., None] * L * dtq[:, None, :, :]            # [B,Q(t),Q(s),nh]
    y_intra = jnp.einsum("btsh,bshp->bthp", W, xh)
    # inter-chunk: contribution of the incoming state
    y_inter = jnp.einsum("bqn,bhpn->bqhp", cq, h_prev) * jnp.exp(cA)[..., None]
    # state update: decay-to-chunk-end factor exp(cA[-1] - cA_s)
    decay_end = jnp.exp(cA[:, -1:, :] - cA)                    # [B,Q,nh]
    h_next = jnp.exp(cA[:, -1])[:, :, None, None] * h_prev + \
        jnp.einsum("bsn,bshp,bsh->bhpn", bq, xh, dtq * decay_end)
    return y_intra + y_inter, h_next


def _mamba2_inner(cfg, p, xbc, z, dt_raw, return_state=False):
    s = cfg.ssm
    din = s.expand * cfg.d_model
    N = s.state_dim
    nh = din // s.head_dim
    hp = s.head_dim
    B, S, _ = xbc.shape

    x = xbc[..., :din]
    Bc = xbc[..., din:din + N].astype(jnp.float32)
    Cc = xbc[..., din + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # [B,S,nh]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # [nh]

    Q = s.chunk
    xh, _ = _pad_chunks(x.astype(jnp.float32).reshape(B, S, nh, hp), Q)
    Bc, _ = _pad_chunks(Bc, Q)
    Cc, _ = _pad_chunks(Cc, Q)
    dt, _ = _pad_chunks(dt, Q)
    # dt=0 on padded steps => exp(0)=1 decay, zero input: exact final state
    valid = (jnp.arange(xh.shape[1]) < S).astype(jnp.float32)
    dt = dt * valid[None, :, None]
    nc = xh.shape[1] // Q

    def chunk(h, xs):
        xq, bq, cq, dtq = xs
        y, h2 = _ssd_chunk(cfg, xq, bq, cq, dtq, A, h)
        return h2, y

    xs = tuple(t.reshape(B, nc, Q, *t.shape[2:]).swapaxes(0, 1)
               for t in (xh, Bc, Cc, dt))
    h0 = jnp.zeros((B, nh, hp, N), jnp.float32)
    h_fin, ys = jax.lax.scan(chunk, h0, xs)
    y = ys.swapaxes(0, 1).reshape(B, nc * Q, nh, hp)[:, :S]
    y = y + xh.reshape(B, nc * Q, nh, hp)[:, :S] * p["D"].astype(jnp.float32)[:, None]
    y = y.reshape(B, S, din)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y, p["gate_norm"], cfg.norm_eps, dtype=jnp.float32)
    if return_state:
        return y, h_fin
    return y


def mamba2_forward(cfg, p, x, return_cache=False):
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,dc->bsc", h, p["in_proj"].astype(x.dtype))
    z, xbc, dt = _mamba2_split(cfg, zxbcdt)
    xbc = constrain(xbc, ("batch", None, "act_mlp"))
    pre_conv = xbc
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    if return_cache:
        y, hst = _mamba2_inner(cfg, p, xbc, z, dt, return_state=True)
        out = jnp.einsum("bsc,cd->bsd", y.astype(x.dtype),
                         p["out_proj"].astype(x.dtype))
        return out, {"conv": _conv_tail(pre_conv, cfg.ssm.conv_dim), "ssm": hst}
    y = _mamba2_inner(cfg, p, xbc, z, dt)
    return jnp.einsum("bsc,cd->bsd", y.astype(x.dtype), p["out_proj"].astype(x.dtype))


def mamba2_cache_spec(cfg, batch, dtype):
    s = cfg.ssm
    din = s.expand * cfg.d_model
    nh = din // s.head_dim
    return {
        "conv": jax.ShapeDtypeStruct((batch, s.conv_dim - 1, din + 2 * s.state_dim), dtype),
        "ssm": jax.ShapeDtypeStruct((batch, nh, s.head_dim, s.state_dim), jnp.float32),
    }


def mamba2_cache_axes():
    return {"conv": ("batch", None, "dinner"),
            "ssm": ("batch", "act_heads", None, None)}


def mamba2_decode(cfg, p, x, cache):
    s = cfg.ssm
    din = s.expand * cfg.d_model
    N = s.state_dim
    nh = din // s.head_dim
    hp = s.head_dim
    h = rms_norm(x, p["norm"], cfg.norm_eps)[:, 0]
    zxbcdt = jnp.einsum("bd,dc->bc", h, p["in_proj"].astype(x.dtype))
    z, xbc, dt_raw = _mamba2_split(cfg, zxbcdt[:, None])
    z, xbc, dt_raw = z[:, 0], xbc[:, 0], dt_raw[:, 0]
    xbc, conv_state = _conv_step(xbc, cache["conv"].astype(xbc.dtype),
                                 p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(xbc)
    x1 = xbc[..., :din].astype(jnp.float32).reshape(-1, nh, hp)
    Bc = xbc[..., din:din + N].astype(jnp.float32)
    Cc = xbc[..., din + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    hst = cache["ssm"]
    decay = jnp.exp(dt * A)                                     # [B,nh]
    hst = decay[:, :, None, None] * hst + \
        jnp.einsum("bn,bhp,bh->bhpn", Bc, x1, dt)
    y = jnp.einsum("bhpn,bn->bhp", hst, Cc) + x1 * p["D"].astype(jnp.float32)[:, None]
    y = y.reshape(-1, din) * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y, p["gate_norm"], cfg.norm_eps, dtype=jnp.float32)
    out = jnp.einsum("bc,cd->bd", y.astype(x.dtype), p["out_proj"].astype(x.dtype))
    return out[:, None], {"conv": conv_state.astype(cache["conv"].dtype), "ssm": hst}
