"""train_step builder: value_and_grad + clip + AdamW, mesh-aware.

Under GSPMD, data-parallel gradient reduction is implicit: the loss is a
global-batch mean, so XLA emits the reduce-scatter/all-reduce pattern dictated
by the param shardings (ZeRO-3 over 'pod'+'data', TP over 'model').
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import build_model
from repro.train.optimizer import adamw, clip_by_global_norm
from repro.train.schedule import warmup_cosine
from repro.train.state import TrainState


def build_loss_fn(cfg):
    model = build_model(cfg)

    def loss_fn(params, batch):
        return model.loss(params, batch)

    return loss_fn


def build_train_step(cfg, *, peak_lr=3e-4, warmup=100, total_steps=10000,
                     grad_clip=1.0, weight_decay=0.1):
    """Returns (init_state_fn(key) -> TrainState, train_step(state, batch) ->
    (state, metrics)). Both are pure and jit-able."""
    model = build_model(cfg)
    sched = warmup_cosine(peak_lr, warmup, total_steps)
    opt_init, opt_update = adamw(sched, weight_decay=weight_decay,
                                 moment_dtype=cfg.moment_dtype)

    def init_state(key) -> TrainState:
        params = model.init(key)
        opt = opt_init(params)
        rng = jax.random.key_data(jax.random.fold_in(key, 1))
        return TrainState(params=params, mu=opt.mu, nu=opt.nu,
                          step=jnp.zeros((), jnp.int32), rng=rng)

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(state.params, batch)
        if grad_clip:
            grads, gnorm = clip_by_global_norm(grads, grad_clip)
        else:
            from repro.train.optimizer import global_norm
            gnorm = global_norm(grads)
        from repro.train.optimizer import AdamWState
        new_params, opt = opt_update(grads, AdamWState(state.mu, state.nu),
                                     state.params, state.step)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = sched(state.step)
        new_state = TrainState(params=new_params, mu=opt.mu, nu=opt.nu,
                               step=state.step + 1, rng=state.rng)
        return new_state, metrics

    return init_state, train_step


def state_shapes(cfg, **kw):
    """ShapeDtypeStructs of the TrainState without allocating (dry-run)."""
    init_state, _ = build_train_step(cfg, **kw)
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(init_state, key)
