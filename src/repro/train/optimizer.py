"""AdamW on pytrees, built from scratch (no optax in this environment).

Moments are stored in ``moment_dtype`` (fp32 default; bf16 for the 671B
config where fp32 moments would not fit HBM) but all arithmetic is fp32.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: object
    nu: object


def _tmap(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def adamw(schedule: Callable, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          moment_dtype="float32"):
    mdt = jnp.dtype(moment_dtype)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, mdt)
        return AdamWState(mu=_tmap(zeros, params), nu=_tmap(zeros, params))

    def update(grads, state: AdamWState, params, step):
        """Returns (new_params, new_state). step is the 0-based int32 step."""
        t = jnp.asarray(step, jnp.float32) + 1.0
        lr = schedule(step)
        c1 = 1.0 - jnp.power(b1, t)
        c2 = 1.0 - jnp.power(b2, t)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            mhat = m32 / c1
            vhat = v32 / c2
            step_ = mhat / (jnp.sqrt(vhat) + eps)
            p32 = p.astype(jnp.float32)
            if weight_decay and p.ndim >= 2:   # decay matrices only
                step_ = step_ + weight_decay * p32
            return ((p32 - lr * step_).astype(p.dtype),
                    m32.astype(mdt), v32.astype(mdt))

        out = _tmap(upd, grads, state.mu, state.nu, params)
        new_params = _tmap(lambda _, o: o[0], grads, out)
        new_mu = _tmap(lambda _, o: o[1], grads, out)
        new_nu = _tmap(lambda _, o: o[2], grads, out)
        return new_params, AdamWState(mu=new_mu, nu=new_nu)

    return init, update


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return _tmap(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                 grads), gn
