from repro.train.state import TrainState  # noqa: F401
from repro.train.step import build_train_step, build_loss_fn  # noqa: F401
from repro.train.optimizer import adamw  # noqa: F401
from repro.train.schedule import warmup_cosine  # noqa: F401
