"""TrainState: the *explicit changeset* of one training iteration.

In JAX the side-effects of an epoch are exactly the outputs of the pure
train_step — this pytree. Flor's functional-tier lean checkpointing
checkpoints precisely this object (DESIGN.md section 2).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class TrainState(NamedTuple):
    params: Any
    mu: Any
    nu: Any
    step: jnp.ndarray          # int32 scalar
    rng: jnp.ndarray           # PRNG key (uint32[2] raw form)

    @classmethod
    def create(cls, params, opt_state, rng, step=0):
        return cls(params=params, mu=opt_state.mu, nu=opt_state.nu,
                   step=jnp.asarray(step, jnp.int32),
                   rng=jax.random.key_data(rng) if hasattr(rng, "dtype") and
                   jnp.issubdtype(rng.dtype, jax.dtypes.prng_key) else rng)
