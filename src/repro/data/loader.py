"""Double-buffered host prefetch around the synthetic source.

The producer thread builds batch t+1 while the device runs step t, so input
generation never sits on the critical path (this matters for Flor's record
overhead measurements: the vanilla baseline and the Flor run share the same
input pipeline cost).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional


class PrefetchLoader:
    def __init__(self, make_batch: Callable[[int], dict], start_step: int,
                 num_steps: int, depth: int = 2):
        self._make = make_batch
        self._range = range(start_step, start_step + num_steps)
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._err: Optional[BaseException] = None
        self._t = threading.Thread(target=self._produce, daemon=True)
        self._t.start()

    def _produce(self):
        try:
            for s in self._range:
                self._q.put((s, self._make(s)))
        except BaseException as e:              # surfaced on next __next__
            self._err = e
        finally:
            self._q.put(None)

    def __iter__(self) -> Iterator:
        while True:
            item = self._q.get()
            if item is None:
                if self._err:
                    raise self._err
                return
            yield item
