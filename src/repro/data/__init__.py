from repro.data.synthetic import synthetic_batch, batch_for_step  # noqa: F401
from repro.data.loader import PrefetchLoader  # noqa: F401
