"""Deterministic synthetic data: a pure function of (step, seed).

This determinism is a correctness substrate for Flor: logical redo of any
epoch reproduces the exact same batches, so record and replay consume
bit-identical inputs without storing any data (the paper's assumption that
model-training inputs are replayable, made structural).

Tokens come from a splitmix64-style counter hash — stateless, seekable,
cheap. Text tokens follow a skewed (Zipf-ish) distribution so losses move.
"""
from __future__ import annotations

import numpy as np


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


def _counters(step: int, seed: int, n: int, salt: int) -> np.ndarray:
    base = (np.uint64(seed) << np.uint64(32)) ^ np.uint64(step) \
        ^ (np.uint64(salt) << np.uint64(48))
    return _splitmix64(base + np.arange(n, dtype=np.uint64))


def _tokens(step, seed, shape, vocab, salt=0):
    r = _counters(step, seed, int(np.prod(shape)), salt)
    u = (r >> np.uint64(11)).astype(np.float64) / float(1 << 53)
    # Zipf-ish skew so the model has structure to learn
    toks = np.floor(vocab * np.power(u, 3.0)).astype(np.int64)
    return np.clip(toks, 0, vocab - 1).astype(np.int32).reshape(shape)


def _embeds(step, seed, shape, salt=1):
    r = _counters(step, seed, int(np.prod(shape)), salt)
    u = (r >> np.uint64(11)).astype(np.float64) / float(1 << 53)
    return ((u - 0.5) * 2.0).astype(np.float32).reshape(shape)


def synthetic_batch(cfg, batch: int, seq: int, step: int, seed: int = 0) -> dict:
    """Batch matching Model.input_specs for a train shape."""
    if cfg.family == "audio":
        half = seq // 2
        return {
            "enc_embeds": _embeds(step, seed, (batch, half, cfg.d_model)),
            "dec_tokens": _tokens(step, seed, (batch, half), cfg.vocab_size),
        }
    if cfg.family == "vlm":
        F = cfg.frontend_tokens
        return {
            "embeds": _embeds(step, seed, (batch, F, cfg.d_model)),
            "tokens": _tokens(step, seed, (batch, seq - F), cfg.vocab_size),
        }
    return {"tokens": _tokens(step, seed, (batch, seq), cfg.vocab_size)}


def batch_for_step(cfg, shape, step: int, seed: int = 0) -> dict:
    return synthetic_batch(cfg, shape.global_batch, shape.seq_len, step, seed)
