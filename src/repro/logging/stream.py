"""FingerprintLog: the per-run metric/probe log, off the step path.

The paper's task (i) — "efficient background logging in Python" — landed
everywhere in this repro EXCEPT the log itself: checkpoints materialize in
the background, but every ``flor.log`` used to serialize and write JSONL
synchronously on the training thread. This module is the fix:

* **record (async, the default)** — ``log()`` assigns a seq number and
  enqueues ``(epoch, seq, key, captured value)`` onto a bounded
  :class:`~repro.checkpoint.async_writer.AsyncStage`; the stage thread does
  the device->host copy, JSON serialization, large-value spill, and the
  crash-safe segment write (``repro.logging.segment``). JAX arrays are
  captured as device REFERENCES (immutable, so deferral is free — the step
  path never blocks on ``.item()``/``device_get``); host numpy arrays are
  snapshotted with a memcpy (they are mutable); plain Python values are
  lowered with :func:`~repro.logging.jsonable.jsonable` inline (cheap, and
  it freezes mutable lists/dicts at log time, keeping async output
  bit-identical to sync).
* **record (sync, ``async_log=False``)** — the legacy path: serialize and
  write a line-buffered flat JSONL file on the calling thread. Same
  serializer, same rows; only WHERE the work runs differs.
* **replay** — each attempt rotates its per-pid stream (``fresh=True``);
  both modes apply.

Large values: a logged array whose host size exceeds ``spill_bytes`` is
stored to the run's checkpoint store under ``logref__<stream>__<seq>`` and
the log row carries ``{"ref": key, dtype, shape, nbytes}`` instead of a
megabyte JSON literal. The ref key is derived from (stream, seq), so sync
and async spills are identical.

Overhead accounting: every serialize+spill+write batch reports its wall
time and byte count to ``on_overhead`` — FlorContext points this at
``AdaptiveController.observe_logging``, so observed logging cost draws down
the same epsilon budget that gates checkpoint materialization.
"""
from __future__ import annotations

import copy
import json
import os
import time
from typing import Callable, Optional

import numpy as np

from repro.checkpoint.async_writer import AsyncStage
from repro.logging.jsonable import json_default, jsonable
from repro.logging.segment import (DEFAULT_ROLL_BYTES, SegmentSink,
                                   migrate_flat_to_segments, needs_migration,
                                   read_stream, remove_stream, tail_seq)

DEFAULT_QUEUE_DEPTH = 1024
DEFAULT_SPILL_BYTES = 1 << 20          # 1 MiB of host bytes


class FingerprintLog:
    """Append-only metric log; record/replay logs are diffed by the deferred
    correctness check (paper section 5.2.2).

    ``fresh=True`` truncates (each replay ATTEMPT rotates its stream —
    stale lines from a previous attempt with the same pid would corrupt the
    deferred diff); ``fresh=False`` appends and continues ``seq`` from the
    existing tail (bounded-tail recovery, not a full re-parse), so a
    resumed record run never emits duplicate seqs.

    ``async_log=True`` moves serialization and I/O onto a background stage
    and switches the on-disk layout to crash-safe segments; the row
    contract of :meth:`read` is identical either way. A stream that is
    ALREADY segmented stays segmented even when reopened with
    ``async_log=False`` (the layout is a property of the run dir, not of
    the process that happens to reopen it)."""

    def __init__(self, path: str, fresh: bool = False, *,
                 async_log: bool = False,
                 queue_depth: int = DEFAULT_QUEUE_DEPTH,
                 spill_bytes: Optional[int] = DEFAULT_SPILL_BYTES,
                 store=None, stream: Optional[str] = None,
                 on_overhead: Optional[Callable] = None,
                 on_seal: Optional[Callable] = None,
                 roll_bytes: int = DEFAULT_ROLL_BYTES):
        self.path = path
        self.stream = stream or \
            os.path.splitext(os.path.basename(path))[0]
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        if fresh:
            remove_stream(path)
        segmented = async_log or os.path.isdir(path) \
            or (not fresh and os.path.isfile(path + ".migrate"))
        if segmented and needs_migration(path):
            # resume of a sync-era run dir with async on: adopt the flat
            # file as segment 0 so one reader pass sees the whole stream
            # (also completes a migration a crash interrupted) — BEFORE
            # tail_seq, which must see the adopted rows
            migrate_flat_to_segments(path)
        self._seq = 0 if fresh else tail_seq(path)
        self._spill = int(spill_bytes) if spill_bytes else 0
        self._store = store
        self._on_overhead = on_overhead
        self.stats = {"rows": 0, "bytes": 0, "overhead_s": 0.0,
                      "spilled": 0}
        self._f = None
        self._sink = None
        if segmented:
            # on_seal is the query index's incremental-maintenance hook
            # (repro.querydb): it fires on the sealing thread — the
            # background stage on roll, the closing thread on close — so
            # index upkeep rides the same off-step-path budget as the
            # serialize+write work itself
            self._sink = SegmentSink(path, roll_bytes=roll_bytes,
                                     on_seal=on_seal)
        else:
            self._f = open(path, "w" if fresh else "a", buffering=1)
        self._stage = AsyncStage(self._emit, max_queue=queue_depth) \
            if async_log else None

    # ------------------------------------------------------------- write --
    def log(self, epoch, key: str, value):
        """Record one (epoch, key, value) row. Async mode: O(1) capture +
        enqueue on the calling thread (blocking only when the bounded queue
        is full — backpressure, the same contract as checkpoint submits);
        sync mode: serialize + write here and now."""
        epoch = int(epoch) if epoch is not None else None
        seq = self._seq
        self._seq += 1
        if self._stage is not None:
            self._stage.put((epoch, seq, key, _capture(value, key)))
            return
        t0 = time.perf_counter()
        line, nbytes = self._serialize(epoch, seq, key, value)
        self._f.write(line) if self._f is not None \
            else self._sink.append(line, seq)
        self._account(time.perf_counter() - t0, nbytes)

    def _emit(self, item):
        """Background stage: device->host + serialize + spill + segment
        write for one enqueued row."""
        epoch, seq, key, value = item
        t0 = time.perf_counter()
        line, nbytes = self._serialize(epoch, seq, key, value)
        self._sink.append(line, seq)
        self._account(time.perf_counter() - t0, nbytes)

    def _serialize(self, epoch, seq, key, value) -> tuple[str, int]:
        if isinstance(value, np.ndarray) or hasattr(value, "dtype"):
            host = np.asarray(value)       # device_get for jax, free for np
            if self._spill and self._store is not None \
                    and host.ndim and int(host.nbytes) > self._spill:
                value = self._spill_value(host, seq)
            else:
                value = jsonable(host, key)
        else:
            value = jsonable(value, key)   # idempotent for captured values
        rec = {"epoch": epoch, "seq": seq, "key": key, "value": value}
        # default= lowers non-JSON leaves nested INSIDE containers (dict of
        # arrays, ...) instead of raising — on the background stage a dumps
        # TypeError would otherwise surface as a deferred crash at close()
        line = json.dumps(rec, default=json_default(key)) + "\n"
        return line, len(line.encode("utf-8"))

    def _spill_value(self, host: np.ndarray, seq: int) -> dict:
        """Store an oversized array as checkpoint-store chunks and log a
        pointer row instead. The key is a pure function of (stream, seq),
        so sync and async modes produce the same ref. The row also carries
        a content DIGEST: record and replay spill under different stream
        names, and the deferred check compares spill rows by digest — same
        bytes pass, divergent bytes are an anomaly — rather than by the
        pointer."""
        import hashlib
        ref = f"logref__{self.stream}__{seq:08d}"
        self._store.put_tree(ref, {"v": host})
        self.stats["spilled"] += 1
        return {"ref": ref, "dtype": str(host.dtype),
                "shape": list(host.shape), "nbytes": int(host.nbytes),
                "digest": hashlib.blake2b(
                    np.ascontiguousarray(host).tobytes(),
                    digest_size=16).hexdigest()}

    def _account(self, seconds: float, nbytes: int):
        self.stats["rows"] += 1
        self.stats["bytes"] += nbytes
        self.stats["overhead_s"] += seconds
        if self._on_overhead:
            self._on_overhead(seconds, nbytes)

    # --------------------------------------------------------- lifecycle --
    def drain(self):
        """Block until every enqueued row is durable (async mode no-op when
        sync). Background errors surface here."""
        if self._stage is not None:
            self._stage.drain()

    def close(self):
        try:
            if self._stage is not None:
                stage, self._stage = self._stage, None
                stage.close()
        finally:
            # a background error must still seal the rows that DID land and
            # release the handle — durability of the good prefix beats
            # tidiness of the failure
            if self._sink is not None:
                self._sink.close()
            if self._f is not None:
                self._f.close()

    # ------------------------------------------------------------- read ---
    @staticmethod
    def read(path: str) -> list[dict]:
        """All rows of a stream in seq order — flat file or segment dir
        (record and replay alike); torn tails from a killed writer are
        skipped, seal footers are invisible."""
        return read_stream(path)


def _capture(value, key):
    """Make a value safe to serialize LATER, as cheaply as possible on the
    step path. JAX arrays are immutable: keep the device reference and let
    the stage pay the transfer. Host numpy arrays are mutable: snapshot
    bytes (memcpy — still far cheaper than tolist+json). Everything else is
    lowered inline; mutable containers are deep-copied so a later mutation
    by the training loop cannot reach back into the queue."""
    if isinstance(value, np.ndarray):
        return value.copy()              # 0-d arrays are mutable too
    if hasattr(value, "dtype"):
        return value
    v = jsonable(value, key)
    return copy.deepcopy(v) if isinstance(v, (list, dict)) else v
