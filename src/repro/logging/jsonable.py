"""Log-value serialization: the one place a raw logged value becomes JSON.

``jsonable`` is shared by the synchronous and background log paths (and by
``flor.arg`` persistence), so the two logging modes are bit-identical by
construction. Unknown objects degrade to ``repr(v)`` — but no longer
silently: the first time a log KEY degrades, a :class:`FlorLogValueWarning`
names the offending type, so "why is my metric a string?" is answered at
record time instead of at query time.
"""
from __future__ import annotations

import threading
import warnings

_warned_keys: set = set()
_warned_lock = threading.Lock()


class FlorLogValueWarning(UserWarning):
    """A logged value of an unsupported type was degraded to ``repr(v)``.
    Emitted once per log key (record and replay both): the value still
    lands in the log as a string, but it will not compare numerically in
    the deferred check or pivot as a number in the query surface."""


def reset_warned_keys():
    """Forget which keys already warned (tests)."""
    with _warned_lock:
        _warned_keys.clear()


def jsonable(v, key=None):
    """Lower a logged value to a JSON-encodable one.

    0-d array-likes (jax or numpy scalars) become floats, ndarrays become
    nested lists, native JSON types pass through (containers may still hold
    array/object leaves — ``json_default`` lowers those at dump time);
    anything else degrades to ``repr(v)`` with a one-time
    :class:`FlorLogValueWarning` per `key`."""
    try:
        import numpy as np
        if hasattr(v, "item") and getattr(v, "ndim", 1) == 0:
            return float(v.item()) if hasattr(v, "dtype") else v
        if isinstance(v, (np.ndarray,)):
            return v.tolist()
        if hasattr(v, "dtype") and getattr(v, "ndim", 0) > 0:
            # non-numpy array-likes (jax device arrays — incl. ones nested
            # inside logged containers): lower exactly like a top-level
            # array, not to repr
            return np.asarray(v).tolist()
    except Exception:
        pass
    if isinstance(v, (int, float, str, bool, type(None), list, dict)):
        return v
    _warn_degraded(key, v)
    return repr(v)


def json_default(key=None):
    """A ``json.dumps(default=)`` hook lowering non-JSON LEAVES inside
    logged containers (a dict of numpy arrays, a list holding a jax
    scalar, ...) through the same rules as :func:`jsonable` — instead of
    ``json.dumps`` raising TypeError, which on the background stage would
    surface as a deferred crash at ``close()``. Unknown leaf types degrade
    to ``repr`` with the same one-time warning."""
    def default(o):
        out = jsonable(o, key)
        if out is o:                     # jsonable passed it through as-is:
            _warn_degraded(key, o)       # json couldn't encode it, so lower
            return repr(o)               # to repr (and warn) rather than die
        return out
    return default


def _warn_degraded(key, v):
    if key is None:
        return
    with _warned_lock:
        first = key not in _warned_keys
        _warned_keys.add(key)
    if first:
        warnings.warn(
            f"flor.log({key!r}, ...): value of type "
            f"{type(v).__module__}.{type(v).__qualname__} is not "
            f"JSON-serializable; degrading to repr(). It will compare "
            f"as a string in the deferred check and the query surface.",
            FlorLogValueWarning, stacklevel=3)
