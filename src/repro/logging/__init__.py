"""Background logging subsystem (paper task (i): efficient background
logging in Python).

``flor.log`` on the record/replay step path is a non-blocking enqueue; a
background stage owns device->host copies, JSON serialization, large-value
spill to the checkpoint store, and crash-safe segment-file I/O. The
segmented reader keeps the historical one-row-per-line contract for every
consumer (deferred check, replay merge, cross-run query), whichever layout
a stream was written in. See ``docs/logging.md`` for the overhead model
and the on-disk format.

Modules:
  * ``stream``   — :class:`FingerprintLog`, the per-run log stream facade
  * ``segment``  — segment files, seal footers, torn-tail-tolerant reader
  * ``jsonable`` — value lowering + :class:`FlorLogValueWarning`
"""
from repro.logging.jsonable import (FlorLogValueWarning, jsonable,  # noqa: F401
                                    reset_warned_keys)
from repro.logging.segment import (DEFAULT_ROLL_BYTES, SegmentSink,  # noqa: F401
                                   list_segments, read_stream,
                                   remove_stream, segment_path, tail_seq)
from repro.logging.stream import (DEFAULT_QUEUE_DEPTH,  # noqa: F401
                                  DEFAULT_SPILL_BYTES, FingerprintLog)
