"""Crash-safe, seq-ordered segment files for the fingerprint log.

One log STREAM (``logs/record.jsonl``, ``logs/replay_p3.jsonl``) is either

* a legacy FLAT file — one JSON record per line (the pre-subsystem layout,
  still written by ``async_log=False`` streams and still read forever), or
* a segment DIRECTORY at the very same path, holding ordered segment files
  ``log.<n>.jsonl``. The background writer appends records to the current
  segment and, at the roll threshold (and on clean close), SEALS it with a
  one-line footer ``{"__seal__": 1, "rows": R, "first_seq": a,
  "last_seq": b}``.

Keeping the directory at the legacy path means every consumer that treats
the path as an opaque stream id (``FingerprintLog.read``, the cross-run
query surface, ``run_logs``, the replay merge) keeps working unchanged —
``read_stream`` below dispatches on what it finds.

Crash safety. Records are written append-only and a stream NEVER reopens an
existing segment: a resumed writer always starts segment ``n+1``, so a torn
line (the process died mid-``write``) can only sit at the tail of a
segment. The reader skips seal footers and a torn FINAL line; an
unparsable line anywhere else is real corruption and raises. A sealed
segment additionally lets ``tail_seq`` trust ``last_seq`` without parsing
rows. Nothing here fsyncs: like the paper's materialization stage, the log
is allowed to lose the last instants before a crash, but never to
misparse what WAS durable.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Optional

SEAL_KEY = "__seal__"
# roll threshold: segments stay small enough that tail_seq's "parse the
# trailing partial segment" is bounded work
DEFAULT_ROLL_BYTES = 1 << 20
# bounded-tail window for flat files (doubles until a valid row is found)
TAIL_WINDOW_BYTES = 64 * 1024

_SEG_RE = re.compile(r"^log\.(\d+)\.jsonl$")


def segment_path(stream_dir: str, n: int) -> str:
    return os.path.join(stream_dir, f"log.{n:05d}.jsonl")


def list_segments(stream_dir: str) -> list[tuple[int, str]]:
    """Ordered ``(n, path)`` of the segment files a stream dir holds."""
    try:
        names = os.listdir(stream_dir)
    except OSError:
        return []
    out = []
    for fn in names:
        m = _SEG_RE.match(fn)
        if m:
            out.append((int(m.group(1)), os.path.join(stream_dir, fn)))
    return sorted(out)


def remove_stream(path: str) -> None:
    """Delete a log stream, whichever layout it is in (flat file, segment
    dir, or a half-migrated leftover). Missing streams are a no-op."""
    if os.path.isdir(path):
        shutil.rmtree(path, ignore_errors=True)
    else:
        try:
            os.remove(path)
        except OSError:
            pass
    try:
        os.remove(path + ".migrate")
    except OSError:
        pass


def migrate_flat_to_segments(path: str) -> None:
    """Adopt an existing flat log file as segment 0 of a segment dir at the
    same path (a record run resumed with ``async_log=True`` over a run dir
    written by the synchronous path). The old rows keep their byte-exact
    lines; the resumed writer appends from segment 1. Each step is a
    rename, and a process killed between them is recovered on the next
    call (the ``.migrate`` leftover completes its move), so the rows are
    never stranded."""
    tmp = path + ".migrate"
    if os.path.isfile(path):
        os.replace(path, tmp)
    if os.path.isfile(tmp):
        os.makedirs(path, exist_ok=True)
        os.replace(tmp, segment_path(path, 0))


def needs_migration(path: str) -> bool:
    """True when `path` holds a flat file (or an interrupted migration's
    leftover) that must be adopted into the segment layout."""
    return os.path.isfile(path) or os.path.isfile(path + ".migrate")


class SegmentSink:
    """Append-only writer over a stream's segment directory.

    Exactly one thread appends (the background stage in async mode, the
    calling thread in sync-over-segments mode). Segments open lazily on the
    first row, roll at ``roll_bytes``, and are sealed with a footer on roll
    and on close — an unsealed trailing segment is the signature of a
    crashed writer, and the reader treats it accordingly.

    ``on_seal(path, n, footer)`` fires right after a segment seals — on the
    sealing thread (the background log stage on roll, the closing thread on
    close), NEVER on the training step path. The query index's incremental
    maintenance hangs off this hook: a segment becomes indexable exactly
    when it becomes immutable."""

    def __init__(self, stream_dir: str, roll_bytes: int = DEFAULT_ROLL_BYTES,
                 on_seal=None):
        self.dir = stream_dir
        self.on_seal = on_seal
        self.roll_bytes = max(int(roll_bytes), 1)
        os.makedirs(stream_dir, exist_ok=True)
        segs = list_segments(stream_dir)
        # never append to a pre-existing segment: its tail may be torn
        self._n = segs[-1][0] + 1 if segs else 0
        self._f = None
        self._bytes = 0
        self._rows = 0
        self._first_seq: Optional[int] = None
        self._last_seq: Optional[int] = None

    def append(self, line: str, seq: int) -> int:
        """Write one pre-serialized JSONL line (newline included). Returns
        the byte count written."""
        if self._f is None:
            self._f = open(segment_path(self.dir, self._n), "w")
            self._bytes = 0
            self._rows = 0
            self._first_seq = seq
        self._f.write(line)
        self._f.flush()
        n = len(line.encode("utf-8"))
        self._bytes += n
        self._rows += 1
        self._last_seq = seq
        if self._bytes >= self.roll_bytes:
            self._seal()
        return n

    def _seal(self):
        if self._f is None:
            return
        footer = {SEAL_KEY: 1, "rows": self._rows,
                  "first_seq": self._first_seq, "last_seq": self._last_seq}
        self._f.write(json.dumps(footer) + "\n")
        self._f.close()
        self._f = None
        sealed_n = self._n
        self._n += 1
        if self.on_seal is not None:
            self.on_seal(segment_path(self.dir, sealed_n), sealed_n, footer)

    def close(self):
        self._seal()


# ---------------------------------------------------------------- reading --
def parse_text(text: str, path: str = "<segment>") -> list[dict]:
    """Every record line of one file's TEXT, in file order, skipping seal
    footers and blank lines. An unparsable FINAL line is a torn tail — the
    signature of a writer killed mid-write (writers never reopen existing
    segments, so a torn line can only sit at the end of its file) — and is
    skipped. An unparsable line anywhere ELSE is real corruption and
    raises: silently dropping a mid-file record would let the deferred
    check report fidelity on rows it never compared.

    Exposed at the text level so the query index (``repro.querydb``) can
    read a captured byte snapshot through the exact same row contract as
    the file-scan path — the bit-identity guarantee between the two query
    engines rests on sharing this one parser."""
    out = []
    lines = text.split("\n")
    last_content = max((i for i, ln in enumerate(lines) if ln.strip()),
                       default=-1)
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            if i == last_content:
                continue                    # torn tail of a crashed writer
            raise ValueError(
                f"corrupt log line {path}:{i + 1} (not valid JSON and not "
                f"a torn tail)") from None
        if isinstance(rec, dict) and SEAL_KEY not in rec:
            out.append(rec)
    return out


def _parse_lines(path: str) -> list[dict]:
    """parse_text over one file on disk; a missing file is an empty log."""
    try:
        f = open(path)
    except OSError:
        return []
    with f:
        return parse_text(f.read(), path)


def read_stream(path: str) -> list[dict]:
    """All records of a stream, in seq order — flat file or segment dir,
    transparently. This is the single reader behind ``FingerprintLog.read``,
    so every downstream consumer (deferred check, replay merge, cross-run
    query) sees one row contract regardless of how the stream was written."""
    if os.path.isdir(path):
        rows: list[dict] = []
        for _n, seg in list_segments(path):
            rows.extend(_parse_lines(seg))
        return rows
    if not os.path.exists(path):
        return []
    return _parse_lines(path)


def _seal_of(path: str) -> Optional[dict]:
    """The seal footer of a segment, if it is sealed (footer = last line)."""
    try:
        with open(path, "rb") as f:
            f.seek(0, os.SEEK_END)
            size = f.tell()
            back = min(size, 4096)
            f.seek(size - back)
            tail = f.read().decode("utf-8", errors="replace")
    except OSError:
        return None
    lines = [ln for ln in tail.split("\n") if ln.strip()]
    if not lines:
        return None
    try:
        rec = json.loads(lines[-1])
    except json.JSONDecodeError:
        return None
    return rec if isinstance(rec, dict) and SEAL_KEY in rec else None


def _max_seq(rows: list[dict]) -> int:
    best = -1
    for r in rows:
        try:
            best = max(best, int(r["seq"]))
        except (KeyError, TypeError, ValueError):
            continue
    return best


def _flat_tail_seq(path: str) -> int:
    """Bounded-tail seq recovery for flat files: read a window from the end
    (doubling on miss) instead of parsing the whole file — resume cost is
    O(tail), not O(run length)."""
    try:
        size = os.path.getsize(path)
    except OSError:
        return 0
    window = TAIL_WINDOW_BYTES
    while True:
        start = max(size - window, 0)
        with open(path, "rb") as f:
            f.seek(start)
            tail = f.read().decode("utf-8", errors="replace")
        lines = tail.split("\n")
        if start > 0:
            lines = lines[1:]              # first line may be cut mid-record
        best = -1
        for line in lines:
            line = line.strip()
            if not line:
                continue
            try:
                best = max(best, int(json.loads(line)["seq"]))
            except (KeyError, TypeError, ValueError, json.JSONDecodeError):
                continue
        if best >= 0:
            return best + 1
        if start == 0:
            return 0
        window *= 2


def tail_seq(path: str) -> int:
    """1 + the last durable seq of a stream (0 for a missing/empty stream).

    Segment dirs walk segments from the END: a sealed trailing segment
    answers from its footer alone; an unsealed (crashed) one is parsed in
    full — bounded by the roll threshold — and the walk steps back past
    segments whose every line tore. Flat files use the bounded-tail window.
    Either way, resume never re-parses the whole history."""
    if os.path.isdir(path):
        for _n, seg in reversed(list_segments(path)):
            seal = _seal_of(seg)
            if seal is not None and seal.get("last_seq") is not None:
                return int(seal["last_seq"]) + 1
            best = _max_seq(_parse_lines(seg))
            if best >= 0:
                return best + 1
        return 0
    if not os.path.exists(path):
        return 0
    return _flat_tail_seq(path)
