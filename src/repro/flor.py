"""The Flor public API (paper: ``import flor``).

Record:
    import repro.flor as flor
    flor.init(run_dir, mode="record")
    for epoch in flor.generator(range(N)):
        if flor.skipblock.step_into("train"):
            for batch in batches(epoch):
                state, m = train_step(state, batch)
                flor.log("loss", m["loss"])
        state = flor.skipblock.end("train", state)
    flor.finish()

Replay (hindsight logging): re-run the same script with
    flor.init(run_dir, mode="replay", pid=PID, nworkers=G,
              init_mode="strong"|"weak", probed={"train"})
adding any flor.log(...) probes you wished you had — only probed blocks
re-execute; everything else restores physically from checkpoints.
"""
from __future__ import annotations

from repro.core.changeset import (    # noqa: F401
    analyze_loop, augment_changeset, outer_assignments, register_augmenter)
from repro.core.context import (      # noqa: F401
    FlorContext, finish, get_context, init)
from repro.core.fingerprint import deferred_check, run_logs  # noqa: F401
from repro.core.generator import (generator, partition,      # noqa: F401
                                  sampling_generator)
from repro.core.instrument import (   # noqa: F401
    exec_instrumented, instrument_source)
from repro.core.probes import detect_probes                  # noqa: F401
from repro.core.skipblock import skipblock                   # noqa: F401


def log(key: str, value):
    """Log a metric / probe value (goes into the fingerprint log)."""
    ctx = get_context()
    ctx.log.log(ctx.current_epoch, key, value)


def augment(namespace_subset: dict, namespace: dict) -> dict:
    """Script-tier helper: apply framework-knowledge augmentation to a
    changeset dict (instrument.py emits calls to this)."""
    names = list(namespace_subset)
    extra = augment_changeset(names, namespace)
    out = dict(namespace_subset)
    for n in extra:
        if n not in out and n in namespace:
            out[n] = namespace[n]
    return out


def current_epoch():
    return get_context().current_epoch
