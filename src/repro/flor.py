"""The Flor public API (paper: ``import flor``) — session-first.

Record:
    import repro.flor as flor
    with flor.Session(run_dir) as sess:               # mode="record"
        lr = flor.arg("peak_lr", 1e-3)                # replay-stable hparam
        with flor.checkpointing(state=state) as ckpt:
            for epoch in flor.loop("epochs", range(flor.arg("epochs", N))):
                for step, batch in flor.loop("train", lambda: loader()):
                    ckpt.state, m = train_step(ckpt.state, batch)
                flor.log("loss", m["loss"])
        state = ckpt.state

Replay (hindsight logging): the same script with
    flor.Session(run_dir, mode="replay",
                 replay=flor.ReplaySpec(probed={"train"}))
plus any ``flor.log(...)`` probes you wished you had. Parallel replay is
PLANNED (repro.replay): ``flor.build_plan(run_dir, probed=...)`` (or
``probed="auto"`` to source-diff the recorded script copy) selects which
epochs re-execute and estimates their cost; a cost-balanced scheduler
assigns per-worker visit lists (``ReplaySpec(segments=...)``, or
``ReplaySpec(plan=plan)`` for one worker). The legacy
``ReplaySpec(pid=, nworkers=)`` contiguous split is a deprecation shim.
The OUTER loop drives
epoch bookkeeping and the replay init/exec phases; each INNER loop is a
SkipBlock: skipped epochs yield nothing and the ``checkpointing`` scope is
physically restored from the Loop End Checkpoint, probed epochs re-execute
logically. ``flor.arg`` returns the RECORDED value, so hyperparameters can
never drift between record and replay. Guard post-loop logging that needs
real execution with ``flor.executed("train")``.

``flor.log`` itself is OFF the step path: by default it captures the value
and enqueues; a background stage (``repro.logging``) pays the device->host
copy, serialization, large-value spill, and crash-safe segment I/O, and its
observed cost draws down the same epsilon overhead budget as checkpoint
materialization (docs/logging.md).

Sessions are explicit and STACKED — they nest and sequence with no hidden
global. Typed specs subsume the old kwargs bag:

    flor.RecordSpec(epsilon=, adaptive=, async_materialize=,
                    full_manifest_every=, async_log=, log_queue_depth=,
                    log_spill_bytes=)
    flor.ReplaySpec(pid=, nworkers=, init_mode=, probed=,
                    async_log=, log_queue_depth=, log_spill_bytes=)
    flor.LineageSpec(store_root=, run_id=, parent_run=)

Run lineage (multi-run shared store): point several runs at one store and
declare the edge —

    with flor.Session(runB_dir,
                      lineage=flor.LineageSpec(store_root=STORE,
                                               parent_run="base",
                                               run_id="ft1")) as sess:
        state = sess.warm_start("train", like=state)  # ancestor's final ckpt
        ...fine-tune...                               # 1st ckpt already a delta

Query the accumulated logs of a whole lineage as data:

    flor.log_records(STORE)           # flat rows: run_id, parent_run, epoch,
                                      #   seq, key, value (+ replay sources)
    flor.pivot(STORE, "loss")         # one row per (run, epoch), keys as cols
    flor.reindex(STORE)               # catch the sqlite query index up

Queries are served by the incrementally-maintained sqlite index
(``<store_root>/index/flor.db``, repro.querydb) whenever its watermarks
prove it current, and fall back to scanning the log files otherwise — the
two paths return bit-identical rows (docs/queries.md). Or from the shell:
``python -m repro.launch.runs logs|pivot|reindex --store-root ...`` (plus
the PR-2 ``list|show|gc|rm`` lineage management).

Legacy surface: ``flor.init/finish/get_context/generator/skipblock`` keep
working as thin shims but warn with ``FlorDeprecationWarning`` (set
``FLOR_STRICT_DEPRECATIONS=1`` to make any use raise). Migration is
mechanical: ``init/finish`` -> ``with Session(...)``; ``generator(it)`` ->
``loop("epochs", it)``; ``step_into(b)``/``end(b, state)`` ->
``loop(b, items)`` under ``with checkpointing(state=state)``.
"""
from __future__ import annotations

from repro.core.changeset import (    # noqa: F401
    analyze_loop, augment_changeset, outer_assignments, register_augmenter)
from repro.core.context import (      # noqa: F401
    FlorContext, FlorDeprecationWarning, finish, get_context, init)
from repro.logging import FingerprintLog, FlorLogValueWarning  # noqa: F401
from repro.core.fingerprint import deferred_check, run_logs  # noqa: F401
from repro.core.generator import (generator, partition,      # noqa: F401
                                  sampling_generator)
from repro.core.instrument import (   # noqa: F401
    exec_instrumented, instrument_source)
from repro.core.probes import detect_probes                  # noqa: F401
from repro.core.query import (log_records, merge_replay_logs,  # noqa: F401
                              pivot)
from repro.querydb import reindex                            # noqa: F401
from repro.core.session import (      # noqa: F401
    CheckpointScope, LineageSpec, RecordSpec, ReplaySpec, Session, arg,
    checkpointing, executed, loop)
from repro.core.skipblock import skipblock                   # noqa: F401
from repro.replay import ReplayPlan, build_plan              # noqa: F401


def log(key: str, value):
    """Log a metric / probe value into the fingerprint log.

    Record: the row is part of the fingerprint replay must reproduce; the
    call is a non-blocking enqueue by default — device->host copies, JSON
    serialization, large-value spill, and segment I/O run on a background
    stage whose observed cost shares the epsilon overhead budget with
    checkpoints (``RecordSpec(async_log=, log_queue_depth=,
    log_spill_bytes=)``; see docs/logging.md). Replay: identical
    mechanics into the attempt's per-pid stream; keys the record run also
    logged are diffed by ``deferred_check``, new keys are hindsight
    probes. Values that cannot be JSON-lowered degrade to ``repr`` with a
    one-time ``FlorLogValueWarning`` per key."""
    ctx = get_context()
    ctx.log.log(ctx.current_epoch, key, value)


def warm_start(block_id: str = "train", like=None):
    """Restore the parent run's final checkpoint for `block_id` (see
    ``LineageSpec(store_root=, parent_run=)``) and, when recording, seed
    the delta pipeline so this run's first checkpoint is a cross-run delta
    against its ancestor. Returns the restored state — unflattened into
    `like` when given, else a flat {path: array} dict."""
    return get_context().warm_start(block_id, like=like)


def augment(namespace_subset: dict, namespace: dict) -> dict:
    """Script-tier helper: apply framework-knowledge augmentation to a
    changeset dict (instrument.py emits calls to this)."""
    names = list(namespace_subset)
    extra = augment_changeset(names, namespace)
    out = dict(namespace_subset)
    for n in extra:
        if n not in out and n in namespace:
            out[n] = namespace[n]
    return out


def current_epoch():
    """Epoch of the active outer loop's current iteration (None outside
    one). Record: 0..N-1 in order; replay: follows the planned visit
    order."""
    return get_context().current_epoch
