"""The Flor public API (paper: ``import flor``).

Record:
    import repro.flor as flor
    flor.init(run_dir, mode="record")
    for epoch in flor.generator(range(N)):
        if flor.skipblock.step_into("train"):
            for batch in batches(epoch):
                state, m = train_step(state, batch)
                flor.log("loss", m["loss"])
        state = flor.skipblock.end("train", state)
    flor.finish()

Replay (hindsight logging): re-run the same script with
    flor.init(run_dir, mode="replay", pid=PID, nworkers=G,
              init_mode="strong"|"weak", probed={"train"})
adding any flor.log(...) probes you wished you had — only probed blocks
re-execute; everything else restores physically from checkpoints.

Run lineage (multi-run shared store): continuous-training workflows chain
runs — a fine-tune of a fine-tune should pay for what CHANGED since its
ancestor, not for the model. Point several runs at one store and declare
the lineage edge:

    flor.init(runA_dir, mode="record", store_root=STORE, run_id="base")
    ...record run A...; flor.finish()

    flor.init(runB_dir, mode="record", store_root=STORE,
              parent_run="base", run_id="ft1")
    state = flor.warm_start("train", like=state)   # A's final checkpoint
    ...fine-tune...                                # 1st ckpt already a delta

Each run gets its own manifest namespace inside `store_root` (keys never
collide) while chunks dedup globally; `warm_start` restores the parent
run's final checkpoint AND seeds the delta pipeline (structure signatures,
writer-side chunk hashes, Pallas-fingerprint digest rehydration), so run
B's first checkpoint transfers only the hot fraction. Record writes the
binding to `<run_dir>/flor.run.json`; replaying run B reads it back and
resolves delta chains through run A's chunks transparently. The registry
(`<store_root>/runs/*.json`) tracks every run's parent, status and final
per-scope checkpoint keys; inspect and reclaim with
`python -m repro.launch.runs list | show RUN | gc | rm RUN` — gc keeps any
chunk reachable from ANY registered run's manifest closure.
"""
from __future__ import annotations

from repro.core.changeset import (    # noqa: F401
    analyze_loop, augment_changeset, outer_assignments, register_augmenter)
from repro.core.context import (      # noqa: F401
    FlorContext, finish, get_context, init)
from repro.core.fingerprint import deferred_check, run_logs  # noqa: F401
from repro.core.generator import (generator, partition,      # noqa: F401
                                  sampling_generator)
from repro.core.instrument import (   # noqa: F401
    exec_instrumented, instrument_source)
from repro.core.probes import detect_probes                  # noqa: F401
from repro.core.skipblock import skipblock                   # noqa: F401


def log(key: str, value):
    """Log a metric / probe value (goes into the fingerprint log)."""
    ctx = get_context()
    ctx.log.log(ctx.current_epoch, key, value)


def warm_start(block_id: str = "train", like=None):
    """Restore the parent run's final checkpoint for `block_id` (see
    `flor.init(..., store_root=, parent_run=)`) and, when recording, seed
    the delta pipeline so this run's first checkpoint is a cross-run delta
    against its ancestor. Returns the restored state — unflattened into
    `like` when given, else a flat {path: array} dict."""
    return get_context().warm_start(block_id, like=like)


def augment(namespace_subset: dict, namespace: dict) -> dict:
    """Script-tier helper: apply framework-knowledge augmentation to a
    changeset dict (instrument.py emits calls to this)."""
    names = list(namespace_subset)
    extra = augment_changeset(names, namespace)
    out = dict(namespace_subset)
    for n in extra:
        if n not in out and n in namespace:
            out[n] = namespace[n]
    return out


def current_epoch():
    return get_context().current_epoch
