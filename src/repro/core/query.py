"""Cross-run log query surface: the PR-2 run registry exposed as DATA.

FlorDB (arXiv:2408.02498) treats the accumulated logs of every run sharing
a store as one queryable relation. This module gives that surface to the
library tier:

* ``log_records(path)`` — flat rows across ALL registered runs:
  ``{run_id, parent_run, source, epoch, seq, key, value}`` (source is
  ``record`` or ``replay_p<pid>``; hindsight replay probes appear alongside
  the original record rows).
* ``pivot(path, *keys)`` — one row per (run, epoch) with the requested log
  keys as columns: the "loss across a whole lineage" view.

``path`` is a shared store root, a run dir carrying ``flor.run.json`` (the
binding is followed to its store), or a bare legacy run dir (queried as a
single pseudo-run). The CLI lives in ``repro.launch.runs``
(``python -m repro.launch.runs logs|pivot``).
"""
from __future__ import annotations

import json
import os
from typing import Optional

from repro.checkpoint.lineage import RunRegistry, read_run_meta
from repro.core.context import FingerprintLog


def resolve_store_root(path: str) -> str:
    """Accept a store root directly, or a run dir carrying flor.run.json
    (follow the binding), or a legacy run dir with a private ./store."""
    meta = read_run_meta(path)
    if meta.get("store_root"):
        return meta["store_root"]
    if os.path.isdir(os.path.join(path, "store")) \
            and not os.path.isdir(os.path.join(path, "manifests")):
        return os.path.join(path, "store")
    return path


def _registered_runs(path: str) -> list[dict]:
    """[{run_id, parent, run_dir}] for every run reachable from `path`, in
    registry (creation) order; falls back to `path` itself as a single
    pseudo-run when no registry exists (pre-lineage run dirs)."""
    root = resolve_store_root(path)
    runs = []
    if os.path.isdir(os.path.join(root, "runs")):
        runs = [r for r in RunRegistry(root).list_runs()]
    if not runs and os.path.isdir(os.path.join(path, "logs")):
        meta = read_run_meta(path)
        runs = [{"run_id": meta.get("run_id")
                 or os.path.basename(os.path.abspath(path)),
                 "parent": meta.get("parent_run"),
                 "namespace": meta.get("namespace"),
                 "run_dir": os.path.abspath(path)}]
    return runs


def _run_log_files(run_dir: Optional[str],
                   include_replay: bool) -> list[tuple[str, str]]:
    """[(source, path)] of the fingerprint log STREAMS a run dir holds. A
    stream path may be a flat file or a background-writer segment dir at
    the same name (repro.logging) — ``FingerprintLog.read`` dispatches, so
    this listing treats them uniformly."""
    if not run_dir:
        return []
    d = os.path.join(run_dir, "logs")
    if not os.path.isdir(d):
        return []
    out = [("record", os.path.join(d, "record.jsonl"))]
    if include_replay:
        for fn in sorted(os.listdir(d)):
            if fn.startswith("replay_") and fn.endswith(".jsonl"):
                out.append((fn[: -len(".jsonl")], os.path.join(d, fn)))
    return [(src, p) for src, p in out if os.path.exists(p)]


def _is_spill_ref(value) -> bool:
    """A large-value pointer row written by the background log's spill path
    (repro.logging): {"ref": "logref__<stream>__<seq>", dtype, shape,
    nbytes, digest}."""
    return (isinstance(value, dict)
            and str(value.get("ref", "")).startswith("logref__")
            and "nbytes" in value)


def _inline_spill(value: dict, rec: dict, path: str, cache: dict):
    """Materialize one spilled value back from the checkpoint store (the
    inverse of FingerprintLog._spill_value), JSON-lowered like a never-
    spilled row would have been. Best-effort: a missing ref (gc'd store,
    detached run dir) leaves the pointer row untouched."""
    from repro.checkpoint.store import CheckpointStore
    from repro.logging import jsonable
    try:
        root = resolve_store_root(rec.get("run_dir") or path)
        store = cache.get(root)
        if store is None:
            store = cache[root] = CheckpointStore(root)
        # spills live in the run's manifest namespace; "::" pins the flat
        # namespace for legacy private stores
        qual = f"{rec.get('namespace') or ''}::{value['ref']}"
        arr = store.get_tree(qual)["['v']"]
        return jsonable(arr, value["ref"])
    except Exception:
        return value


def log_records(path: str, run: Optional[str] = None,
                key: Optional[str] = None,
                include_replay: bool = True,
                inline_spill_bytes: int = 0) -> list[dict]:
    """Every logged value across every run registered under `path`, as flat
    row dicts tagged with the run lineage. Filter with ``run=`` (a run id)
    and ``key=`` (a log key).

    ``inline_spill_bytes`` re-inlines spilled large values: a pointer row
    whose recorded ``nbytes`` is at or below the threshold is resolved from
    the checkpoint store and returned as the actual value (as if it had
    never spilled); larger spills keep their pointer dict. 0 (default)
    leaves every pointer untouched."""
    rows = []
    cache: dict = {}
    for rec in _registered_runs(path):
        rid = rec.get("run_id")
        if run is not None and rid != run:
            continue
        for source, lp in _run_log_files(rec.get("run_dir"), include_replay):
            for r in FingerprintLog.read(lp):
                if key is not None and r.get("key") != key:
                    continue
                value = r.get("value")
                if inline_spill_bytes and _is_spill_ref(value) \
                        and int(value["nbytes"]) <= inline_spill_bytes:
                    value = _inline_spill(value, rec, path, cache)
                rows.append({"run_id": rid,
                             "parent_run": rec.get("parent"),
                             "source": source,
                             "epoch": r.get("epoch"),
                             "seq": r.get("seq"),
                             "key": r.get("key"),
                             "value": value})
    return rows


MERGED_LOG = "merged_replay.jsonl"     # NOT "replay_*": run_logs must skip it


def merge_replay_logs(run_dir: str, owners: list,
                      out_path: Optional[str] = None) -> list[dict]:
    """Merge per-worker replay logs by PLAN SEGMENT into one canonical log.

    `owners` is ``[(source, [epoch, ...]), ...]`` — for each worker log
    (source is the log-file stem, e.g. ``replay_p3``) the work epochs that
    worker OWNS under the plan's assignment. For every owned epoch, exactly
    the owner's rows are taken (in their original order); rows a worker
    emitted while INIT-visiting someone else's epoch — and rows from a
    cancelled straggler duplicate — are dropped. Epochs are emitted in
    global order and ``seq`` is renumbered, so a multi-worker merge is
    bit-identical to a single-worker replay of the same plan.

    Writes ``<run_dir>/logs/merged_replay.jsonl`` when `out_path` is True-ish
    (default path) or a string path; returns the merged rows either way."""
    logs_dir = os.path.join(run_dir, "logs")
    rows_by_source: dict[str, dict] = {}
    for source, _epochs in owners:
        by_epoch: dict = {}
        for r in FingerprintLog.read(os.path.join(logs_dir,
                                                  source + ".jsonl")):
            by_epoch.setdefault(r.get("epoch"), []).append(r)
        rows_by_source[source] = by_epoch
    owner_of: dict = {}
    for source, epochs in owners:
        for e in epochs:
            owner_of[e] = source
    merged: list[dict] = []
    for e in sorted(owner_of):
        source = owner_of[e]
        for r in rows_by_source.get(source, {}).get(e, []):
            merged.append({"epoch": r.get("epoch"), "seq": len(merged),
                           "key": r.get("key"), "value": r.get("value")})
    if out_path:
        path = out_path if isinstance(out_path, str) \
            else os.path.join(logs_dir, MERGED_LOG)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            for r in merged:
                f.write(json.dumps(r) + "\n")
    return merged


def pivot(path: str, *keys: str, run: Optional[str] = None,
          include_replay: bool = True,
          inline_spill_bytes: int = 0) -> list[dict]:
    """One row per (run, epoch) with log keys as columns, across the whole
    lineage: ``[{run_id, parent_run, epoch, <key>: value, ...}, ...]``.
    With no explicit `keys`, every observed key becomes a column. The LAST
    logged occurrence in an epoch wins (replay attempts, logging after
    record, override earlier values — hindsight refines the log).
    ``inline_spill_bytes`` resolves small spilled values like
    :func:`log_records` does."""
    rows = log_records(path, run=run, include_replay=include_replay,
                       inline_spill_bytes=inline_spill_bytes)
    want = list(keys)
    if not want:
        seen = []
        for r in rows:
            if r["key"] not in seen:
                seen.append(r["key"])
        want = seen
    order: list[tuple] = []
    cells: dict[tuple, dict] = {}
    for r in rows:
        if r["key"] not in want:
            continue
        g = (r["run_id"], r["epoch"])
        if g not in cells:
            order.append(g)
            cells[g] = {"run_id": r["run_id"], "parent_run": r["parent_run"],
                        "epoch": r["epoch"]}
        cells[g][r["key"]] = r["value"]
    return [cells[g] for g in order]
