"""Cross-run log query surface: the PR-2 run registry exposed as DATA.

FlorDB (arXiv:2408.02498) treats the accumulated logs of every run sharing
a store as one queryable relation. This module gives that surface to the
library tier:

* ``log_records(path)`` — flat rows across ALL registered runs:
  ``{run_id, parent_run, source, epoch, seq, key, value}`` (source is
  ``record`` or ``replay_p<pid>``; hindsight replay probes appear alongside
  the original record rows).
* ``pivot(path, *keys)`` — one row per (run, epoch) with the requested log
  keys as columns: the "loss across a whole lineage" view.

``path`` is a shared store root, a run dir carrying ``flor.run.json`` (the
binding is followed to its store), or a bare legacy run dir (queried as a
single pseudo-run). The CLI lives in ``repro.launch.runs``
(``python -m repro.launch.runs logs|pivot``).
"""
from __future__ import annotations

import os
from typing import Optional

from repro.checkpoint.lineage import RunRegistry, read_run_meta
from repro.core.context import FingerprintLog


def resolve_store_root(path: str) -> str:
    """Accept a store root directly, or a run dir carrying flor.run.json
    (follow the binding), or a legacy run dir with a private ./store."""
    meta = read_run_meta(path)
    if meta.get("store_root"):
        return meta["store_root"]
    if os.path.isdir(os.path.join(path, "store")) \
            and not os.path.isdir(os.path.join(path, "manifests")):
        return os.path.join(path, "store")
    return path


def _registered_runs(path: str) -> list[dict]:
    """[{run_id, parent, run_dir}] for every run reachable from `path`, in
    registry (creation) order; falls back to `path` itself as a single
    pseudo-run when no registry exists (pre-lineage run dirs)."""
    root = resolve_store_root(path)
    runs = []
    if os.path.isdir(os.path.join(root, "runs")):
        runs = [r for r in RunRegistry(root).list_runs()]
    if not runs and os.path.isdir(os.path.join(path, "logs")):
        meta = read_run_meta(path)
        runs = [{"run_id": meta.get("run_id")
                 or os.path.basename(os.path.abspath(path)),
                 "parent": meta.get("parent_run"),
                 "run_dir": os.path.abspath(path)}]
    return runs


def _run_log_files(run_dir: Optional[str],
                   include_replay: bool) -> list[tuple[str, str]]:
    """[(source, path)] of the fingerprint logs a run dir holds."""
    if not run_dir:
        return []
    d = os.path.join(run_dir, "logs")
    if not os.path.isdir(d):
        return []
    out = [("record", os.path.join(d, "record.jsonl"))]
    if include_replay:
        for fn in sorted(os.listdir(d)):
            if fn.startswith("replay_") and fn.endswith(".jsonl"):
                out.append((fn[: -len(".jsonl")], os.path.join(d, fn)))
    return [(src, p) for src, p in out if os.path.exists(p)]


def log_records(path: str, run: Optional[str] = None,
                key: Optional[str] = None,
                include_replay: bool = True) -> list[dict]:
    """Every logged value across every run registered under `path`, as flat
    row dicts tagged with the run lineage. Filter with ``run=`` (a run id)
    and ``key=`` (a log key)."""
    rows = []
    for rec in _registered_runs(path):
        rid = rec.get("run_id")
        if run is not None and rid != run:
            continue
        for source, lp in _run_log_files(rec.get("run_dir"), include_replay):
            for r in FingerprintLog.read(lp):
                if key is not None and r.get("key") != key:
                    continue
                rows.append({"run_id": rid,
                             "parent_run": rec.get("parent"),
                             "source": source,
                             "epoch": r.get("epoch"),
                             "seq": r.get("seq"),
                             "key": r.get("key"),
                             "value": r.get("value")})
    return rows


def pivot(path: str, *keys: str, run: Optional[str] = None,
          include_replay: bool = True) -> list[dict]:
    """One row per (run, epoch) with log keys as columns, across the whole
    lineage: ``[{run_id, parent_run, epoch, <key>: value, ...}, ...]``.
    With no explicit `keys`, every observed key becomes a column. The LAST
    logged occurrence in an epoch wins (replay attempts, logging after
    record, override earlier values — hindsight refines the log)."""
    rows = log_records(path, run=run, include_replay=include_replay)
    want = list(keys)
    if not want:
        seen = []
        for r in rows:
            if r["key"] not in seen:
                seen.append(r["key"])
        want = seen
    order: list[tuple] = []
    cells: dict[tuple, dict] = {}
    for r in rows:
        if r["key"] not in want:
            continue
        g = (r["run_id"], r["epoch"])
        if g not in cells:
            order.append(g)
            cells[g] = {"run_id": r["run_id"], "parent_run": r["parent_run"],
                        "epoch": r["epoch"]}
        cells[g][r["key"]] = r["value"]
    return [cells[g] for g in order]
