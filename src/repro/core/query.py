"""Cross-run log query surface: the PR-2 run registry exposed as DATA.

FlorDB (arXiv:2408.02498) treats the accumulated logs of every run sharing
a store as one queryable relation. This module gives that surface to the
library tier:

* ``log_records(path)`` — flat rows across ALL registered runs:
  ``{run_id, parent_run, source, epoch, seq, key, value}`` (source is
  ``record`` or ``replay_p<pid>``; hindsight replay probes appear alongside
  the original record rows).
* ``pivot(path, *keys)`` — one row per (run, epoch) with the requested log
  keys as columns: the "loss across a whole lineage" view.

Two engines serve the same relation:

* the **file scan** — parse every log stream on every call; always correct,
  O(total log bytes) per query;
* the **index** (``repro.querydb``) — the sqlite database the background
  log stage maintains incrementally as segments seal. ``engine="auto"``
  (the default) serves each run from the index exactly when its watermarks
  prove the index covers the run's on-disk streams, and falls back to the
  file scan for that run otherwise — the two paths are bit-identical by
  contract, so callers cannot tell which one answered. ``engine="files"``
  forces the scan; ``engine="index"`` demands the index and raises on any
  run it cannot serve (tests and benchmarks pin the path this way).

``lineage=<run_id>`` restricts a query to that run's ancestor chain — a
recursive CTE over the indexed ``runs`` mirror, or an equivalent
parent-link walk on fallback. ``where=``/``limit=``/``tail=`` push into SQL
when the index serves, and are applied post-hoc on the scan.

``path`` is a shared store root, a run dir carrying ``flor.run.json`` (the
binding is followed to its store), or a bare legacy run dir (queried as a
single pseudo-run). The CLI lives in ``repro.launch.runs``
(``python -m repro.launch.runs logs|pivot|reindex``).
"""
from __future__ import annotations

import json
import os
from typing import Optional, Sequence, Union

from repro.checkpoint.lineage import (RunRegistry, read_run_meta,
                                      registry_dirsig)
from repro.core.context import FingerprintLog

# where= columns grouped by how each engine applies them: per-run/stream
# constants short-circuit before any rows are read; row columns push into
# SQL (or filter inline on the scan); everything else — "value" — is
# filtered post-hoc on the built rows, identically in both engines
_CONST_COLS = ("run_id", "parent_run", "source")
_ROW_COLS = ("epoch", "seq", "key", "step")


def resolve_store_root(path: str) -> str:
    """Accept a store root directly, or a run dir carrying flor.run.json
    (follow the binding), or a legacy run dir with a private ./store."""
    meta = read_run_meta(path)
    if meta.get("store_root"):
        return meta["store_root"]
    if os.path.isdir(os.path.join(path, "store")) \
            and not os.path.isdir(os.path.join(path, "manifests")):
        return os.path.join(path, "store")
    return path


def _registered_runs(path: str) -> list[dict]:
    """[{run_id, parent, run_dir}] for every run reachable from `path`, in
    registry (creation) order; falls back to `path` itself as a single
    pseudo-run when no registry exists (pre-lineage run dirs). This is the
    JSON-scanning listing — ``_runs_listing`` routes around it through the
    indexed ``runs`` mirror when that mirror is provably current."""
    root = resolve_store_root(path)
    runs = []
    if os.path.isdir(os.path.join(root, "runs")):
        runs = [r for r in RunRegistry(root).list_runs()]
    if not runs and os.path.isdir(os.path.join(path, "logs")):
        meta = read_run_meta(path)
        runs = [{"run_id": meta.get("run_id")
                 or os.path.basename(os.path.abspath(path)),
                 "parent": meta.get("parent_run"),
                 "namespace": meta.get("namespace"),
                 "run_dir": os.path.abspath(path)}]
    return runs


def _runs_listing(path: str, root: str, idx) -> tuple[list[dict], bool]:
    """(runs listing, served-from-index) — preferring the indexed mirror:
    when the registry directory's signature matches the one the mirror was
    synced under, the listing is one SELECT instead of one JSON parse per
    registered run. Pseudo-run stores (no registered runs) never route
    through the mirror — their listing depends on which path the caller
    queried from."""
    if idx is not None:
        sig = registry_dirsig(root)
        if sig is not None and sig[1] > 0:
            listing = idx.runs_listing(sig)
            if listing is not None:
                return listing, True
    return _registered_runs(path), False


def _ancestors(listing: list[dict], run_id: str) -> set:
    """Run ids on ``run_id``'s ancestor chain (inclusive), walking parent
    links through `listing` — cycle-safe, stops at the first unlisted
    ancestor. Mirrors both ``RunRegistry.ancestry`` and the index's
    recursive CTE, so lineage filters agree across engines."""
    by_id = {r.get("run_id"): r for r in listing}
    chain = set()
    cur = run_id
    while cur is not None and cur not in chain:
        chain.add(cur)
        rec = by_id.get(cur)
        if rec is None:
            break
        cur = rec.get("parent")
    return chain


def _run_log_files(run_dir: Optional[str],
                   include_replay: bool) -> list[tuple[str, str]]:
    """[(source, path)] of the fingerprint log STREAMS a run dir holds. A
    stream path may be a flat file or a background-writer segment dir at
    the same name (repro.logging) — ``FingerprintLog.read`` dispatches, so
    this listing treats them uniformly. Both engines select streams from
    THIS disk enumeration: index rows for a stream that no longer exists on
    disk are unreachable, not wrong answers."""
    if not run_dir:
        return []
    d = os.path.join(run_dir, "logs")
    if not os.path.isdir(d):
        return []
    out = [("record", os.path.join(d, "record.jsonl"))]
    if include_replay:
        for fn in sorted(os.listdir(d)):
            if fn.startswith("replay_") and fn.endswith(".jsonl"):
                out.append((fn[: -len(".jsonl")], os.path.join(d, fn)))
    return [(src, p) for src, p in out if os.path.exists(p)]


def _is_spill_ref(value) -> bool:
    """A large-value pointer row written by the background log's spill path
    (repro.logging): {"ref": "logref__<stream>__<seq>", dtype, shape,
    nbytes, digest}."""
    return (isinstance(value, dict)
            and str(value.get("ref", "")).startswith("logref__")
            and "nbytes" in value)


def _inline_spill(value: dict, rec: dict, path: str, cache: dict):
    """Materialize one spilled value back from the checkpoint store (the
    inverse of FingerprintLog._spill_value), JSON-lowered like a never-
    spilled row would have been. Best-effort: a missing ref (gc'd store,
    detached run dir) leaves the pointer row untouched."""
    from repro.checkpoint.store import CheckpointStore
    from repro.logging import jsonable
    try:
        root = resolve_store_root(rec.get("run_dir") or path)
        store = cache.get(root)
        if store is None:
            store = cache[root] = CheckpointStore(root)
        # spills live in the run's manifest namespace; "::" pins the flat
        # namespace for legacy private stores
        qual = f"{rec.get('namespace') or ''}::{value['ref']}"
        arr = store.get_tree(qual)["['v']"]
        return jsonable(arr, value["ref"])
    except Exception:
        return value


def _open_engine(path: str, engine: str):
    """(store_root, LogIndex-or-None) for a query. ``engine="files"`` never
    opens the index; ``engine="index"`` requires one to exist."""
    if engine not in ("auto", "files", "index"):
        raise ValueError(f"engine must be auto|files|index, got {engine!r}")
    root = resolve_store_root(path)
    if engine == "files":
        return root, None
    from repro.querydb import open_index
    idx = open_index(root)
    if engine == "index" and idx is None:
        raise RuntimeError(f"engine='index' but no query index exists under "
                           f"{root!r} — run flor.reindex() first")
    return root, idx


def log_records(path: str, run: Optional[str] = None,
                key: Union[str, Sequence[str], None] = None,
                include_replay: bool = True,
                inline_spill_bytes: int = 0, *,
                lineage: Optional[str] = None,
                where: Optional[dict] = None,
                limit: Optional[int] = None,
                tail: Optional[int] = None,
                engine: str = "auto") -> list[dict]:
    """Every logged value across every run registered under `path`, as flat
    row dicts tagged with the run lineage.

    Filters compose and behave identically whichever engine serves:

    * ``run=`` — one run id; ``key=`` — one log key or a sequence of keys.
    * ``lineage=`` — restrict to the ancestor chain (inclusive) of a run.
    * ``where=`` — {column: value} equality over row fields (``run_id``,
      ``parent_run``, ``source``, ``epoch``, ``seq``, ``key``, ``value``).
    * ``limit=`` — at most N rows (in global row order); ``tail=`` — the
      LAST N rows after all other filters (both given: limit first).

    ``inline_spill_bytes`` re-inlines spilled large values: a pointer row
    whose recorded ``nbytes`` is at or below the threshold is resolved from
    the checkpoint store and returned as the actual value (as if it had
    never spilled); larger spills keep their pointer dict. 0 (default)
    leaves every pointer untouched. Resolution runs AFTER filtering, so the
    store is touched only for rows the query actually returns.

    ``engine`` selects the serving path (see module docstring)."""
    keys = None
    if key is not None:
        keys = (key,) if isinstance(key, str) else tuple(key)
    where = dict(where or {})
    const_where = {c: where.pop(c) for c in _CONST_COLS if c in where}
    row_where = {c: where.pop(c) for c in _ROW_COLS if c in where}
    post_where = where                      # whatever remains (e.g. value)
    # limit can stop the scan early only when nothing downstream of it
    # still needs to see (or drop) rows
    eager_limit = limit if (tail is None and not post_where) else None

    root, idx = _open_engine(path, engine)
    try:
        listing, runs_from_idx = _runs_listing(path, root, idx)
        anc = None
        if lineage is not None:
            # same chain either way — the CTE walks the same parent links
            # the Python fallback does, just inside sqlite
            anc = idx.ancestry_ids(lineage) if runs_from_idx \
                else _ancestors(listing, lineage)
        rows: list[dict] = []
        done = False
        for rec in listing:
            rid = rec.get("run_id")
            if run is not None and rid != run:
                continue
            if anc is not None and rid not in anc:
                continue
            if "run_id" in const_where and const_where["run_id"] != rid:
                continue
            if "parent_run" in const_where \
                    and const_where["parent_run"] != rec.get("parent"):
                continue
            streams = _run_log_files(rec.get("run_dir"), include_replay)
            if "source" in const_where:
                streams = [(s, p) for s, p in streams
                           if s == const_where["source"]]
            use_idx = idx is not None and idx.covers(rid, streams)
            if engine == "index" and not use_idx:
                raise RuntimeError(
                    f"engine='index' but the index does not cover run "
                    f"{rid!r} (stale or never-indexed stream) — run "
                    f"flor.reindex() to catch up")
            for source, lp in streams:
                if use_idx:
                    remaining = None if eager_limit is None \
                        else eager_limit - len(rows)
                    rows.extend(idx.select_rows(
                        rid, rec.get("parent"), source, keys=keys,
                        where=row_where, limit=remaining))
                else:
                    for r in FingerprintLog.read(lp):
                        if keys is not None and r.get("key") not in keys:
                            continue
                        if any(r.get(c) != v for c, v in row_where.items()):
                            continue
                        rows.append({"run_id": rid,
                                     "parent_run": rec.get("parent"),
                                     "source": source,
                                     "epoch": r.get("epoch"),
                                     "seq": r.get("seq"),
                                     "key": r.get("key"),
                                     "value": r.get("value")})
                        if eager_limit is not None \
                                and len(rows) >= eager_limit:
                            break
                if eager_limit is not None and len(rows) >= eager_limit:
                    done = True
                    break
            if done:
                break
    finally:
        if idx is not None:
            idx.close()

    if post_where:
        rows = [r for r in rows
                if all(r.get(c) == v for c, v in post_where.items())]
    if limit is not None:
        rows = rows[:limit]
    if tail is not None:
        rows = rows[-tail:] if tail > 0 else []
    if inline_spill_bytes:
        cache: dict = {}
        by_id = {r.get("run_id"): r for r in listing}
        for row in rows:
            v = row["value"]
            if _is_spill_ref(v) and int(v["nbytes"]) <= inline_spill_bytes:
                row["value"] = _inline_spill(v, by_id.get(row["run_id"], {}),
                                             path, cache)
    return rows


MERGED_LOG = "merged_replay.jsonl"     # NOT "replay_*": run_logs must skip it


def merge_replay_logs(run_dir: str, owners: list,
                      out_path: Optional[str] = None) -> list[dict]:
    """Merge per-worker replay logs by PLAN SEGMENT into one canonical log.

    `owners` is ``[(source, [epoch, ...]), ...]`` — for each worker log
    (source is the log-file stem, e.g. ``replay_p3``) the work epochs that
    worker OWNS under the plan's assignment. For every owned epoch, exactly
    the owner's rows are taken (in their original order); rows a worker
    emitted while INIT-visiting someone else's epoch — and rows from a
    cancelled straggler duplicate — are dropped. Epochs are emitted in
    global order and ``seq`` is renumbered, so a multi-worker merge is
    bit-identical to a single-worker replay of the same plan.

    Writes ``<run_dir>/logs/merged_replay.jsonl`` when `out_path` is True-ish
    (default path) or a string path; returns the merged rows either way."""
    logs_dir = os.path.join(run_dir, "logs")
    rows_by_source: dict[str, dict] = {}
    for source, _epochs in owners:
        by_epoch: dict = {}
        for r in FingerprintLog.read(os.path.join(logs_dir,
                                                  source + ".jsonl")):
            by_epoch.setdefault(r.get("epoch"), []).append(r)
        rows_by_source[source] = by_epoch
    owner_of: dict = {}
    for source, epochs in owners:
        for e in epochs:
            owner_of[e] = source
    merged: list[dict] = []
    for e in sorted(owner_of):
        source = owner_of[e]
        for r in rows_by_source.get(source, {}).get(e, []):
            merged.append({"epoch": r.get("epoch"), "seq": len(merged),
                           "key": r.get("key"), "value": r.get("value")})
    if out_path:
        path = out_path if isinstance(out_path, str) \
            else os.path.join(logs_dir, MERGED_LOG)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            for r in merged:
                f.write(json.dumps(r) + "\n")
    return merged


def pivot(path: str, *keys: str, run: Optional[str] = None,
          include_replay: bool = True,
          inline_spill_bytes: int = 0,
          lineage: Optional[str] = None,
          engine: str = "auto") -> list[dict]:
    """One row per (run, epoch) with log keys as columns, across the whole
    lineage: ``[{run_id, parent_run, epoch, <key>: value, ...}, ...]``.
    With no explicit `keys`, every observed key becomes a column. The LAST
    logged occurrence in an epoch wins (replay attempts, logging after
    record, override earlier values — hindsight refines the log).
    ``lineage=<run_id>`` restricts the aggregation to that run's ancestor
    chain; ``inline_spill_bytes`` resolves small spilled values like
    :func:`log_records` does; ``engine`` selects the serving path. When
    explicit `keys` are given and the index serves, only matching rows are
    ever parsed — the key filter pushes into SQL."""
    rows = log_records(path, run=run, key=(keys or None),
                       include_replay=include_replay,
                       inline_spill_bytes=inline_spill_bytes,
                       lineage=lineage, engine=engine)
    want = list(keys)
    if not want:
        seen = []
        for r in rows:
            if r["key"] not in seen:
                seen.append(r["key"])
        want = seen
    order: list[tuple] = []
    cells: dict[tuple, dict] = {}
    for r in rows:
        if r["key"] not in want:
            continue
        g = (r["run_id"], r["epoch"])
        if g not in cells:
            order.append(g)
            cells[g] = {"run_id": r["run_id"], "parent_run": r["parent_run"],
                        "epoch": r["epoch"]}
        cells[g][r["key"]] = r["value"]
    return [cells[g] for g in order]
