"""Flor core: the paper's record-replay machinery."""
from repro.core.adaptive import AdaptiveController  # noqa: F401
from repro.core.context import FlorContext, get_context  # noqa: F401
