"""The session-first Flor surface: typed specs, `flor.Session`, nested
`flor.loop`, declarative `flor.checkpointing`, replay-stable `flor.arg`.

The paper pitches Flor as a library adopted with minimal ceremony; FlorDB
(arXiv:2408.02498) shows where that lands: named nested loops instead of a
hand-paired ``step_into``/``end`` protocol, checkpointing declared as a
scope instead of threaded through call sites, and hyperparameters that
record on record and replay the recorded value on replay.

    with flor.Session(run_dir) as sess:                   # record
        lr = flor.arg("peak_lr", 1e-3)
        with flor.checkpointing(state=state) as ckpt:
            for epoch in flor.loop("epochs", range(flor.arg("epochs", 8))):
                for step, batch in flor.loop("train", lambda: loader()):
                    ckpt.state, m = ts(ckpt.state, batch)
                flor.log("loss", m["loss"])
        state = ckpt.state

Replay is the same script with ``mode="replay"`` (plus any hindsight
``flor.log`` probes): the OUTER loop drives epoch bookkeeping and the
replay init/exec phases; each INNER loop is a SkipBlock — skipped epochs
yield nothing and the checkpointing scope is physically restored, probed
epochs re-execute logically. Loops opened with no enclosing
``checkpointing`` scope are sub-epoch probes: they always execute and never
checkpoint.

Sessions nest and sequence (the context binding is a stack, not a global);
the legacy ``flor.init``/``finish`` shims keep working but warn with
:class:`FlorDeprecationWarning`.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, fields
from typing import Any, Iterable, Optional, Union

from repro.core.context import (FlorContext, FlorDeprecationWarning,  # noqa: F401
                                get_context, pop_context, push_context)
from repro.core.generator import epoch_iter
from repro.core.skipblock import skipblock
from repro.logging import DEFAULT_QUEUE_DEPTH, DEFAULT_SPILL_BYTES

VALID_INIT_MODES = ("strong", "weak")


def _check_log_knobs(queue_depth: int, spill_bytes: int):
    """Shared RecordSpec/ReplaySpec validation of the logging knobs."""
    if queue_depth < 1:
        raise ValueError(f"log_queue_depth must be >= 1, got {queue_depth}")
    if spill_bytes < 0:
        raise ValueError("log_spill_bytes must be >= 0 (0 disables), "
                         f"got {spill_bytes}")


# ------------------------------------------------------------- typed specs --
@dataclass(frozen=True)
class RecordSpec:
    """Record-side knobs (subsumes the old kwargs bag's record half).

    ``epsilon`` budgets TOTAL record overhead — checkpoint materialization
    AND observed background-logging cost share it (docs/logging.md). The
    ``log_*`` knobs configure the background logging subsystem
    (``repro.logging``): ``async_log=False`` reverts ``flor.log`` to the
    synchronous flat-file path; ``log_queue_depth`` bounds how far the
    training thread can run ahead of the log writer before enqueues apply
    backpressure; a logged array larger than ``log_spill_bytes`` host bytes
    is spilled to the checkpoint store and logged as a ``{"ref": ...}``
    pointer row (0 disables spilling).

    ``ckpt_error_bounds`` declares WHAT ERROR each lossy slot tolerates
    instead of how to encode it: ``{"mu": 1e-2}`` (slot name or glob ->
    absolute per-element tolerance). The pipeline picks, per changed chunk,
    the cheapest wire encoding whose guaranteed blockwise bound satisfies
    the tolerance — int4 packed nibbles when the chunk's amplitude allows,
    else int8, else exact — and the writer thread may additionally
    entropy-compress the result. ``ckpt_quantize_slots`` is the older
    fixed-q8 spelling (DEPRECATED — prefer an error bound of
    ``absmax / 126`` intent via ``ckpt_error_bounds``); when a slot matches
    both, the error bound wins. Everything unmatched stays exact: the
    bit-identical restore invariant holds by default.

    ``full_manifest_every`` bounds delta-chain length; pass ``"auto"`` to
    let the pipeline retune the cadence from the store's measured read
    bandwidth and learned per-hop restore cost (restore-bound stores get
    short chains, cheap-hop stores amortize fulls over long ones).
    ``ckpt_overlap`` overlaps the fused fingerprint pass with training: the
    step thread only dispatches kernels and the mask sync + gather + encode
    move to the writer thread (the adaptive controller then charges only
    the measured foreground stall against epsilon)."""
    epsilon: float = 1.0 / 15          # record-overhead budget (Eq. 1)
    adaptive: bool = True              # adaptive checkpointing (section 5.3)
    async_materialize: bool = True     # background checkpoint write stage
    full_manifest_every: Any = 8       # delta-chain length bound (or "auto")
    async_log: bool = True             # background flor.log (repro.logging)
    log_index: bool = True             # incremental query index (repro.querydb)
    log_queue_depth: int = DEFAULT_QUEUE_DEPTH    # bounded queue (backpressure)
    log_spill_bytes: int = DEFAULT_SPILL_BYTES    # spill threshold (0 = off)
    ckpt_quantize_slots: tuple = ()    # slots stored lossy-q8 (deprecated)
    ckpt_error_bounds: tuple = ()      # {slot: atol} adaptive encodings
    ckpt_overlap: bool = False         # overlap fused pass with the step
    # mesh-sharded record: with a jax.sharding.Mesh here, each device shard
    # fingerprints/gathers its OWN buffer and writes to its host's store
    # shard (v4 stitching manifests; restore reshards onto any mesh).
    # ckpt_shard_axes picks the mesh axes that map onto store shards
    # (default () = all axes: one store shard per device).
    mesh: Optional[Any] = None
    ckpt_shard_axes: tuple = ()
    # true multi-process record (jax.distributed): every REAL host runs the
    # fused pass over its local shards and publishes member manifests into
    # its own pool; process 0 stitches the v4 through a file rendezvous.
    # ``distributed=True`` reads the fleet shape from the initialized jax
    # runtime (process_index/process_count); a
    # parallel.rendezvous.ProcessGroup pins it explicitly. A host past
    # ``stitch_timeout_s`` marks the checkpoint incomplete (replay skips
    # it) instead of wedging training.
    distributed: Any = False
    stitch_timeout_s: float = 30.0

    def __post_init__(self):
        if not 0 < self.epsilon <= 1:
            raise ValueError(f"epsilon must be in (0, 1], got {self.epsilon}")
        if isinstance(self.full_manifest_every, str):
            if self.full_manifest_every != "auto":
                raise ValueError(
                    "full_manifest_every must be an int >= 1 or \"auto\", "
                    f"got {self.full_manifest_every!r}")
        elif self.full_manifest_every < 1:
            raise ValueError("full_manifest_every must be >= 1")
        _check_log_knobs(self.log_queue_depth, self.log_spill_bytes)
        if isinstance(self.ckpt_quantize_slots, str):
            raise ValueError(
                "ckpt_quantize_slots must be a sequence of slot names / "
                "globs, not a bare string (a string would match per-char)")
        object.__setattr__(self, "ckpt_quantize_slots",
                           tuple(self.ckpt_quantize_slots))
        if isinstance(self.ckpt_error_bounds, str):
            raise ValueError(
                "ckpt_error_bounds must be a {slot: atol} mapping (or a "
                "sequence of (slot, atol) pairs), not a bare string")
        eb = self.ckpt_error_bounds
        pairs = sorted(eb.items()) if isinstance(eb, dict) \
            else sorted(tuple(p) for p in eb)
        for p in pairs:
            if len(p) != 2 or not isinstance(p[0], str) or not p[0]:
                raise ValueError(
                    f"ckpt_error_bounds entries must be (slot, atol) with a "
                    f"non-empty slot name/glob, got {p!r}")
            if not float(p[1]) > 0:
                raise ValueError(
                    f"ckpt_error_bounds atol must be > 0, got {p[1]!r} for "
                    f"slot {p[0]!r}")
        object.__setattr__(self, "ckpt_error_bounds",
                           tuple((s, float(a)) for s, a in pairs))
        if self.ckpt_overlap and not self.async_materialize:
            raise ValueError("ckpt_overlap requires async_materialize=True "
                             "(the writer thread finalizes the deferred "
                             "fused pass)")
        if isinstance(self.ckpt_shard_axes, str):
            raise ValueError("ckpt_shard_axes must be a sequence of mesh "
                             "axis names, not a bare string")
        object.__setattr__(self, "ckpt_shard_axes",
                           tuple(self.ckpt_shard_axes))
        if self.mesh is not None and not hasattr(self.mesh, "devices"):
            raise ValueError(f"mesh must be a jax.sharding.Mesh, got "
                             f"{type(self.mesh).__name__}")
        if self.ckpt_shard_axes and self.mesh is None:
            raise ValueError("ckpt_shard_axes requires mesh=")
        if self.mesh is not None and self.ckpt_shard_axes:
            names = {str(a) for a in self.mesh.axis_names}
            bad = [a for a in self.ckpt_shard_axes if str(a) not in names]
            if bad:
                raise ValueError(f"ckpt_shard_axes {bad} not in mesh axes "
                                 f"{sorted(names)}")
        if self.distributed and self.mesh is None:
            raise ValueError("distributed record requires mesh= (the global "
                             "device mesh spanning every process)")
        if not float(self.stitch_timeout_s) > 0:
            raise ValueError(f"stitch_timeout_s must be > 0, got "
                             f"{self.stitch_timeout_s!r}")

    def to_kwargs(self) -> dict:
        return {f.name: getattr(self, f.name) for f in fields(self)}


@dataclass(frozen=True)
class ReplaySpec:
    """Replay-side knobs: work assignment, init mode, probed blocks.

    Two assignment forms:
      * ``segments=`` — an explicit ordered visit list from the replay
        planner (``repro.replay``): ``[(epoch, "init"|"exec"), ...]``, or
        bare epochs (treated as exec visits). ``plan=`` accepts a
        ``ReplayPlan`` directly and derives the full single-worker visit
        list (and the probed set, unless given).
      * ``pid``/``nworkers`` — the legacy contiguous split, kept as a
        deprecation shim (the generator warns when ``nworkers > 1``).

    The ``log_*`` knobs mirror :class:`RecordSpec`'s: hindsight probes
    logged during replay go through the same background subsystem (each
    replay attempt rotates its per-pid stream)."""
    pid: int = 0
    nworkers: int = 1
    init_mode: str = "strong"          # strong | weak
    probed: frozenset = frozenset()    # block names to re-execute ('*' = all)
    segments: Optional[tuple] = None   # planned visits [(epoch, phase), ...]
    plan: Optional[Any] = None         # a ReplayPlan (repro.replay.plan)
    async_log: bool = True             # background flor.log (repro.logging)
    log_index: bool = True             # incremental query index (repro.querydb)
    log_queue_depth: int = DEFAULT_QUEUE_DEPTH
    log_spill_bytes: int = DEFAULT_SPILL_BYTES

    def __post_init__(self):
        _check_log_knobs(self.log_queue_depth, self.log_spill_bytes)
        if self.init_mode not in VALID_INIT_MODES:
            raise ValueError(f"init_mode must be one of {VALID_INIT_MODES}, "
                             f"got {self.init_mode!r}")
        if self.plan is not None:
            if self.segments is None:
                object.__setattr__(self, "segments",
                                   tuple(self.plan.visits_for()))
            if not self.probed:
                object.__setattr__(self, "probed",
                                   frozenset(self.plan.probed))
        if self.segments is not None:
            norm = []
            for s in self.segments:
                e, ph = s if isinstance(s, (tuple, list)) else (s, "exec")
                if ph not in ("init", "exec"):
                    raise ValueError(f"segment phase must be 'init' or "
                                     f"'exec', got {ph!r}")
                norm.append((int(e), ph))
            object.__setattr__(self, "segments", tuple(norm))
            if self.pid < 0:
                raise ValueError(f"pid must be >= 0, got {self.pid}")
        elif not 0 <= self.pid < self.nworkers:
            raise ValueError(f"pid {self.pid} outside [0, {self.nworkers})")
        object.__setattr__(self, "probed", frozenset(self.probed))

    def to_kwargs(self) -> dict:
        return {"pid": self.pid, "nworkers": self.nworkers,
                "init_mode": self.init_mode, "probed": set(self.probed),
                "segments": self.segments, "async_log": self.async_log,
                "log_index": self.log_index,
                "log_queue_depth": self.log_queue_depth,
                "log_spill_bytes": self.log_spill_bytes}


@dataclass(frozen=True)
class LineageSpec:
    """Multi-run shared-store binding (PR 2's run lineage, typed)."""
    store_root: Optional[str] = None   # shared store (default: private store)
    run_id: Optional[str] = None       # explicit id in the shared store
    parent_run: Optional[str] = None   # ancestor run id: enables warm_start

    def __post_init__(self):
        if self.parent_run and not self.store_root:
            # a parent ref only resolves against a store that can hold two
            # runs; a private flat store cannot
            raise ValueError("parent_run requires store_root (a shared "
                             "store) to resolve the ancestor")

    def to_kwargs(self) -> dict:
        return {"store_root": self.store_root, "run_id": self.run_id,
                "parent_run": self.parent_run}


_RECORD_KEYS = {f.name for f in fields(RecordSpec)}
_REPLAY_KEYS = {f.name for f in fields(ReplaySpec)}
_LINEAGE_KEYS = {f.name for f in fields(LineageSpec)}


def specs_from_kwargs(mode: str, kw: dict) -> tuple[
        Optional[RecordSpec], Optional[ReplaySpec], Optional[LineageSpec]]:
    """Partition a legacy kwargs bag into typed specs (unknown keys raise).
    Used by the `flor.init` shim and `exec_instrumented` so every entry
    point validates through the same typed layer."""
    rec_kw = {k: v for k, v in kw.items() if k in _RECORD_KEYS}
    rep_kw = {k: v for k, v in kw.items() if k in _REPLAY_KEYS}
    lin_kw = {k: v for k, v in kw.items() if k in _LINEAGE_KEYS}
    unknown = set(kw) - _RECORD_KEYS - _REPLAY_KEYS - _LINEAGE_KEYS
    if unknown:
        raise TypeError(f"unknown Flor arguments {sorted(unknown)}; valid: "
                        f"{sorted(_RECORD_KEYS | _REPLAY_KEYS | _LINEAGE_KEYS)}")
    if rep_kw.get("probed") is not None:
        rep_kw["probed"] = frozenset(rep_kw["probed"])
    record = RecordSpec(**rec_kw) if (rec_kw and mode == "record") else None
    replay = ReplaySpec(**rep_kw) if (rep_kw and mode == "replay") else None
    lineage = LineageSpec(**lin_kw) if any(v is not None
                                           for v in lin_kw.values()) else None
    return record, replay, lineage


# ------------------------------------------------------------------ session --
class Session:
    """An explicit Flor run: `with flor.Session(run_dir, mode=...) as sess`.

    Owns one :class:`FlorContext` for its extent, binds it on the context
    STACK (so sessions nest and sequence safely — no single mutable global),
    and finishes it on exit (registry status ``finished``, or ``failed``
    when the body raised). All module-level surface functions
    (``flor.loop``/``checkpointing``/``log``/``arg``) resolve the innermost
    active session; the methods on this object address THIS session
    explicitly, which is the primary, non-ambient path.
    """

    def __init__(self, run_dir: str, mode: str = "record", *,
                 record: Optional[RecordSpec] = None,
                 replay: Optional[ReplaySpec] = None,
                 lineage: Optional[LineageSpec] = None):
        if mode not in ("record", "replay"):
            raise ValueError(f"mode must be 'record' or 'replay', got {mode!r}")
        if mode == "record" and replay is not None:
            raise ValueError("ReplaySpec given for a record session")
        if mode == "replay" and record is not None:
            raise ValueError("RecordSpec given for a replay session")
        self.run_dir = run_dir
        self.mode = mode
        self.record = record if mode == "record" else None
        self.replay = replay if mode == "replay" else None
        self.lineage = lineage or LineageSpec()
        self._ctx: Optional[FlorContext] = None

    # ------------------------------------------------------- lifecycle --
    def __enter__(self) -> "Session":
        if self._ctx is not None:
            raise RuntimeError("Session is not re-entrant; create a new one")
        kw = dict(self.lineage.to_kwargs())
        if self.mode == "record":
            kw.update((self.record or RecordSpec()).to_kwargs())
        else:
            kw.update((self.replay or ReplaySpec()).to_kwargs())
        self._ctx = FlorContext(self.run_dir, self.mode, **kw)
        push_context(self._ctx)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        ctx, self._ctx = self._ctx, None
        if ctx is not None:
            pop_context(ctx)
            ctx.finish(status="finished" if exc_type is None else "failed")
        return False

    @property
    def ctx(self) -> FlorContext:
        if self._ctx is None:
            raise RuntimeError("Session is not active (use `with Session(...) "
                               "as sess:`)")
        return self._ctx

    # ------------------------------------------------- explicit surface --
    @property
    def run_id(self):
        """This run's registry id (record: generated or explicit; replay:
        read back from ``flor.run.json``)."""
        return self.ctx.run_id

    @property
    def parent_run(self):
        """Ancestor run id of the lineage edge, or None (same value on
        record and replay — replay reads the recorded binding)."""
        return self.ctx.parent_run

    @property
    def store_root(self):
        """The checkpoint store this session reads/writes (shared root or
        the private ``<run_dir>/store``)."""
        return self.ctx.store_root

    @property
    def current_epoch(self):
        """Epoch of the outer loop's current iteration (None outside it).
        On replay this follows the planned visit order, not 0..N."""
        return self.ctx.current_epoch

    def log(self, key: str, value):
        """Log a metric/probe value into THIS session's fingerprint log.
        Record: the row becomes part of the fingerprint replay must
        reproduce. Replay: rows land in the attempt's own per-pid stream and
        are diffed (or, for hindsight-only keys, admitted) by
        ``flor.deferred_check``. Non-blocking by default: the value is
        captured and enqueued; serialization and I/O happen on the
        background log stage (``RecordSpec/ReplaySpec(async_log=)``)."""
        ctx = self.ctx
        ctx.log.log(ctx.current_epoch, key, value)

    def arg(self, name: str, default=None):
        """Replay-stable hyperparameter. Record: resolve (``FLOR_ARGS=``
        overrides the default), persist to store meta, return. Replay:
        return the RECORDED value, coerced to the default's type."""
        return self.ctx.hparam(name, default)

    def loop(self, name: str, iterable):
        """Named Flor loop bound to THIS session (see module-level
        :func:`loop`). Record: iterate + bookkeep (outer) / checkpoint via
        the enclosing scope (inner). Replay: the outer loop walks the
        planned init/exec visits; inner loops skip-and-restore or
        re-execute per the probed set."""
        return loop(name, iterable, ctx=self.ctx)

    def checkpointing(self, **slots) -> "checkpointing":
        """Declare WHAT gets checkpointed for the loops in the scope.
        Record: the slots are the Loop End Checkpoint payload. Replay: a
        skipped block physically restores INTO these slots."""
        return checkpointing(_ctx=self.ctx, **slots)

    def executed(self, name: str) -> bool:
        """Whether block `name`'s latest occurrence actually ran. Record:
        always True after the loop. Replay: False when it was skipped and
        physically restored — guard post-loop logging with this."""
        return self.ctx.block_executed.get(name, False)

    def warm_start(self, block_id: str = "train", like=None):
        """Restore the parent run's final checkpoint for `block_id`.
        Record: also seeds the delta pipeline (first checkpoint becomes a
        cross-run delta). Replay: restore only, through the parent run's
        chunks."""
        return self.ctx.warm_start(block_id, like=like)


# -------------------------------------------------------------- scopes -----
class CheckpointScope:
    """A mutable namespace of named state slots — WHAT gets checkpointed for
    the `flor.loop` blocks in its extent. Slots are read/written as
    attributes or items; a skipped block's physical restore lands back in
    the same slots."""

    def __init__(self, slots: dict):
        object.__setattr__(self, "_slots", dict(slots))

    def __getattr__(self, name: str):
        try:
            return object.__getattribute__(self, "_slots")[name]
        except KeyError:
            raise AttributeError(f"no checkpointing slot {name!r} "
                                 f"(declared: {sorted(self._slots)})") from None

    def __setattr__(self, name: str, value):
        self._slots[name] = value

    def __getitem__(self, name: str):
        return self._slots[name]

    def __setitem__(self, name: str, value):
        self._slots[name] = value

    def __contains__(self, name: str) -> bool:
        return name in self._slots

    def keys(self):
        return self._slots.keys()

    def update(self, **kw):
        self._slots.update(kw)

    def state_dict(self) -> dict:
        """The checkpoint payload: a plain dict pytree of the slots."""
        return dict(self._slots)

    def _restore(self, tree: dict):
        self._slots.update(tree)

    def __repr__(self):
        return f"CheckpointScope({sorted(self._slots)})"


class checkpointing:
    """``with flor.checkpointing(state=..., opt=...) as ckpt:`` — declare the
    checkpointed state for the `flor.loop` blocks inside the scope, instead
    of threading it through `skipblock.end`. Scopes nest; a loop binds to
    the INNERMOST active scope. Record: the slots are each block's Loop End
    Checkpoint payload. Replay: a skipped block physically restores the
    recorded payload INTO the slots; an executed block leaves what the
    re-execution computed."""

    def __init__(self, _ctx: Optional[FlorContext] = None, **slots):
        self._ctx = _ctx
        self._scope = CheckpointScope(slots)
        self._bound: Optional[FlorContext] = None

    def __enter__(self) -> CheckpointScope:
        self._bound = self._ctx or get_context()
        self._bound.scope_stack.append(self._scope)
        return self._scope

    def __exit__(self, exc_type, exc, tb) -> bool:
        if self._bound is not None and self._scope in self._bound.scope_stack:
            self._bound.scope_stack.remove(self._scope)
        self._bound = None
        return False


# --------------------------------------------------------------- flor.loop --
def loop(name: str, iterable: Union[Iterable, Any], *,
         ctx: Optional[FlorContext] = None):
    """Named Flor loop. The FIRST loop entered on a context is the MAIN loop
    (epoch bookkeeping, replay partitioning and init/exec phases); loops
    nested inside it are SkipBlocks bound to the innermost
    `flor.checkpointing` scope — on replay they skip (yield nothing,
    physically restore the scope) or re-execute per the probed set. A
    nested loop with NO active scope is a sub-epoch probe: always executes,
    never checkpoints.

    ``iterable`` may be a zero-arg callable returning the iterable — it is
    only invoked when the block actually executes, so skipped epochs never
    pay for (or leak) data-loader construction."""
    ctx = ctx or get_context()
    if ctx.loop_depth == 0 and ctx.current_epoch is None:
        return _outer_loop(ctx, name, _materialize(iterable))
    return _inner_loop(ctx, name, iterable)


def _materialize(iterable):
    return iterable() if callable(iterable) else iterable


def _outer_loop(ctx: FlorContext, name: str, iterable: Iterable):
    ctx.loop_depth += 1
    try:
        for e in epoch_iter(ctx, iterable, name=name):
            yield e
    finally:
        ctx.loop_depth -= 1
        # sequential main loops on one context each start fresh
        ctx.current_epoch = None


def _inner_loop(ctx: FlorContext, name: str, iterable):
    scope = ctx.scope_stack[-1] if ctx.scope_stack else None
    if scope is None:
        yield from _probe_loop(ctx, name, iterable)
        return
    execute = skipblock._open(ctx, name)
    ctx.loop_depth += 1
    completed = False
    try:
        if execute:
            for item in _materialize(iterable):
                yield item
        completed = True
    finally:
        ctx.loop_depth -= 1
        if completed:
            # both branches close the block: executed -> (maybe) memoize the
            # scope's slots; skipped -> physically restore them
            scope._restore(
                skipblock._close(ctx, name, scope.state_dict()))
        else:
            # early exit (break / exception): no checkpoint — replay then
            # re-executes this block logically, the only consistent outcome
            skipblock._abort(ctx, name)


def _probe_loop(ctx: FlorContext, name: str, iterable):
    """A nested loop with no checkpointing scope: nothing declared to
    restore, so it always executes (logical redo on replay)."""
    t0 = time.perf_counter()
    ctx.block_executed[name] = True
    ctx.loop_depth += 1
    try:
        for item in _materialize(iterable):
            yield item
    finally:
        ctx.loop_depth -= 1
        elapsed = time.perf_counter() - t0
        ctx.controller.observe_execution(name, elapsed)
        ctx.note_block_profile(name, elapsed)
        ctx.advance_block(name)


# ----------------------------------------------------------- module surface --
def arg(name: str, default=None):
    """Replay-stable hyperparameter: record the resolved value on record
    (``FLOR_ARGS="name=value,..."`` overrides the code default), return the
    RECORDED value on replay."""
    return get_context().hparam(name, default)


def executed(name: str) -> bool:
    """Whether the most recent occurrence of loop/block `name` actually ran
    (False = skipped + physically restored). Guard post-loop logging that
    only makes sense after real execution."""
    return skipblock.executed(name)
