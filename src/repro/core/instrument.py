"""Auto-instrumentation (paper sections 3.1/4.2, Figs. 4 & 8).

Rewrites a training script's AST onto the SESSION surface so that:
  * the MAIN loop's iterator is wrapped in flor.loop("main_L<line>", ...)
    (Fig. 8's generator, session-surface spelling), and
  * each instrumentable nested loop becomes a named flor.loop inside a
    flor.checkpointing scope holding its statically-estimated changeset —
    captured at the Loop End Checkpoint, physically restored on skip.

A loop qualifies when the Table-1 analysis (core/changeset.py) produces a
changeset (no rule 0/5 refusal). Refused loops are left intact — they are
fully re-executed on replay, exactly the paper's behavior for the main loop.

The transform is purely syntactic:

    with flor.checkpointing(
            **flor.augment({"net": net, "opt": opt}, globals())) as __flor_s:
        for batch in flor.loop("L<line>", <original iterator>):
            try:
                <original body>
            finally:
                __flor_s.update(**flor.augment({"net": net, "opt": opt},
                                               globals()))
    net = __flor_s["net"]; opt = __flor_s["opt"]

(the per-iteration ``update`` keeps the scope tracking live values even
across ``continue``, mirroring the old end-of-block capture; a loop that
exits EARLY — ``break`` or an exception — writes no checkpoint for that
occurrence and warns, so replay re-executes it logically, which is the only
outcome consistent with a partially-run body).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Optional

from repro.core.changeset import analyze_loop, outer_assignments


@dataclass
class InstrumentReport:
    main_loops: list[int] = field(default_factory=list)       # linenos
    instrumented: dict[str, list[str]] = field(default_factory=dict)
    refused: dict[int, str] = field(default_factory=dict)


def _block_id(loop: ast.stmt) -> str:
    return f"L{loop.lineno}"


def _loop_wrap(loop: ast.For, changeset: list[str]) -> list[ast.stmt]:
    bid = _block_id(loop)
    scope_var = f"__flor_scope_{bid}"
    dict_src = "{" + ", ".join(f"{n!r}: {n}" for n in changeset) + "}"
    update = ast.parse(f"{scope_var}.update(**flor.augment({dict_src}, "
                       f"globals()))").body[0]
    # per-iteration capture survives continue/break in the original body
    loop.body = [ast.Try(body=loop.body, handlers=[], orelse=[],
                         finalbody=[update])]
    # lazy iterator (lambda): a skipped replay epoch must not construct the
    # loader / consume a shared iterator — matching the old `if step_into:`
    # guard, which only evaluated the iterator when the block executed
    wrapped_iter = ast.parse(f"flor.loop({bid!r}, lambda: None)",
                             mode="eval").body
    wrapped_iter.args[1].body = loop.iter
    loop.iter = ast.copy_location(wrapped_iter, loop.iter)
    with_stmt = ast.parse(
        f"with flor.checkpointing(**flor.augment({dict_src}, globals())) "
        f"as {scope_var}:\n    pass").body[0]
    with_stmt.body = [loop]
    restores = [ast.parse(f"{n} = {scope_var}[{n!r}]").body[0]
                for n in changeset]
    return [with_stmt] + restores


class _Instrumenter(ast.NodeTransformer):
    def __init__(self, module: ast.Module, report: InstrumentReport):
        self.module = module
        self.report = report
        self._depth = 0

    def visit_For(self, node: ast.For):
        self._depth += 1
        try:
            node = self.generic_visit(node)     # instrument inner loops first
        finally:
            self._depth -= 1
        if self._depth == 0:
            # MAIN loop: wrap iterator in the outer flor.loop (Fig. 8's
            # generator); the loop itself is not skipped (paper: refused /
            # re-executed)
            self.report.main_loops.append(node.lineno)
            wrapped = ast.parse(f"flor.loop('main_L{node.lineno}', None)",
                                mode="eval").body
            wrapped.args[1] = node.iter
            node.iter = ast.copy_location(wrapped, node.iter)
            ast.fix_missing_locations(node)
            return node
        outer = outer_assignments(self.module, node.lineno)
        res = analyze_loop(node, outer_assigned=outer)
        if not res.ok:
            self.report.refused[node.lineno] = res.refused_reason or "?"
            return node
        self.report.instrumented[_block_id(node)] = res.changeset
        stmts = _loop_wrap(node, res.changeset)
        for s in stmts:
            ast.fix_missing_locations(s)
            ast.copy_location(s, node)
        return stmts


def instrument_source(src: str) -> tuple[str, InstrumentReport]:
    """Instrument a training script. Returns (new_source, report)."""
    module = ast.parse(src)
    report = InstrumentReport()
    tr = _Instrumenter(module, report)
    new_body = []
    for stmt in module.body:
        out = tr.visit(stmt)
        if isinstance(out, list):
            new_body.extend(out)
        elif out is not None:
            new_body.append(out)
    module.body = new_body
    header = ast.parse("import repro.flor as flor").body
    module.body = header + module.body
    ast.fix_missing_locations(module)
    return ast.unparse(module), report


def exec_instrumented(path: str, namespace: Optional[dict] = None,
                      run_dir: Optional[str] = None, mode: str = "record",
                      **flor_kw) -> tuple[dict, InstrumentReport]:
    """The script tier's entry point: `import flor` is the only user-visible
    change; this function instruments and runs the file under Flor."""
    import repro.flor as flor
    from repro.core.session import Session, specs_from_kwargs
    with open(path) as f:
        src = f.read()
    new_src, report = instrument_source(src)
    ns = namespace if namespace is not None else {}
    ns.setdefault("__name__", "__main__")
    ns["flor"] = flor
    code = compile(new_src, path + ".flor", "exec")
    if run_dir is None:
        exec(code, ns)
        return ns, report
    record, replay, lineage = specs_from_kwargs(mode, flor_kw)
    with Session(run_dir, mode=mode, record=record, replay=replay,
                 lineage=lineage) as sess:
        if mode == "record":
            # keep a copy of the un-instrumented source for probe detection
            sess.ctx.store.put_meta("source", {"path": path, "src": src})
        exec(code, ns)
    return ns, report
