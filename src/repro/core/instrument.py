"""Auto-instrumentation (paper sections 3.1/4.2, Figs. 4 & 8).

Rewrites a training script's AST so that:
  * the MAIN loop's iterator is wrapped in flor.generator(...)  (Fig. 8), and
  * each instrumentable nested loop is enclosed in a SkipBlock (Fig. 4),
    with its statically-estimated changeset captured at the Loop End
    Checkpoint and restored on skip.

A loop qualifies when the Table-1 analysis (core/changeset.py) produces a
changeset (no rule 0/5 refusal). Refused loops are left intact — they are
fully re-executed on replay, exactly the paper's behavior for the main loop.

The transform is purely syntactic:

    if flor.skipblock.step_into("L<line>"):
        <original loop>
    __flor_cs = flor.skipblock.end("L<line>", {"net": net, "opt": opt})
    net = __flor_cs["net"]; opt = __flor_cs["opt"]
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Optional

from repro.core.changeset import analyze_loop, outer_assignments


@dataclass
class InstrumentReport:
    main_loops: list[int] = field(default_factory=list)       # linenos
    instrumented: dict[str, list[str]] = field(default_factory=dict)
    refused: dict[int, str] = field(default_factory=dict)


def _block_id(loop: ast.stmt) -> str:
    return f"L{loop.lineno}"


def _skipblock_wrap(loop: ast.stmt, changeset: list[str]) -> list[ast.stmt]:
    bid = _block_id(loop)
    cond = ast.parse(f"flor.skipblock.step_into({bid!r})", mode="eval").body
    guarded = ast.If(test=cond, body=[loop], orelse=[])
    dict_src = "{" + ", ".join(f"{n!r}: {n}" for n in changeset) + "}"
    end_stmt = ast.parse(
        f"__flor_cs = flor.skipblock.end({bid!r}, "
        f"flor.augment({dict_src}, globals()))").body[0]
    restores = [ast.parse(f"{n} = __flor_cs[{n!r}]").body[0]
                for n in changeset]
    return [guarded, end_stmt] + restores


class _Instrumenter(ast.NodeTransformer):
    def __init__(self, module: ast.Module, report: InstrumentReport):
        self.module = module
        self.report = report
        self._depth = 0

    def visit_For(self, node: ast.For):
        self._depth += 1
        try:
            node = self.generic_visit(node)     # instrument inner loops first
        finally:
            self._depth -= 1
        if self._depth == 0:
            # MAIN loop: wrap iterator in flor.generator (Fig. 8); the loop
            # itself is not skipped (paper: refused / re-executed)
            self.report.main_loops.append(node.lineno)
            node.iter = ast.copy_location(
                ast.Call(
                    func=ast.Attribute(
                        value=ast.Name(id="flor", ctx=ast.Load()),
                        attr="generator", ctx=ast.Load()),
                    args=[node.iter], keywords=[]),
                node.iter)
            ast.fix_missing_locations(node)
            return node
        outer = outer_assignments(self.module, node.lineno)
        res = analyze_loop(node, outer_assigned=outer)
        if not res.ok:
            self.report.refused[node.lineno] = res.refused_reason or "?"
            return node
        self.report.instrumented[_block_id(node)] = res.changeset
        stmts = _skipblock_wrap(node, res.changeset)
        for s in stmts:
            ast.fix_missing_locations(s)
            ast.copy_location(s, node)
        return stmts


def instrument_source(src: str) -> tuple[str, InstrumentReport]:
    """Instrument a training script. Returns (new_source, report)."""
    module = ast.parse(src)
    report = InstrumentReport()
    tr = _Instrumenter(module, report)
    new_body = []
    for stmt in module.body:
        out = tr.visit(stmt)
        if isinstance(out, list):
            new_body.extend(out)
        elif out is not None:
            new_body.append(out)
    module.body = new_body
    header = ast.parse("import repro.flor as flor").body
    module.body = header + module.body
    ast.fix_missing_locations(module)
    return ast.unparse(module), report


def exec_instrumented(path: str, namespace: Optional[dict] = None,
                      run_dir: Optional[str] = None, mode: str = "record",
                      **flor_kw) -> tuple[dict, InstrumentReport]:
    """The script tier's entry point: `import flor` is the only user-visible
    change; this function instruments and runs the file under Flor."""
    import repro.flor as flor
    with open(path) as f:
        src = f.read()
    new_src, report = instrument_source(src)
    ns = namespace if namespace is not None else {}
    ns.setdefault("__name__", "__main__")
    ns["flor"] = flor
    if run_dir is not None:
        flor.init(run_dir, mode=mode, **flor_kw)
        if mode == "record":
            # keep a copy of the un-instrumented source for probe detection
            flor.get_context().store.put_meta("source", {"path": path,
                                                         "src": src})
    code = compile(new_src, path + ".flor", "exec")
    exec(code, ns)
    if run_dir is not None:
        flor.finish()
    return ns, report
