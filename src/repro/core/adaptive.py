"""Adaptive checkpointing (paper section 5.3, Table 2, Eq. 1/3/4).

Per SkipBlock i the controller tracks n_i (executions), k_i (materialized
checkpoints), and EMAs of C_i (block compute time) and M_i (materialization
time). A checkpoint is materialized only while the Joint Invariant holds:

    M_i / C_i  <  n_i / (k_i + 1) * min(1 / (1 + c), epsilon)      (Eq. 4)

which simultaneously enforces the Record Overhead invariant (Eq. 1: total
materialization time <= epsilon * total compute) and the Replay Latency
invariant (Eq. 3: record+replay never slower than two vanilla runs, for any
parallelism G >= 2). The restore/materialize ratio c starts at the paper's
naive 1.0 and is refined online from observed restores (paper: measured
average c = 1.38 across workloads).

Logging shares the budget: epsilon bounds TOTAL record overhead, and the
background log writer (repro.logging) reports its serialize+spill+write
wall time here via ``observe_logging``. The epsilon the Joint Invariant
tests against is the RESIDUAL after observed logging cost — a
logging-heavy run materializes fewer checkpoints rather than silently
blowing the user's overhead bound.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.utils.timing import EMA


@dataclass
class BlockStats:
    n: int = 0                  # executions so far
    k: int = 0                  # checkpoints materialized so far
    C: EMA = field(default_factory=lambda: EMA(0.7))   # compute time
    M: EMA = field(default_factory=lambda: EMA(0.7))   # materialization time
    # transferred/logical bytes per checkpoint: with the delta pipeline a
    # mostly-frozen state transfers a small fraction of its nbytes, and the
    # pre-measurement M estimate must reflect that (honest M_i)
    tfrac: EMA = field(default_factory=lambda: EMA(0.7))
    pending: int = 0            # submitted but not yet measured


# default M estimate before we've ever materialized: bytes / ~1 GB/s
DEFAULT_WRITE_BPS = 1e9


class AdaptiveController:
    def __init__(self, epsilon: float = 1.0 / 15, c: float = 1.0,
                 enabled: bool = True, write_bps: float = DEFAULT_WRITE_BPS):
        self.epsilon = epsilon
        self.c = EMA(0.7)
        self.c.update(c)
        self.enabled = enabled
        # calibrated store throughput: the M estimate used BEFORE the first
        # materialization of a block (a bad default here lets the bootstrap
        # checkpoint blow the eps budget on short-epoch workloads)
        self.write_bps = write_bps
        self.blocks: dict[str, BlockStats] = {}
        # observed background-logging cost (repro.logging reports every
        # flush): draws down the same epsilon budget as materialization
        self.log_s = 0.0
        self.log_bytes = 0
        # writer-thread time spent finalizing overlapped checkpoints (mask
        # sync + gather + encode). NOT charged against epsilon — overlap mode
        # exists precisely to move that work off the step path — but tracked
        # so the snapshot shows where the machine's time went
        self.bg_s = 0.0

    def _b(self, block_id: str) -> BlockStats:
        return self.blocks.setdefault(block_id, BlockStats())

    # ----------------------------------------------------------- logging --
    def observe_logging(self, seconds: float, nbytes: int = 0):
        """Account one log serialize/spill/write batch (thread-safe enough:
        float += races only smudge an EMA-free accumulator by one sample)."""
        self.log_s += float(seconds)
        self.log_bytes += int(nbytes)

    def _total_compute_s(self) -> float:
        return sum(b.n * b.C.value for b in self.blocks.values())

    def effective_epsilon(self) -> float:
        """The overhead budget LEFT for checkpoint materialization once
        observed logging cost is charged against epsilon (never negative —
        at/over budget, checkpointing pauses until compute catches up)."""
        total = self._total_compute_s()
        if not total or not self.log_s:
            return self.epsilon
        return max(self.epsilon - self.log_s / total, 0.0)

    # ------------------------------------------------------------ record --
    def observe_execution(self, block_id: str, compute_s: float):
        b = self._b(block_id)
        b.n += 1
        b.C.update(compute_s)

    def should_materialize(self, block_id: str, est_bytes: int = 0) -> bool:
        """Joint Invariant test (run after execution, before materialization:
        hence k_i + 1)."""
        if not self.enabled:
            return True
        b = self._b(block_id)
        C = b.C.value
        if C <= 0:
            return True
        if b.M.count:
            M = b.M.value
        else:
            # scale the logical size by the observed delta-transfer fraction
            # (1.0 until the pipeline has reported one)
            frac = b.tfrac.value if b.tfrac.count else 1.0
            M = est_bytes * frac / self.write_bps
        k_eff = b.k + b.pending
        thr = (b.n / (k_eff + 1)) * min(1.0 / (1.0 + self.c.value),
                                        self.effective_epsilon())
        return (M / C) < thr

    def observe_materialization(self, block_id: str, materialize_s: float):
        b = self._b(block_id)
        b.k += 1
        b.pending = max(0, b.pending - 1)
        b.M.update(materialize_s)

    def note_transfer(self, block_id: str, transferred_bytes: int,
                      logical_bytes: int):
        """Called at SUBMIT time (the fraction is known before the write
        stage finishes), so the pre-measurement M estimate of a block whose
        first materialization is still pending already reflects delta
        savings."""
        if logical_bytes:
            self._b(block_id).tfrac.update(transferred_bytes / logical_bytes)

    def note_submitted(self, block_id: str):
        self._b(block_id).pending += 1

    def note_background(self, seconds: float):
        """Account writer-thread work that overlap mode moved OFF the step
        path (fused-pass finalize: mask sync + gather + encode). Kept out of
        M_i / epsilon by design; visible in the snapshot."""
        self.bg_s += float(seconds)

    # ------------------------------------------------------------ replay --
    def observe_restore(self, block_id: str, restore_s: float):
        b = self._b(block_id)
        if b.M.count and b.M.value > 0:
            self.c.update(restore_s / b.M.value)

    # --------------------------------------------------------- invariants --
    def record_overhead_bound_ok(self, block_id: str) -> bool:
        """Eq. 1 check: k_i * M_i < n_i * eps * C_i (used by tests)."""
        b = self._b(block_id)
        if not b.n or not b.C.value:
            return True
        return b.k * b.M.value <= b.n * self.epsilon * b.C.value * 1.001

    def snapshot(self) -> dict:
        return {
            "epsilon": self.epsilon,
            "epsilon_effective": self.effective_epsilon(),
            "log_s": self.log_s,
            "log_bytes": self.log_bytes,
            "bg_s": self.bg_s,
            "c": self.c.value,
            "write_bps": self.write_bps,
            "blocks": {
                bid: {"n": b.n, "k": b.k, "C": b.C.value, "M": b.M.value,
                      "transfer_frac": b.tfrac.value if b.tfrac.count else None}
                for bid, b in self.blocks.items()
            },
        }
