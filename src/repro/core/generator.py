"""The Flor generator (paper section 5.4, Fig. 9): main-loop iterator
partitioning + worker initialization for hindsight parallelism.

Each of G workers receives a contiguous work segment of the main loop. Before
its segment it runs an INIT segment with SkipBlocks in replay-init mode:

  strong init — every epoch 0..k-1 (each restored physically from its Loop
    End Checkpoint when one exists, re-executed logically otherwise);
  weak init   — only from the LATEST materialized checkpoint <= k-1 (the
    paper's weak init assumes the k-1 checkpoint exists; with adaptive/sparse
    checkpointing we generalize to the nearest one, re-executing the gap).

Workers never communicate — replay is embarrassingly parallel.
"""
from __future__ import annotations

from typing import Iterable, Optional, Sequence

from repro.core.context import get_context


def partition(items: Sequence, nworkers: int, pid: int) -> tuple[list, list]:
    """Contiguous split of `items` over workers; returns (before, mine).
    Work is balanced to within one item (paper Fig. 13 load-balancing note)."""
    n = len(items)
    base, rem = divmod(n, nworkers)
    start = pid * base + min(pid, rem)
    size = base + (1 if pid < rem else 0)
    return list(items[:start]), list(items[start:start + size])


def _latest_ckpt_epoch(ctx, epochs: Sequence[int], block_hint: str = "") -> Optional[int]:
    """Latest epoch in `epochs` with at least one materialized checkpoint."""
    for e in reversed(list(epochs)):
        keys = [k for k in ctx.store.list_keys()
                if k.endswith(f"_at_{e}.0") or f"_at_{e}." in k]
        if keys:
            return e
    return None


def sampling_generator(iterator: Iterable, sample: Sequence[int]):
    """Sampling replay (paper section 8, implemented): random access to any
    subset of main-loop iterations. For each sampled epoch the nearest
    materialized checkpoint <= epoch-1 provides the start state (weak-init
    machinery); the gap re-executes logically; everything else is skipped.
    This is the paper's 'searching and approximate query processing' POC —
    binary-search over the loss trajectory costs O(log N) epoch replays."""
    ctx = get_context()
    assert ctx.mode == "replay", "sampling replay is a replay-time feature"
    items = list(iterator)
    index = {e: i for i, e in enumerate(items)}
    todo = sorted(set(sample), key=lambda e: index[e])
    covered = -1
    for e in todo:
        i = index[e]
        if i <= covered:
            continue
        # init: jump to the nearest checkpointed epoch before e
        anchor = _latest_ckpt_epoch(ctx, items[covered + 1:i])
        start = index[anchor] if anchor is not None else covered + 1
        ctx.replay_phase = "init"
        for j in range(start, i):
            ctx.begin_epoch(items[j])
            yield items[j]
        ctx.replay_phase = "exec"
        ctx.begin_epoch(e)
        yield e
        covered = i


def epoch_iter(ctx, iterator: Iterable, name: Optional[str] = None):
    """MAIN-loop epoch iteration against an explicit context: record-side
    run metadata, replay-side work assignment + strong/weak init phases.
    Both the legacy ``generator()`` shim and the session-surface
    ``flor.loop`` outer iterator drive this.

    Replay iterates one of two assignments:
      * planned segments (``ctx.segments``, from ``repro.replay``'s
        ReplayPlan/scheduler): an explicit ordered visit list
        ``[(epoch, "init"|"exec"), ...]`` — the query-driven path;
      * the legacy contiguous ``pid``/``nworkers`` split (deprecation shim).
    """
    items = list(iterator)

    if ctx.mode == "record":
        ctx.store.put_meta("run", {"num_epochs": len(items),
                                   "main_loop": name,
                                   "epochs": [int(e) if isinstance(e, (int,))
                                              else None for e in items]})
        for e in items:
            ctx.begin_epoch(e)
            yield e
        return

    # ---- replay: planned segments ----
    if ctx.segments is not None:
        index = {}
        for i, e in enumerate(items):
            try:
                index[e] = i
            except TypeError:
                pass
        for epoch, phase in ctx.segments:
            item = items[index[epoch]] if epoch in index else epoch
            ctx.replay_phase = "exec" if phase == "exec" else "init"
            ctx.begin_epoch(item)
            yield item
        ctx.replay_phase = "exec"
        return

    # ---- replay: legacy contiguous split ----
    if ctx.nworkers > 1:
        from repro.core.context import _deprecated
        _deprecated("the contiguous pid/nworkers replay split is deprecated;"
                    " build a ReplayPlan (repro.replay.build_plan) and pass "
                    "ReplaySpec(segments=...)")
    init_all, work = partition(items, ctx.nworkers, ctx.pid)
    if ctx.init_mode == "weak" and init_all:
        anchor = _latest_ckpt_epoch(ctx, init_all)
        if anchor is None:
            init_sgmnt = init_all            # no checkpoints: full logical redo
        else:
            # jump to the anchor checkpoint, re-execute any gap after it
            init_sgmnt = [e for e in init_all if e >= anchor]
    else:
        init_sgmnt = init_all

    ctx.replay_phase = "init"
    for e in init_sgmnt:
        ctx.begin_epoch(e)
        yield e
    ctx.replay_phase = "exec"
    for e in work:
        ctx.begin_epoch(e)
        yield e


def generator(iterator: Iterable):
    """DEPRECATED shim: wrap the MAIN loop's iterator (Fig. 8 line 2).
    New code spells this ``for e in flor.loop("epochs", iterator)``."""
    from repro.core.context import _deprecated
    _deprecated("flor.generator() is deprecated; use "
                "flor.loop(name, iterable) under a flor.Session")
    return epoch_iter(get_context(), iterator)
