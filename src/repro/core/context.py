"""FlorContext: per-run global state shared by generator / SkipBlock / probes.

Mirrors the paper's parameterized-branching state machine (section 4.2):
mode in {record, replay}; replay phase in {init, exec}; plus the probed-block
set, the adaptive controller, the checkpoint store/async writer, and the
fingerprint log (background by default — `repro.logging`; ``flor.log`` on
the step path is an enqueue, and observed logging cost draws down the same
epsilon budget that gates checkpoint materialization).

Run lineage: `store_root=` shares one content-addressed store across runs
(per-run manifest namespaces, global chunk dedup); `parent_run=` declares
the lineage edge and enables `warm_start` — restore the ancestor's final
checkpoint and record this run's first checkpoint as a cross-run delta.
The binding persists in `<run_dir>/flor.run.json` so replay reconnects
without arguments; run records live in the `RunRegistry` beside the store.
"""
from __future__ import annotations

import os
import time
import warnings
from typing import Optional

from repro.checkpoint import (CheckpointPipeline, CheckpointStore,
                              RunIdCollision, RunRegistry)
from repro.checkpoint.lineage import (generate_run_id, read_run_meta,
                                      write_run_meta)
from repro.core.adaptive import AdaptiveController
# Re-exported here for backward compatibility: FingerprintLog lived in this
# module before the background logging subsystem (PR 5) made it a package.
from repro.logging import (DEFAULT_QUEUE_DEPTH, DEFAULT_SPILL_BYTES,  # noqa: F401
                           FingerprintLog, FlorLogValueWarning, jsonable)

_jsonable = jsonable                     # legacy private name, kept importable

# Contexts form a STACK: `flor.Session` pushes on enter and pops on exit, so
# nested and sequential sessions compose without a single mutable global.
# The legacy `flor.init` shim manages exactly one stack entry of its own.
_CTX_STACK: list["FlorContext"] = []
_LEGACY_CTX: Optional["FlorContext"] = None


class FlorDeprecationWarning(DeprecationWarning):
    """Raised-or-warned category for the pre-Session Flor surface
    (`flor.init`/`finish`/`generator`/`skipblock`). Set
    ``FLOR_STRICT_DEPRECATIONS=1`` to turn any use into a hard error — CI
    runs the examples that way, so no shim call can hide in them."""


def _deprecated(msg: str):
    if os.environ.get("FLOR_STRICT_DEPRECATIONS"):
        raise FlorDeprecationWarning(msg)
    warnings.warn(msg, FlorDeprecationWarning, stacklevel=3)


class FlorContext:
    def __init__(self, run_dir: str, mode: str = "record", *,
                 epsilon: float = 1.0 / 15, adaptive: bool = True,
                 pid: int = 0, nworkers: int = 1, init_mode: str = "strong",
                 probed: Optional[set] = None,
                 segments: Optional[list] = None,
                 async_materialize: bool = True,
                 full_manifest_every: int = 8, store_root: Optional[str] = None,
                 parent_run: Optional[str] = None, run_id: Optional[str] = None,
                 async_log: bool = True, log_index: bool = True,
                 log_queue_depth: int = DEFAULT_QUEUE_DEPTH,
                 log_spill_bytes: int = DEFAULT_SPILL_BYTES,
                 ckpt_quantize_slots=(), ckpt_error_bounds=(),
                 ckpt_overlap: bool = False,
                 mesh=None, ckpt_shard_axes=(),
                 distributed=False, stitch_timeout_s: float = 30.0):
        assert mode in ("record", "replay")
        # ---- true multi-process record (jax.distributed) ----
        # `distributed` is False, True (read the fleet shape from the
        # already-initialized jax runtime) or an explicit
        # parallel.rendezvous.ProcessGroup. Every process derives the SAME
        # run identity; only process 0 (the lead) probes the store,
        # stitches v4 manifests and finalizes the registry.
        self.dist_group = None
        self.rendezvous = None
        if mode == "record" and distributed:
            from repro.parallel.rendezvous import ProcessGroup, current_group
            self.dist_group = distributed \
                if isinstance(distributed, ProcessGroup) else current_group()
            # give each record process a distinct worker identity so
            # per-process artifacts (controller meta, staging dbs) never
            # collide; single-process record keeps pid as passed
            pid = self.dist_group.process_id
        self._is_lead = self.dist_group is None or self.dist_group.is_lead
        if ckpt_quantize_slots:
            _deprecated(
                "ckpt_quantize_slots is deprecated: declare WHAT error each "
                "slot tolerates via ckpt_error_bounds={slot: atol} and let "
                "the pipeline pick the cheapest encoding per chunk "
                "(ckpt_quantize_slots still works as fixed q8)")
        self.run_dir = run_dir
        self.mode = mode
        self.replay_phase = "init"           # init | exec (replay only)
        self.pid = pid
        self.nworkers = nworkers
        self.init_mode = init_mode           # strong | weak
        self.probed: set = set(probed or ())
        # planned replay (repro.replay): an explicit ordered visit list
        # [(epoch, "init"|"exec"), ...] supersedes the contiguous
        # pid/nworkers split — the generator iterates exactly these
        self.segments = None if segments is None else \
            tuple((e, ph) for e, ph in segments)
        self.current_epoch: Optional[int] = None
        self._intra_epoch_counts: dict[str, int] = {}
        self.controller = AdaptiveController(epsilon=epsilon, enabled=adaptive)
        # ---- run lineage binding (multi-run shared store) ----
        # `store_root=` shares one content-addressed store across runs: each
        # run gets a manifest NAMESPACE (its run id) so keys never collide,
        # while chunks dedup globally. Without it, the store stays private
        # at <run_dir>/store in the legacy flat layout. Record writes the
        # binding to <run_dir>/flor.run.json; replay reads it back, so a
        # derived run's hindsight replay reconnects to the shared store (and
        # resolves through ancestor-run chunks) with zero extra arguments.
        os.makedirs(run_dir, exist_ok=True)
        if mode == "record":
            shared = store_root is not None
            self.store_root = os.path.abspath(store_root) if shared \
                else os.path.join(run_dir, "store")
            saved = read_run_meta(run_dir)
            generated = False
            if run_id:
                self.run_id = run_id
            elif shared and saved.get("run_id") \
                    and saved.get("store_root") == self.store_root:
                # re-init of the same run dir against the same shared store
                # is a crash-restart/resume, not a new run: forking a fresh
                # namespace would orphan the run's own checkpoints
                self.run_id = saved["run_id"]
            elif self.dist_group is not None \
                    and self.dist_group.num_processes > 1:
                # every process of the fleet must derive the SAME id with no
                # coordination channel yet: a deterministic name from the
                # (shared) run dir. Peers registering it concurrently land
                # on the resume path (same run_dir/namespace) — never a
                # collision, never a random retry that would fork the fleet
                self.run_id = "dist-" + os.path.basename(
                    os.path.abspath(run_dir).rstrip("/"))
            else:
                self.run_id = generate_run_id()
                generated = True
            if parent_run is None and self.run_id == saved.get("run_id"):
                # resuming the same run (however identified) keeps its
                # lineage edge — dropping it would orphan the ancestor
                # binding and skip warm_start on replay
                parent_run = saved.get("parent_run")
            self.namespace = self.run_id if shared else None
            self.parent_run = parent_run
            self._run_meta = {
                "run_id": self.run_id, "namespace": self.namespace,
                "store_root": self.store_root if shared else None,
                "parent_run": self.parent_run}
            if self.run_id == saved.get("run_id"):   # resume: keep bindings
                self._run_meta["warm_start_keys"] = \
                    saved.get("warm_start_keys") or {}
            # register BEFORE binding the store handle: simultaneous
            # recorders race the registry on a shared filesystem. The
            # atomic create-or-retry applies to every NEW registration —
            # a generated id retries with a fresh one, an explicit id
            # surfaces the conflict (two recorders given the same
            # --run-id must not silently clobber each other); a resume of
            # this run's own (run_dir, namespace) is never a collision.
            self.registry = RunRegistry(self.store_root)
            for attempt in range(8):
                try:
                    self.registry.register(self.run_id,
                                           parent=self.parent_run,
                                           run_dir=os.path.abspath(run_dir),
                                           namespace=self.namespace,
                                           exclusive=True)
                    break
                except RunIdCollision:
                    if not generated or attempt == 7:
                        raise
                    self.run_id = generate_run_id()
                    self.namespace = self.run_id if shared else None
                    self._run_meta["run_id"] = self.run_id
                    self._run_meta["namespace"] = self.namespace
            self._registered = True
            write_run_meta(run_dir, self._run_meta)
        else:
            saved = read_run_meta(run_dir)
            self._run_meta = saved
            self.run_id = run_id or saved.get("run_id")
            self.store_root = os.path.abspath(store_root) if store_root \
                else (saved.get("store_root") or os.path.join(run_dir, "store"))
            self.namespace = saved.get("namespace") if saved \
                else (self.run_id if store_root else None)
            self.parent_run = parent_run or saved.get("parent_run")
            self.registry = RunRegistry(self.store_root)
            self._registered = False
        # FLOR_PREFER_SHARDS="0,2": read-affinity ordering over the store's
        # shard pools — a distributed replay worker mounts its own host's
        # pool first (content addressing keeps every pool valid regardless)
        prefer = [s.strip() for s in
                  os.environ.get("FLOR_PREFER_SHARDS", "").split(",")
                  if s.strip()]
        self.store = CheckpointStore(self.store_root, run_id=self.namespace,
                                     prefer_shards=prefer or None)
        if self.dist_group is not None \
                and self.dist_group.num_processes > 1:
            from repro.parallel.rendezvous import StitchRendezvous
            self.rendezvous = StitchRendezvous(
                self.store_root, self.run_id, self.dist_group,
                timeout_s=stitch_timeout_s)
        if mode == "record" and self._is_lead:
            self._snapshot_source()
        self.warmstart_stats: dict[str, dict] = {}
        if adaptive and mode == "record":
            # a resumed run (or any run sharing this store namespace) already
            # measured the store's throughput: reuse the persisted figure and
            # skip the ~8MB probe write; fresh stores still calibrate once
            calib = self.store.get_meta("store_calib")
            if not (calib and calib.get("write_bps")) \
                    and not self._is_lead:
                # only the lead probes a distributed store (two concurrent
                # probes would race the same __calib__ manifest); peers
                # briefly wait for its figure, then fall back to defaults
                deadline = time.monotonic() + min(5.0,
                                                  float(stitch_timeout_s))
                while time.monotonic() < deadline:
                    calib = self.store.get_meta("store_calib")
                    if calib and calib.get("write_bps"):
                        break
                    time.sleep(0.05)
            if calib and calib.get("write_bps"):
                self.controller.write_bps = float(calib["write_bps"])
            elif self._is_lead:
                calib = self._calibrate_store()
                calib["measured_at"] = time.time()
                self.store.put_meta("store_calib", calib)
                self.controller.write_bps = calib["write_bps"]
        self.async_materialize = async_materialize
        # the delta-aware record flow; replay never submits checkpoints, so
        # it gets no pipeline (and no idle writer thread)
        self.pipeline = CheckpointPipeline(
            self.store, async_stage=async_materialize,
            full_every=full_manifest_every,
            quantize_slots=ckpt_quantize_slots,
            error_bounds=dict(ckpt_error_bounds or {}),
            overlap=ckpt_overlap,
            mesh=mesh, shard_axes=ckpt_shard_axes,
            dist=self.rendezvous,
            on_materialized=self._on_materialized) \
            if mode == "record" else None
        # backward-compat handle (benchmarks call ctx.writer.drain())
        self.writer = self.pipeline.writer if self.pipeline else None
        # distributed record: non-lead processes run the same SPMD program
        # and would log the same rows — they keep a per-process debug stream
        # (invisible to run_logs, which reads record.jsonl + replay_*) so
        # the query surface sees exactly one copy, the lead's
        if mode == "record":
            suffix = "record" if self._is_lead else f"record_p{pid}"
        else:
            suffix = f"replay_p{pid}"
        # incremental query-index maintenance (repro.querydb): sealed log
        # segments are ingested into <store_root>/index/flor.db the moment
        # they seal, off the step path, drawing from the same epsilon budget
        # as the logging work itself. Best-effort by design — any failure
        # just leaves this run file-scan-served.
        self.log_indexer = None
        if log_index and self.run_id:
            try:
                from repro.querydb import SegmentIndexer
                self.log_indexer = SegmentIndexer(
                    self.store_root, self.run_id, suffix,
                    registry=self.registry,
                    # multi-process record: each process ingests into its
                    # OWN staging db and merges it into flor.db at finish —
                    # seal-time writers never contend on the shared index
                    staging=(pid if self.rendezvous is not None else None),
                    on_overhead=self.controller.observe_logging)
                if mode == "replay":
                    # this attempt rotates its stream below (fresh=True):
                    # rows a previous attempt indexed are no longer truth
                    self.log_indexer.invalidate()
            except Exception:
                self.log_indexer = None
        # record resumes (seq continues from the tail); each replay attempt
        # rotates its per-pid log so stale lines never pollute deferred_check.
        # async_log (default) puts serialization + I/O on a background stage
        # writing crash-safe segments; the observed logging overhead feeds
        # the controller so it shares the epsilon budget with checkpoints.
        self.log = FingerprintLog(
            os.path.join(run_dir, "logs", f"{suffix}.jsonl"),
            fresh=(mode == "replay"), async_log=async_log,
            queue_depth=log_queue_depth, spill_bytes=log_spill_bytes,
            store=self.store, stream=suffix,
            on_overhead=self.controller.observe_logging,
            on_seal=(self.log_indexer.on_seal if self.log_indexer else None))
        self._block_keys_meta: dict[str, dict] = {}
        # ---- session-surface state (flor.loop / flor.checkpointing /
        # flor.arg): nesting depth of active flor.loop iterators (0 = the
        # next loop opened is the MAIN loop), the stack of declared
        # checkpointing scopes, and replay-stable hyperparameters
        self.loop_depth = 0
        self.scope_stack: list = []
        self.block_executed: dict[str, bool] = {}
        # record-side per-(block, epoch) execution profile: the replay
        # planner's exec-cost estimates come from here (store meta
        # "block_profile"), so cost-balanced partitioning sees real skew
        self._block_profile: dict[str, dict[int, dict]] = {}
        self._hparams: dict = {}
        self._arg_overrides = _parse_arg_overrides(
            os.environ.get("FLOR_ARGS", ""))
        self.t_start = time.time()
        # background-materialization callback bookkeeping: map store key ->
        # block id so M_i lands on the right block
        self._key_to_block: dict[str, str] = {}
        self.restore_stats: list[dict] = []

    def _snapshot_source(self):
        """Keep a copy of the driving script in store meta ("source") for
        `--probe auto` source-diff detection (paper section 3.2). A resumed
        run keeps the ORIGINAL recorded copy — the diff base must be what
        the run actually executed first. The script tier overwrites this
        with the exact user script it instruments."""
        try:
            import __main__
            path = getattr(__main__, "__file__", None)
            if not path or not os.path.isfile(path) \
                    or os.path.getsize(path) > (1 << 20):
                return
            if self.store.get_meta("source"):
                return
            with open(path) as f:
                self.store.put_meta("source", {"path": os.path.abspath(path),
                                               "src": f.read()})
        except Exception:
            pass                 # snapshotting is best-effort, never fatal

    def _calibrate_store(self) -> dict:
        """One ~8MB probe measures real store throughput BOTH ways: the write
        (serialize+compress+write — the pre-measurement M estimate) and a
        read-back (read+decompress+deserialize — the replay planner's
        restore-cost prior, refined later by observed restores in finish()).
        The probe is UNIQUE random data (so its chunks cannot be shared with
        any real checkpoint) and is deleted afterwards — calibration must not
        pollute list_keys() or stored_bytes() accounting."""
        import numpy as np
        rng = np.random.default_rng()        # unseeded => unshared chunks
        probe = rng.standard_normal(1 << 21).astype(np.float32)   # 8 MB
        t0 = time.perf_counter()
        self.store.put_tree("__calib__", {"x": probe})
        dt_w = max(time.perf_counter() - t0, 1e-4)
        t0 = time.perf_counter()
        self.store.get_tree("__calib__")
        dt_r = max(time.perf_counter() - t0, 1e-4)
        self.store.delete_manifest("__calib__", delete_chunks=True)
        return {"write_bps": max(probe.nbytes / dt_w, 1e7),
                "read_bps": max(probe.nbytes / dt_r, 1e7)}

    # ------------------------------------------------------------ keys ----
    def begin_epoch(self, epoch: int):
        self.current_epoch = epoch
        self._intra_epoch_counts = {}

    def block_key(self, block_id: str) -> str:
        """Stable checkpoint key for the CURRENT occurrence of a block."""
        idx = self._intra_epoch_counts.get(block_id, 0)
        return f"{block_id}@{self.current_epoch}.{idx}"

    def advance_block(self, block_id: str):
        self._intra_epoch_counts[block_id] = \
            self._intra_epoch_counts.get(block_id, 0) + 1

    def note_block_profile(self, block_id: str, seconds: float):
        """Record that `block_id` EXECUTED in the current epoch for
        `seconds` (record mode only) — the planner's per-segment exec-cost
        ground truth."""
        if self.mode != "record" or self.current_epoch is None:
            return
        try:
            epoch = int(self.current_epoch)
        except (TypeError, ValueError):
            return
        cell = self._block_profile.setdefault(block_id, {}) \
            .setdefault(epoch, {"n": 0, "s": 0.0})
        cell["n"] += 1
        cell["s"] += float(seconds)

    # ----------------------------------------------------- materialization
    def _on_materialized(self, stat: dict):
        block = self._key_to_block.pop(stat["key"], None)
        if block is None:
            return
        if stat.get("overlap"):
            # overlap mode: the fused pass ran async with the step, and the
            # mask sync + gather + encode + write all happened on the writer
            # thread. Only the measured foreground stall (dispatch + any
            # queue backpressure) is record overhead; the writer-thread time
            # is accounted separately, and the transfer fraction — unknown
            # at submit — lands here once measured
            self.controller.observe_materialization(
                block, stat.get("submit_stall_s", 0.0))
            self.controller.note_background(stat["materialize_s"])
            if stat.get("transferred_bytes") is not None:
                self.controller.note_transfer(block,
                                              stat["transferred_bytes"],
                                              stat["logical_bytes"])
        else:
            # M_i = foreground stall on the training thread (fingerprint +
            # changed-chunk DMA) + background write stage; counting only the
            # latter would let the eps-overhead invariant undercount record
            # cost. The writer-thread entropy stage is the exception: it
            # only runs when an async writer exists, so its seconds are
            # genuinely concurrent with training — they move to the
            # background accumulator instead of the epsilon-charged M_i
            entropy_s = stat.get("entropy_s") or 0.0
            self.controller.observe_materialization(
                block,
                max(0.0, stat["materialize_s"] - entropy_s)
                + stat.get("submit_stall_s", 0.0))
            if entropy_s:
                self.controller.note_background(entropy_s)

    def submit_checkpoint(self, block_id: str, key: str, tree, meta):
        assert self.pipeline is not None, \
            "submit_checkpoint is a record-mode operation"
        self._key_to_block[key] = block_id
        self.controller.note_submitted(block_id)
        stat = self.pipeline.submit(key, tree, meta, scope=block_id)
        if stat is not None and stat["transferred_bytes"] is not None:
            # overlap mode reports None here (the gather is deferred to the
            # writer thread); the measured figure arrives in _on_materialized
            self.controller.note_transfer(block_id,
                                          stat["transferred_bytes"],
                                          stat["logical_bytes"])

    # ------------------------------------------------------- warm start --
    def warm_start(self, block_id: str = "train", like=None):
        """Restore the PARENT RUN's final checkpoint for `block_id` from the
        shared store and (in record mode) seed the delta pipeline with it —
        the derived run's first checkpoint is then a delta against its
        ancestor instead of a cold full recording. Returns the restored
        state (unflattened into `like` when given, else {path: array}).

        In replay mode this only restores — a replayed derived run starts
        from the same bytes its record run did, through the parent run's
        chunks, with no pipeline to seed."""
        import jax
        if not self.parent_run:
            raise RuntimeError(
                "warm_start needs flor.init(..., store_root=, parent_run=)")
        # replay must not depend on the REGISTRY still knowing the parent:
        # `runs rm A` keeps descendants' chunk closure alive, so a derived
        # run stays replayable from the key its record run persisted into
        # its own flor.run.json
        saved_keys = self._run_meta.get("warm_start_keys") or {}
        qual = saved_keys.get(block_id) if self.mode == "replay" else None
        if qual is None:
            rec = self.registry.get(self.parent_run)
            if rec is None:
                raise RuntimeError(
                    f"parent run {self.parent_run!r} is not registered in "
                    f"{self.store_root!r}")
            fk = (rec.get("final_keys") or {}).get(block_id)
            if fk is None:
                raise KeyError(
                    f"parent run {self.parent_run!r} recorded no final "
                    f"checkpoint for block {block_id!r} (scopes: "
                    f"{sorted(rec.get('final_keys') or {})})")
            # a scope that never submitted in the parent inherits ITS
            # parent's qualified tip — already addressable as-is. "::key"
            # is the explicit flat namespace (parent recorded without a
            # shared store): an unqualified key would bind to OUR namespace.
            qual = fk if "::" in fk \
                else f"{rec.get('namespace') or ''}::{fk}"
        if self.mode == "record":
            saved_keys = dict(saved_keys)
            saved_keys[block_id] = qual
            self._run_meta["warm_start_keys"] = saved_keys
            write_run_meta(self.run_dir, self._run_meta)
        manifest = self.store.resolve_manifest(qual)
        flat = self.store.get_tree(qual, manifest=manifest)
        info = {"block": block_id, "parent_run": self.parent_run,
                "parent_key": qual, "seeded": False}
        if self.pipeline is not None:
            try:
                info.update(self.pipeline.warm_start(block_id, qual,
                                                     manifest, flat))
                info["seeded"] = True
            except ValueError as e:
                # incompatible ancestor manifest (v1 / other chunk_words):
                # state still restores, but the first checkpoint records cold
                info["reason"] = str(e)
        self.warmstart_stats[block_id] = info
        if like is None:
            return flat
        leaves, treedef = jax.tree_util.tree_flatten(like)
        arrays = [flat[lf["path"]] for lf in manifest["leaves"]]
        assert len(leaves) == len(arrays), \
            f"structure mismatch: like has {len(leaves)} leaves, parent " \
            f"checkpoint {len(arrays)}"
        return jax.tree_util.tree_unflatten(treedef, arrays)

    # ---------------------------------------------------- hyperparameters --
    def hparam(self, name: str, default=None):
        """Replay-stable hyperparameter (`flor.arg`). Record: resolve the
        value (``FLOR_ARGS="name=value,..."`` overrides the code default),
        persist it in store meta, return it. Replay: return the RECORDED
        value — the run dir, not the code, is the source of truth — coerced
        to the default's type when one is given."""
        if self.mode == "record":
            val = default
            if name in self._arg_overrides:
                val = _coerce(self._arg_overrides[name], default)
            self._hparams[name] = jsonable(val, name)
            self.store.put_meta("hparams", {"args": self._hparams})
            return val
        recorded = (self.store.get_meta("hparams") or {}).get("args", {})
        if name in recorded:
            return _coerce(recorded[name], default)
        return default        # hindsight arg the record run never declared

    def restore_checkpoint(self, key: str, like=None):
        """Load a checkpoint (delta manifests resolve transparently) and
        account the restore for the controller's restore/materialize ratio
        and replay diagnostics. Each sample records the restored byte count
        and the parent hops the resolution walked — finish() fits a learned
        restore cost model (read_bps, hop_s) from them that the replay
        planner consumes via store calibration meta."""
        import numpy as np
        from repro.checkpoint.store import np_dtype
        t0 = time.perf_counter()
        manifest = self.store.resolve_manifest(key)
        read_stats: dict = {}
        tree = self.store.get_tree(key, like=like, manifest=manifest,
                                   stats_out=read_stats)
        dt = time.perf_counter() - t0
        nbytes = sum(
            int(lf["nbytes"]) if lf.get("nbytes") is not None
            else int(np.prod(lf["shape"], dtype=np.int64))
            * np_dtype(lf["dtype"]).itemsize
            for lf in manifest["leaves"])
        sample = {"key": key, "restore_s": dt, "bytes": nbytes,
                  "hops": int(manifest.get("hops") or 0)}
        if read_stats.get("bytes_by_shard"):
            # sharded restore: what each store shard actually served (a
            # resharded read touches only overlapping chunks) — the raw
            # material for per-shard read_bps calibration
            sample["shard_bytes"] = {str(k): int(v) for k, v in
                                     read_stats["bytes_by_shard"].items()}
            sample["chunks_read"] = int(read_stats.get("chunks_read") or 0)
        self.restore_stats.append(sample)
        return tree, dt

    # ---------------------------------------------------------------- gc --
    def gc(self, keep_keys: Optional[list] = None) -> dict:
        """Collect unreferenced chunks. Default live set = every manifest
        key of THIS run (removes only orphans from crashed/partial runs);
        pass `keep_keys` for rolling retention on long record runs. The
        active delta-chain tips are always kept live — collecting them would
        leave the pipeline inheriting chunk hashes from deleted manifests,
        making every subsequent checkpoint unrestorable. In a shared store,
        every OTHER registered run stays fully live: retention here is a
        run-local policy; cross-run reclamation is the registry's job
        (`python -m repro.launch.runs gc`)."""
        if self.pipeline is not None:
            self.pipeline.drain()      # don't race in-flight manifests
        live = self.store.list_keys() if keep_keys is None \
            else list(keep_keys)
        if self.pipeline is not None:
            # on BOTH branches: a warm-started run's tip may be a parent-run
            # key that does not appear in this run's own namespace listing
            live += self.pipeline.chain_keys()
        live = [self.store.qualify(k) for k in live]
        # every OTHER registered run stays fully live (retention is a
        # run-local policy; cross-run reclamation belongs to `runs gc`)
        live += self.registry.live_keys(self.store,
                                        exclude_run_id=self.run_id)
        return self.store.gc(live)

    # ------------------------------------------------------------ finish --
    def finish(self, status: str = "finished"):
        # close the log FIRST: it drains the background stage (rows become
        # durable) and its final overhead totals land in the controller
        # snapshot persisted below. A deferred background-log error must
        # NOT abort finalization — the pipeline still drains, the registry
        # still records the run, and the error re-raises at the end.
        log_err: Optional[BaseException] = None
        try:
            self.log.close()
        except BaseException as e:
            log_err = e
        final_keys: dict[str, str] = {}
        if self.pipeline is not None:
            # tips are read AFTER close(): a distributed pipeline rolls each
            # scope's tip back past keys whose stitch never happened, and
            # final_keys must never name an unstitched checkpoint
            pipeline, self.pipeline = self.pipeline, None
            pipeline.close()
            self.writer = None
            final_keys = {s: k for s, k in pipeline._last_key.items() if k}
        if self.rendezvous is not None:
            # all stitches are settled (pipeline closed above): stop the
            # liveness beater so a dead-on-exit process cannot look alive
            self.rendezvous.close()
        if self._registered:
            # the per-scope tips are what a derived run warm-starts from.
            # Only the LEAD of a distributed fleet finalizes — concurrent
            # finalize read-modify-writes would lose each other's updates,
            # and every process computes the same tips anyway
            if self._is_lead:
                self.registry.finalize(self.run_id, final_keys=final_keys,
                                       status=status)
            self._registered = False
        if self.log_indexer is not None:
            # log closed above (final segment sealed+ingested), registry
            # finalized: sync the runs mirror + directory signature so the
            # whole store's listing is index-serviceable. Best-effort.
            indexer, self.log_indexer = self.log_indexer, None
            indexer.finish(self.registry)
        if self.mode == "record" and self._block_profile and self._is_lead:
            # merge over any previous profile so a resumed run keeps the
            # epochs it recorded before the restart
            prev = (self.store.get_meta("block_profile") or {}).get("blocks",
                                                                    {})
            for bid, per_epoch in self._block_profile.items():
                cur = prev.setdefault(bid, {})
                cur.update({str(e): v for e, v in per_epoch.items()})
            self.store.put_meta("block_profile", {"blocks": prev})
        self.store.put_meta(f"controller_{self.mode}_p{self.pid}",
                            self.controller.snapshot())
        self._persist_restore_calib()
        if log_err is not None:
            raise log_err

    def _persist_restore_calib(self):
        """Fold observed restores into store calibration meta: a learned
        (read_bps, hop_s) restore cost model the replay planner consumes
        (plan.restore_cost). Measured restores supersede the probe read-back
        — they go through the real chunk/decompress/delta-resolve path at
        real checkpoint sizes — and hop_s is only fit when the samples
        actually span different chain depths (a rank-deficient fit would
        hallucinate a hop latency)."""
        fit = _fit_restore_model(self.restore_stats)
        shard_fit = _fit_shard_read_bps(self.restore_stats)
        if fit is None and shard_fit is None:
            return
        try:
            calib = dict(self.store.get_meta("store_calib") or {})
            calib.update(fit or {})
            if shard_fit:
                # per-store-shard service rate (merged over runs): the
                # planner's max-over-hosts restore cost consumes it
                merged = dict(calib.get("shard_read_bps") or {})
                merged.update(shard_fit)
                calib["shard_read_bps"] = merged
            calib["restore_samples"] = len(self.restore_stats)
            calib["restore_measured_at"] = time.time()
            self.store.put_meta("store_calib", calib)
        except OSError:
            pass            # calibration is advisory, never fatal at finish


def _fit_restore_model(stats: list) -> Optional[dict]:
    """Least-squares (read_bps, hop_s) from restore samples of the form
    {"restore_s", "bytes", "hops"}. Model: t = bytes/read_bps + hops*hop_s.
    Returns {"read_bps"} alone when the samples don't constrain hop_s (all
    the same chain depth, or the fit goes non-physical), None when there is
    nothing usable to learn from."""
    import numpy as np
    rows = [s for s in stats
            if s.get("bytes") and float(s.get("restore_s") or 0) > 0]
    if not rows:
        return None
    b = np.array([float(s["bytes"]) for s in rows])
    h = np.array([float(s.get("hops") or 0) for s in rows])
    t = np.array([float(s["restore_s"]) for s in rows])
    # effective end-to-end throughput: the always-valid fallback figure
    eff_bps = float(np.clip(b.sum() / max(t.sum(), 1e-9), 1e6, 1e12))
    if len(rows) >= 3 and np.unique(h).size >= 2:
        coef, *_ = np.linalg.lstsq(np.stack([b, h], axis=1), t, rcond=None)
        sec_per_byte, hop_s = float(coef[0]), float(coef[1])
        if sec_per_byte > 0 and hop_s >= 0:
            return {"read_bps": float(np.clip(1.0 / sec_per_byte, 1e6, 1e12)),
                    "hop_s": hop_s}
    return {"read_bps": eff_bps}


def _fit_shard_read_bps(stats: list) -> Optional[dict]:
    """Per-store-shard service rate from sharded restore samples (those that
    carry a {"shard_bytes": {hid: bytes}} breakdown). Shards are read
    concurrently in production, so attributing each sample's full wall time
    to every participating shard gives a conservative (lower-bound) per-shard
    rate — exactly the right bias for a cost model used to schedule work."""
    bytes_by = {}
    secs_by = {}
    for s in stats:
        sb = s.get("shard_bytes")
        wall = float(s.get("restore_s") or 0)
        if not sb or wall <= 0:
            continue
        for hid, nbytes in sb.items():
            if not nbytes:
                continue
            bytes_by[str(hid)] = bytes_by.get(str(hid), 0) + int(nbytes)
            secs_by[str(hid)] = secs_by.get(str(hid), 0.0) + wall
    if not bytes_by:
        return None
    return {hid: float(min(max(bytes_by[hid] / max(secs_by[hid], 1e-9),
                                1e6), 1e12))
            for hid in bytes_by}


def _parse_arg_overrides(spec: str) -> dict[str, str]:
    """``FLOR_ARGS="epochs=12,peak_lr=3e-4"`` -> {"epochs": "12", ...}."""
    out = {}
    for part in spec.split(","):
        part = part.strip()
        if "=" in part:
            k, v = part.split("=", 1)
            out[k.strip()] = v.strip()
    return out


def _coerce(val, default):
    """Coerce a recorded/override value to the default's type (JSON and env
    round-trips lose int/float/bool/tuple-ness)."""
    if default is None or isinstance(val, type(default)):
        return val
    try:
        if isinstance(default, bool):
            return val if isinstance(val, bool) \
                else str(val).lower() in ("1", "true", "yes", "on")
        return type(default)(val)
    except (TypeError, ValueError):
        return val


# ------------------------------------------------------- context binding --
def push_context(ctx: FlorContext) -> FlorContext:
    _CTX_STACK.append(ctx)
    return ctx


def pop_context(ctx: FlorContext):
    """Unbind `ctx`. Sessions unwind LIFO; an out-of-order pop (e.g. a
    leaked legacy context under an active Session) removes just that entry."""
    if ctx in _CTX_STACK:
        _CTX_STACK.remove(ctx)


def get_context() -> FlorContext:
    if not _CTX_STACK:
        raise RuntimeError(
            "no active Flor context — enter `with flor.Session(run_dir, "
            "mode=...)` (or call the legacy flor.init) first")
    return _CTX_STACK[-1]


def init(run_dir: str, mode: str = "record", **kw) -> FlorContext:
    """DEPRECATED shim: the pre-Session single-slot API. Finishes any
    previous init()-made context, then constructs and binds a new one. The
    old context is unbound BEFORE construction, so a constructor failure
    leaves no closed context reachable from get_context()."""
    global _LEGACY_CTX
    _deprecated("flor.init() is deprecated; use `with flor.Session(run_dir, "
                "mode=...)` (typed RecordSpec/ReplaySpec/LineageSpec specs)")
    if _LEGACY_CTX is not None:
        old, _LEGACY_CTX = _LEGACY_CTX, None
        pop_context(old)
        old.finish()
    ctx = FlorContext(run_dir, mode, **kw)
    _LEGACY_CTX = ctx
    return push_context(ctx)


def finish():
    """DEPRECATED shim: finish + unbind the context made by flor.init()."""
    global _LEGACY_CTX
    _deprecated("flor.finish() is deprecated; Session.__exit__ finishes "
                "the run")
    if _LEGACY_CTX is not None:
        old, _LEGACY_CTX = _LEGACY_CTX, None
        pop_context(old)
        old.finish()
