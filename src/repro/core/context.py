"""FlorContext: per-run global state shared by generator / SkipBlock / probes.

Mirrors the paper's parameterized-branching state machine (section 4.2):
mode in {record, replay}; replay phase in {init, exec}; plus the probed-block
set, the adaptive controller, the checkpoint store/async writer, and the
fingerprint log.
"""
from __future__ import annotations

import json
import os
import time
from typing import Optional

from repro.checkpoint import CheckpointPipeline, CheckpointStore
from repro.core.adaptive import AdaptiveController

_CTX: Optional["FlorContext"] = None


class FingerprintLog:
    """Append-only metric log; record/replay logs are diffed by the deferred
    correctness check (paper section 5.2.2)."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path), exist_ok=True)
        self._f = open(path, "a", buffering=1)
        self._seq = 0

    def log(self, epoch, key: str, value):
        rec = {"epoch": int(epoch) if epoch is not None else None,
               "seq": self._seq, "key": key, "value": _jsonable(value)}
        self._f.write(json.dumps(rec) + "\n")
        self._seq += 1

    def close(self):
        self._f.close()

    @staticmethod
    def read(path: str) -> list[dict]:
        out = []
        if not os.path.exists(path):
            return out
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    out.append(json.loads(line))
        return out


def _jsonable(v):
    try:
        import numpy as np
        if hasattr(v, "item") and getattr(v, "ndim", 1) == 0:
            return float(v.item()) if hasattr(v, "dtype") else v
        if isinstance(v, (np.ndarray,)):
            return v.tolist()
    except Exception:
        pass
    if isinstance(v, (int, float, str, bool, type(None), list, dict)):
        return v
    return repr(v)


class FlorContext:
    def __init__(self, run_dir: str, mode: str = "record", *,
                 epsilon: float = 1.0 / 15, adaptive: bool = True,
                 pid: int = 0, nworkers: int = 1, init_mode: str = "strong",
                 probed: Optional[set] = None, async_materialize: bool = True,
                 full_manifest_every: int = 8):
        assert mode in ("record", "replay")
        self.run_dir = run_dir
        self.mode = mode
        self.replay_phase = "init"           # init | exec (replay only)
        self.pid = pid
        self.nworkers = nworkers
        self.init_mode = init_mode           # strong | weak
        self.probed: set = set(probed or ())
        self.current_epoch: Optional[int] = None
        self._intra_epoch_counts: dict[str, int] = {}
        self.controller = AdaptiveController(epsilon=epsilon, enabled=adaptive)
        self.store = CheckpointStore(os.path.join(run_dir, "store"))
        if adaptive and mode == "record":
            self.controller.write_bps = self._calibrate_store()
        self.async_materialize = async_materialize
        # the delta-aware record flow; replay never submits checkpoints, so
        # it gets no pipeline (and no idle writer thread)
        self.pipeline = CheckpointPipeline(
            self.store, async_stage=async_materialize,
            full_every=full_manifest_every,
            on_materialized=self._on_materialized) \
            if mode == "record" else None
        # backward-compat handle (benchmarks call ctx.writer.drain())
        self.writer = self.pipeline.writer if self.pipeline else None
        suffix = "record" if mode == "record" else f"replay_p{pid}"
        self.log = FingerprintLog(os.path.join(run_dir, "logs",
                                               f"{suffix}.jsonl"))
        self._block_keys_meta: dict[str, dict] = {}
        self.t_start = time.time()
        # background-materialization callback bookkeeping: map store key ->
        # block id so M_i lands on the right block
        self._key_to_block: dict[str, str] = {}
        self.restore_stats: list[dict] = []

    def _calibrate_store(self) -> float:
        """One ~8MB probe write measures real serialize+compress+write
        throughput, so the pre-measurement M estimate is honest. The probe is
        UNIQUE random data (so its chunks cannot be shared with any real
        checkpoint) and is deleted afterwards — calibration must not pollute
        list_keys() or stored_bytes() accounting."""
        import numpy as np
        rng = np.random.default_rng()        # unseeded => unshared chunks
        probe = rng.standard_normal(1 << 21).astype(np.float32)   # 8 MB
        t0 = time.perf_counter()
        self.store.put_tree("__calib__", {"x": probe})
        dt = max(time.perf_counter() - t0, 1e-4)
        self.store.delete_manifest("__calib__", delete_chunks=True)
        return max(probe.nbytes / dt, 1e7)

    # ------------------------------------------------------------ keys ----
    def begin_epoch(self, epoch: int):
        self.current_epoch = epoch
        self._intra_epoch_counts = {}

    def block_key(self, block_id: str) -> str:
        """Stable checkpoint key for the CURRENT occurrence of a block."""
        idx = self._intra_epoch_counts.get(block_id, 0)
        return f"{block_id}@{self.current_epoch}.{idx}"

    def advance_block(self, block_id: str):
        self._intra_epoch_counts[block_id] = \
            self._intra_epoch_counts.get(block_id, 0) + 1

    # ----------------------------------------------------- materialization
    def _on_materialized(self, stat: dict):
        block = self._key_to_block.pop(stat["key"], None)
        if block is not None:
            # M_i = foreground stall on the training thread (fingerprint +
            # changed-chunk DMA) + background write stage; counting only the
            # latter would let the eps-overhead invariant undercount record
            # cost
            self.controller.observe_materialization(
                block,
                stat["materialize_s"] + stat.get("submit_stall_s", 0.0))

    def submit_checkpoint(self, block_id: str, key: str, tree, meta):
        assert self.pipeline is not None, \
            "submit_checkpoint is a record-mode operation"
        self._key_to_block[key] = block_id
        self.controller.note_submitted(block_id)
        stat = self.pipeline.submit(key, tree, meta, scope=block_id)
        if stat is not None:
            self.controller.note_transfer(block_id,
                                          stat["transferred_bytes"],
                                          stat["logical_bytes"])

    def restore_checkpoint(self, key: str, like=None):
        """Load a checkpoint (delta manifests resolve transparently) and
        account the restore for the controller's restore/materialize ratio
        and replay diagnostics."""
        t0 = time.perf_counter()
        tree = self.store.get_tree(key, like=like)
        dt = time.perf_counter() - t0
        self.restore_stats.append({"key": key, "restore_s": dt})
        return tree, dt

    # ---------------------------------------------------------------- gc --
    def gc(self, keep_keys: Optional[list] = None) -> dict:
        """Collect unreferenced chunks. Default live set = every manifest
        key (removes only orphans from crashed/partial runs); pass
        `keep_keys` for rolling retention on long record runs. The active
        delta-chain tips are always kept live — collecting them would leave
        the pipeline inheriting chunk hashes from deleted manifests, making
        every subsequent checkpoint unrestorable."""
        if self.pipeline is not None:
            self.pipeline.drain()      # don't race in-flight manifests
        if keep_keys is None:
            live = self.store.list_keys()
        else:
            live = list(keep_keys)
            if self.pipeline is not None:
                live += self.pipeline.chain_keys()
        return self.store.gc(live)

    # ------------------------------------------------------------ finish --
    def finish(self):
        if self.pipeline is not None:
            self.pipeline.close()
            self.pipeline = None
            self.writer = None
        self.store.put_meta(f"controller_{self.mode}_p{self.pid}",
                            self.controller.snapshot())
        self.log.close()


def init(run_dir: str, mode: str = "record", **kw) -> FlorContext:
    global _CTX
    if _CTX is not None:
        _CTX.finish()
    _CTX = FlorContext(run_dir, mode, **kw)
    return _CTX


def get_context() -> FlorContext:
    if _CTX is None:
        raise RuntimeError("flor.init(run_dir, mode=...) must be called first")
    return _CTX


def finish():
    global _CTX
    if _CTX is not None:
        _CTX.finish()
        _CTX = None
