"""FlorContext: per-run global state shared by generator / SkipBlock / probes.

Mirrors the paper's parameterized-branching state machine (section 4.2):
mode in {record, replay}; replay phase in {init, exec}; plus the probed-block
set, the adaptive controller, the checkpoint store/async writer, and the
fingerprint log.
"""
from __future__ import annotations

import json
import os
import time
from typing import Optional

from repro.checkpoint import AsyncWriter, CheckpointStore
from repro.core.adaptive import AdaptiveController

_CTX: Optional["FlorContext"] = None


class FingerprintLog:
    """Append-only metric log; record/replay logs are diffed by the deferred
    correctness check (paper section 5.2.2)."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path), exist_ok=True)
        self._f = open(path, "a", buffering=1)
        self._seq = 0

    def log(self, epoch, key: str, value):
        rec = {"epoch": int(epoch) if epoch is not None else None,
               "seq": self._seq, "key": key, "value": _jsonable(value)}
        self._f.write(json.dumps(rec) + "\n")
        self._seq += 1

    def close(self):
        self._f.close()

    @staticmethod
    def read(path: str) -> list[dict]:
        out = []
        if not os.path.exists(path):
            return out
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    out.append(json.loads(line))
        return out


def _jsonable(v):
    try:
        import numpy as np
        if hasattr(v, "item") and getattr(v, "ndim", 1) == 0:
            return float(v.item()) if hasattr(v, "dtype") else v
        if isinstance(v, (np.ndarray,)):
            return v.tolist()
    except Exception:
        pass
    if isinstance(v, (int, float, str, bool, type(None), list, dict)):
        return v
    return repr(v)


class FlorContext:
    def __init__(self, run_dir: str, mode: str = "record", *,
                 epsilon: float = 1.0 / 15, adaptive: bool = True,
                 pid: int = 0, nworkers: int = 1, init_mode: str = "strong",
                 probed: Optional[set] = None, async_materialize: bool = True):
        assert mode in ("record", "replay")
        self.run_dir = run_dir
        self.mode = mode
        self.replay_phase = "init"           # init | exec (replay only)
        self.pid = pid
        self.nworkers = nworkers
        self.init_mode = init_mode           # strong | weak
        self.probed: set = set(probed or ())
        self.current_epoch: Optional[int] = None
        self._intra_epoch_counts: dict[str, int] = {}
        self.controller = AdaptiveController(epsilon=epsilon, enabled=adaptive)
        self.store = CheckpointStore(os.path.join(run_dir, "store"))
        if adaptive and mode == "record":
            self.controller.write_bps = self._calibrate_store()
        self.async_materialize = async_materialize
        self.writer = AsyncWriter(
            self.store, on_materialized=self._on_materialized) \
            if async_materialize else None
        suffix = "record" if mode == "record" else f"replay_p{pid}"
        self.log = FingerprintLog(os.path.join(run_dir, "logs",
                                               f"{suffix}.jsonl"))
        self._block_keys_meta: dict[str, dict] = {}
        self.t_start = time.time()
        # background-materialization callback bookkeeping: map store key ->
        # block id so M_i lands on the right block
        self._key_to_block: dict[str, str] = {}

    def _calibrate_store(self) -> float:
        """One ~8MB probe write measures real serialize+compress+write
        throughput, so the pre-measurement M estimate is honest."""
        import numpy as np
        rng = np.random.default_rng(0)
        probe = rng.standard_normal(1 << 21).astype(np.float32)   # 8 MB
        t0 = time.perf_counter()
        self.store.put_tree("__calib__", {"x": probe})
        dt = max(time.perf_counter() - t0, 1e-4)
        return max(probe.nbytes / dt, 1e7)

    # ------------------------------------------------------------ keys ----
    def begin_epoch(self, epoch: int):
        self.current_epoch = epoch
        self._intra_epoch_counts = {}

    def block_key(self, block_id: str) -> str:
        """Stable checkpoint key for the CURRENT occurrence of a block."""
        idx = self._intra_epoch_counts.get(block_id, 0)
        return f"{block_id}@{self.current_epoch}.{idx}"

    def advance_block(self, block_id: str):
        self._intra_epoch_counts[block_id] = \
            self._intra_epoch_counts.get(block_id, 0) + 1

    # ----------------------------------------------------- materialization
    def _on_materialized(self, stat: dict):
        block = self._key_to_block.pop(stat["key"], None)
        if block is not None:
            self.controller.observe_materialization(block,
                                                    stat["materialize_s"])

    def submit_checkpoint(self, block_id: str, key: str, tree, meta):
        self._key_to_block[key] = block_id
        self.controller.note_submitted(block_id)
        if self.writer is not None:
            self.writer.submit(key, tree, meta)
        else:
            import time as _t
            t0 = _t.perf_counter()
            stat = self.store.put_tree(key, _to_host(tree), meta)
            stat["materialize_s"] = _t.perf_counter() - t0
            self._on_materialized(stat)

    # ------------------------------------------------------------ finish --
    def finish(self):
        if self.writer is not None:
            self.writer.close()
            self.writer = None
        self.store.put_meta(f"controller_{self.mode}_p{self.pid}",
                            self.controller.snapshot())
        self.log.close()


def _to_host(tree):
    import jax
    import numpy as np
    return jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)), tree)


def init(run_dir: str, mode: str = "record", **kw) -> FlorContext:
    global _CTX
    if _CTX is not None:
        _CTX.finish()
    _CTX = FlorContext(run_dir, mode, **kw)
    return _CTX


def get_context() -> FlorContext:
    if _CTX is None:
        raise RuntimeError("flor.init(run_dir, mode=...) must be called first")
    return _CTX


def finish():
    global _CTX
    if _CTX is not None:
        _CTX.finish()
        _CTX = None
