"""SkipBlock (paper section 4.2): parameterized branching + side-effect
memoization/restoration, in functional JAX form.

Usage (the functional tier — the changeset is the explicit state pytree):

    if flor.skipblock.step_into("train"):
        for batch in batches(epoch):
            state, metrics = train_step(state, batch)
    state = flor.skipblock.end("train", state)

``end`` must run on BOTH branches: when the block executed it (maybe)
memoizes and passes state through; when it was skipped it restores the Loop
End Checkpoint — the physical half of physiological recovery.
"""
from __future__ import annotations

import time
from typing import Any

from repro.core.context import get_context
from repro.utils.pytree import tree_bytes


class _SkipBlockAPI:
    def __init__(self):
        self._t_enter: dict[str, float] = {}
        self._executed: dict[str, bool] = {}

    # -- internal protocol (shared with the session surface's flor.loop) --
    def _open(self, ctx, block_id: str) -> bool:
        key = ctx.block_key(block_id)
        if ctx.mode == "record":
            execute = True
        else:
            has = ctx.store.has(key)
            if ctx.replay_phase == "init":
                # initialization: skip whenever physically possible
                execute = not has
            else:
                # work segment: re-execute probed blocks (logical redo);
                # skip unprobed memoized blocks (physical redo)
                probed = block_id in ctx.probed or "*" in ctx.probed
                execute = probed or not has
        self._executed[block_id] = execute
        ctx.block_executed[block_id] = execute   # per-context, not global
        self._t_enter[block_id] = time.perf_counter()
        return execute

    def _abort(self, ctx, block_id: str):
        """Abandon an open block without memoizing (early exit / exception):
        no checkpoint is written, so replay re-executes the block logically —
        the only consistent outcome for a partially-run body. In record mode
        this is worth a warning: an every-epoch early exit (e.g. a `break`
        in an instrumented legacy loop) would silently leave the whole run
        checkpoint-less."""
        ran = self._executed.pop(block_id, False)
        self._t_enter.pop(block_id, None)
        if ran and ctx.mode == "record":
            import warnings
            warnings.warn(
                f"flor block {block_id!r} exited early (break/exception); "
                f"no checkpoint was written for this occurrence, so replay "
                f"will re-execute it logically", stacklevel=3)
        ctx.advance_block(block_id)

    def executed(self, block_id: str) -> bool:
        """Whether the most recent occurrence of `block_id` on the ACTIVE
        context actually ran (False = it was skipped and physically restored
        on replay). Per-context state: sequential/nested sessions never see
        each other's blocks."""
        return get_context().block_executed.get(block_id, False)

    # ---------------------------------------------------------------------
    def step_into(self, block_id: str) -> bool:
        """True => execute the enclosed loop; False => skip (end() restores).
        DEPRECATED with end(): use `for x in flor.loop(name, iterable)`
        inside a `with flor.checkpointing(...)` scope."""
        from repro.core.context import _deprecated
        _deprecated("flor.skipblock.step_into/end are deprecated; use "
                    "flor.loop(name, iterable) + flor.checkpointing(...)")
        return self._open(get_context(), block_id)

    # ---------------------------------------------------------------------
    def end(self, block_id: str, state: Any) -> Any:
        """Close the block. Returns the (possibly restored) state."""
        return self._close(get_context(), block_id, state)

    def _close(self, ctx, block_id: str, state: Any) -> Any:
        key = ctx.block_key(block_id)
        executed = self._executed.pop(block_id, True)
        elapsed = time.perf_counter() - self._t_enter.pop(block_id, time.perf_counter())

        if executed:
            import jax
            state = jax.block_until_ready(state)
            ctx.controller.observe_execution(block_id, elapsed)
            if ctx.mode == "record":
                ctx.note_block_profile(block_id, elapsed)
                est = tree_bytes(state)
                if ctx.controller.should_materialize(block_id, est_bytes=est):
                    ctx.submit_checkpoint(block_id, key, state,
                                          meta={"epoch": ctx.current_epoch,
                                                "block": block_id})
            ctx.advance_block(block_id)
            return state

        # skipped: physical restoration from the Loop End Checkpoint (delta
        # manifests resolve transparently through the store)
        restored, restore_s = ctx.restore_checkpoint(key, like=state)
        ctx.controller.observe_restore(block_id, restore_s)
        ctx.advance_block(block_id)
        return restored


skipblock = _SkipBlockAPI()
