"""SkipBlock (paper section 4.2): parameterized branching + side-effect
memoization/restoration, in functional JAX form.

Usage (the functional tier — the changeset is the explicit state pytree):

    if flor.skipblock.step_into("train"):
        for batch in batches(epoch):
            state, metrics = train_step(state, batch)
    state = flor.skipblock.end("train", state)

``end`` must run on BOTH branches: when the block executed it (maybe)
memoizes and passes state through; when it was skipped it restores the Loop
End Checkpoint — the physical half of physiological recovery.
"""
from __future__ import annotations

import time
from typing import Any

from repro.core.context import get_context
from repro.utils.pytree import tree_bytes


class _SkipBlockAPI:
    def __init__(self):
        self._t_enter: dict[str, float] = {}
        self._executed: dict[str, bool] = {}

    # ---------------------------------------------------------------------
    def step_into(self, block_id: str) -> bool:
        """True => execute the enclosed loop; False => skip (end() restores)."""
        ctx = get_context()
        key = ctx.block_key(block_id)
        if ctx.mode == "record":
            execute = True
        else:
            has = ctx.store.has(key)
            if ctx.replay_phase == "init":
                # initialization: skip whenever physically possible
                execute = not has
            else:
                # work segment: re-execute probed blocks (logical redo);
                # skip unprobed memoized blocks (physical redo)
                probed = block_id in ctx.probed or "*" in ctx.probed
                execute = probed or not has
        self._executed[block_id] = execute
        self._t_enter[block_id] = time.perf_counter()
        return execute

    # ---------------------------------------------------------------------
    def end(self, block_id: str, state: Any) -> Any:
        """Close the block. Returns the (possibly restored) state."""
        ctx = get_context()
        key = ctx.block_key(block_id)
        executed = self._executed.pop(block_id, True)
        elapsed = time.perf_counter() - self._t_enter.pop(block_id, time.perf_counter())

        if executed:
            import jax
            state = jax.block_until_ready(state)
            ctx.controller.observe_execution(block_id, elapsed)
            if ctx.mode == "record":
                est = tree_bytes(state)
                if ctx.controller.should_materialize(block_id, est_bytes=est):
                    ctx.submit_checkpoint(block_id, key, state,
                                          meta={"epoch": ctx.current_epoch,
                                                "block": block_id})
            ctx.advance_block(block_id)
            return state

        # skipped: physical restoration from the Loop End Checkpoint (delta
        # manifests resolve transparently through the store)
        restored, restore_s = ctx.restore_checkpoint(key, like=state)
        ctx.controller.observe_restore(block_id, restore_s)
        ctx.advance_block(block_id)
        return restored


skipblock = _SkipBlockAPI()
