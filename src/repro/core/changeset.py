"""Static side-effect analysis (paper section 5.2.1, Table 1).

Estimates the changeset of a loop from its AST using the paper's six rules,
in descending precedence:

  rule 0  v1..vn = u1..um  with some vi already in the changeset -> refuse
  rule 1  v1..vn = obj.method(args)       -> {obj, v1..vn}
  rule 2  v1..vn = func(args)             -> {v1..vn}
  rule 3  v1..vn = u1..um                 -> {v1..vn}
  rule 4  obj.method(args)                -> {obj}
  rule 5  func(args)                      -> refuse (unknown side effects)

followed by loop-scoped filtering (variables first bound inside the loop are
dropped) and framework-knowledge augmentation (e.g. "an optimizer in the
changeset implies the model it optimizes changed") which runs at runtime so
isinstance checks can be used.

This is the SCRIPT tier: the functional tier's changeset is simply the
TrainState (state.py). Both tiers share the SkipBlock machinery.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass
class ChangesetResult:
    ok: bool
    changeset: list[str] = field(default_factory=list)   # ordered, deduped
    refused_reason: Optional[str] = None
    rule_trace: list[tuple[int, str]] = field(default_factory=list)
    loop_scoped: list[str] = field(default_factory=list)


def _root_name(node: ast.AST) -> Optional[str]:
    """obj.method -> 'obj'; pkg.mod.fn -> 'pkg'. None if not name-rooted."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return None


def _target_names(t: ast.AST) -> Optional[list[str]]:
    """Flatten assignment targets to plain names; None if non-name targets
    (attribute/subscript assignment -> treat root object as modified)."""
    if isinstance(t, ast.Name):
        return [t.id]
    if isinstance(t, (ast.Tuple, ast.List)):
        out = []
        for e in t.elts:
            sub = _target_names(e)
            if sub is None:
                return None
            out.extend(sub)
        return out
    return None


def analyze_loop(loop: ast.For | ast.While,
                 outer_assigned: Optional[set] = None) -> ChangesetResult:
    """Apply Table 1 to the loop body. `outer_assigned`: names bound before
    the loop in the enclosing scope (for loop-scoped filtering)."""
    changeset: list[str] = []
    bound_in_loop: set[str] = set()
    trace: list[tuple[int, str]] = []

    if isinstance(loop, ast.For):
        tn = _target_names(loop.target)
        if tn:
            bound_in_loop.update(tn)
            for n in tn:
                if n not in changeset:
                    changeset.append(n)
            trace.append((2, f"loop target {tn}"))

    def add(names):
        for n in names:
            if n not in changeset:
                changeset.append(n)

    def visit_stmt(stmt) -> Optional[str]:
        """Returns a refusal reason or None."""
        if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            if isinstance(stmt, ast.Assign):
                targets = stmt.targets
                value = stmt.value
            elif isinstance(stmt, ast.AugAssign):
                targets = [stmt.target]
                value = stmt.value
            else:
                targets = [stmt.target] if stmt.value is not None else []
                value = stmt.value
            names: list[str] = []
            for t in targets:
                tn = _target_names(t)
                if tn is None:
                    root = _root_name(t)
                    if root is None:
                        return f"unanalyzable assignment target at line {stmt.lineno}"
                    names.append(root)
                else:
                    names.extend(tn)
            # rule 0 (highest precedence): assignment to a variable already
            # in the changeset — without alias analysis the old value would
            # be missing from the Loop End Checkpoint, so refuse.
            if isinstance(stmt, ast.Assign) and any(n in changeset for n in names):
                trace.append((0, ast.unparse(stmt)))
                return (f"rule 0: reassignment of changed variable "
                        f"{[n for n in names if n in changeset]} at line "
                        f"{stmt.lineno}")
            if isinstance(value, ast.Call):
                if isinstance(value.func, ast.Attribute):
                    obj = _root_name(value.func)
                    trace.append((1, ast.unparse(stmt)))
                    add(([obj] if obj else []) + names)
                else:
                    trace.append((2, ast.unparse(stmt)))
                    add(names)
            else:
                trace.append((3, ast.unparse(stmt)))
                add(names)
            bound_in_loop.update(n for n in names
                                 if n not in (outer_assigned or set()))
            return None
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
            call = stmt.value
            if isinstance(call.func, ast.Attribute):
                obj = _root_name(call.func)
                trace.append((4, ast.unparse(stmt)))
                if obj:
                    add([obj])
                return None
            trace.append((5, ast.unparse(stmt)))
            return (f"rule 5: side-effecting call "
                    f"'{ast.unparse(call)[:40]}' at line {stmt.lineno}")
        if isinstance(stmt, (ast.If, ast.With)):
            for s in (stmt.body + getattr(stmt, "orelse", [])):
                r = visit_stmt(s)
                if r:
                    return r
            return None
        if isinstance(stmt, (ast.For, ast.While)):
            # nested loop: fold its (recursive) changeset in
            sub = analyze_loop(stmt, outer_assigned)
            if not sub.ok:
                return sub.refused_reason
            add(sub.changeset)
            return None
        if isinstance(stmt, (ast.Pass, ast.Break, ast.Continue, ast.Expr)):
            return None
        if isinstance(stmt, (ast.Import, ast.ImportFrom, ast.FunctionDef,
                             ast.Return, ast.Raise, ast.Assert, ast.Delete,
                             ast.Global, ast.Nonlocal, ast.Try)):
            return f"unsupported statement {type(stmt).__name__} at line {stmt.lineno}"
        return None

    for stmt in loop.body:
        reason = visit_stmt(stmt)
        if reason:
            return ChangesetResult(ok=False, refused_reason=reason,
                                   rule_trace=trace)

    # loop-scoped filtering: drop names first bound inside the loop
    outer = outer_assigned or set()
    loop_scoped = [n for n in changeset if n in bound_in_loop and n not in outer]
    final = [n for n in changeset if n not in loop_scoped]
    return ChangesetResult(ok=True, changeset=final, rule_trace=trace,
                           loop_scoped=loop_scoped)


def outer_assignments(module: ast.Module, before_line: int) -> set:
    """Names assigned at module scope before a given line (incl. imports and
    for-targets) — the enclosing-scope binding set for loop-scoped filtering."""
    names: set[str] = set()
    for node in module.body:
        if node.lineno >= before_line:
            continue
        if isinstance(node, ast.Assign):
            for t in node.targets:
                tn = _target_names(t)
                if tn:
                    names.update(tn)
        elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
            tn = _target_names(node.target)
            if tn:
                names.update(tn)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for a in node.names:
                names.add((a.asname or a.name).split(".")[0])
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            names.add(node.name)
        elif isinstance(node, ast.For):
            tn = _target_names(node.target)
            if tn:
                names.update(tn)
    return names


# ---------------------------------------------------------------------------
# Framework-knowledge augmentation (paper: "optimizer implies model").
# Runs at runtime on the actual objects so isinstance-style checks work.
# ---------------------------------------------------------------------------

_AUGMENTERS: list[Callable] = []


def register_augmenter(fn: Callable):
    """fn(name, obj, namespace) -> dict of extra {name: obj} implied changed."""
    _AUGMENTERS.append(fn)
    return fn


def augment_changeset(changeset: list[str], namespace: dict) -> list[str]:
    out = list(changeset)
    for name in list(changeset):
        obj = namespace.get(name)
        if obj is None:
            continue
        for aug in _AUGMENTERS:
            extra = aug(name, obj, namespace) or {}
            for n in extra:
                if n not in out:
                    out.append(n)
    return out


@register_augmenter
def _optimizer_implies_model(name, obj, namespace):
    """If an optimizer-like object is in the changeset, the parameters it
    optimizes changed too (paper's PyTorch fact (a)); likewise an LR
    scheduler implies its optimizer (fact (b))."""
    out = {}
    tracked = getattr(obj, "flor_tracks", None)
    if callable(tracked):
        for tname in tracked():
            if tname in namespace:
                out[tname] = namespace[tname]
    return out
