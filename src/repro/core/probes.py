"""Hindsight probes: which blocks must be re-executed on replay?

Two detection tiers:
  * explicit — the user passes probed={"train"} (or "*") to flor.init; the
    functional tier's normal path;
  * source diff (the paper's mechanism, section 3.2) — record stores a copy
    of the script; at replay the current file is diffed against it, each
    ADDED line is mapped to its innermost enclosing loop, and that loop's
    SkipBlock is marked probed. Deleted/changed non-logging lines are
    reported as suspicious (replay assumes only log statements were added).
"""
from __future__ import annotations

import ast
import difflib
from dataclasses import dataclass, field


@dataclass
class ProbeReport:
    probed_blocks: set = field(default_factory=set)
    added_lines: list = field(default_factory=list)      # (new_lineno, text)
    suspicious: list = field(default_factory=list)       # non-additive edits


def _loop_spans(src: str) -> list[tuple[int, int, str]]:
    """(first_line, last_line, block_id) of every for/while loop."""
    tree = ast.parse(src)
    spans = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.For, ast.While)):
            spans.append((node.lineno, node.end_lineno or node.lineno,
                          f"L{node.lineno}"))
    return spans


def detect_probes(recorded_src: str, current_src: str) -> ProbeReport:
    report = ProbeReport()
    old = recorded_src.splitlines()
    new = current_src.splitlines()
    sm = difflib.SequenceMatcher(a=old, b=new)
    added: list[tuple[int, str]] = []
    for tag, i1, i2, j1, j2 in sm.get_opcodes():
        if tag == "insert":
            for j in range(j1, j2):
                added.append((j + 1, new[j]))
        elif tag in ("replace", "delete"):
            report.suspicious.append(
                {"tag": tag, "old": old[i1:i2], "new": new[j1:j2]})
    report.added_lines = added
    if not added:
        return report

    # map added lines to enclosing loops IN THE NEW source, then translate
    # the loop back to its block id in the OLD source via line alignment
    new_spans = _loop_spans(current_src)
    # build new->old line map from matching blocks
    new_to_old = {}
    for tag, i1, i2, j1, j2 in sm.get_opcodes():
        if tag == "equal":
            for k in range(i2 - i1):
                new_to_old[j1 + k + 1] = i1 + k + 1
    for lineno, _text in added:
        enclosing = [s for s in new_spans if s[0] <= lineno <= s[1]]
        if not enclosing:
            continue
        # innermost loop = max first_line
        first, _last, _bid = max(enclosing, key=lambda s: s[0])
        old_first = new_to_old.get(first, first)
        report.probed_blocks.add(f"L{old_first}")
    return report
