"""Hindsight probes: which blocks must be re-executed on replay?

Two detection tiers:
  * explicit — the user passes probed={"train"} (or "*") to the ReplaySpec;
    the functional tier's normal path;
  * source diff (the paper's mechanism, section 3.2) — record stores a copy
    of the script; at replay the current file is diffed against it, each
    ADDED line is mapped to its innermost enclosing loop, and that loop is
    marked probed. Deleted/changed non-logging lines are reported as
    suspicious (replay assumes only log statements were added).

Loop identity: a loop whose iterator is a ``flor.loop("name", ...)`` /
``sess.loop("name", ...)`` call is identified by that NAME (shift-proof:
adding lines above it cannot change the id); any other loop falls back to
``L<lineno>`` in the RECORDED source (added lines in the new file are
translated back through the diff's line alignment).

Probes also classify by DEPTH: a line added inside a top-level (main) loop
but outside any nested loop is an OUTER probe — it needs every epoch
restore-visited but no block re-executed; a line inside a nested loop is an
INNER probe — that block re-executes logically. ``replay/plan.py`` turns
this split into exec vs restore segments.
"""
from __future__ import annotations

import ast
import difflib
from dataclasses import dataclass, field


@dataclass
class LoopSpan:
    first: int                   # first source line of the loop statement
    last: int                    # last source line of its body
    name: str | None             # flor.loop("name", ...) when named
    depth: int = 0               # 0 = top-level (main) loop

    def block_id(self, lineno: int | None = None) -> str:
        return self.name if self.name is not None \
            else f"L{lineno if lineno is not None else self.first}"


@dataclass
class ProbeReport:
    probed_blocks: set = field(default_factory=set)  # inner loops: re-execute
    probed_outer: set = field(default_factory=set)   # main loops: restore-visit
    added_lines: list = field(default_factory=list)      # (new_lineno, text)
    suspicious: list = field(default_factory=list)       # non-additive edits

    @property
    def empty(self) -> bool:
        return not (self.probed_blocks or self.probed_outer)


def _flor_loop_name(node: ast.For) -> str | None:
    """The string name of a ``*.loop("name", ...)`` / ``loop("name", ...)``
    iterator call, if the loop has one."""
    it = node.iter
    if not isinstance(it, ast.Call) or not it.args:
        return None
    fn = it.func
    called = fn.attr if isinstance(fn, ast.Attribute) else \
        fn.id if isinstance(fn, ast.Name) else None
    if called != "loop":
        return None
    first = it.args[0]
    if isinstance(first, ast.Constant) and isinstance(first.value, str):
        return first.value
    return None


def loop_spans(src: str) -> list[LoopSpan]:
    """Every for/while loop in `src` with its span, flor name (when the
    iterator is a flor.loop/sess.loop call) and nesting depth."""
    tree = ast.parse(src)
    spans: list[LoopSpan] = []

    def walk(node, depth):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.For, ast.While)):
                name = _flor_loop_name(child) \
                    if isinstance(child, ast.For) else None
                spans.append(LoopSpan(child.lineno,
                                      child.end_lineno or child.lineno,
                                      name, depth))
                walk(child, depth + 1)
            else:
                # functions/classes reset loop depth: a loop inside a helper
                # called from the main loop is not "nested" syntactically
                nd = 0 if isinstance(child, (ast.FunctionDef,
                                             ast.AsyncFunctionDef,
                                             ast.ClassDef)) else depth
                walk(child, nd)

    walk(tree, 0)
    return spans


def _loop_spans(src: str) -> list[tuple[int, int, str]]:
    """Back-compat shape: (first_line, last_line, 'L<first>')."""
    return [(s.first, s.last, f"L{s.first}") for s in loop_spans(src)]


def detect_probes(recorded_src: str, current_src: str) -> ProbeReport:
    """Diff the recorded script against the current one and map every ADDED
    line to its innermost enclosing loop. Named flor loops are reported by
    name; anonymous loops by ``L<lineno>`` in the RECORDED source. Fast
    path: identical sources (or edits with no additions) never parse."""
    report = ProbeReport()
    if recorded_src == current_src:
        return report
    old = recorded_src.splitlines()
    new = current_src.splitlines()
    sm = difflib.SequenceMatcher(a=old, b=new)
    added: list[tuple[int, str]] = []
    for tag, i1, i2, j1, j2 in sm.get_opcodes():
        if tag == "insert":
            for j in range(j1, j2):
                added.append((j + 1, new[j]))
        elif tag == "replace":
            # difflib coalesces an insertion ADJACENT to a changed line into
            # one replace block; split it by line similarity — a new line
            # with a close old counterpart is a CHANGED line (suspicious),
            # one without is an ADDED probe
            pool = list(range(i1, i2))
            for j in range(j1, j2):
                best, best_r = None, 0.0
                for i in pool:
                    r = difflib.SequenceMatcher(a=old[i], b=new[j]).ratio()
                    if r > best_r:
                        best, best_r = i, r
                if best is not None and best_r >= 0.6:
                    pool.remove(best)
                    report.suspicious.append(
                        {"tag": "replace", "old": [old[best]],
                         "new": [new[j]]})
                else:
                    added.append((j + 1, new[j]))
            for i in pool:                     # old lines with no new match
                report.suspicious.append(
                    {"tag": "delete", "old": [old[i]], "new": []})
        elif tag == "delete":
            report.suspicious.append(
                {"tag": tag, "old": old[i1:i2], "new": []})
    report.added_lines = added
    if not added:
        return report

    # map added lines to enclosing loops IN THE NEW source, then translate
    # anonymous loops back to their block id in the OLD source via line
    # alignment (named loops are shift-proof and need no translation)
    new_spans = loop_spans(current_src)
    new_to_old = {}
    for tag, i1, i2, j1, j2 in sm.get_opcodes():
        if tag == "equal":
            for k in range(i2 - i1):
                new_to_old[j1 + k + 1] = i1 + k + 1
    for lineno, _text in added:
        enclosing = [s for s in new_spans if s.first <= lineno <= s.last]
        if not enclosing:
            continue
        # innermost loop = max first_line
        inner = max(enclosing, key=lambda s: s.first)
        bid = inner.block_id(new_to_old.get(inner.first, inner.first))
        if inner.depth == 0:
            report.probed_outer.add(bid)
        else:
            report.probed_blocks.add(bid)
    return report
