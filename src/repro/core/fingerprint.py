"""Deferred correctness checks (paper section 5.2.2).

The side-effect analysis is deliberately unsafe (fast record beats strict
guarantees); instead, user-observable metrics logged during record form a
fingerprint that replay must reproduce. After replay we diff the two logs:
any divergence other than hindsight additions is flagged as an anomaly.
"""
from __future__ import annotations

import math
import os
from dataclasses import dataclass, field

from repro.core.context import FingerprintLog


@dataclass
class CheckResult:
    ok: bool
    anomalies: list = field(default_factory=list)
    compared: int = 0
    hindsight_only: int = 0


def _index(records):
    """(epoch, key, occurrence) -> value."""
    idx = {}
    counts = {}
    for r in records:
        k = (r["epoch"], r["key"])
        occ = counts.get(k, 0)
        counts[k] = occ + 1
        idx[(r["epoch"], r["key"], occ)] = r["value"]
    return idx


def _close(a, b, rtol=1e-4, atol=1e-6):
    if isinstance(a, (int, float)) and isinstance(b, (int, float)):
        if math.isnan(a) and math.isnan(b):
            return True
        return abs(a - b) <= atol + rtol * max(abs(a), abs(b))
    if isinstance(a, dict) and isinstance(b, dict) \
            and "ref" in a and "digest" in a \
            and "ref" in b and "digest" in b:
        # large-value SPILL rows (repro.logging): record and replay store
        # under different stream-derived keys by construction, so the
        # pointer can never match — fidelity means same bytes, compared by
        # content digest + structure. Requiring BOTH marker fields keeps
        # user-logged dicts that merely contain a "ref" key on the plain
        # equality path.
        return (a["digest"], a.get("dtype"), a.get("shape"),
                a.get("nbytes")) == \
               (b["digest"], b.get("dtype"), b.get("shape"),
                b.get("nbytes"))
    return a == b


def deferred_check(record_log_path: str, replay_log_paths: list,
                   replayed_epochs: list[int] | None = None,
                   rtol: float = 1e-4) -> CheckResult:
    """`replay_log_paths` entries may be file paths OR already-loaded row
    dicts — the planned-replay driver feeds the MERGED per-segment rows
    (core/query.merge_replay_logs) instead of raw per-worker files, so
    straggler duplicates and init-phase re-logs never skew occurrence
    counting."""
    rec = _index(FingerprintLog.read(record_log_path))
    rep_records = []
    for p in replay_log_paths:
        if isinstance(p, str):
            rep_records.extend(FingerprintLog.read(p))
        else:
            rep_records.append(p)
    rep = _index(rep_records)

    res = CheckResult(ok=True)
    epochs = set(replayed_epochs) if replayed_epochs is not None else None
    for k, v_rep in rep.items():
        epoch, key, occ = k
        if epochs is not None and epoch not in epochs:
            continue
        if k not in rec:
            res.hindsight_only += 1       # a hindsight probe — expected
            continue
        res.compared += 1
        if not _close(rec[k], v_rep, rtol=rtol):
            res.ok = False
            res.anomalies.append({"epoch": epoch, "key": key, "occ": occ,
                                  "record": rec[k], "replay": v_rep})
    # record entries missing from replay are anomalies only for epochs the
    # replay actually re-executed. A skipped epoch may still emit
    # hindsight-only probes (outer-loop logging over restored state), so
    # "re-executed" means: replay reproduced at least one key that the
    # record log also has for that epoch.
    rec_keys_by_epoch: dict = {}
    for (epoch, key, _occ) in rec:
        rec_keys_by_epoch.setdefault(epoch, set()).add(key)
    replay_epochs_seen = {
        k[0] for k in rep
        if k[1] in rec_keys_by_epoch.get(k[0], ())}
    for k, v_rec in rec.items():
        epoch, key, occ = k
        if epoch not in replay_epochs_seen:
            continue
        if epochs is not None and epoch not in epochs:
            continue
        if k not in rep:
            res.ok = False
            res.anomalies.append({"epoch": epoch, "key": key, "occ": occ,
                                  "record": v_rec, "replay": None})
    return res


def run_logs(run_dir: str) -> tuple[str, list[str]]:
    """(record stream, [replay streams]) of a run dir. Paths are stream
    ids — flat files or background-writer segment dirs at the same name —
    readable by ``FingerprintLog.read`` either way."""
    d = os.path.join(run_dir, "logs")
    record = os.path.join(d, "record.jsonl")
    replays = sorted(os.path.join(d, f) for f in os.listdir(d)
                     if f.startswith("replay_"))
    return record, replays
