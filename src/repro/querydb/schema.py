"""Relational schema of the incremental log index (stdlib sqlite3).

One database per store root at ``<store_root>/index/flor.db`` holds the
accumulated log records of EVERY run sharing that store — the FlorDB view
(arXiv:2408.02498): logs are a relation, queries are SQL, and the relation
is maintained incrementally as the training loop seals log segments.

Three tables:

* ``runs`` — a mirror of the ``RunRegistry`` JSON records (run_id, parent,
  namespace, run_dir, status, created_at). The lineage dimension: recursive
  CTEs over ``parent`` answer ancestor-chain queries without re-walking
  registry JSON. The mirror's freshness is judged against a directory
  signature of ``<store_root>/runs/`` stored in ``meta`` — when stale, the
  query surface falls back to scanning the JSON records.

* ``segments`` — the per-stream WATERMARKS: one row per indexed log segment
  (``seg`` is the segment number; ``-1`` is a whole flat legacy file),
  recording whether it was sealed and the byte size that was ingested. A
  (run, stream) is index-serviceable iff the segment set on disk matches
  this table exactly — same segment numbers, same sizes. An unsealed tail
  that grew, a replay re-attempt that rotated the stream, a segment never
  ingested: all surface as a mismatch, and the query transparently falls
  back to the file scan for that run.

* ``records`` — the log rows themselves. ``value_json`` is the JSON text of
  the row's value (round-trips bit-identically through ``json.loads``);
  ``spill_ref``/``spill_digest`` are lifted out of large-value pointer rows
  so spill-aware queries can reason about spilled bytes in SQL without
  parsing values. ``step`` is reserved for sub-epoch row addressing (serve
  tier); today's rows carry only ``epoch``/``seq``. Row order within a
  (run, source) is ``(seg, rowid)`` — segments are ingested whole, in file
  order, inside one transaction, so rowid order within a segment is file
  order and the index reproduces the file scan's row order exactly.

Crash safety is transactional: a segment's rows and its watermark commit in
the SAME transaction (WAL journal), so a torn ingest is invisible — the
watermark is absent, the segment re-ingests next time, and until then the
file-scan fallback serves the truth.
"""
from __future__ import annotations

import os
import sqlite3

SCHEMA_VERSION = 1

# a whole flat (legacy, sync-mode) log file indexed as one pseudo-segment
FLAT_SEG = -1

DDL = """
CREATE TABLE IF NOT EXISTS meta(
  k TEXT PRIMARY KEY,
  v TEXT
);
CREATE TABLE IF NOT EXISTS runs(
  run_id     TEXT PRIMARY KEY,
  parent     TEXT,
  namespace  TEXT,
  run_dir    TEXT,
  status     TEXT,
  created_at REAL
);
CREATE TABLE IF NOT EXISTS segments(
  run_id TEXT NOT NULL,
  stream TEXT NOT NULL,
  seg    INTEGER NOT NULL,
  sealed INTEGER NOT NULL,
  size   INTEGER NOT NULL,
  rows   INTEGER NOT NULL,
  first_seq INTEGER,
  last_seq  INTEGER,
  PRIMARY KEY (run_id, stream, seg)
);
CREATE TABLE IF NOT EXISTS records(
  run_id TEXT NOT NULL,
  source TEXT NOT NULL,
  seg    INTEGER NOT NULL,
  seq    INTEGER,
  epoch  INTEGER,
  step   INTEGER,
  key    TEXT,
  value_json   TEXT NOT NULL,
  spill_ref    TEXT,
  spill_digest TEXT
);
CREATE INDEX IF NOT EXISTS ix_records_run ON records(run_id, source, seg);
CREATE INDEX IF NOT EXISTS ix_records_key ON records(key, run_id);
"""


def connect(db_path: str, create: bool = False) -> sqlite3.Connection:
    """Open (optionally creating) the index database: WAL mode so one
    background writer and any number of query readers coexist without
    blocking each other, NORMAL sync (the index is a cache over the
    segment files — it may lose the last instants before a crash, the
    fallback path covers the gap), and a busy timeout so two runs sealing
    into one shared store serialize instead of erroring.

    ``check_same_thread=False``: the seal hook ingests from the background
    log stage while ``close()``-time seals ingest from the finishing
    thread; the two are serialized by the stage lifecycle (close drains
    the stage first), never concurrent."""
    if create:
        os.makedirs(os.path.dirname(db_path), exist_ok=True)
    elif not os.path.exists(db_path):
        raise FileNotFoundError(db_path)
    conn = sqlite3.connect(db_path, timeout=30.0, check_same_thread=False)
    conn.execute("PRAGMA journal_mode=WAL")
    conn.execute("PRAGMA synchronous=NORMAL")
    conn.executescript(DDL)
    cur = conn.execute("SELECT v FROM meta WHERE k='schema_version'")
    row = cur.fetchone()
    if row is None:
        with conn:
            conn.execute("INSERT OR REPLACE INTO meta(k, v) VALUES "
                         "('schema_version', ?)", (str(SCHEMA_VERSION),))
    elif int(row[0]) != SCHEMA_VERSION:
        # a future schema we don't understand: refuse — the caller degrades
        # to the file-scan path rather than misreading a newer layout
        conn.close()
        raise RuntimeError(f"query index schema v{row[0]} != "
                           f"v{SCHEMA_VERSION} at {db_path}")
    return conn
