"""FlorDB-style incremental query engine: an indexed, incrementally-
maintained sqlite mirror of every run's fingerprint logs, living at
``<store_root>/index/flor.db`` behind the ``log_records``/``pivot`` query
surface. See ``docs/queries.md`` for the schema, the watermark/freshness
rules, and the bit-identity contract with the file-scan path."""
from repro.querydb.index import (LogIndex, ensure_index, index_path,
                                 open_index)
from repro.querydb.maintain import SegmentIndexer, reindex
from repro.querydb.schema import FLAT_SEG, SCHEMA_VERSION

__all__ = ["LogIndex", "index_path", "open_index", "ensure_index",
           "SegmentIndexer", "reindex", "FLAT_SEG", "SCHEMA_VERSION"]
