"""Incremental maintenance of the log index.

Two feeders keep ``<store_root>/index/flor.db`` current:

* :class:`SegmentIndexer` — the LIVE feeder. FlorContext hands its
  ``on_seal`` to the run's :class:`~repro.logging.stream.FingerprintLog`;
  the background log stage (or the closing thread) calls it the moment a
  segment seals, and the segment's rows land in sqlite while the training
  loop keeps stepping. Ingest wall time is reported to ``on_overhead``
  (``AdaptiveController.observe_logging``), so index upkeep draws from the
  same epsilon budget as the logging work it rides behind. Every failure
  degrades silently: the index is a cache, the segment files are the truth,
  and a broken index must never break training.

* :func:`reindex` — the CATCH-UP feeder. Walks every registered run's log
  streams and ingests exactly what the watermarks say is missing: sealed
  segments never seen, unsealed tails / flat files whose byte size moved,
  watermarks whose segment vanished from disk (a rotated replay stream, a
  gc'd run). Runs that logged with ``log_index=False``, crashed mid-run, or
  predate the index all become index-serviceable here.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

from repro.logging.segment import _seal_of, list_segments
from repro.querydb.index import LogIndex, ensure_index, open_index
from repro.querydb.schema import FLAT_SEG

import os


class SegmentIndexer:
    """Per-(run, stream) seal hook bound to one store root's index.

    Construction is cheap and safe: the sqlite handle opens lazily on the
    first seal, and any error anywhere permanently disables the hook for
    this instance (``self.dead``) — subsequent seals cost one attribute
    check. ``finish(registry)`` runs at context close to sync the full runs
    mirror + directory signature, making the whole store's runs listing
    index-serviceable."""

    def __init__(self, store_root: str, run_id: str, stream: str,
                 registry=None, on_overhead: Optional[Callable] = None,
                 staging=None):
        self.store_root = store_root
        self.run_id = run_id
        self.stream = stream
        self.registry = registry
        self.on_overhead = on_overhead
        # multi-process record: ``staging`` labels a PER-PROCESS database
        # (<root>/index/staging/p<label>.db) this indexer ingests into —
        # concurrent recorders never contend on the shared flor.db; each
        # process absorbs its own staging file into the main index at
        # finish(), and `reindex` sweeps leftovers of crashed processes
        self.staging = staging
        self.dead = False
        self._idx: Optional[LogIndex] = None
        self._seeded = False

    def _index(self) -> LogIndex:
        if self._idx is None:
            if self.staging is not None:
                from repro.querydb.index import staging_path
                sp = staging_path(self.store_root, self.staging)
                self._idx = LogIndex(self.store_root, create=True,
                                     db_path=sp)
                _write_alive_marker(sp)
            else:
                self._idx = ensure_index(self.store_root)
        return self._idx

    def _seed_run(self, idx: LogIndex):
        """Mirror this run's registry record on first contact so lineage
        queries see the row even before the close-time full sync."""
        if self._seeded:
            return
        self._seeded = True
        if self.registry is not None:
            rec = self.registry.get(self.run_id)
            if rec:
                idx.upsert_run(rec)

    # ------------------------------------------------------------- hooks --
    def on_seal(self, seg_path: str, seg_no: int, footer: dict):
        """SegmentSink seal callback — fires on the sealing thread, never on
        the training step path. All-exception barrier: a failure here marks
        the hook dead and the run simply stays file-scan-served."""
        if self.dead:
            return
        t0 = time.perf_counter()
        try:
            idx = self._index()
            self._seed_run(idx)
            idx.ingest_segment(self.run_id, self.stream, seg_no,
                               seg_path, sealed=True)
        except Exception:
            self.dead = True
            return
        if self.on_overhead:
            self.on_overhead(time.perf_counter() - t0, 0)

    def invalidate(self):
        """Drop everything indexed for this stream — called before a replay
        attempt rotates (truncates) it, so rows of the previous attempt can
        never be served as current."""
        if self.dead:
            return
        try:
            self._index().invalidate_stream(self.run_id, self.stream)
        except Exception:
            self.dead = True

    def finish(self, registry=None):
        """Close-time sync: merge this process's staging database into the
        main index (multi-process record), mirror the full registry listing
        (the run's own record now carries final status/keys) and stamp the
        directory signature, then release the handle. Best-effort, like
        every other path into the index."""
        registry = registry or self.registry
        try:
            if self.staging is not None:
                # release the staging handle first (WAL checkpoint), then
                # absorb into the main db — sqlite's busy timeout serializes
                # sibling processes merging concurrently. The staging file
                # is deleted only after the absorb transaction committed.
                if self._idx is not None:
                    self._idx.close()
                    self._idx = None
                from repro.querydb.index import ensure_index, staging_path
                sp = staging_path(self.store_root, self.staging)
                if not self.dead and os.path.exists(sp):
                    main = ensure_index(self.store_root)
                    try:
                        main.absorb(sp)
                        _remove_db(sp)
                    finally:
                        main.close()
            if not self.dead and registry is not None:
                from repro.checkpoint.lineage import registry_dirsig
                from repro.querydb.index import ensure_index
                idx = ensure_index(self.store_root) if self.staging \
                    is not None else self._index()
                try:
                    sig = registry_dirsig(self.store_root)
                    idx.set_runs(registry.list_runs(), sig)
                finally:
                    if self.staging is not None:
                        idx.close()
        except Exception:
            self.dead = True
        finally:
            if self._idx is not None:
                self._idx.close()
                self._idx = None


def _remove_db(db_path: str):
    """Delete a sqlite database, its WAL sidecar files, and the alive
    marker the staging path hangs next to it."""
    for suffix in ("", "-wal", "-shm", "-journal", ".alive"):
        try:
            os.remove(db_path + suffix)
        except OSError:
            pass


def _write_alive_marker(db_path: str):
    """Stamp ``<db>.alive`` with this process's identity (atomic rename,
    so a concurrent sweep never reads a torn marker). The sweep uses it to
    tell a LIVE recorder's staging db from a crashed process's leftover —
    deleting a live one would orphan every row the recorder seals after
    the sweep (it keeps writing to the unlinked inode, and its finish()
    absorb finds no file)."""
    import json
    import socket
    from repro.checkpoint.store import _atomic_write
    _atomic_write(db_path + ".alive",
                  json.dumps({"pid": os.getpid(),
                              "host": socket.gethostname()}).encode())


_FOREIGN_LIVE_WINDOW_S = 600.0


def _staging_live(db_path: str) -> bool:
    """Whether the process that owns this staging db still looks alive. No
    marker means no live owner: a SegmentIndexer stamps the marker the
    moment it creates the db, so an unmarked file is a pre-marker layout
    or test fixture — sweepable either way. A marker from THIS host is
    checked against the pid; one from another host (shared store) cannot
    be probed, so the db counts as live while it moved recently."""
    import json
    import socket
    try:
        with open(db_path + ".alive", "rb") as f:
            mark = json.loads(f.read())
    except (OSError, ValueError):
        return False
    if mark.get("host") == socket.gethostname():
        try:
            pid = int(mark.get("pid") or 0)
        except (TypeError, ValueError):
            return False
        if pid <= 0:
            return False
        try:
            os.kill(pid, 0)
        except OSError:
            return False
        return True
    newest = 0.0
    for suffix in ("", "-wal", "-shm", ".alive"):
        try:
            newest = max(newest, os.path.getmtime(db_path + suffix))
        except OSError:
            pass
    return time.time() - newest < _FOREIGN_LIVE_WINDOW_S


def sweep_staging(store_root: str, idx: LogIndex) -> int:
    """Absorb (then delete) leftover per-process staging databases — the
    residue of record processes that crashed between sealing segments and
    merging at finish. Absorbing (rather than just deleting) keeps streams
    the file walk cannot enumerate (non-lead record_p<N> debug streams);
    anything else the walk re-ingests from the segment files anyway.

    A staging db whose owner is still alive (``_staging_live``) is left
    untouched: a reindex racing an in-flight distributed record must not
    delete a database another process is mid-write on — its rows merge at
    that process's own finish()."""
    sdir = os.path.join(store_root, "index", "staging")
    swept = 0
    try:
        names = sorted(os.listdir(sdir))
    except OSError:
        return 0
    for fn in names:
        if not fn.endswith(".db"):
            continue
        sp = os.path.join(sdir, fn)
        if _staging_live(sp):
            continue
        try:
            idx.absorb(sp)
        except Exception:
            pass          # torn staging db from a crash: drop it regardless
        _remove_db(sp)
        swept += 1
    return swept


def reindex(path: str) -> dict:
    """Bring ``path``'s index fully up to date and return ingestion stats.

    ``path`` is anything the query surface accepts (store root, bound run
    dir, legacy run dir). Only work the watermarks prove necessary is done:
    a segment whose (number, size, sealed) watermark already matches disk is
    skipped without opening it. Crash-safe by construction — each segment's
    rows and watermark commit in one transaction, so an interrupted reindex
    leaves a consistent prefix and the next call resumes past it."""
    from repro.checkpoint.lineage import registry_dirsig
    from repro.core.query import (_registered_runs, _run_log_files,
                                  resolve_store_root)
    root = resolve_store_root(path)
    # signature BEFORE the listing: a racing registration makes the mirror
    # stale (harmless), never fresh-but-incomplete
    sig = registry_dirsig(root)
    listing = _registered_runs(path)
    idx = ensure_index(root)
    stats = {"runs": len(listing), "segments_ingested": 0,
             "segments_skipped": 0, "segments_pruned": 0, "rows": 0}
    try:
        stats["staging_swept"] = sweep_staging(root, idx)
        idx.set_runs(listing, sig)
        for rec in listing:
            rid = rec.get("run_id")
            streams = _run_log_files(rec.get("run_dir"), include_replay=True)
            # a stream deleted wholesale (a cleaned-up replay log, a pruned
            # run dir) is invisible to the disk enumeration below — drop its
            # lingering watermarks and rows outright
            on_disk = {source for source, _sp in streams}
            for (stream,) in idx.conn.execute(
                    "SELECT DISTINCT stream FROM segments WHERE run_id=?",
                    (rid,)).fetchall():
                if stream not in on_disk:
                    n_gone = len(idx.stream_segments(rid, stream))
                    idx.invalidate_stream(rid, stream)
                    stats["segments_pruned"] += n_gone
            for source, sp in streams:
                marks = idx.stream_segments(rid, source)
                disk: dict[int, tuple[str, int, bool]] = {}
                if os.path.isdir(sp):
                    for n, seg_path in list_segments(sp):
                        try:
                            size = os.path.getsize(seg_path)
                        except OSError:
                            continue
                        sealed = _seal_of(seg_path) is not None
                        disk[n] = (seg_path, size, sealed)
                elif os.path.exists(sp):
                    # flat legacy file: one pseudo-segment, size-watermarked
                    disk[FLAT_SEG] = (sp, os.path.getsize(sp), False)
                for n, (seg_path, size, sealed) in sorted(disk.items()):
                    if marks.get(n) == size:
                        stats["segments_skipped"] += 1
                        continue
                    stats["rows"] += idx.ingest_segment(
                        rid, source, n, seg_path, sealed=sealed)
                    stats["segments_ingested"] += 1
                gone = set(marks) - set(disk)
                if gone:
                    idx.prune_segments(rid, source, disk.keys())
                    stats["segments_pruned"] += len(gone)
        stats.update(idx.stats())
    finally:
        idx.close()
    return stats


__all__ = ["SegmentIndexer", "reindex", "sweep_staging", "open_index",
           "ensure_index"]
