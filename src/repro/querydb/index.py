"""LogIndex: the sqlite-backed store behind ``log_records``/``pivot``.

This is the storage half of the query engine. It knows how to ingest one
sealed (or snapshot-watermarked) log segment transactionally, how to judge
whether it can SERVE a run's streams (watermark check against the files on
disk), and how to answer the row queries the surface needs — including the
lineage dimension via a recursive CTE over ``runs``.

Correctness contract: a query served from here is bit-identical to the
file-scan path. That holds because (a) ingestion parses segment text
through the very same ``repro.logging.segment.parse_text`` the scan uses,
(b) values round-trip as JSON text, (c) row order is reproduced as
``(seg, rowid)`` per stream, and (d) ``covers`` refuses to serve any run
whose on-disk segments don't exactly match the ingested watermarks — the
caller then falls back to scanning files for that run.
"""
from __future__ import annotations

import json
import os
from typing import Iterable, Optional

from repro.logging.segment import list_segments, parse_text
from repro.querydb.schema import FLAT_SEG, connect

# columns a WHERE filter may push down into SQL; run_id/parent_run/source
# are per-stream constants and are tested in Python before the SELECT
SQL_WHERE_COLS = ("epoch", "seq", "key", "step")


def index_path(store_root: str) -> str:
    return os.path.join(store_root, "index", "flor.db")


def staging_path(store_root: str, label) -> str:
    """Per-process staging database for multi-process record: process
    ``label`` ingests its sealed segments here (zero contention on the
    shared ``flor.db``) and ``absorb``-s the file into the main index at
    finish. A crashed process's leftover is swept by ``reindex``."""
    return os.path.join(store_root, "index", "staging", f"p{label}.db")


def spill_fields(value) -> tuple[Optional[str], Optional[str]]:
    """(spill_ref, spill_digest) of a large-value pointer row written by the
    background log's spill path (``{"ref": "logref__<stream>__<seq>",
    dtype, shape, nbytes, digest}``), (None, None) for ordinary values."""
    if (isinstance(value, dict)
            and str(value.get("ref", "")).startswith("logref__")
            and "nbytes" in value):
        return str(value["ref"]), value.get("digest")
    return None, None


class LogIndex:
    """Handle on one store root's index database.

    Writers (the seal hook, ``reindex``) and readers (the query surface)
    hold separate handles; WAL keeps them from blocking each other. Every
    write method is transactional — rows and their watermark commit
    atomically."""

    def __init__(self, store_root: str, create: bool = False,
                 db_path: Optional[str] = None):
        self.store_root = store_root
        # db_path overrides the default <root>/index/flor.db — the staging
        # databases of multi-process record use the same schema + methods
        self.path = db_path or index_path(store_root)
        self.conn = connect(self.path, create=create)

    def close(self):
        try:
            self.conn.close()
        except Exception:
            pass

    # ------------------------------------------------------------ ingest --
    def ingest_segment(self, run_id: str, stream: str, seg: int,
                       seg_path: str, sealed: bool) -> int:
        """Index one segment file (or, with ``seg=FLAT_SEG``, one whole flat
        legacy file). The file's bytes are snapshotted FIRST and the byte
        count becomes the watermark, so rows appended after the snapshot
        make the watermark stale rather than silently missing — ``covers``
        then routes the run to the file scan until a re-ingest catches up.
        Delete + insert + watermark are one transaction: a crash mid-ingest
        leaves the previous consistent state."""
        with open(seg_path, "rb") as f:
            data = f.read()
        rows = parse_text(data.decode("utf-8", errors="replace"), seg_path)
        seqs = [r["seq"] for r in rows
                if isinstance(r.get("seq"), int)]
        params = []
        for r in rows:
            value = r.get("value")
            ref, digest = spill_fields(value)
            params.append((run_id, stream, int(seg), r.get("seq"),
                           r.get("epoch"), r.get("step"), r.get("key"),
                           json.dumps(value), ref, digest))
        with self.conn:
            self.conn.execute(
                "DELETE FROM records WHERE run_id=? AND source=? AND seg=?",
                (run_id, stream, int(seg)))
            self.conn.executemany(
                "INSERT INTO records(run_id, source, seg, seq, epoch, step, "
                "key, value_json, spill_ref, spill_digest) "
                "VALUES (?,?,?,?,?,?,?,?,?,?)", params)
            self.conn.execute(
                "INSERT OR REPLACE INTO segments(run_id, stream, seg, "
                "sealed, size, rows, first_seq, last_seq) "
                "VALUES (?,?,?,?,?,?,?,?)",
                (run_id, stream, int(seg), int(bool(sealed)), len(data),
                 len(rows), min(seqs) if seqs else None,
                 max(seqs) if seqs else None))
        return len(rows)

    def invalidate_stream(self, run_id: str, stream: str):
        """Drop a stream's rows AND watermarks — a replay re-attempt rotated
        (truncated) the stream, so everything indexed for it is stale."""
        with self.conn:
            self.conn.execute(
                "DELETE FROM records WHERE run_id=? AND source=?",
                (run_id, stream))
            self.conn.execute(
                "DELETE FROM segments WHERE run_id=? AND stream=?",
                (run_id, stream))

    def prune_segments(self, run_id: str, stream: str,
                       keep_segs: Iterable[int]):
        """Drop indexed segments that no longer exist on disk (a truncated
        replay stream indexed by a previous attempt, a gc'd run dir)."""
        keep = {int(s) for s in keep_segs}
        rows = self.conn.execute(
            "SELECT seg FROM segments WHERE run_id=? AND stream=?",
            (run_id, stream)).fetchall()
        stale = [s for (s,) in rows if s not in keep]
        if not stale:
            return
        with self.conn:
            for s in stale:
                self.conn.execute(
                    "DELETE FROM records WHERE run_id=? AND source=? "
                    "AND seg=?", (run_id, stream, s))
                self.conn.execute(
                    "DELETE FROM segments WHERE run_id=? AND stream=? "
                    "AND seg=?", (run_id, stream, s))

    def absorb(self, other_path: str) -> int:
        """Merge a staging database (same schema) into this index: for each
        (run, stream, segment) the staging db ingested, replace this db's
        rows and watermark with the staged ones — the exact DELETE+INSERT
        a direct ingest performs, so a merged index is engine-identical to
        one that ingested the segments itself. Rows copy ordered by
        (source, seg, rowid): per-stream file order is preserved under
        fresh rowids, which is all ``select_rows``' (seg, rowid) ordering
        needs. Rows + watermarks commit in ONE transaction — a crash
        mid-merge leaves the main index at its previous consistent state
        and the staging file intact for the next sweep."""
        if not os.path.exists(other_path):
            return 0
        self.conn.execute("ATTACH DATABASE ? AS stg", (other_path,))
        try:
            segs = self.conn.execute(
                "SELECT run_id, stream, seg FROM stg.segments").fetchall()
            with self.conn:
                for rid, stream, seg in segs:
                    self.conn.execute(
                        "DELETE FROM records WHERE run_id=? AND source=? "
                        "AND seg=?", (rid, stream, seg))
                self.conn.execute(
                    "INSERT INTO records(run_id, source, seg, seq, epoch, "
                    "step, key, value_json, spill_ref, spill_digest) "
                    "SELECT run_id, source, seg, seq, epoch, step, key, "
                    "value_json, spill_ref, spill_digest FROM stg.records "
                    "ORDER BY source, seg, rowid")
                self.conn.execute(
                    "INSERT OR REPLACE INTO segments "
                    "SELECT * FROM stg.segments")
                # staged run rows only fill gaps: the main mirror's rows
                # (possibly already finalized via set_runs) stay as-is
                self.conn.execute(
                    "INSERT OR IGNORE INTO runs SELECT * FROM stg.runs")
            return len(segs)
        finally:
            try:
                self.conn.execute("DETACH DATABASE stg")
            except Exception:
                pass

    # -------------------------------------------------------------- runs --
    def upsert_run(self, rec: dict):
        """Mirror one registry record (the seal hook keeps its OWN run row
        current without paying a full registry sync per seal). Does NOT
        update the listing signature: the full-listing mirror only becomes
        authoritative through ``set_runs``."""
        with self.conn:
            self.conn.execute(
                "INSERT OR REPLACE INTO runs(run_id, parent, namespace, "
                "run_dir, status, created_at) VALUES (?,?,?,?,?,?)",
                (rec.get("run_id"), rec.get("parent"), rec.get("namespace"),
                 rec.get("run_dir"), rec.get("status"),
                 rec.get("created_at")))

    def set_runs(self, listing: list[dict], dirsig):
        """Replace the runs mirror with a full registry listing and stamp
        the registry-directory signature it was read under. The signature
        was captured BEFORE the listing was read, so a registration racing
        the sync can only make the mirror look stale (safe), never fresh
        with missing rows. ``dirsig=None`` (no registry directory) stores
        an unmatchable sentinel: pseudo-run listings are never routed
        through the mirror."""
        with self.conn:
            self.conn.execute("DELETE FROM runs")
            self.conn.executemany(
                "INSERT OR REPLACE INTO runs(run_id, parent, namespace, "
                "run_dir, status, created_at) VALUES (?,?,?,?,?,?)",
                [(r.get("run_id"), r.get("parent"), r.get("namespace"),
                  r.get("run_dir"), r.get("status"), r.get("created_at"))
                 for r in listing])
            self.conn.execute(
                "INSERT OR REPLACE INTO meta(k, v) VALUES ('runs_dirsig', ?)",
                (json.dumps(dirsig) if dirsig is not None else "unsynced",))

    def runs_listing(self, dirsig) -> Optional[list[dict]]:
        """The mirrored registry listing in registry order — or None when
        the stored signature doesn't match ``dirsig`` (registrations,
        removals, or finalizations happened since the last sync; the caller
        then scans the JSON records instead)."""
        if dirsig is None:
            return None
        row = self.conn.execute(
            "SELECT v FROM meta WHERE k='runs_dirsig'").fetchone()
        if row is None or row[0] != json.dumps(dirsig):
            return None
        out = []
        for rid, parent, ns, rdir, status, created in self.conn.execute(
                "SELECT run_id, parent, namespace, run_dir, status, "
                "created_at FROM runs "
                "ORDER BY COALESCE(created_at, 0), COALESCE(run_id, '')"):
            out.append({"run_id": rid, "parent": parent, "namespace": ns,
                        "run_dir": rdir, "status": status,
                        "created_at": created})
        return out

    def ancestry_ids(self, run_id: str) -> set:
        """Run ids on ``run_id``'s ancestor chain (itself included when
        mirrored), via a recursive CTE over the runs mirror — the indexed
        replacement for walking registry JSON parent links."""
        rows = self.conn.execute(
            "WITH RECURSIVE anc(run_id) AS ("
            "  SELECT :r "
            "  UNION "
            "  SELECT runs.parent FROM runs "
            "  JOIN anc ON runs.run_id = anc.run_id "
            "  WHERE runs.parent IS NOT NULL) "
            "SELECT run_id FROM anc", {"r": run_id}).fetchall()
        return {r for (r,) in rows}

    # --------------------------------------------------------- freshness --
    def stream_segments(self, run_id: str, stream: str) -> dict[int, int]:
        """{seg: ingested byte size} for one stream's watermarks."""
        return {seg: size for seg, size in self.conn.execute(
            "SELECT seg, size FROM segments WHERE run_id=? AND stream=?",
            (run_id, stream))}

    def covers(self, run_id: str, streams: list[tuple[str, str]]) -> bool:
        """Whether the index can serve ``streams`` (the ``(source, path)``
        list the file scan would read for this run) bit-identically: every
        stream's on-disk segment set must match the ingested watermarks
        EXACTLY — same segment numbers, same byte sizes. Growth of an
        unsealed tail, a rotated replay stream, an un-ingested segment, or
        a lingering watermark for a deleted segment all fail the check and
        route the run to the file scan. Cost is a listdir + stat per
        segment; no file contents are read."""
        for source, path in streams:
            disk: dict[int, int] = {}
            if os.path.isdir(path):
                for n, sp in list_segments(path):
                    try:
                        disk[n] = os.path.getsize(sp)
                    except OSError:
                        return False
            elif os.path.exists(path):
                try:
                    disk[FLAT_SEG] = os.path.getsize(path)
                except OSError:
                    return False
            if disk != self.stream_segments(run_id, source):
                return False
        return True

    # ------------------------------------------------------------- query --
    def select_rows(self, run_id: str, parent_run, source: str,
                    keys: Optional[tuple] = None,
                    where: Optional[dict] = None,
                    limit: Optional[int] = None) -> list[dict]:
        """One stream's rows as query-surface dicts, in file order. ``keys``
        and the SQL-safe ``where`` columns are pushed into the SELECT;
        ``limit`` bounds the scan when the caller may stop early."""
        sql = ["SELECT epoch, seq, key, value_json FROM records "
               "WHERE run_id=? AND source=?"]
        args: list = [run_id, source]
        if keys:
            sql.append(f"AND key IN ({','.join('?' * len(keys))})")
            args.extend(keys)
        for col, val in (where or {}).items():
            if col not in SQL_WHERE_COLS:
                continue                 # non-pushable: caller post-filters
            if val is None:
                sql.append(f"AND {col} IS NULL")
            else:
                sql.append(f"AND {col}=?")
                args.append(val)
        sql.append("ORDER BY seg, rowid")
        if limit is not None:
            sql.append("LIMIT ?")
            args.append(int(limit))
        out = []
        for epoch, seq, key, vj in self.conn.execute(" ".join(sql), args):
            out.append({"run_id": run_id, "parent_run": parent_run,
                        "source": source, "epoch": epoch, "seq": seq,
                        "key": key, "value": json.loads(vj)})
        return out

    def stats(self) -> dict:
        """Row/segment/run counts — `runs reindex` and tests report these."""
        one = lambda q: self.conn.execute(q).fetchone()[0]  # noqa: E731
        return {"runs": one("SELECT COUNT(*) FROM runs"),
                "segments": one("SELECT COUNT(*) FROM segments"),
                "records": one("SELECT COUNT(*) FROM records"),
                "spilled": one("SELECT COUNT(*) FROM records "
                               "WHERE spill_ref IS NOT NULL")}


def open_index(store_root: str) -> Optional[LogIndex]:
    """The store's index handle, or None when no index exists (or it is
    unreadable / a future schema) — callers treat None as 'file-scan'."""
    try:
        return LogIndex(store_root)
    except (FileNotFoundError, RuntimeError, OSError):
        return None
    except Exception:
        return None


def ensure_index(store_root: str) -> LogIndex:
    """Open the store's index, creating the database on first use."""
    return LogIndex(store_root, create=True)
