from repro.serve.step import build_prefill_step, build_decode_step  # noqa: F401
