"""serve_step builders: prefill and single-token decode (jit-able, pure).

``decode_step`` consumes and re-emits the KV/SSM caches; the dry-run lowers
it with cache ShapeDtypeStructs to prove the serving path shards on the
production mesh (SWA ring caches and SSM O(1) states are what make the
long_500k cells feasible)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import build_model


def build_prefill_step(cfg, max_len: int):
    model = build_model(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len)

    return prefill_step


def build_decode_step(cfg):
    model = build_model(cfg)

    def decode_step(params, caches, tokens, pos):
        logits, new_caches = model.decode(params, caches, tokens, pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, logits, new_caches

    return decode_step


def greedy_generate(cfg, params, prompt_batch, steps: int, max_len: int):
    """Tiny driver: prefill a prompt then greedy-decode `steps` tokens.
    Used by examples and smoke tests (not the dry-run)."""
    model = build_model(cfg)
    prefill = jax.jit(build_prefill_step(cfg, max_len))
    decode = jax.jit(build_decode_step(cfg))
    caches, logits = prefill(params, prompt_batch)
    if cfg.family == "audio":
        start = prompt_batch["dec_tokens"].shape[1]
        B = prompt_batch["dec_tokens"].shape[0]
    elif cfg.family == "vlm":
        start = prompt_batch["tokens"].shape[1] + cfg.frontend_tokens
        B = prompt_batch["tokens"].shape[0]
    else:
        start = prompt_batch["tokens"].shape[1]
        B = prompt_batch["tokens"].shape[0]
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    for i in range(steps - 1):
        tok, _, caches = decode(params, caches, tok,
                                jnp.asarray(start + i, jnp.int32))
        out.append(tok)
    return jnp.concatenate(out, axis=1)
