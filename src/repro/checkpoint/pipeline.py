"""CheckpointPipeline: the delta-aware record-side checkpoint flow.

The paper's "lean checkpointing" thesis is that checkpoint cost should track
what CHANGED, not model size. This layer wires the device-side Pallas
fingerprint path end-to-end so the record path does, in order:

1. **Fingerprint on device** — per leaf, `DeltaTracker` runs the Pallas
   chunk-fingerprint kernel (one read of the leaf at HBM bandwidth) and
   diffs against the digests of the last materialized checkpoint. Digests
   never leave the device; only the [G] change mask and the changed rows do.
2. **Transfer only changed chunks** — the u32 block rows whose digest moved
   are gathered and DMA'd to host (`kernels.ops.gather_blocks`). On a
   frozen-majority workload the device->host traffic drops by the frozen
   fraction — `transferred_bytes` in the per-checkpoint stats is this real
   DMA payload (native-byte accounting), the honest M_i input for the
   adaptive controller's ε-overhead model.
3. **Write stage** (`AsyncWriter` job, FIFO on the writer thread) — hash the
   changed chunks (blake2b-16), store them content-addressed, and emit a
   **delta manifest**.

Delta manifest format (store manifest v2)::

    {
      "key": str, "version": 2,
      "kind": "full" | "delta",
      "parent": str | null,          # delta only: previous checkpoint key
      "treedef": str,
      "chunk_words": int,            # fingerprint chunk size in u32 words
      "meta": {...},
      "leaves": [{
         "path": str, "dtype": str, "shape": [int], "nbytes": int,
         "n_chunks": int,
         "chunks": [hash, ...],      # kind == "full": complete ordered list
         "delta": {"<idx>": hash},   # kind == "delta": changed indices only
      }, ...],
    }

A delta manifest inherits every unlisted chunk hash from its parent chain
(`CheckpointStore.resolve_manifest`). Chains are bounded: a FULL manifest is
written (a) for the first checkpoint of a scope, (b) every `full_every`
checkpoints, and (c) whenever the leaf structure changes (leaf added or
removed, dtype or shape changed) — so restore never chases unbounded
history and structure changes never alias stale chunks. A leaf whose chunk
size in native bytes is `chunk_words * native_bytes_per_word(dtype)`; the
final chunk is truncated to the leaf's `nbytes`, so restored bytes
concatenate exactly.

Scopes: checkpoints of different SkipBlocks pass distinct `scope` ids, so
each block keeps its own digest state, parent chain and full-manifest
cadence — interleaved blocks never diff against each other's trees.

Cross-run warm start (run lineage): ``warm_start(scope, parent_key,
manifest, tree)`` seeds a scope's state from an ANCESTOR RUN's final
resolved manifest in a shared store — per-leaf structure signatures, the
writer-side full chunk-hash lists, and the device-side digests (rehydrated
by running the Pallas fingerprint path over the restored tree, the same one
read submit() would pay). The scope's parent key is set to the ancestor's
QUALIFIED key (``"<run_id>::<key>"``), so the FIRST checkpoint of a derived
run is already a delta manifest chained across the run boundary: a
fine-tune of a 96%-frozen model transfers and stores ~4% on its very first
checkpoint instead of re-recording the model. `CheckpointStore` resolves
the qualified parent chain transparently; `store.gc` retains it (see
checkpoint/lineage.py for the registry that decides which runs are live).
"""
from __future__ import annotations

import time
from typing import Any, Optional

import numpy as np

from repro.checkpoint.async_writer import AsyncWriter
from repro.checkpoint.delta import DeltaTracker, blocks_to_native_bytes
from repro.kernels.ops import native_bytes_per_word

DEFAULT_FULL_EVERY = 8
# storage/fingerprint granularity: 16384 u32 words = 64 KiB chunks for
# 4-byte dtypes. Finer chunks transfer marginally less but cost one object
# FILE per chunk — at 4 KiB the filesystem round-trips dominate the write
# stage. 64 KiB keeps a [8, 16384] u32 fingerprint tile at 512 KiB of VMEM.
PIPELINE_CHUNK_WORDS = 16 * 1024


class CheckpointPipeline:
    def __init__(self, store, *, chunk_words: int = PIPELINE_CHUNK_WORDS,
                 full_every: int = DEFAULT_FULL_EVERY,
                 async_stage: bool = True, max_queue: int = 2,
                 on_materialized=None):
        self.store = store
        self.chunk_words = chunk_words
        self.full_every = max(1, int(full_every))
        self.tracker = DeltaTracker(chunk_words)
        self._on_mat = on_materialized
        self.writer = AsyncWriter(store, max_queue=max_queue,
                                  on_materialized=self._materialized) \
            if async_stage else None
        # submit-side per-scope state (owned by the training thread)
        self._sig: dict[str, dict[str, tuple]] = {}
        self._last_key: dict[str, Optional[str]] = {}
        self._since_full: dict[str, int] = {}
        # writer-side per-scope state: path -> full ordered chunk-hash list.
        # Only the writer thread (or the inline sync path) touches it; jobs
        # run FIFO so it always reflects the previously written manifest.
        self._hashes: dict[str, dict[str, list]] = {}
        self._stats: list[dict] = []

    # -------------------------------------------------------------- record --
    def submit(self, key: str, tree: Any, meta: Optional[dict] = None,
               scope: str = "default", block: bool = True) -> Optional[dict]:
        """Fingerprint `tree`, transfer only changed chunks, and enqueue the
        write stage. Returns submit-side stats (or None when the writer
        queue is full and block=False — the checkpoint is skipped and the
        device digest state is rolled back so the next delta stays correct).
        """
        import jax
        t_submit0 = time.perf_counter()
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        prev_sig = self._sig.get(scope, {})
        sig: dict[str, tuple] = {}
        payload_leaves = []
        rollback: list[tuple[str, Any]] = []
        transferred = 0
        logical = 0
        changed_chunks_n = 0
        total_chunks_n = 0
        structure_changed = False
        for path, leaf in flat:
            pstr = jax.tree_util.keystr(path)
            if not hasattr(leaf, "dtype"):     # Python int/float/bool leaf
                leaf = np.asarray(leaf)
            dtype = str(leaf.dtype)
            shape = list(getattr(leaf, "shape", ()))
            nbytes = _leaf_nbytes(leaf)
            sig[pstr] = (dtype, tuple(shape))
            if nbytes == 0:
                payload_leaves.append({
                    "path": pstr, "dtype": dtype, "shape": shape,
                    "nbytes": 0, "n_chunks": 0, "changed_idx": [],
                    "chunks": []})
                continue
            tpath = f"{scope}::{pstr}"
            old = prev_sig.get(pstr)
            if old is None or old != sig[pstr]:
                structure_changed = True
                # dtype change with identical block count would otherwise
                # slip through the digest comparison
                self.tracker.forget(tpath)
            rollback.append((tpath, self.tracker._digests.get(tpath)))
            d = self.tracker.delta(tpath, _fp_view(leaf))
            bpw = native_bytes_per_word(dtype)
            chunk_native = self.chunk_words * bpw
            n_chunks = -(-nbytes // chunk_native)
            native = blocks_to_native_bytes(d["changed_blocks"], dtype)
            # tracker clamps changed_idx to the leaf's real chunk count, so
            # every row lands in [0, n_chunks); only the last needs trimming
            idx_keep: list[int] = []
            chunks_keep: list[bytes] = []
            for i, data in zip(d["changed_idx"].tolist(), native):
                if i == n_chunks - 1:
                    data = data[: nbytes - (n_chunks - 1) * chunk_native]
                idx_keep.append(int(i))
                chunks_keep.append(data)
            transferred += sum(len(c) for c in chunks_keep)
            logical += nbytes
            changed_chunks_n += len(idx_keep)
            total_chunks_n += n_chunks
            payload_leaves.append({
                "path": pstr, "dtype": dtype, "shape": shape,
                "nbytes": nbytes, "n_chunks": n_chunks,
                "changed_idx": idx_keep, "chunks": chunks_keep})
        if set(prev_sig) - set(sig):           # leaf removed
            structure_changed = True
        last = self._last_key.get(scope)
        since = self._since_full.get(scope, 0)
        full = (last is None or structure_changed
                or since + 1 >= self.full_every)
        payload = {
            "key": key, "scope": scope, "meta": meta or {},
            "kind": "full" if full else "delta",
            "parent": None if full else last,
            "treedef": str(treedef), "chunk_words": self.chunk_words,
            "leaves": payload_leaves,
            "transferred_bytes": transferred, "logical_bytes": logical,
            "changed_chunks": changed_chunks_n,
            "total_chunks": total_chunks_n,
            # foreground stall on the training thread (fingerprint + mask
            # sync + changed-row DMA): part of the real M_i — the epsilon
            # overhead invariant is meaningless if this goes uncounted
            "submit_stall_s": time.perf_counter() - t_submit0,
        }
        ok = self._dispatch(payload, block=block)
        if not ok:
            # checkpoint skipped: next delta must still diff against the
            # last STORED checkpoint
            for tpath, prev in rollback:
                if prev is None:
                    self.tracker.forget(tpath)
                else:
                    self.tracker._digests[tpath] = prev
            return None
        self._sig[scope] = sig
        self._last_key[scope] = key
        self._since_full[scope] = 0 if full else since + 1
        return {"key": key, "kind": payload["kind"],
                "parent": payload["parent"],
                "transferred_bytes": transferred, "logical_bytes": logical,
                "changed_chunks": changed_chunks_n,
                "total_chunks": total_chunks_n,
                "submit_stall_s": payload["submit_stall_s"]}

    def _dispatch(self, payload: dict, block: bool) -> bool:
        job = self._make_job(payload)
        if self.writer is not None:
            return self.writer.submit_job(payload["key"], job, block=block)
        t0 = time.perf_counter()
        stat = job(self.store)
        stat["materialize_s"] = time.perf_counter() - t0
        self._materialized(stat)
        return True

    def _make_job(self, payload: dict):
        def job(store):
            scope = payload["scope"]
            hashes_map = self._hashes.setdefault(scope, {})
            full = payload["kind"] == "full"
            new_bytes = 0
            new_chunks = 0
            manifest_leaves = []
            for leaf in payload["leaves"]:
                path, n = leaf["path"], leaf["n_chunks"]
                base = hashes_map.get(path)
                if base is None or len(base) != n:
                    base = [None] * n
                else:
                    base = list(base)
                delta_hashes = {}
                for i, data in zip(leaf["changed_idx"], leaf["chunks"]):
                    h, nb, new = store.put_chunk(data)
                    base[i] = h
                    delta_hashes[str(i)] = h
                    new_bytes += nb
                    new_chunks += int(new)
                if any(h is None for h in base):
                    raise RuntimeError(
                        f"delta pipeline inconsistency for leaf {path!r}: "
                        f"unchanged chunks have no known hash (manifest kind "
                        f"{payload['kind']!r})")
                hashes_map[path] = base
                mleaf = {"path": path, "dtype": leaf["dtype"],
                         "shape": leaf["shape"], "nbytes": leaf["nbytes"],
                         "n_chunks": n}
                if full:
                    mleaf["chunks"] = base
                else:
                    mleaf["delta"] = delta_hashes
                manifest_leaves.append(mleaf)
            if full:    # drop leaves that left the tree
                current = {lf["path"] for lf in payload["leaves"]}
                for stale in set(hashes_map) - current:
                    del hashes_map[stale]
            store.put_manifest({
                "key": payload["key"], "version": 2,
                "kind": payload["kind"], "parent": payload["parent"],
                "treedef": payload["treedef"],
                "chunk_words": payload["chunk_words"],
                "meta": payload["meta"], "leaves": manifest_leaves,
            })
            return {"key": payload["key"], "kind": payload["kind"],
                    "parent": payload["parent"],
                    "transferred_bytes": payload["transferred_bytes"],
                    "logical_bytes": payload["logical_bytes"],
                    "changed_chunks": payload["changed_chunks"],
                    "total_chunks": payload["total_chunks"],
                    "submit_stall_s": payload["submit_stall_s"],
                    "new_bytes": new_bytes, "new_chunks": new_chunks}
        return job

    def _materialized(self, stat: dict):
        self._stats.append(stat)
        if self._on_mat:
            self._on_mat(stat)

    # ---------------------------------------------------------- warm start --
    def warm_start(self, scope: str, parent_key: str, manifest: dict,
                   arrays_by_path: dict) -> dict:
        """Seed one scope's record state from an ancestor run's final
        RESOLVED manifest, so the next submit() is a delta against it.

        `parent_key` must be the key the shared store resolves the manifest
        under — QUALIFIED (``"run::key"``) when it lives in another run's
        namespace. `manifest` is the ``resolve_manifest`` output (complete
        chunk lists per leaf); `arrays_by_path` the restored host arrays
        keyed by leaf path (``get_tree`` with no `like`). Seeds:

        * structure signatures — so the first submit is not forced full;
        * writer-side chunk-hash lists — so unchanged chunks inherit the
          ancestor's hashes instead of tripping the consistency check;
        * device digests — rehydrated with the Pallas fingerprint over the
          restored bytes, so only truly-changed chunks transfer.

        Call before the scope's first submit (its writer-side state is not
        yet shared with the writer thread). Raises ValueError when the
        manifest cannot seed this pipeline (v1, unresolved holes, different
        `chunk_words`) — the caller falls back to a cold start."""
        if manifest.get("version", 1) < 2:
            raise ValueError(
                f"warm start needs a v2 pipeline manifest; {manifest['key']!r}"
                " is v1 (put_tree) and uses incompatible chunking")
        if int(manifest.get("chunk_words") or 0) != self.chunk_words:
            raise ValueError(
                f"chunk_words mismatch: manifest {manifest.get('chunk_words')}"
                f" vs pipeline {self.chunk_words} — digests would never match")
        sig: dict[str, tuple] = {}
        hashes: dict[str, list] = {}
        seeded_bytes = 0
        for leaf in manifest["leaves"]:
            path = leaf["path"]
            chunks = leaf.get("chunks")
            if chunks is None or any(h is None for h in chunks):
                raise ValueError(
                    f"manifest {manifest['key']!r} is not resolved at leaf "
                    f"{path!r} — pass resolve_manifest() output")
            if path not in arrays_by_path:
                raise ValueError(f"restored tree is missing leaf {path!r}")
            sig[path] = (leaf["dtype"], tuple(leaf["shape"]))
            hashes[path] = list(chunks)
            nbytes = int(leaf.get("nbytes", 0))
            seeded_bytes += nbytes
            if nbytes > 0:
                self.tracker.seed(f"{scope}::{path}",
                                  _fp_view(arrays_by_path[path]))
        self._sig[scope] = sig
        self._hashes[scope] = hashes
        self._last_key[scope] = parent_key
        self._since_full[scope] = 0
        return {"scope": scope, "parent": parent_key,
                "leaves": len(sig), "seeded_bytes": seeded_bytes}

    # ----------------------------------------------------------- lifecycle --
    def drain(self):
        if self.writer is not None:
            self.writer.drain()

    def close(self):
        if self.writer is not None:
            self.writer.close()
            self.writer = None

    def chain_keys(self) -> list[str]:
        """The tip checkpoint key of every scope's delta chain. A GC that
        runs mid-record MUST keep these live (their parent closure carries
        every chunk hash the next delta manifest will inherit)."""
        return [k for k in self._last_key.values() if k]

    def reset(self):
        """Forget all digest / chain state (next submits are full)."""
        self.tracker.reset()
        self._sig.clear()
        self._last_key.clear()
        self._since_full.clear()
        self._hashes.clear()

    @property
    def stats(self) -> list[dict]:
        return list(self._stats)


def _fp_view(leaf):
    """The array the fingerprint actually runs over. 64-bit HOST leaves get
    a bit-preserving u32 view: jit would silently downcast them when jax x64
    is disabled, corrupting the stored bytes (native_bytes_per_word is 4
    either way). Shared by submit() and warm_start() so rehydrated digests
    are byte-for-byte comparable with recorded ones."""
    if isinstance(leaf, np.ndarray) and leaf.dtype.itemsize == 8:
        return np.ascontiguousarray(leaf).reshape(-1).view(np.uint32)
    return leaf


def _leaf_nbytes(leaf) -> int:
    if hasattr(leaf, "nbytes"):
        return int(leaf.nbytes)
    a = np.asarray(leaf)
    return int(a.nbytes)
