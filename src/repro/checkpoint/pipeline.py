"""CheckpointPipeline: the delta-aware record-side checkpoint flow.

The paper's "lean checkpointing" thesis is that checkpoint cost should track
what CHANGED, not model size. This layer wires the device-side Pallas
fingerprint path end-to-end so the record path does, in order:

1. **Fingerprint + diff on device, fused** — per leaf, `DeltaTracker` runs
   the fused Pallas fingerprint+changed kernel (one read of the leaf at HBM
   bandwidth produces BOTH the new digests and the change mask). Digests
   never leave the device; only the [G] change mask and the changed rows do.
2. **Transfer only changed chunks, wire-format** — exact leaves gather the
   changed u32 block rows; leaves matching the per-slot ``quantize_slots``
   policy run the fused gather+quantize kernel instead, so the rows leave
   the device already blockwise-int8 (q + scales — the q8 wire format, ~4x
   smaller than f32). On a frozen-majority workload the device->host
   traffic drops by the frozen fraction times the codec ratio —
   `transferred_bytes` in the per-checkpoint stats is this real DMA payload
   (wire-byte accounting), the honest M_i input for the adaptive
   controller's ε-overhead model.
3. **Write stage** (`AsyncWriter` job, FIFO on the writer thread) — hash the
   wire chunks (blake2b-16), store them content-addressed, and emit a
   **delta manifest**. In **overlap mode** (``overlap=True``) steps 1-2 are
   split: the training thread only DISPATCHES the fused fingerprint pass
   (digest state updates to async device arrays; no host sync), and the
   mask sync + gather + encode all run here on the writer thread — the
   foreground stall shrinks to kernel-launch time, and the bounded queue
   provides natural backpressure when the writer falls behind.

Delta manifest format (store manifest v3)::

    {
      "key": str, "version": 3,
      "kind": "full" | "delta",
      "parent": str | null,          # delta only: previous checkpoint key
      "treedef": str,
      "chunk_words": int,            # fingerprint chunk size in u32 words
      "meta": {...},
      "leaves": [{
         "path": str, "dtype": str, "shape": [int], "nbytes": int,
         "n_chunks": int,
         "leaf_enc": "q8"|"eb:...",  # slot POLICY, only when lossy
         "chunks": [hash, ...],      # kind == "full": complete ordered list
         "enc": [enc, ...],          # full only, parallel to chunks; only
                                     # present when any chunk is non-raw.
                                     # Per-chunk enc is "raw" | "q8" | "q4",
                                     # optionally suffixed "+z" when the
                                     # writer-thread entropy stage kept a
                                     # compressed payload
         "delta": {"<idx>": hash},   # kind == "delta": changed indices only
         "denc": {"<idx>": enc},     # delta only: non-raw changed chunks
      }, ...],
    }

v2 manifests (no per-chunk encodings — everything raw/exact) remain fully
readable; `resolve_manifest` inherits encodings through the parent chain
exactly like chunk hashes, and `get_tree` decodes non-raw chunks
transparently on restore (kernels.ops.decode_wire_chunk). Exact slots
restore bit-identical; q8 slots restore with per-element error bounded by
half a quantization step (absmax_block / 254), q4 by absmax_block / 14.
Slots declared via ``error_bounds`` pick, per changed chunk, the cheapest
encoding whose GUARANTEED bound (delta.Q4_ATOL_DIV / Q8_ATOL_DIV margins)
satisfies the slot's atol.

Mesh-aware record (``mesh=``): the same flow runs PER DEVICE SHARD — each
shard's fused fingerprint+gather pass reads only its own buffer, its wire
chunks land in its host's store shard, and the job writes one v3 member
manifest per store shard plus a v4 stitching manifest recording the global
layout (per-leaf shape, recorded PartitionSpec, shard bounds + placement).
Delta chains run per shard (``<key>.shard<h>``), so inheritance, full-every
bounds and structure-change fallbacks behave exactly as in the flat path —
a layout change is a structure change and forces a full manifest. See
checkpoint/mesh.py for the restore-side stitch/reshard geometry.

A delta manifest inherits every unlisted chunk hash from its parent chain
(`CheckpointStore.resolve_manifest`). Chains are bounded: a FULL manifest is
written (a) for the first checkpoint of a scope, (b) every `full_every`
checkpoints, and (c) whenever the leaf structure changes (leaf added or
removed, dtype or shape changed) — so restore never chases unbounded
history and structure changes never alias stale chunks. A leaf whose chunk
size in native bytes is `chunk_words * native_bytes_per_word(dtype)`; the
final chunk is truncated to the leaf's `nbytes`, so restored bytes
concatenate exactly.

Scopes: checkpoints of different SkipBlocks pass distinct `scope` ids, so
each block keeps its own digest state, parent chain and full-manifest
cadence — interleaved blocks never diff against each other's trees.

Cross-run warm start (run lineage): ``warm_start(scope, parent_key,
manifest, tree)`` seeds a scope's state from an ANCESTOR RUN's final
resolved manifest in a shared store — per-leaf structure signatures, the
writer-side full chunk-hash lists, and the device-side digests (rehydrated
by running the Pallas fingerprint path over the restored tree, the same one
read submit() would pay). The scope's parent key is set to the ancestor's
QUALIFIED key (``"<run_id>::<key>"``), so the FIRST checkpoint of a derived
run is already a delta manifest chained across the run boundary: a
fine-tune of a 96%-frozen model transfers and stores ~4% on its very first
checkpoint instead of re-recording the model. `CheckpointStore` resolves
the qualified parent chain transparently; `store.gc` retains it (see
checkpoint/lineage.py for the registry that decides which runs are live).
"""
from __future__ import annotations

import fnmatch
import time
from typing import Any, Iterable, Optional

import numpy as np

from repro.checkpoint.async_writer import AsyncWriter
from repro.checkpoint.delta import DeltaTracker, blocks_to_native_bytes
from repro.kernels.ops import (Q4_BLOCK, Q8_BLOCK, native_bytes_per_word,
                               q4_encode_chunk, q8_encode_chunk,
                               quantizable_dtype)
from repro.parallel.compression import entropy_encode_bytes

DEFAULT_FULL_EVERY = 8
# fallback hop cost for full_every="auto" before any replay calibration has
# been learned — mirror of replay.plan.RESTORE_HOP_S (kept local: pipeline
# must not import the replay layer)
DEFAULT_HOP_S = 0.002
# storage/fingerprint granularity: 16384 u32 words = 64 KiB chunks for
# 4-byte dtypes. Finer chunks transfer marginally less but cost one object
# FILE per chunk — at 4 KiB the filesystem round-trips dominate the write
# stage. 64 KiB keeps a [8, 16384] u32 fingerprint tile at 512 KiB of VMEM.
PIPELINE_CHUNK_WORDS = 16 * 1024


class CheckpointPipeline:
    def __init__(self, store, *, chunk_words: int = PIPELINE_CHUNK_WORDS,
                 full_every=DEFAULT_FULL_EVERY,
                 async_stage: bool = True, max_queue: int = 2,
                 on_materialized=None,
                 quantize_slots: Optional[Iterable[str]] = None,
                 error_bounds: Optional[dict] = None,
                 entropy: bool = True,
                 overlap: bool = False,
                 mesh=None, shard_axes: Iterable[str] = (),
                 dist=None):
        self.store = store
        self.chunk_words = chunk_words
        # full_every="auto": start at the default cadence and retune after
        # every full manifest from the store's learned read/hop costs — see
        # _retune_full_every. Restore-bound stores shorten chains; stores
        # with cheap manifest hops lengthen them.
        self.full_every_auto = (full_every == "auto")
        self.full_every = DEFAULT_FULL_EVERY if self.full_every_auto \
            else max(1, int(full_every))
        self.tracker = DeltaTracker(chunk_words)
        # mesh-aware record: each device shard runs the fused fingerprint
        # pass over its OWN buffer, its chunks land in its host's store
        # shard, and a v4 stitching manifest records the layout. shard_axes
        # picks which mesh axes map onto store shards (default: all — one
        # store shard per device).
        self.mesh = mesh
        self.shard_axes = tuple(shard_axes or ())
        # true multi-process record: ``dist`` is a
        # parallel.rendezvous.StitchRendezvous carrying this process's
        # ProcessGroup. Each process fingerprints/gathers ONLY the shards
        # its devices own and writes ONLY its own hosts' member manifests;
        # the lead process gathers every host's publication through the
        # file barrier and writes the v4 stitch (or marks the checkpoint
        # incomplete past the deadline).
        self.dist = dist
        self._anchor = (0, 0)
        self._incomplete: list[str] = []
        self._key_chain: dict[str, list[str]] = {}
        if mesh is not None:
            from repro.checkpoint.mesh import (device_maps, local_anchor,
                                               mesh_meta)
            self._dev_ord, self._dev_host = device_maps(mesh,
                                                        self.shard_axes)
            self._mesh_meta = mesh_meta(mesh, self.shard_axes)
            if dist is not None:
                self._anchor = local_anchor(mesh, self._dev_ord,
                                            self._dev_host, 0)
        self._mesh_meta_written = False
        # per-slot lossy policy: leaf paths matching any of these names /
        # glob patterns are stored blockwise-int8 (q8 wire format) when the
        # dtype supports it. Empty (the default) = every leaf exact, so the
        # bit-identical restore invariant holds unless explicitly opted out.
        self.quantize_slots = tuple(quantize_slots or ())
        # declarative per-slot error bounds: {slot_or_glob: atol}. A matching
        # leaf uses the ADAPTIVE encoding selector — per changed chunk, the
        # cheapest wire encoding (q4 / q8 / raw) whose guaranteed blockwise
        # bound satisfies the atol. Takes precedence over quantize_slots.
        self.error_bounds = dict(error_bounds or {})
        # writer-thread entropy stage: byte-compress already-gathered wire
        # chunks of lossy-policy leaves off the step path (kept only when it
        # actually shrinks them). Requires the async stage — a sync pipeline
        # would pay it on the training thread, violating the epsilon budget.
        self.entropy = bool(entropy)
        # overlap mode defers mask-sync + gather to the writer thread; it
        # needs the async stage to exist (sync pipelines gain nothing)
        self.overlap = bool(overlap) and async_stage
        self._on_mat = on_materialized
        self.writer = AsyncWriter(store, max_queue=max_queue,
                                  on_materialized=self._materialized) \
            if async_stage else None
        # submit-side per-scope state (owned by the training thread)
        self._sig: dict[str, dict[str, tuple]] = {}
        self._last_key: dict[str, Optional[str]] = {}
        self._since_full: dict[str, int] = {}
        # writer-side per-scope state: path -> full ordered chunk-hash list
        # (and the parallel per-chunk encoding list). Only the writer thread
        # (or the inline sync path) touches them; jobs run FIFO so they
        # always reflect the previously written manifest.
        self._hashes: dict[str, dict[str, list]] = {}
        self._encs: dict[str, dict[str, list]] = {}
        self._stats: list[dict] = []

    def _slot_policy(self, pstr: str, dtype: str) -> str:
        """Per-leaf encoding POLICY: "eb:<atol>" when the leaf path matches
        an error_bounds entry (adaptive selector), "q8" when it matches a
        quantize_slots entry, "raw" otherwise. Both matchers take a slot
        name or a glob over the keystr path, and only fire when the dtype is
        one the fused quantize path supports. error_bounds wins when a leaf
        matches both."""
        if not quantizable_dtype(dtype):
            return "raw"
        for pat, atol in self.error_bounds.items():
            if _match_slot(pstr, pat):
                return f"eb:{float(atol):g}"
        for pat in self.quantize_slots:
            if _match_slot(pstr, pat):
                return "q8"
        return "raw"

    @staticmethod
    def _policy_delta_kwargs(policy: str) -> dict:
        """DeltaTracker kwargs for one leaf policy string."""
        if policy.startswith("eb:"):
            return {"error_bound": float(policy[3:])}
        if policy != "raw":
            return {"enc": policy}
        return {}

    # -------------------------------------------------------------- record --
    def submit(self, key: str, tree: Any, meta: Optional[dict] = None,
               scope: str = "default", block: bool = True) -> Optional[dict]:
        """Fingerprint `tree`, transfer only changed chunks, and enqueue the
        write stage. Returns submit-side stats (or None when the writer
        queue is full and block=False — the checkpoint is skipped and the
        device digest state is rolled back so the next delta stays correct).
        """
        if self.mesh is not None:
            return self._submit_sharded(key, tree, meta, scope, block)
        import jax
        t_submit0 = time.perf_counter()
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        prev_sig = self._sig.get(scope, {})
        sig: dict[str, tuple] = {}
        payload_leaves = []
        rollback: list[tuple[str, Any]] = []
        transferred = 0
        logical = 0
        changed_chunks_n = 0
        total_chunks_n = 0
        structure_changed = False
        for path, leaf in flat:
            pstr = jax.tree_util.keystr(path)
            if not hasattr(leaf, "dtype"):     # Python int/float/bool leaf
                leaf = np.asarray(leaf)
            dtype = str(leaf.dtype)
            shape = list(getattr(leaf, "shape", ()))
            nbytes = _leaf_nbytes(leaf)
            policy = self._slot_policy(pstr, dtype)
            # the policy is part of the structure signature: flipping a
            # slot's policy (or changing its error bound) forces a FULL
            # manifest (and a digest reset), so a chain never inherits
            # chunks recorded under another encoding without declaring it
            # per-chunk. Per-chunk choices WITHIN one "eb:" policy do not
            # force fulls — the manifest's enc/denc fields carry them.
            sig[pstr] = (dtype, tuple(shape), policy)
            if nbytes == 0:
                payload_leaves.append({
                    "path": pstr, "dtype": dtype, "shape": shape,
                    "nbytes": 0, "n_chunks": 0, "enc": "raw",
                    "changed_idx": [], "chunks": [], "chunk_encs": []})
                continue
            tpath = f"{scope}::{pstr}"
            old = prev_sig.get(pstr)
            if old is None or old != sig[pstr]:
                structure_changed = True
                # dtype change with identical block count would otherwise
                # slip through the digest comparison
                self.tracker.forget(tpath)
            rollback.append((tpath, self.tracker._digests.get(tpath)))
            n_chunks = -(-nbytes // (self.chunk_words
                                     * native_bytes_per_word(dtype)))
            lmeta = {"path": pstr, "dtype": dtype, "shape": shape,
                     "nbytes": nbytes, "n_chunks": n_chunks, "enc": policy}
            logical += nbytes
            total_chunks_n += n_chunks
            dkw = self._policy_delta_kwargs(policy)
            if self.overlap:
                # dispatch-only: the fused fingerprint+mask launches here;
                # mask sync, gather and encode run on the writer thread
                lmeta["handle"] = self.tracker.delta_dispatch(
                    tpath, _fp_view(leaf), **dkw)
            else:
                d = self.tracker.delta(tpath, _fp_view(leaf), **dkw)
                idx_keep, chunks_keep, encs_keep, t_bytes = _encode_changed(
                    d, lmeta, self.chunk_words)
                lmeta["changed_idx"] = idx_keep
                lmeta["chunks"] = chunks_keep
                lmeta["chunk_encs"] = encs_keep
                transferred += t_bytes
                changed_chunks_n += len(idx_keep)
            payload_leaves.append(lmeta)
        if set(prev_sig) - set(sig):           # leaf removed
            structure_changed = True
        last = self._last_key.get(scope)
        since = self._since_full.get(scope, 0)
        full = (last is None or structure_changed
                or since + 1 >= self.full_every)
        payload = {
            "key": key, "scope": scope, "meta": meta or {},
            "kind": "full" if full else "delta",
            "parent": None if full else last,
            "treedef": str(treedef), "chunk_words": self.chunk_words,
            "leaves": payload_leaves, "overlap": self.overlap,
            # overlap mode: transferred/changed are only known once the
            # writer thread finalizes the deferred gathers (None here; the
            # materialized stat carries the measured values)
            "transferred_bytes": None if self.overlap else transferred,
            "logical_bytes": logical,
            "changed_chunks": None if self.overlap else changed_chunks_n,
            "total_chunks": total_chunks_n,
            # foreground stall on the training thread (fused fingerprint +
            # mask sync + changed-row DMA — or dispatch-only in overlap
            # mode): part of the real M_i — the epsilon overhead invariant
            # is meaningless if this goes uncounted
            "submit_stall_s": time.perf_counter() - t_submit0,
        }
        ok = self._dispatch(payload, block=block)
        if not ok:
            # checkpoint skipped: next delta must still diff against the
            # last STORED checkpoint
            for tpath, prev in rollback:
                if prev is None:
                    self.tracker.forget(tpath)
                else:
                    self.tracker._digests[tpath] = prev
            return None
        self._sig[scope] = sig
        self._last_key[scope] = key
        self._since_full[scope] = 0 if full else since + 1
        return {"key": key, "kind": payload["kind"],
                "parent": payload["parent"],
                "transferred_bytes": payload["transferred_bytes"],
                "logical_bytes": logical,
                "changed_chunks": payload["changed_chunks"],
                "total_chunks": total_chunks_n,
                "overlap": self.overlap,
                "submit_stall_s": payload["submit_stall_s"]}

    def _dispatch(self, payload: dict, block: bool) -> bool:
        job = self._make_job(payload)
        if self.writer is not None:
            return self.writer.submit_job(payload["key"], job, block=block)
        t0 = time.perf_counter()
        stat = job(self.store)
        stat["materialize_s"] = time.perf_counter() - t0
        self._materialized(stat)
        return True

    def _make_job(self, payload: dict):
        if payload.get("sharded"):
            return lambda store: self._sharded_job(payload, store)

        def job(store):
            scope = payload["scope"]
            if payload.get("overlap"):
                # deferred half of the fused pass: sync masks, gather (and
                # quantize) changed rows, encode wire payloads — all off the
                # training thread
                transferred = 0
                changed_n = 0
                for leaf in payload["leaves"]:
                    h = leaf.pop("handle", None)
                    if h is None:              # zero-byte leaf
                        continue
                    d = self.tracker.finalize(h)
                    idx_keep, chunks_keep, encs_keep, t_bytes = \
                        _encode_changed(d, leaf, payload["chunk_words"])
                    leaf["changed_idx"] = idx_keep
                    leaf["chunks"] = chunks_keep
                    leaf["chunk_encs"] = encs_keep
                    transferred += t_bytes
                    changed_n += len(idx_keep)
                payload["transferred_bytes"] = transferred
                payload["changed_chunks"] = changed_n
            entropy_s = sum(self._entropy_pass(leaf)
                            for leaf in payload["leaves"])
            hashes_map = self._hashes.setdefault(scope, {})
            encs_map = self._encs.setdefault(scope, {})
            full = payload["kind"] == "full"
            new_bytes = 0
            new_chunks = 0
            stored_bytes = 0
            manifest_leaves = []
            for leaf in payload["leaves"]:
                path, n = leaf["path"], leaf["n_chunks"]
                lenc = leaf.get("enc", "raw")
                cencs = leaf.get("chunk_encs") \
                    or ["raw"] * len(leaf["changed_idx"])
                base = hashes_map.get(path)
                if base is None or len(base) != n:
                    base = [None] * n
                else:
                    base = list(base)
                ebase = encs_map.get(path)
                if ebase is None or len(ebase) != n:
                    ebase = ["raw"] * n        # pre-v3 state: chunks are raw
                else:
                    ebase = list(ebase)
                delta_hashes = {}
                for i, data, ce in zip(leaf["changed_idx"], leaf["chunks"],
                                       cencs):
                    h, nb, new = store.put_chunk(data)
                    base[i] = h
                    ebase[i] = ce
                    delta_hashes[str(i)] = h
                    new_bytes += nb
                    new_chunks += int(new)
                    stored_bytes += len(data)
                if any(h is None for h in base):
                    raise RuntimeError(
                        f"delta pipeline inconsistency for leaf {path!r}: "
                        f"unchanged chunks have no known hash (manifest kind "
                        f"{payload['kind']!r})")
                hashes_map[path] = base
                encs_map[path] = ebase
                mleaf = {"path": path, "dtype": leaf["dtype"],
                         "shape": leaf["shape"], "nbytes": leaf["nbytes"],
                         "n_chunks": n}
                if lenc != "raw":
                    # leaf-level POLICY (what this pipeline writes), distinct
                    # from the per-chunk enc lists below: warm_start seeds
                    # the structure signature from it
                    mleaf["leaf_enc"] = lenc
                if full:
                    mleaf["chunks"] = base
                    if any(e != "raw" for e in ebase):
                        mleaf["enc"] = ebase
                else:
                    mleaf["delta"] = delta_hashes
                    denc = {str(i): ce
                            for i, ce in zip(leaf["changed_idx"], cencs)
                            if ce != "raw"}
                    if denc:
                        mleaf["denc"] = denc
                manifest_leaves.append(mleaf)
            if full:    # drop leaves that left the tree
                current = {lf["path"] for lf in payload["leaves"]}
                for stale in set(hashes_map) - current:
                    del hashes_map[stale]
                    encs_map.pop(stale, None)
            store.put_manifest({
                "key": payload["key"], "version": 3,
                "kind": payload["kind"], "parent": payload["parent"],
                "treedef": payload["treedef"],
                "chunk_words": payload["chunk_words"],
                "meta": payload["meta"], "leaves": manifest_leaves,
            })
            if full:
                self._retune_full_every(store, payload["logical_bytes"])
            return {"key": payload["key"], "kind": payload["kind"],
                    "parent": payload["parent"],
                    "transferred_bytes": payload["transferred_bytes"],
                    "logical_bytes": payload["logical_bytes"],
                    "changed_chunks": payload["changed_chunks"],
                    "total_chunks": payload["total_chunks"],
                    "submit_stall_s": payload["submit_stall_s"],
                    "overlap": payload.get("overlap", False),
                    "new_bytes": new_bytes, "new_chunks": new_chunks,
                    "stored_bytes": stored_bytes,
                    "entropy_s": entropy_s,
                    "full_every": self.full_every}
        return job

    def _entropy_pass(self, leaf: dict) -> float:
        """Writer-thread entropy stage for one leaf: byte-compress its wire
        chunks in place (suffixing the chunk encoding with "+z") when the
        leaf has a lossy policy and compression actually pays — a payload is
        kept only below 0.95x its original size, so restore never decodes a
        compression pass that bought nothing. Runs ONLY when an async writer
        exists; on a sync pipeline this stage would land on the training
        thread and silently inflate the foreground stall. Returns seconds
        spent (the caller reports them as ``entropy_s`` so the adaptive
        controller can move them to the background accumulator)."""
        if self.writer is None or not self.entropy:
            return 0.0
        if leaf.get("enc", "raw") == "raw" or not leaf.get("chunks"):
            return 0.0
        t0 = time.perf_counter()
        chunks = leaf["chunks"]
        cencs = list(leaf.get("chunk_encs")
                     or ["raw"] * len(chunks))
        # raw chunks of a lossy-policy leaf (adaptive selector fallback) are
        # still float words — byte-plane shuffle at the dtype's width;
        # q8/q4 payloads are already byte-homogeneous, stride 1
        raw_isz = 2 if leaf["dtype"] in ("bfloat16", "float16") else 4
        for j, (data, ce) in enumerate(zip(chunks, cencs)):
            if ce.endswith("+z"):
                continue
            z = entropy_encode_bytes(
                data, itemsize=raw_isz if ce == "raw" else 1)
            if len(z) < 0.95 * len(data):
                chunks[j] = z
                cencs[j] = ce + "+z"
        leaf["chunk_encs"] = cencs
        return time.perf_counter() - t0

    def _retune_full_every(self, store, full_bytes: int):
        """Close the loop on the full-manifest cadence (full_every="auto"):
        pick the chain length K whose worst-case replay overhead — K
        manifest hops — costs about half the time re-reading a full
        checkpoint does, using the store's measured read bandwidth and the
        learned per-hop resolve cost (PR-6 restore calibration). A
        restore-bound store (expensive hops) gets short chains; a store with
        cheap local hops amortizes fulls over long ones. Runs on the writer
        thread right after each full manifest; submit() reads the updated
        value for the next cadence decision."""
        if not self.full_every_auto:
            return
        calib = store.get_meta("store_calib") or {}
        read_bps = float(calib.get("read_bps") or calib.get("write_bps")
                         or 1e9)
        hop_s = float(calib.get("hop_s") or DEFAULT_HOP_S)
        full_read_s = full_bytes / max(read_bps, 1.0)
        self.full_every = min(64, max(2, int(0.5 * full_read_s
                                             / max(hop_s, 1e-9))))

    # ------------------------------------------------------ sharded record --
    def _submit_sharded(self, key: str, tree: Any, meta: Optional[dict],
                        scope: str, block: bool) -> Optional[dict]:
        """Mesh-aware submit: per pytree leaf, enumerate the disjoint owner
        shards (checkpoint/mesh.py) and run the fused fingerprint+gather
        pass on EACH shard's own device buffer — no all-gather; a shard's
        bytes only move device -> its host's store shard. Emits one v3
        member manifest per store shard plus a v4 stitching manifest."""
        import jax
        from repro.checkpoint.mesh import leaf_spec_entries, owned_shards
        t_submit0 = time.perf_counter()
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        prev_sig = self._sig.get(scope, {})
        sig: dict[str, tuple] = {}
        entries: list[dict] = []       # one per (leaf, device shard)
        layout: list[dict] = []        # global-manifest leaves
        rollback: list[tuple[str, Any]] = []
        transferred = 0
        logical = 0
        changed_chunks_n = 0
        total_chunks_n = 0
        structure_changed = False
        shard_stall: dict[int, float] = {}
        for path, leaf in flat:
            pstr = jax.tree_util.keystr(path)
            if not hasattr(leaf, "dtype"):
                leaf = np.asarray(leaf)
            dtype = str(leaf.dtype)
            shape = list(getattr(leaf, "shape", ()))
            nbytes = _leaf_nbytes(leaf)
            policy = self._slot_policy(pstr, dtype)
            if nbytes == 0:
                sig[pstr] = (dtype, tuple(shape), policy, ())
                layout.append({"path": pstr, "dtype": dtype, "shape": shape,
                               "nbytes": 0, "spec": None, "shards": []})
                continue
            shards = owned_shards(
                leaf, self._dev_ord, self._dev_host,
                process_index=(self.dist.group.process_id
                               if self.dist is not None else None),
                anchor=self._anchor)
            # the placement is part of the structure signature: a layout
            # change (resharded mid-run, mesh swap) forces a FULL manifest —
            # per-shard digests from another layout cover different bytes
            mesh_sig = tuple((s["sid"], s["hid"],
                              tuple(map(tuple, s["bounds"])))
                             for s in shards)
            sig[pstr] = (dtype, tuple(shape), policy, mesh_sig)
            layout.append({"path": pstr, "dtype": dtype, "shape": shape,
                           "nbytes": nbytes,
                           "spec": leaf_spec_entries(leaf),
                           "shards": [{"sid": s["sid"], "hid": s["hid"],
                                       "bounds": s["bounds"]}
                                      for s in shards]})
            logical += nbytes
            if prev_sig.get(pstr) != sig[pstr]:
                structure_changed = True
                for s in shards:
                    self.tracker.forget(f"{scope}::{pstr}::s{s['sid']}")
            for s in shards:
                tpath = f"{scope}::{pstr}::s{s['sid']}"
                rollback.append((tpath, self.tracker._digests.get(tpath)))
                local = s["data"]
                lnb = _leaf_nbytes(local)
                n_chunks = -(-lnb // (self.chunk_words
                                      * native_bytes_per_word(dtype)))
                ent = {"path": pstr, "sid": s["sid"], "hid": s["hid"],
                       "bounds": s["bounds"], "dtype": dtype,
                       "shape": list(getattr(local, "shape", ())),
                       "nbytes": lnb, "n_chunks": n_chunks, "enc": policy}
                total_chunks_n += n_chunks
                dkw = self._policy_delta_kwargs(policy)
                t0 = time.perf_counter()
                if self.overlap:
                    ent["handle"] = self.tracker.delta_dispatch(
                        tpath, _fp_view(local), **dkw)
                else:
                    d = self.tracker.delta(tpath, _fp_view(local), **dkw)
                    idx_keep, chunks_keep, encs_keep, t_bytes = \
                        _encode_changed(d, ent, self.chunk_words)
                    ent["changed_idx"] = idx_keep
                    ent["chunks"] = chunks_keep
                    ent["chunk_encs"] = encs_keep
                    transferred += t_bytes
                    changed_chunks_n += len(idx_keep)
                # per-host foreground cost: hosts run concurrently in a
                # real deployment, so the simulated per-checkpoint wall is
                # max over hosts, not the serial sum this process pays
                shard_stall[s["hid"]] = shard_stall.get(s["hid"], 0.0) \
                    + (time.perf_counter() - t0)
                entries.append(ent)
        if set(prev_sig) - set(sig):
            structure_changed = True
        last = self._last_key.get(scope)
        since = self._since_full.get(scope, 0)
        full = (last is None or structure_changed
                or since + 1 >= self.full_every)
        payload = {
            "key": key, "scope": scope, "meta": meta or {},
            "sharded": True, "mesh": self._mesh_meta,
            "kind": "full" if full else "delta",
            "parent": None if full else last,
            "treedef": str(treedef), "chunk_words": self.chunk_words,
            "entries": entries, "layout": layout, "overlap": self.overlap,
            "transferred_bytes": None if self.overlap else transferred,
            "logical_bytes": logical,
            "changed_chunks": None if self.overlap else changed_chunks_n,
            "total_chunks": total_chunks_n,
            "shard_stall_s": shard_stall,
            "submit_stall_s": time.perf_counter() - t_submit0,
        }
        ok = self._dispatch(payload, block=block)
        if not ok:
            for tpath, prev in rollback:
                if prev is None:
                    self.tracker.forget(tpath)
                else:
                    self.tracker._digests[tpath] = prev
            return None
        self._sig[scope] = sig
        self._last_key[scope] = key
        self._since_full[scope] = 0 if full else since + 1
        if self.dist is not None:
            self._key_chain.setdefault(scope, []).append(key)
        return {"key": key, "kind": payload["kind"], "sharded": True,
                "parent": payload["parent"],
                "transferred_bytes": payload["transferred_bytes"],
                "logical_bytes": logical,
                "changed_chunks": payload["changed_chunks"],
                "total_chunks": total_chunks_n,
                "overlap": self.overlap,
                "n_store_shards": self._mesh_meta["n_store_shards"],
                "shard_stall_s": dict(shard_stall),
                "submit_stall_s": payload["submit_stall_s"]}

    def _sharded_job(self, payload: dict, store) -> dict:
        """Writer half of a sharded checkpoint: per store shard, write the
        changed chunks into that shard's pool and a v3 member manifest
        (chained ``<key>.shard<h>`` -> ``<parent>.shard<h>``); then the v4
        stitching manifest. Members land BEFORE the global manifest, so a
        crash can leave orphan members but never a global that references a
        missing one."""
        scope = payload["scope"]
        if payload.get("overlap"):
            transferred = 0
            changed_n = 0
            for ent in payload["entries"]:
                h = ent.pop("handle", None)
                if h is None:
                    continue
                t0 = time.perf_counter()
                d = self.tracker.finalize(h)
                idx_keep, chunks_keep, encs_keep, t_bytes = _encode_changed(
                    d, ent, payload["chunk_words"])
                ent["changed_idx"] = idx_keep
                ent["chunks"] = chunks_keep
                ent["chunk_encs"] = encs_keep
                transferred += t_bytes
                changed_n += len(idx_keep)
                ss = payload["shard_stall_s"]
                ss[ent["hid"]] = ss.get(ent["hid"], 0.0) \
                    + (time.perf_counter() - t0)
            payload["transferred_bytes"] = transferred
            payload["changed_chunks"] = changed_n
        entropy_s = sum(self._entropy_pass(ent)
                        for ent in payload["entries"])
        hashes_map = self._hashes.setdefault(scope, {})
        encs_map = self._encs.setdefault(scope, {})
        full = payload["kind"] == "full"
        key, parent = payload["key"], payload["parent"]
        by_hid: dict[int, list[dict]] = {}
        for ent in payload["entries"]:
            by_hid.setdefault(ent["hid"], []).append(ent)
        new_bytes = 0
        new_chunks = 0
        members: dict[str, str] = {}
        shard_write_s: dict[int, float] = {}
        shard_bytes: dict[int, int] = {}
        for hid in sorted(by_hid):
            t0 = time.perf_counter()
            mleaves = []
            for ent in by_hid[hid]:
                wkey = f"{ent['path']}::shard{ent['sid']}"
                n = ent["n_chunks"]
                lenc = ent["enc"]
                cencs = ent.get("chunk_encs") \
                    or ["raw"] * len(ent["changed_idx"])
                base = hashes_map.get(wkey)
                base = [None] * n if base is None or len(base) != n \
                    else list(base)
                ebase = encs_map.get(wkey)
                ebase = ["raw"] * n if ebase is None or len(ebase) != n \
                    else list(ebase)
                delta_hashes = {}
                for i, data, ce in zip(ent["changed_idx"], ent["chunks"],
                                       cencs):
                    h, nb, new = store.put_chunk(data, shard=hid)
                    base[i] = h
                    ebase[i] = ce
                    delta_hashes[str(i)] = h
                    new_bytes += nb
                    new_chunks += int(new)
                    shard_bytes[hid] = shard_bytes.get(hid, 0) + len(data)
                if any(h is None for h in base):
                    raise RuntimeError(
                        f"sharded delta inconsistency for {wkey!r}: "
                        f"unchanged chunks have no known hash (manifest "
                        f"kind {payload['kind']!r})")
                hashes_map[wkey] = base
                encs_map[wkey] = ebase
                mleaf = {"path": wkey, "dtype": ent["dtype"],
                         "shape": ent["shape"], "nbytes": ent["nbytes"],
                         "n_chunks": n, "bounds": ent["bounds"]}
                if lenc != "raw":
                    mleaf["leaf_enc"] = lenc
                if full:
                    mleaf["chunks"] = base
                    if any(e != "raw" for e in ebase):
                        mleaf["enc"] = ebase
                else:
                    mleaf["delta"] = delta_hashes
                    denc = {str(i): ce
                            for i, ce in zip(ent["changed_idx"], cencs)
                            if ce != "raw"}
                    if denc:
                        mleaf["denc"] = denc
                mleaves.append(mleaf)
            member_key = f"{key}.shard{hid}"
            store.put_manifest({
                "key": member_key, "version": 3,
                "kind": payload["kind"],
                "parent": f"{parent}.shard{hid}" if parent else None,
                "treedef": payload["treedef"],
                "chunk_words": payload["chunk_words"],
                "store_shard": hid, "meta": {},
                "leaves": mleaves,
            })
            members[str(hid)] = member_key
            shard_write_s[hid] = time.perf_counter() - t0
        if full:
            current = {f"{ent['path']}::shard{ent['sid']}"
                       for ent in payload["entries"]}
            for stale in set(hashes_map) - current:
                del hashes_map[stale]
                encs_map.pop(stale, None)
        # stitched: True = v4 written, False = marked incomplete, None =
        # outcome unknown here (non-lead of a distributed fleet; the lead
        # decides, close() reconciles the tips from the store)
        stitched: Optional[bool] = True
        if self.dist is None:
            store.put_manifest({
                "key": key, "version": 4, "kind": "sharded",
                "ckpt_kind": payload["kind"], "parent": parent,
                "treedef": payload["treedef"],
                "chunk_words": payload["chunk_words"],
                "mesh": payload["mesh"], "members": members,
                "meta": payload["meta"], "leaves": payload["layout"],
            })
        else:
            stitched = self._dist_stitch(payload, store, members)
        if not self._mesh_meta_written and \
                (self.dist is None or self.dist.group.is_lead):
            store.put_meta("mesh", payload["mesh"])
            self._mesh_meta_written = True
        if full and stitched:
            self._retune_full_every(store, payload["logical_bytes"])
        return {"key": key, "kind": payload["kind"], "sharded": True,
                "stitched": stitched,
                "parent": parent,
                "transferred_bytes": payload["transferred_bytes"],
                "logical_bytes": payload["logical_bytes"],
                "changed_chunks": payload["changed_chunks"],
                "total_chunks": payload["total_chunks"],
                "submit_stall_s": payload["submit_stall_s"],
                "overlap": payload.get("overlap", False),
                "new_bytes": new_bytes, "new_chunks": new_chunks,
                "n_store_shards": len(by_hid),
                "shard_stall_s": dict(payload["shard_stall_s"]),
                "shard_write_s": shard_write_s,
                "shard_bytes": shard_bytes,
                "entropy_s": entropy_s,
                "full_every": self.full_every}

    # ------------------------------------------------- distributed stitch --
    def _dist_stitch(self, payload: dict, store,
                     members: dict) -> Optional[bool]:
        """Multi-process tail of a sharded checkpoint (writer thread).
        Every process PUBLISHES its member-manifest names + local layout
        fragment through the file rendezvous; the LEAD process gathers all
        publications, validates them, merges the global layout, and writes
        the v4 stitch atomically. Publication order is the crash-safety
        invariant: member manifests land before the marker, the marker
        before the stitch — so a crash anywhere in between leaves only
        unreferenced members (GC food), never a v4 naming a missing one.
        Past the deadline (or on validation failure) the lead marks the
        checkpoint ``incomplete`` in run meta and training moves on.

        Returns the stitch outcome on the lead (True = v4 written, False =
        incomplete); ``None`` on non-leads, whose publication returns long
        before the lead's verdict exists — their stats must not claim an
        outcome, and close() reconciles their tips from the store."""
        import os as _os
        from repro.parallel import rendezvous as rdv
        key = payload["key"]
        group = self.dist.group
        if rdv.crash_requested(key, group.process_id):
            # fault injection: die AFTER member publication, BEFORE the
            # marker — the exact window the crash-safety argument is about
            _os._exit(rdv.CRASH_EXIT_CODE)
        self.dist.publish(key, {
            "process": group.process_id,
            "kind": payload["kind"],
            "members": dict(members),
            "layout_shards": {lf["path"]: lf["shards"]
                              for lf in payload["layout"]},
        })
        if not group.is_lead:
            return None      # publication done; outcome is the lead's call
        got = self.dist.gather(key)
        merged = self._merge_markers(store, payload, got) \
            if got is not None else None
        if merged is None:
            self._mark_incomplete(store, key)
            return False
        layout, all_members = merged
        store.put_manifest({
            "key": key, "version": 4, "kind": "sharded",
            "ckpt_kind": payload["kind"], "parent": payload["parent"],
            "treedef": payload["treedef"],
            "chunk_words": payload["chunk_words"],
            "mesh": payload["mesh"], "members": all_members,
            "meta": payload["meta"], "leaves": layout,
        })
        self.dist.clear(key)
        return True

    def _merge_markers(self, store, payload: dict,
                       got: list) -> Optional[tuple]:
        """Validate every host's publication and merge the global (layout,
        members). None on any inconsistency — a member manifest missing
        from disk, a host that decided a different full/delta kind, or a
        shard set that does not tile a leaf — so a bad fleet state becomes
        an ``incomplete`` checkpoint instead of a corrupt stitch."""
        all_members: dict[str, str] = {}
        for marker in got:
            if marker.get("kind") != payload["kind"]:
                return None
            for hid, mkey in marker["members"].items():
                if not store.has(mkey):
                    return None
                all_members[str(hid)] = mkey
        layout = []
        for lf in payload["layout"]:
            merged = {k: v for k, v in lf.items() if k != "shards"}
            shards: list[dict] = []
            for marker in got:
                shards.extend(marker["layout_shards"].get(lf["path"], []))
            shards.sort(key=lambda s: s["sid"])
            merged["shards"] = shards
            layout.append(merged)
            if lf["nbytes"] > 0 and lf["shape"]:
                covered = 0
                for s in shards:
                    vol = 1
                    for lo, hi in s["bounds"]:
                        vol *= max(0, hi - lo)
                    covered += vol
                want = 1
                for d in lf["shape"]:
                    want *= int(d)
                if covered != want:
                    return None    # shards don't tile the leaf
        return layout, all_members

    def _mark_incomplete(self, store, key: str):
        """Record a failed stitch in run meta (lead-only, so the
        read-modify-write never races): the replay planner skips these
        keys, and close() rolls final_keys back past them."""
        self._incomplete.append(key)
        cur = store.get_meta("incomplete_ckpts") or {"keys": []}
        if key not in cur["keys"]:
            cur["keys"].append(key)
        store.put_meta("incomplete_ckpts", cur)

    def _materialized(self, stat: dict):
        self._stats.append(stat)
        if self._on_mat:
            self._on_mat(stat)

    # ---------------------------------------------------------- warm start --
    def warm_start(self, scope: str, parent_key: str, manifest: dict,
                   arrays_by_path: dict) -> dict:
        """Seed one scope's record state from an ancestor run's final
        RESOLVED manifest, so the next submit() is a delta against it.

        `parent_key` must be the key the shared store resolves the manifest
        under — QUALIFIED (``"run::key"``) when it lives in another run's
        namespace. `manifest` is the ``resolve_manifest`` output (complete
        chunk lists per leaf); `arrays_by_path` the restored host arrays
        keyed by leaf path (``get_tree`` with no `like`). Seeds:

        * structure signatures — so the first submit is not forced full;
        * writer-side chunk-hash lists — so unchanged chunks inherit the
          ancestor's hashes instead of tripping the consistency check;
        * device digests — rehydrated with the Pallas fingerprint over the
          restored bytes, so only truly-changed chunks transfer.

        Call before the scope's first submit (its writer-side state is not
        yet shared with the writer thread). Raises ValueError when the
        manifest cannot seed this pipeline (v1, unresolved holes, different
        `chunk_words`) — the caller falls back to a cold start."""
        if manifest.get("kind") == "sharded":
            raise ValueError(
                f"warm start from sharded (v4) manifest {manifest['key']!r} "
                "is not supported yet — the derived run records cold")
        if manifest.get("version", 1) < 2:
            raise ValueError(
                f"warm start needs a v2 pipeline manifest; {manifest['key']!r}"
                " is v1 (put_tree) and uses incompatible chunking")
        if int(manifest.get("chunk_words") or 0) != self.chunk_words:
            raise ValueError(
                f"chunk_words mismatch: manifest {manifest.get('chunk_words')}"
                f" vs pipeline {self.chunk_words} — digests would never match")
        sig: dict[str, tuple] = {}
        hashes: dict[str, list] = {}
        encs: dict[str, list] = {}
        seeded_bytes = 0
        for leaf in manifest["leaves"]:
            path = leaf["path"]
            chunks = leaf.get("chunks")
            if chunks is None or any(h is None for h in chunks):
                raise ValueError(
                    f"manifest {manifest['key']!r} is not resolved at leaf "
                    f"{path!r} — pass resolve_manifest() output")
            if path not in arrays_by_path:
                raise ValueError(f"restored tree is missing leaf {path!r}")
            sig[path] = (leaf["dtype"], tuple(leaf["shape"]),
                         leaf.get("leaf_enc", "raw"))
            hashes[path] = list(chunks)
            encs[path] = list(leaf.get("enc") or ["raw"] * len(chunks))
            nbytes = int(leaf.get("nbytes", 0))
            seeded_bytes += nbytes
            if nbytes > 0:
                self.tracker.seed(f"{scope}::{path}",
                                  _fp_view(arrays_by_path[path]))
        self._sig[scope] = sig
        self._hashes[scope] = hashes
        self._encs[scope] = encs
        self._last_key[scope] = parent_key
        self._since_full[scope] = 0
        return {"scope": scope, "parent": parent_key,
                "leaves": len(sig), "seeded_bytes": seeded_bytes}

    # ----------------------------------------------------------- lifecycle --
    def drain(self):
        if self.writer is not None:
            self.writer.drain()

    def close(self):
        if self.writer is not None:
            self.writer.close()
            self.writer = None
        if self.dist is not None:
            # roll each scope's tip back to the newest STITCHED key: a tail
            # checkpoint whose stitch never happened (crashed peer,
            # straggler past the deadline) has member manifests but no v4,
            # and final_keys must never name it. Non-lead processes learn
            # the outcome here, from the store, without extra coordination.
            for scope, chain in self._key_chain.items():
                if chain and not self.dist.group.is_lead:
                    self._await_stitch(chain[-1])
                live = [k for k in chain if self.store.has(k)]
                self._last_key[scope] = live[-1] if live else None

    def _await_stitch(self, key: str):
        """Non-lead close-time wait for the lead's verdict on the tip key:
        either the v4 appears or the key lands in the incomplete meta.
        Bounded by the stitch timeout — a dead lead costs one deadline,
        never a wedge."""
        deadline = time.monotonic() + self.dist.timeout_s
        while time.monotonic() < deadline:
            if self.store.has(key):
                return
            inc = self.store.get_meta("incomplete_ckpts") or {"keys": []}
            if key in inc.get("keys", []):
                return
            time.sleep(0.02)

    def chain_keys(self) -> list[str]:
        """The tip checkpoint key of every scope's delta chain. A GC that
        runs mid-record MUST keep these live (their parent closure carries
        every chunk hash the next delta manifest will inherit)."""
        return [k for k in self._last_key.values() if k]

    def reset(self):
        """Forget all digest / chain state (next submits are full)."""
        self.tracker.reset()
        self._sig.clear()
        self._last_key.clear()
        self._since_full.clear()
        self._hashes.clear()
        self._encs.clear()

    @property
    def stats(self) -> list[dict]:
        return list(self._stats)


def _encode_changed(d: dict, lmeta: dict, chunk_words: int):
    """Turn one finalized delta record into per-chunk wire payloads.

    Iterates the delta's ``enc_groups`` — one group per wire encoding the
    tracker chose (a fixed-policy leaf has at most one; the adaptive
    error-bound selector can split one checkpoint's changed chunks across
    q4 / q8 / raw). Raw rows: gathered u32 blocks back to native bytes,
    last chunk trimmed to the leaf's real length. q8 / q4 rows: already
    int8 (resp. packed-nibble) + scales from the fused gather kernels —
    packed into the self-describing chunk formats (per-chunk element count,
    so the last chunk trims the same way). Returns (idx_keep, chunks_keep,
    encs_keep, transferred_bytes) with the three lists parallel and sorted
    by chunk index."""
    nbytes, n_chunks = lmeta["nbytes"], lmeta["n_chunks"]
    dtype = lmeta["dtype"]
    itemsize = 2 if dtype in ("bfloat16", "float16") else 4
    total_elems = nbytes // itemsize
    chunk_native = chunk_words * native_bytes_per_word(dtype)
    out: dict[int, tuple[str, bytes]] = {}
    for gr in d["enc_groups"]:
        e = gr["enc"]
        if e == "q8":
            block = min(Q8_BLOCK, chunk_words)
            for j, i in enumerate(gr["idx"].tolist()):
                n_el = chunk_words if i < n_chunks - 1 \
                    else total_elems - (n_chunks - 1) * chunk_words
                out[int(i)] = ("q8", q8_encode_chunk(
                    gr["q"][j], gr["scales"][j], n_el, block))
        elif e == "q4":
            block = min(Q4_BLOCK, chunk_words)
            for j, i in enumerate(gr["idx"].tolist()):
                n_el = chunk_words if i < n_chunks - 1 \
                    else total_elems - (n_chunks - 1) * chunk_words
                out[int(i)] = ("q4", q4_encode_chunk(
                    gr["packed"][j], gr["scales"][j], n_el, block))
        else:
            native = blocks_to_native_bytes(gr["blocks"], dtype)
            # tracker clamps changed_idx to the leaf's real chunk count, so
            # every row lands in [0, n_chunks); only the last needs trimming
            for i, data in zip(gr["idx"].tolist(), native):
                if i == n_chunks - 1:
                    data = data[: nbytes - (n_chunks - 1) * chunk_native]
                out[int(i)] = ("raw", data)
    idx_keep = sorted(out)
    encs_keep = [out[i][0] for i in idx_keep]
    chunks_keep = [out[i][1] for i in idx_keep]
    return idx_keep, chunks_keep, encs_keep, \
        sum(len(c) for c in chunks_keep)


def _match_slot(pstr: str, pat: str) -> bool:
    """True when a keystr leaf path matches a slot name or glob pattern."""
    return (f"['{pat}']" in pstr or f'["{pat}"]' in pstr
            or f".{pat}" in pstr or fnmatch.fnmatch(pstr, pat))


def _fp_view(leaf):
    """The array the fingerprint actually runs over. 64-bit HOST leaves get
    a bit-preserving u32 view: jit would silently downcast them when jax x64
    is disabled, corrupting the stored bytes (native_bytes_per_word is 4
    either way). Shared by submit() and warm_start() so rehydrated digests
    are byte-for-byte comparable with recorded ones."""
    if isinstance(leaf, np.ndarray) and leaf.dtype.itemsize == 8:
        return np.ascontiguousarray(leaf).reshape(-1).view(np.uint32)
    return leaf


def _leaf_nbytes(leaf) -> int:
    if hasattr(leaf, "nbytes"):
        return int(leaf.nbytes)
    a = np.asarray(leaf)
    return int(a.nbytes)
