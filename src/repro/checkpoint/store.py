"""Content-addressed, chunked checkpoint store (lean checkpointing substrate).

Every pytree leaf is serialized to raw bytes, split into chunks, and stored
under its blake2b hash (compressed). A checkpoint is a small manifest mapping
leaf paths to chunk-hash lists.

Dedup IS the paper's "lean checkpointing" at chunk granularity: unchanged
leaves (frozen weights in fine-tuning, optimizer slots of frozen params,
repeated epochs after convergence) share chunks with earlier checkpoints, so
the marginal bytes of a checkpoint track what actually CHANGED — without any
static analysis, because JAX state is explicit (DESIGN.md section 2).

Three manifest generations coexist:

* v1 (``put_tree``) — full manifests; every leaf lists every chunk hash.
* v2 (older pipeline manifests) — ``kind`` is ``"full"`` or ``"delta"``. A
  delta manifest names a ``parent`` key and stores only the chunk hashes
  that changed since the parent; unchanged hashes are inherited by walking
  the parent chain at read time (``resolve_manifest``). The pipeline bounds
  chain length by writing a full manifest every K checkpoints, so
  resolution never chases unbounded history.
* v3 (written by ``checkpoint/pipeline.py``) — v2 plus per-chunk ENCODINGS:
  a chunk body is either raw native bytes or a self-describing blockwise
  int8 payload (``"q8"``, kernels/ops.py wire codec). Encodings resolve
  through the parent chain exactly like hashes, and ``get_tree``
  dequantizes transparently, so readers never care which generation wrote a
  chunk.
* v4 (``kind == "sharded"``, mesh-aware pipeline) — a STITCHING manifest: a
  run recorded on a device mesh writes one ordinary v3 full/delta member
  manifest per STORE SHARD (simulated host), each covering only the device
  shards that host owns, plus a global v4 manifest recording the logical
  layout: per-leaf global shape, the recorded physical PartitionSpec, and
  each device shard's index bounds + owning store shard. Members chain
  deltas independently (``<key>.shard<h>`` -> ``<parent>.shard<h>``), so
  delta inheritance works per shard exactly as it does globally.
  ``resolve_manifest`` resolves every member chain; ``get_tree`` stitches —
  or, given a target ``NamedSharding``, reads ONLY the chunks the target
  layout overlaps and reshards (checkpoint/mesh.py), which is what lets an
  N-host recording replay bit-identically on an M-host or single-host mesh.

Multi-run sharing (run lineage). One store root may be SHARED by many runs:
each run gets a manifest namespace (``run_id``), so checkpoint keys like
``train@2.0`` never collide across runs, while the content-addressed
``objects/`` pool is shared — a fine-tune of a fine-tune stores (and, with
the warm-started pipeline, transfers) only true deltas against its ancestor
run. Cross-run references use QUALIFIED keys, ``"<run_id>::<key>"``
(``"::<key>"`` addresses the flat, un-namespaced layout explicitly — an
UNqualified key always binds to the handle's own namespace): a delta
manifest whose ``parent`` is qualified resolves through the parent run's
namespace transparently; unqualified parents resolve in the namespace of the
manifest that names them. Run records themselves (parent run, final keys,
status) live in ``checkpoint/lineage.py``'s ``RunRegistry`` beside the store.

``gc(live_keys)`` removes manifests outside the parent-closure of the live
set — ACROSS namespaces: a chunk survives while reachable from any live
manifest's chain, so deleting one run's registration reclaims only what no
surviving run inherits. Chunk writes are tmp+rename atomic: chunks are
cross-run shared state, and a truncated chunk from a killed writer must
never be silently inherited by a descendant run.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
import time
from typing import Any, Iterable, Optional

import numpy as np

from repro.utils.codec import Compressor, pack_obj, unpack_obj

CHUNK = 4 * 1024 * 1024

MANIFEST_VERSION = 3

_CURRENT_RUN = object()          # sentinel: list_keys() default namespace


def _leaf_to_np(x) -> np.ndarray:
    # jax.Array -> np via __array__; np passes through
    return np.asarray(x)


def _hash(b: bytes) -> str:
    return hashlib.blake2b(b, digest_size=16).hexdigest()


def np_dtype(name: str) -> np.dtype:
    """np.dtype from a manifest dtype string, including ml_dtypes names
    (``bfloat16`` etc.) that plain numpy does not understand."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


class CheckpointStore:
    """Thread-safe on-disk store, shareable across runs. Layout:
       <root>/objects/<h[:2]>/<h>.zst        — chunk payloads (shared pool)
       <root>/shards/<host>/objects/...      — per-store-shard pools (mesh
                                               record: each simulated host's
                                               local disk; same addressing)
       <root>/manifests/<key>.msgpack        — un-namespaced manifests
       <root>/manifests/<run>/<key>.msgpack  — per-run manifest namespaces
       <root>/meta/[<run>/]<name>.json       — run-level metadata
       <root>/runs/<run>.json                — RunRegistry records (lineage.py)
    (File extensions are historical; the actual codec is sniffed from
    content, see utils/codec.py.)

    ``run_id`` selects the namespace unqualified keys read and write;
    ``None`` (the default, and the only mode before multi-run sharing) is
    the flat un-namespaced layout. Keys of the form ``"<run>::<key>"`` are
    fully qualified and address any namespace from any handle.
    """

    def __init__(self, root: str, compress_level: int = 3,
                 run_id: Optional[str] = None,
                 prefer_shards: Optional[Iterable] = None):
        self.root = root
        self.run_id = run_id
        # shard-pool read affinity: a multi-host replay worker that only has
        # its own host's pool mounted locally lists those shard ids here, so
        # fallback chunk scans hit local disk first. Purely an ORDERING —
        # content addressing keeps every pool a valid source, so resharded
        # restores that need another host's chunks still work when the
        # store root is shared (network FS).
        self.prefer_shards = [str(s) for s in (prefer_shards or ())]
        os.makedirs(os.path.join(root, "objects"), exist_ok=True)
        os.makedirs(os.path.join(root, "manifests"), exist_ok=True)
        os.makedirs(os.path.join(root, "meta"), exist_ok=True)
        self._codec = Compressor(level=compress_level)
        self._lock = threading.Lock()
        # objects/<h[:2]>/ (and manifest-namespace) fan-out dirs, cached to
        # avoid a mkdir syscall on every chunk (the delta pipeline writes
        # many small chunks)
        self._dirs: set[str] = set()

    # ------------------------------------------------------------ naming --
    def _split_key(self, key: str) -> tuple[Optional[str], str]:
        """(run namespace, run-local key). Unqualified keys belong to this
        handle's namespace."""
        if "::" in key:
            rid, k = key.split("::", 1)
            return rid or None, k
        return self.run_id, key

    def _norm_key(self, key: str) -> tuple[Optional[str], str]:
        """Filesystem-space identity: (sanitized namespace | None, sanitized
        key). Idempotent for already-sanitized names, so raw keys
        ('train@2.0') and list_keys() output ('train_at_2.0') normalize to
        the same tuple."""
        rid, k = self._split_key(key)
        return (_safe(rid) if rid else None, _safe(k))

    def qualify(self, key: str) -> str:
        """This handle's fully-qualified form of a run-local key."""
        if self.run_id and "::" not in key:
            return f"{self.run_id}::{key}"
        return key

    def _ensure_dir(self, d: str):
        if d not in self._dirs:
            os.makedirs(d, exist_ok=True)
            self._dirs.add(d)

    # ------------------------------------------------------------ chunks --
    def _chunk_path(self, h: str, shard=None) -> str:
        """On-disk path of a chunk: the flat shared pool, or (``shard``)
        one store shard's pool — ``shards/<h(ost)>/objects/`` — which in a
        real deployment is that host's local disk."""
        if shard is None:
            base = os.path.join(self.root, "objects")
        else:
            base = os.path.join(self.root, "shards", str(shard), "objects")
        return os.path.join(base, h[:2], h + ".zst")

    def _shard_ids(self) -> list[str]:
        """Store shards with a chunk pool on disk (sorted numerically when
        possible so fallback scans are deterministic)."""
        d = os.path.join(self.root, "shards")
        if not os.path.isdir(d):
            return []
        ids = [e for e in os.listdir(d)
               if os.path.isdir(os.path.join(d, e))]
        return sorted(ids, key=lambda s: (not s.isdigit(),
                                          int(s) if s.isdigit() else s))

    def _find_chunk(self, h: str, shard=None) -> Optional[str]:
        """Locate a chunk, preferring ``shard``'s pool, then the flat pool,
        then every other shard pool. Content addressing makes any copy
        valid; the fallback keeps reads working when a tree is restored on
        a different mesh shape than recorded it."""
        cands = []
        if shard is not None:
            cands.append(self._chunk_path(h, shard))
        for s in self.prefer_shards:
            if shard is None or str(shard) != s:
                cands.append(self._chunk_path(h, s))
        cands.append(self._chunk_path(h))
        seen = {str(shard)} if shard is not None else set()
        seen.update(self.prefer_shards)
        for s in self._shard_ids():
            if s in seen:
                continue
            cands.append(self._chunk_path(h, s))
        for p in cands:
            if os.path.exists(p):
                return p
        return None

    def put_chunk(self, data: bytes, shard=None) -> tuple[str, int, bool]:
        """Store one content-addressed chunk (``shard`` selects a store
        shard's pool — bytes recorded on a host land on that host's disk).
        Returns (hash, compressed_bytes_written, was_new)."""
        h = _hash(data)
        path = self._chunk_path(h, shard)
        if os.path.exists(path):
            return h, 0, False
        self._ensure_dir(os.path.dirname(path))
        payload = self._codec.compress(data)
        _atomic_write(path, payload)   # chunks are cross-run shared state
        return h, len(payload), True

    # kept under the old private name too — tests and older callers use it
    _put_chunk = put_chunk

    def get_chunk(self, h: str, shard=None) -> bytes:
        path = self._chunk_path(h, shard)
        if not os.path.exists(path):
            found = self._find_chunk(h, shard)
            if found is None:
                raise FileNotFoundError(
                    f"chunk {h} not in any pool of {self.root}")
            path = found
        with open(path, "rb") as f:
            return self._codec.decompress(f.read())

    _get_chunk = get_chunk

    def _iter_chunk_files(self):
        """Every chunk file across the flat pool and all shard pools as
        (path, filename) — the single sweep gc/stats share."""
        pools = [os.path.join(self.root, "objects")]
        pools += [os.path.join(self.root, "shards", s, "objects")
                  for s in self._shard_ids()]
        for pool in pools:
            for dirpath, _, files in os.walk(pool):
                for fn in files:
                    yield os.path.join(dirpath, fn), fn

    # --------------------------------------------------------- manifests --
    def _mpath(self, rid_safe: Optional[str], key_safe: str) -> str:
        parts = [self.root, "manifests"]
        if rid_safe:
            parts.append(rid_safe)
        parts.append(key_safe + ".msgpack")
        return os.path.join(*parts)

    def _manifest_path(self, key: str) -> str:
        return self._mpath(*self._norm_key(key))

    def put_manifest(self, manifest: dict, key: Optional[str] = None):
        """Atomically persist a manifest (crash-safe tmp+rename). ``key``
        defaults to the manifest's own (run-local) key."""
        mpath = self._manifest_path(key if key is not None
                                    else manifest["key"])
        self._ensure_dir(os.path.dirname(mpath))
        _atomic_write(mpath, pack_obj(manifest))

    def get_manifest(self, key: str) -> dict:
        with open(self._manifest_path(key), "rb") as f:
            return unpack_obj(f.read())

    def delete_manifest(self, key: str, delete_chunks: bool = False):
        """Remove one manifest; optionally its directly-listed chunks.
        ``delete_chunks`` is only safe when the caller knows the chunks are
        not shared (e.g. the unique random calibration probe)."""
        if delete_chunks:
            try:
                m = self.get_manifest(key)
            except FileNotFoundError:
                m = None
            if m is not None:
                for h in _manifest_chunk_hashes(m):
                    try:
                        os.remove(self._chunk_path(h))
                    except FileNotFoundError:
                        pass
        try:
            os.remove(self._manifest_path(key))
        except FileNotFoundError:
            pass

    def _load_tuple(self, t: tuple, cache: dict) -> Optional[dict]:
        """Memoized manifest read by normalized (rid, key) tuple; None for a
        missing file. Shared by stats() and gc() so each manifest is read at
        most once per pass."""
        if t not in cache:
            try:
                with open(self._mpath(*t), "rb") as f:
                    cache[t] = unpack_obj(f.read())
            except FileNotFoundError:
                cache[t] = None
        return cache[t]

    def _parent_of(self, manifest: dict,
                   child_rid_safe: Optional[str]) -> Optional[tuple]:
        """Normalized (rid, key) of a manifest's parent. Unqualified parents
        live in the same namespace as the child manifest."""
        parent = manifest.get("parent")
        if not parent:
            return None
        if "::" in parent:
            rid, k = parent.split("::", 1)
            return (_safe(rid) if rid else None, _safe(k))
        return (child_rid_safe, _safe(parent))

    def resolve_manifest(self, key: str, _max_depth: int = 10_000) -> dict:
        """Return a manifest with every leaf's full chunk-hash list, walking
        the delta parent chain as needed — across run namespaces when the
        chain crosses a run boundary (warm-started derived runs). v1 and
        full v2 manifests return (normalized) as-is."""
        cur_rid, _ = self._split_key(key)
        manifest = self.get_manifest(key)
        if manifest.get("kind") == "sharded":
            # v4 stitching manifest: resolve every member chain. Members are
            # plain v3 full/delta manifests (one per store shard) living in
            # the SAME namespace as the global key, so each member chain
            # inherits deltas independently, across run lineage included.
            resolved = dict(manifest)
            members: dict[int, dict] = {}
            hops = 0
            for hid, mkey in (manifest.get("members") or {}).items():
                mres = self.resolve_manifest(f"{cur_rid or ''}::{mkey}",
                                             _max_depth=_max_depth)
                members[int(hid)] = mres
                hops = max(hops, int(mres.get("hops", 0)))
            resolved["members_resolved"] = members
            # a restore pays the DEEPEST member chain (shards resolve in
            # parallel on their owning hosts)
            resolved["hops"] = hops
            return resolved
        if manifest.get("version", 1) < 2 or manifest.get("kind", "full") == "full":
            return manifest
        # delta: seed hole-filled lists from this manifest, then walk
        # parents. Per-chunk encodings (v3) resolve alongside the hashes: an
        # enc slot is filled from whichever manifest supplied the chunk.
        leaves = []
        unresolved: dict[str, dict] = {}
        for leaf in manifest["leaves"]:
            n = int(leaf["n_chunks"])
            if leaf.get("chunks"):
                # already-complete list (e.g. a re-saved resolved manifest)
                chunks = list(leaf["chunks"])
                enc = list(leaf.get("enc") or ["raw"] * n)
            else:
                chunks = [None] * n
                enc = [None] * n
                denc = leaf.get("denc") or {}
                for i, h in (leaf.get("delta") or {}).items():
                    chunks[int(i)] = h
                    enc[int(i)] = denc.get(i, "raw")
            out = dict(leaf)
            out.pop("delta", None)
            out.pop("denc", None)
            out["chunks"] = chunks
            out["_enc"] = enc
            leaves.append(out)
            if any(c is None for c in chunks):
                unresolved[leaf["path"]] = out
        parent = manifest.get("parent")
        depth = 0
        while unresolved and parent is not None:
            depth += 1
            if depth > _max_depth:
                raise RuntimeError(f"delta chain too deep resolving {key!r}")
            if "::" in parent:
                cur_rid, parent = parent.split("::", 1)
                cur_rid = cur_rid or None
            # always re-qualify: "::key" is the explicit flat form — a bare
            # key would rebind to THIS handle's namespace
            pkey = f"{cur_rid or ''}::{parent}"
            try:
                pm = self.get_manifest(pkey)
            except FileNotFoundError as e:
                raise RuntimeError(
                    f"delta manifest {key!r} references missing parent "
                    f"{pkey!r} — deleted outside store.gc (which retains "
                    f"the parent closure across run lineage)?") from e
            by_path = {lf["path"]: lf for lf in pm["leaves"]}
            for path, out in list(unresolved.items()):
                src = by_path.get(path)
                if src is None:
                    continue
                if "chunks" in src and src["chunks"] is not None:
                    senc = src.get("enc")
                    for i, c in enumerate(out["chunks"]):
                        if c is None:
                            out["chunks"][i] = src["chunks"][i]
                            out["_enc"][i] = senc[i] if senc else "raw"
                else:
                    sdenc = src.get("denc") or {}
                    for i_s, h in (src.get("delta") or {}).items():
                        i = int(i_s)
                        if out["chunks"][i] is None:
                            out["chunks"][i] = h
                            out["_enc"][i] = sdenc.get(i_s, "raw")
                if all(c is not None for c in out["chunks"]):
                    del unresolved[path]
            parent = pm.get("parent") \
                if pm.get("version", 1) >= 2 and pm.get("kind") == "delta" \
                else None
        if unresolved:
            missing = {p: [i for i, c in enumerate(o["chunks"]) if c is None]
                       for p, o in unresolved.items()}
            raise RuntimeError(
                f"unresolvable delta manifest {key!r}: missing chunks "
                f"{missing} (parent chain broken — was the store gc'd with "
                f"an incomplete live set?)")
        for out in leaves:
            enc = ["raw" if e is None else e for e in out.pop("_enc")]
            if any(e != "raw" for e in enc):
                out["enc"] = enc
            else:
                out.pop("enc", None)
        resolved = dict(manifest)
        resolved["leaves"] = leaves
        # parent hops this resolution actually walked — restore accounting
        # feeds it to the learned cost model (calibration meta "hop_s")
        resolved["hops"] = depth
        return resolved

    # ------------------------------------------------------------- trees --
    def put_tree(self, key: str, tree: Any, meta: Optional[dict] = None) -> dict:
        """Serialize a pytree of arrays as a v1 full manifest.
        Returns stats incl. dedup savings. (The delta-aware record path lives
        in checkpoint/pipeline.py; this remains the simple whole-tree API.)"""
        import jax
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        leaves = []
        new_bytes = 0
        total_bytes = 0
        new_chunks = 0
        total_chunks = 0
        for path, leaf in flat:
            arr = _leaf_to_np(leaf)
            raw = arr.tobytes()
            chunks = []
            for off in range(0, max(len(raw), 1), CHUNK):
                piece = raw[off:off + CHUNK]
                h, nb, new = self.put_chunk(piece)
                chunks.append(h)
                new_bytes += nb
                total_bytes += len(piece)
                new_chunks += int(new)
                total_chunks += 1
            leaves.append({
                "path": jax.tree_util.keystr(path),
                "dtype": str(arr.dtype),
                "shape": list(arr.shape),
                "chunks": chunks,
            })
        manifest = {
            "key": self._split_key(key)[1],
            "treedef": str(treedef),
            "leaves": leaves,
            "meta": meta or {},
        }
        self.put_manifest(manifest, key=key)
        return {"key": key, "total_bytes": total_bytes, "new_bytes": new_bytes,
                "total_chunks": total_chunks, "new_chunks": new_chunks}

    def get_tree(self, key: str, like: Any = None,
                 manifest: Optional[dict] = None,
                 stats_out: Optional[dict] = None):
        """Load a checkpoint (delta manifests resolve transparently, across
        run lineage). If `like` (a pytree with the same structure) is given,
        arrays are unflattened into that structure; otherwise a flat
        {path: array} dict is returned. Pass a pre-``resolve_manifest``'d
        `manifest` to skip re-resolution (warm-start reads it anyway).
        Returned arrays are WRITABLE copies — np.frombuffer views are
        read-only and silently break in-place consumers.

        v4 sharded manifests stitch through checkpoint/mesh.py: a `like`
        leaf carrying a ``NamedSharding`` restores SELECTIVELY (only the
        chunks its target shards overlap are read) and comes back as a
        sharded ``jax.Array``; other leaves stitch to full numpy arrays.
        ``stats_out`` (a dict, sharded path only) receives read accounting:
        {chunks_read, bytes_by_shard}."""
        import jax
        if manifest is None:
            manifest = self.resolve_manifest(key)
        if manifest.get("kind") == "sharded":
            from repro.checkpoint.mesh import stitch_tree
            return stitch_tree(self, manifest, like=like,
                               stats_out=stats_out)
        arrays = []
        for leaf in manifest["leaves"]:
            dt = np_dtype(leaf["dtype"])
            enc = leaf.get("enc")
            if enc and any(e != "raw" for e in enc):
                # encoded chunks decode transparently to native bytes — q8,
                # q4, and entropy-compressed ("+z") payloads alike (deferred
                # import: the wire codecs live with the kernels, and the
                # store stays importable without pulling jax)
                from repro.kernels.ops import decode_wire_chunk
                raw = b"".join(
                    decode_wire_chunk(self.get_chunk(h), e, dt)
                    for h, e in zip(leaf["chunks"], enc))
            else:
                raw = b"".join(self.get_chunk(h) for h in leaf["chunks"])
            nbytes = int(leaf.get("nbytes",
                                  int(np.prod(leaf["shape"], dtype=np.int64))
                                  * dt.itemsize))
            arr = np.frombuffer(raw[:nbytes], dtype=dt).copy()
            arrays.append(arr.reshape(leaf["shape"]))
        if like is not None:
            flat, treedef = jax.tree_util.tree_flatten(like)
            assert len(flat) == len(arrays), \
                f"structure mismatch: {len(flat)} vs {len(arrays)}"
            return jax.tree_util.tree_unflatten(treedef, arrays)
        return {leaf["path"]: a for leaf, a in zip(manifest["leaves"], arrays)}

    def has(self, key: str) -> bool:
        return os.path.exists(self._manifest_path(key))

    def list_keys(self, run=_CURRENT_RUN) -> list[str]:
        """Sanitized run-local manifest names in one namespace (default:
        this handle's)."""
        rid = self.run_id if run is _CURRENT_RUN else run
        d = os.path.join(self.root, "manifests")
        if rid:
            d = os.path.join(d, _safe(rid))
        if not os.path.isdir(d):
            return []
        return sorted(f[: -len(".msgpack")] for f in os.listdir(d)
                      if f.endswith(".msgpack")
                      and not os.path.isdir(os.path.join(d, f)))

    def list_namespaces(self) -> list[str]:
        """Sanitized run namespaces that have at least one manifest dir."""
        d = os.path.join(self.root, "manifests")
        return sorted(e for e in os.listdir(d)
                      if os.path.isdir(os.path.join(d, e)))

    def _iter_manifest_tuples(self):
        """Every manifest in the store as (rid_safe | None, key_safe)."""
        for k in self.list_keys(run=None):
            yield (None, k)
        for rid in self.list_namespaces():
            for k in self.list_keys(run=rid):
                yield (rid, k)

    # --------------------------------------------------------------- stats --
    def stats(self, keys: Optional[Iterable[str]] = None,
              include_chunks: bool = True, per_key: bool = False) -> dict:
        """Single-pass, memoized summary of manifests (default: the whole
        store; pass `keys` — possibly qualified — to restrict to one run's
        manifests while chain depths still follow parents across runs).
        Returns {manifests, full_manifests, delta_manifests, max_chain_depth,
        chunks, stored_bytes}. Chain depth is the number of parent hops a
        restore resolves; broken links (missing parents) end the chain
        rather than raising — this is a diagnostic, not a restore.
        `include_chunks=False` skips the objects-pool walk (O(store) stat
        calls on a large shared pool) and reports chunks/stored_bytes as
        0 — use it when only manifest counts/depths are needed.
        `per_key=True` adds a ``per_key`` map {input key: {depth, kind,
        direct_chunks}} — the resume-cost raw material the replay planner
        turns into per-segment estimates."""
        cache: dict[tuple, Optional[dict]] = {}

        def load(t):
            return self._load_tuple(t, cache)

        if keys is not None:
            key_list = list(keys)
            targets = [self._norm_key(k) for k in key_list]
        else:
            key_list = None
            targets = list(self._iter_manifest_tuples())
        depth: dict[tuple, int] = {}
        counts = {"full": 0, "delta": 0}
        max_depth = 0
        n_manifests = 0
        info: dict[tuple, dict] = {}

        def walk(t0) -> int:
            """Chain depth of one manifest tuple — walk up to the first
            memoized ancestor (or the chain end), then unwind; every
            manifest is read at most once store-wide."""
            chain: list[tuple] = []
            seen: set[tuple] = set()
            t = t0
            while t is not None and t not in depth and t not in seen:
                seen.add(t)
                mm = load(t)
                if mm is None:
                    depth[t] = 0          # broken link: chain ends here
                    break
                chain.append(t)
                t = self._parent_of(mm, t[0])
            for node in reversed(chain):
                p = self._parent_of(load(node), node[0])
                depth[node] = depth[p] + 1 if p is not None and p in depth \
                    else (1 if p is not None and p in seen else 0)
            return depth.get(t0, 0)

        for t0 in targets:
            m = load(t0)
            if m is None:
                continue
            n_manifests += 1
            kind = m.get("kind", "full") if m.get("version", 1) >= 2 else "full"
            counts[kind] = counts.get(kind, 0) + 1
            d0 = walk(t0)
            shards_info = None
            if kind == "sharded":
                # v4: depth/chunks live on the per-store-shard member
                # chains; a restore pays the deepest one (shards resolve in
                # parallel), so that is the depth reported for the key
                shards_info = {}
                for hid, mkey in (m.get("members") or {}).items():
                    mt = (t0[0], _safe(mkey))
                    mm = load(mt)
                    if mm is None:
                        continue
                    shards_info[str(hid)] = {
                        "depth": walk(mt),
                        "chunks": sum(1 for _ in _manifest_chunk_hashes(mm)),
                    }
                if shards_info:
                    d0 = max(s["depth"] for s in shards_info.values())
            max_depth = max(max_depth, d0)
            if per_key:
                direct = sum(1 for _ in _manifest_chunk_hashes(m))
                encc = _manifest_enc_counts(m)
                if shards_info:
                    direct = sum(s["chunks"] for s in shards_info.values())
                    encc = {}
                    for hid, mkey in (m.get("members") or {}).items():
                        mm = load((t0[0], _safe(mkey)))
                        if mm is None:
                            continue
                        for e, c in _manifest_enc_counts(mm).items():
                            encc[e] = encc.get(e, 0) + c
                info[t0] = {"depth": d0, "kind": kind,
                            "direct_chunks": direct,
                            "enc_counts": encc}
                if shards_info is not None:
                    info[t0]["shards"] = shards_info
        chunks = 0
        stored = 0
        if include_chunks:
            for p, fn in self._iter_chunk_files():
                if fn.endswith(".zst"):
                    chunks += 1
                    stored += os.path.getsize(p)
        out = {"manifests": n_manifests,
               "full_manifests": counts.get("full", 0),
               "delta_manifests": counts.get("delta", 0),
               "sharded_manifests": counts.get("sharded", 0),
               "max_chain_depth": max_depth,
               "chunks": chunks, "stored_bytes": stored}
        if per_key:
            if key_list is not None:
                out["per_key"] = {k: info[self._norm_key(k)]
                                  for k in key_list
                                  if self._norm_key(k) in info}
            else:
                # whole-store pass: qualified "rid::key" form ("::key" =
                # explicit flat namespace)
                out["per_key"] = {f"{rid or ''}::{k}": v
                                  for (rid, k), v in info.items()}
        return out

    def encoding_mix(self, key: str) -> dict:
        """Resolved per-encoding storage mix of one checkpoint: for every
        chunk a restore of `key` reads (chain-inherited included),
        {enc: {"chunks": n, "stored_bytes": b}} with b the on-disk
        (compressed) size — dedup-shared chunks count once per reference,
        matching what a restore actually reads. v4 sharded keys aggregate
        over all member manifests."""
        m = self.resolve_manifest(key)
        mix: dict[str, dict] = {}
        size_cache: dict[str, int] = {}

        def chunk_size(h: str) -> int:
            if h not in size_cache:
                p = self._find_chunk(h)
                try:
                    size_cache[h] = os.path.getsize(p) if p else 0
                except OSError:
                    size_cache[h] = 0
            return size_cache[h]

        def add_leaves(leaves):
            for leaf in leaves:
                enc = leaf.get("enc")
                for i, h in enumerate(leaf.get("chunks") or []):
                    if h is None:
                        continue
                    e = enc[i] if enc else "raw"
                    d = mix.setdefault(e, {"chunks": 0, "stored_bytes": 0})
                    d["chunks"] += 1
                    d["stored_bytes"] += chunk_size(h)

        if m.get("kind") == "sharded":
            for mm in (m.get("members_resolved") or {}).values():
                add_leaves(mm["leaves"])
        else:
            add_leaves(m["leaves"])
        return mix

    # ------------------------------------------------------------ closure --
    def _parent_closure(self, keys: Iterable[str],
                        cache: dict) -> set[tuple]:
        """Normalized (rid, key) tuples of `keys` plus every ancestor their
        delta chains resolve through (across run namespaces) AND, for v4
        sharded manifests, their per-store-shard member manifests — a live
        stitching manifest pins every shard chain it stitches, so multi-run
        gc can never collect a live shard's chunks. Tuples whose manifest is
        missing are dropped."""
        live = {self._norm_key(k) for k in keys}
        frontier = list(live)
        while frontier:
            t = frontier.pop()
            m = self._load_tuple(t, cache)
            if m is None:
                live.discard(t)
                continue
            nxt = []
            p = self._parent_of(m, t[0])
            if p is not None:
                nxt.append(p)
            # sharded (v4) members live in the global key's namespace
            for mkey in (m.get("members") or {}).values():
                nxt.append((t[0], _safe(mkey)))
            for p in nxt:
                if p not in live:
                    live.add(p)
                    frontier.append(p)
        return live

    def closure_chunks(self, keys: Iterable[str]) -> set[str]:
        """Every chunk hash reachable from `keys`' manifest parent closure —
        the byte footprint a set of checkpoints actually pins. Two runs'
        closures intersected/differenced give the `runs diff` view of what
        lineage sharing saves."""
        cache: dict[tuple, Optional[dict]] = {}
        hashes: set[str] = set()
        for t in self._parent_closure(keys, cache):
            m = self._load_tuple(t, cache)
            if m is not None:
                hashes.update(_manifest_chunk_hashes(m))
        return hashes

    def chunk_bytes(self, hashes: Iterable[str]) -> int:
        """On-disk (compressed) bytes of the given chunk hashes, wherever
        they live (flat or shard pools); missing chunks count 0."""
        total = 0
        for h in hashes:
            p = self._find_chunk(h)
            if p is not None:
                try:
                    total += os.path.getsize(p)
                except OSError:
                    pass
        return total

    # ---------------------------------------------------------------- gc --
    def gc(self, live_keys: Iterable[str]) -> dict:
        """Delete manifests outside the parent-closure of ``live_keys`` and
        every chunk no surviving manifest references. The closure follows
        delta parents ACROSS run namespaces (qualified ``run::key`` refs), so
        a derived run pins exactly the ancestor manifests its chain resolves
        through — a chunk survives while ANY live run can still reach it.
        Returns {kept_manifests, deleted_manifests, kept_chunks,
        deleted_chunks, deleted_bytes}."""
        with self._lock:
            cache: dict[tuple, Optional[dict]] = {}

            def load(t):
                return self._load_tuple(t, cache)

            # normalize to filesystem-space (rid, key) tuples (callers pass
            # raw keys, listings yield sanitized names) and take the parent
            # closure: a live delta manifest pins its ancestry, run
            # boundaries included
            live = self._parent_closure(live_keys, cache)
            referenced: set[str] = set()
            deleted_manifests = 0
            namespaces: set[Optional[str]] = set()
            for t in list(self._iter_manifest_tuples()):
                namespaces.add(t[0])
                if t not in live:
                    try:
                        os.remove(self._mpath(*t))
                    except FileNotFoundError:
                        pass
                    deleted_manifests += 1
                    continue
                m = load(t)
                if m is not None:
                    referenced.update(_manifest_chunk_hashes(m))
            for rid in namespaces:       # drop emptied namespace dirs
                if rid:
                    try:
                        os.rmdir(os.path.join(self.root, "manifests", rid))
                    except OSError:
                        pass
            kept = deleted = deleted_bytes = deleted_tmp = 0
            now = time.time()
            # sweep the flat pool AND every store shard's pool — a chunk
            # hash is live wherever it lives
            for p, fn in self._iter_chunk_files():
                if not fn.endswith(".zst"):
                    # stray .tmp from a KILLED writer (the in-process
                    # failure path unlinks its own): reclaim once aged —
                    # a live writer holds a tmp for milliseconds, so the
                    # age gate never races an in-flight _atomic_write
                    deleted_tmp += _reclaim_stale_tmp(p, now)
                    continue
                h = fn[: -len(".zst")]
                if h in referenced:
                    kept += 1
                else:
                    deleted_bytes += os.path.getsize(p)
                    os.remove(p)
                    deleted += 1
            for dirpath, _, files in os.walk(os.path.join(self.root,
                                                          "manifests")):
                for fn in files:
                    if not fn.endswith(".msgpack"):
                        deleted_tmp += _reclaim_stale_tmp(
                            os.path.join(dirpath, fn), now)
            return {"kept_manifests": len(live), "deleted_manifests": deleted_manifests,
                    "kept_chunks": kept, "deleted_chunks": deleted,
                    "deleted_bytes": deleted_bytes,
                    "deleted_tmp_files": deleted_tmp}

    # -------------------------------------------------------------- meta --
    def _meta_path(self, name: str) -> str:
        parts = [self.root, "meta"]
        if self.run_id:
            parts.append(_safe(self.run_id))
        parts.append(_safe(name) + ".json")
        return os.path.join(*parts)

    def put_meta(self, name: str, obj: dict):
        path = self._meta_path(name)
        self._ensure_dir(os.path.dirname(path))
        _atomic_write(path, json.dumps(obj, indent=1, default=str).encode())

    def get_meta(self, name: str) -> Optional[dict]:
        path = self._meta_path(name)
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)

    def stored_bytes(self) -> int:
        total = 0
        for p, _ in self._iter_chunk_files():
            total += os.path.getsize(p)
        return total

    def shard_stored_bytes(self) -> dict:
        """On-disk bytes per store shard pool — the `runs show` per-shard
        breakdown."""
        out: dict[str, int] = {}
        for s in self._shard_ids():
            total = 0
            pool = os.path.join(self.root, "shards", s, "objects")
            for dirpath, _, files in os.walk(pool):
                for fn in files:
                    total += os.path.getsize(os.path.join(dirpath, fn))
            out[s] = total
        return out


def _atomic_write(path: str, payload: bytes):
    """Crash-safe write: tmp file + atomic rename, tmp unlinked on failure.
    A killed writer can leave a stray ``*.tmp.*`` (ignored by every reader
    and by gc's chunk sweep) but never a truncated object under its final
    name — which matters doubly now that chunks are shared across runs."""
    tmp = path + f".tmp.{os.getpid()}.{threading.get_ident()}"
    try:
        with open(tmp, "wb") as f:
            f.write(payload)
        os.replace(tmp, path)          # atomic: crash-safe
    except BaseException:
        try:
            os.remove(tmp)
        except OSError:
            pass
        raise


STALE_TMP_S = 60.0       # a live _atomic_write holds its tmp far less


def _reclaim_stale_tmp(path: str, now: float) -> int:
    """Delete one stray ``*.tmp.*`` file if it is old enough that no live
    writer can still own it. Returns 1 if reclaimed."""
    if ".tmp." not in os.path.basename(path):
        return 0
    try:
        if now - os.path.getmtime(path) > STALE_TMP_S:
            os.remove(path)
            return 1
    except OSError:
        pass
    return 0


def _manifest_chunk_hashes(manifest: dict):
    """Every chunk hash DIRECTLY listed by a manifest (no chain resolution —
    ancestors list their own)."""
    for leaf in manifest["leaves"]:
        for h in leaf.get("chunks") or []:
            if h is not None:
                yield h
        for h in (leaf.get("delta") or {}).values():
            yield h


def _manifest_enc_counts(manifest: dict) -> dict:
    """Per-encoding chunk counts of the chunks DIRECTLY listed by a manifest
    (chunks without a recorded encoding count as "raw")."""
    counts: dict[str, int] = {}
    for leaf in manifest.get("leaves") or []:
        enc = leaf.get("enc")
        for i, h in enumerate(leaf.get("chunks") or []):
            if h is None:
                continue
            e = enc[i] if enc else "raw"
            counts[e] = counts.get(e, 0) + 1
        denc = leaf.get("denc") or {}
        for i in (leaf.get("delta") or {}):
            e = denc.get(i, "raw")
            counts[e] = counts.get(e, 0) + 1
    return counts


_MEMBER_RE = None


def member_base(key: str) -> Optional[str]:
    """Base checkpoint key of a sharded MEMBER manifest name
    (``train_at_2.0.shard3`` -> ``train_at_2.0``; raw ``train@2.0.shard3``
    works too); ``None`` for non-member keys. Used by live-set construction
    (lineage.live_keys, context gc): a member whose global v4 stitch was
    never written — a host crashed between member publication and the
    stitch — must NOT seed the gc closure, or the orphans it left would be
    pinned forever. Members of STITCHED checkpoints need no seeding: the
    v4 manifest pulls them (and, through per-shard parent chains, every
    incomplete predecessor a later delta still inherits from) into the
    closure."""
    global _MEMBER_RE
    if _MEMBER_RE is None:
        import re
        _MEMBER_RE = re.compile(r"^(?P<base>.+)\.shard\d+$")
    m = _MEMBER_RE.match(key)
    return m.group("base") if m else None


def filter_orphan_members(keys: Iterable[str]) -> list[str]:
    """Drop member-manifest names whose base (stitched v4) key is absent
    from the SAME listing — the gc-seed form of the orphan rule above."""
    keys = list(keys)
    present = set(keys)
    return [k for k in keys
            if (lambda b: b is None or b in present)(member_base(k))]


def _safe(key: str) -> str:
    return key.replace("/", "_").replace("@", "_at_").replace(":", "_")
