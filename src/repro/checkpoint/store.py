"""Content-addressed, chunked checkpoint store (lean checkpointing substrate).

Every pytree leaf is serialized to raw bytes, split into fixed-size chunks,
and stored under its blake2b hash (zstd-compressed). A checkpoint is a small
msgpack manifest mapping leaf paths to chunk-hash lists.

Dedup IS the paper's "lean checkpointing" at chunk granularity: unchanged
leaves (frozen weights in fine-tuning, optimizer slots of frozen params,
repeated epochs after convergence) share chunks with earlier checkpoints, so
the marginal bytes of a checkpoint track what actually CHANGED — without any
static analysis, because JAX state is explicit (DESIGN.md section 2).
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
from typing import Any, Optional

import msgpack
import numpy as np
import zstandard as zstd

CHUNK = 4 * 1024 * 1024


def _leaf_to_np(x) -> np.ndarray:
    # jax.Array -> np via __array__; np passes through
    return np.asarray(x)


def _hash(b: bytes) -> str:
    return hashlib.blake2b(b, digest_size=16).hexdigest()


class CheckpointStore:
    """Thread-safe on-disk store. Layout:
       <root>/objects/<h[:2]>/<h>.zst      — chunk payloads
       <root>/manifests/<key>.msgpack      — checkpoint manifests
       <root>/meta/<name>.json             — run-level metadata
    """

    def __init__(self, root: str, compress_level: int = 3):
        self.root = root
        os.makedirs(os.path.join(root, "objects"), exist_ok=True)
        os.makedirs(os.path.join(root, "manifests"), exist_ok=True)
        os.makedirs(os.path.join(root, "meta"), exist_ok=True)
        self._level = compress_level
        # zstd (de)compressor objects are NOT thread-safe for concurrent
        # calls; keep per-thread instances (concurrent writers segfaulted)
        self._tl = threading.local()
        self._lock = threading.Lock()

    @property
    def _cctx(self):
        c = getattr(self._tl, "cctx", None)
        if c is None:
            c = self._tl.cctx = zstd.ZstdCompressor(level=self._level)
        return c

    @property
    def _dctx(self):
        d = getattr(self._tl, "dctx", None)
        if d is None:
            d = self._tl.dctx = zstd.ZstdDecompressor()
        return d

    # ------------------------------------------------------------ chunks --
    def _chunk_path(self, h: str) -> str:
        return os.path.join(self.root, "objects", h[:2], h + ".zst")

    def _put_chunk(self, data: bytes) -> tuple[str, int, bool]:
        """Returns (hash, bytes_written, was_new)."""
        h = _hash(data)
        path = self._chunk_path(h)
        if os.path.exists(path):
            return h, 0, False
        os.makedirs(os.path.dirname(path), exist_ok=True)
        payload = self._cctx.compress(data)
        tmp = path + f".tmp.{os.getpid()}.{threading.get_ident()}"
        with open(tmp, "wb") as f:
            f.write(payload)
        os.replace(tmp, path)          # atomic: crash-safe
        return h, len(payload), True

    def _get_chunk(self, h: str) -> bytes:
        with open(self._chunk_path(h), "rb") as f:
            return self._dctx.decompress(f.read())

    # ------------------------------------------------------------- trees --
    def put_tree(self, key: str, tree: Any, meta: Optional[dict] = None) -> dict:
        """Serialize a pytree of arrays. Returns stats incl. dedup savings."""
        import jax
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        leaves = []
        new_bytes = 0
        total_bytes = 0
        new_chunks = 0
        total_chunks = 0
        for path, leaf in flat:
            arr = _leaf_to_np(leaf)
            raw = arr.tobytes()
            chunks = []
            for off in range(0, max(len(raw), 1), CHUNK):
                piece = raw[off:off + CHUNK]
                h, nb, new = self._put_chunk(piece)
                chunks.append(h)
                new_bytes += nb
                total_bytes += len(piece)
                new_chunks += int(new)
                total_chunks += 1
            leaves.append({
                "path": jax.tree_util.keystr(path),
                "dtype": str(arr.dtype),
                "shape": list(arr.shape),
                "chunks": chunks,
            })
        manifest = {
            "key": key,
            "treedef": str(treedef),
            "leaves": leaves,
            "meta": meta or {},
        }
        mpath = os.path.join(self.root, "manifests", _safe(key) + ".msgpack")
        tmp = mpath + f".tmp.{os.getpid()}"
        with open(tmp, "wb") as f:
            f.write(msgpack.packb(manifest))
        os.replace(tmp, mpath)
        return {"key": key, "total_bytes": total_bytes, "new_bytes": new_bytes,
                "total_chunks": total_chunks, "new_chunks": new_chunks}

    def get_manifest(self, key: str) -> dict:
        mpath = os.path.join(self.root, "manifests", _safe(key) + ".msgpack")
        with open(mpath, "rb") as f:
            return msgpack.unpackb(f.read())

    def get_tree(self, key: str, like: Any = None):
        """Load a checkpoint. If `like` (a pytree with the same structure) is
        given, arrays are unflattened into that structure; otherwise a flat
        {path: array} dict is returned."""
        import jax
        manifest = self.get_manifest(key)
        arrays = []
        for leaf in manifest["leaves"]:
            raw = b"".join(self._get_chunk(h) for h in leaf["chunks"])
            arr = np.frombuffer(raw, dtype=np.dtype(leaf["dtype"]))
            arrays.append(arr.reshape(leaf["shape"]))
        if like is not None:
            flat, treedef = jax.tree_util.tree_flatten(like)
            assert len(flat) == len(arrays), \
                f"structure mismatch: {len(flat)} vs {len(arrays)}"
            return jax.tree_util.tree_unflatten(treedef, arrays)
        return {leaf["path"]: a for leaf, a in zip(manifest["leaves"], arrays)}

    def has(self, key: str) -> bool:
        return os.path.exists(os.path.join(self.root, "manifests",
                                           _safe(key) + ".msgpack"))

    def list_keys(self) -> list[str]:
        d = os.path.join(self.root, "manifests")
        return sorted(f[: -len(".msgpack")] for f in os.listdir(d)
                      if f.endswith(".msgpack"))

    # -------------------------------------------------------------- meta --
    def put_meta(self, name: str, obj: dict):
        path = os.path.join(self.root, "meta", _safe(name) + ".json")
        tmp = path + f".tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(obj, f, indent=1, default=str)
        os.replace(tmp, path)

    def get_meta(self, name: str) -> Optional[dict]:
        path = os.path.join(self.root, "meta", _safe(name) + ".json")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)

    def stored_bytes(self) -> int:
        total = 0
        for dirpath, _, files in os.walk(os.path.join(self.root, "objects")):
            for fn in files:
                total += os.path.getsize(os.path.join(dirpath, fn))
        return total


def _safe(key: str) -> str:
    return key.replace("/", "_").replace("@", "_at_").replace(":", "_")
