"""Content-addressed, chunked checkpoint store (lean checkpointing substrate).

Every pytree leaf is serialized to raw bytes, split into chunks, and stored
under its blake2b hash (compressed). A checkpoint is a small manifest mapping
leaf paths to chunk-hash lists.

Dedup IS the paper's "lean checkpointing" at chunk granularity: unchanged
leaves (frozen weights in fine-tuning, optimizer slots of frozen params,
repeated epochs after convergence) share chunks with earlier checkpoints, so
the marginal bytes of a checkpoint track what actually CHANGED — without any
static analysis, because JAX state is explicit (DESIGN.md section 2).

Two manifest generations coexist:

* v1 (``put_tree``) — full manifests; every leaf lists every chunk hash.
* v2 (written by ``checkpoint/pipeline.py``) — ``kind`` is ``"full"`` or
  ``"delta"``. A delta manifest names a ``parent`` key and stores only the
  chunk hashes that changed since the parent; unchanged hashes are inherited
  by walking the parent chain at read time (``resolve_manifest``). The
  pipeline bounds chain length by writing a full manifest every K
  checkpoints, so resolution never chases unbounded history.

``gc(live_keys)`` removes manifests outside the parent-closure of the live
set and any chunk no surviving manifest references — long record runs with
rolling retention stay bounded on disk.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
from typing import Any, Iterable, Optional

import numpy as np

from repro.utils.codec import Compressor, pack_obj, unpack_obj

CHUNK = 4 * 1024 * 1024

MANIFEST_VERSION = 2


def _leaf_to_np(x) -> np.ndarray:
    # jax.Array -> np via __array__; np passes through
    return np.asarray(x)


def _hash(b: bytes) -> str:
    return hashlib.blake2b(b, digest_size=16).hexdigest()


def np_dtype(name: str) -> np.dtype:
    """np.dtype from a manifest dtype string, including ml_dtypes names
    (``bfloat16`` etc.) that plain numpy does not understand."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


class CheckpointStore:
    """Thread-safe on-disk store. Layout:
       <root>/objects/<h[:2]>/<h>.zst      — chunk payloads
       <root>/manifests/<key>.msgpack      — checkpoint manifests
       <root>/meta/<name>.json             — run-level metadata
    (File extensions are historical; the actual codec is sniffed from
    content, see utils/codec.py.)
    """

    def __init__(self, root: str, compress_level: int = 3):
        self.root = root
        os.makedirs(os.path.join(root, "objects"), exist_ok=True)
        os.makedirs(os.path.join(root, "manifests"), exist_ok=True)
        os.makedirs(os.path.join(root, "meta"), exist_ok=True)
        self._codec = Compressor(level=compress_level)
        self._lock = threading.Lock()
        # objects/<h[:2]>/ fan-out dirs, cached to avoid a mkdir syscall on
        # every chunk (the delta pipeline writes many small chunks)
        self._dirs: set[str] = set()

    # ------------------------------------------------------------ chunks --
    def _chunk_path(self, h: str) -> str:
        return os.path.join(self.root, "objects", h[:2], h + ".zst")

    def put_chunk(self, data: bytes) -> tuple[str, int, bool]:
        """Store one content-addressed chunk.
        Returns (hash, compressed_bytes_written, was_new)."""
        h = _hash(data)
        path = self._chunk_path(h)
        if os.path.exists(path):
            return h, 0, False
        d = os.path.dirname(path)
        if d not in self._dirs:
            os.makedirs(d, exist_ok=True)
            self._dirs.add(d)
        payload = self._codec.compress(data)
        tmp = path + f".tmp.{os.getpid()}.{threading.get_ident()}"
        with open(tmp, "wb") as f:
            f.write(payload)
        os.replace(tmp, path)          # atomic: crash-safe
        return h, len(payload), True

    # kept under the old private name too — tests and older callers use it
    _put_chunk = put_chunk

    def get_chunk(self, h: str) -> bytes:
        with open(self._chunk_path(h), "rb") as f:
            return self._codec.decompress(f.read())

    _get_chunk = get_chunk

    # --------------------------------------------------------- manifests --
    def _manifest_path(self, key: str) -> str:
        return os.path.join(self.root, "manifests", _safe(key) + ".msgpack")

    def put_manifest(self, manifest: dict):
        """Atomically persist a manifest (crash-safe tmp+rename)."""
        mpath = self._manifest_path(manifest["key"])
        tmp = mpath + f".tmp.{os.getpid()}.{threading.get_ident()}"
        with open(tmp, "wb") as f:
            f.write(pack_obj(manifest))
        os.replace(tmp, mpath)

    def get_manifest(self, key: str) -> dict:
        with open(self._manifest_path(key), "rb") as f:
            return unpack_obj(f.read())

    def delete_manifest(self, key: str, delete_chunks: bool = False):
        """Remove one manifest; optionally its directly-listed chunks.
        ``delete_chunks`` is only safe when the caller knows the chunks are
        not shared (e.g. the unique random calibration probe)."""
        if delete_chunks:
            try:
                m = self.get_manifest(key)
            except FileNotFoundError:
                m = None
            if m is not None:
                for h in _manifest_chunk_hashes(m):
                    try:
                        os.remove(self._chunk_path(h))
                    except FileNotFoundError:
                        pass
        try:
            os.remove(self._manifest_path(key))
        except FileNotFoundError:
            pass

    def resolve_manifest(self, key: str, _max_depth: int = 10_000) -> dict:
        """Return a manifest with every leaf's full chunk-hash list, walking
        the delta parent chain as needed. v1 and full v2 manifests return
        (normalized) as-is."""
        manifest = self.get_manifest(key)
        if manifest.get("version", 1) < 2 or manifest.get("kind", "full") == "full":
            return manifest
        # delta: seed hole-filled lists from this manifest, then walk parents
        leaves = []
        unresolved: dict[str, dict] = {}
        for leaf in manifest["leaves"]:
            n = int(leaf["n_chunks"])
            if leaf.get("chunks"):
                # already-complete list (e.g. a re-saved resolved manifest)
                chunks = list(leaf["chunks"])
            else:
                chunks = [None] * n
                for i, h in (leaf.get("delta") or {}).items():
                    chunks[int(i)] = h
            out = dict(leaf)
            out.pop("delta", None)
            out["chunks"] = chunks
            leaves.append(out)
            if any(c is None for c in chunks):
                unresolved[leaf["path"]] = out
        parent = manifest.get("parent")
        depth = 0
        while unresolved and parent is not None:
            depth += 1
            if depth > _max_depth:
                raise RuntimeError(f"delta chain too deep resolving {key!r}")
            try:
                pm = self.get_manifest(parent)
            except FileNotFoundError as e:
                raise RuntimeError(
                    f"delta manifest {key!r} references missing parent "
                    f"{parent!r} — deleted outside store.gc (which retains "
                    f"the parent closure)?") from e
            by_path = {lf["path"]: lf for lf in pm["leaves"]}
            for path, out in list(unresolved.items()):
                src = by_path.get(path)
                if src is None:
                    continue
                if "chunks" in src and src["chunks"] is not None:
                    for i, c in enumerate(out["chunks"]):
                        if c is None:
                            out["chunks"][i] = src["chunks"][i]
                else:
                    for i, h in (src.get("delta") or {}).items():
                        i = int(i)
                        if out["chunks"][i] is None:
                            out["chunks"][i] = h
                if all(c is not None for c in out["chunks"]):
                    del unresolved[path]
            parent = pm.get("parent") \
                if pm.get("version", 1) >= 2 and pm.get("kind") == "delta" \
                else None
        if unresolved:
            missing = {p: [i for i, c in enumerate(o["chunks"]) if c is None]
                       for p, o in unresolved.items()}
            raise RuntimeError(
                f"unresolvable delta manifest {key!r}: missing chunks "
                f"{missing} (parent chain broken — was the store gc'd with "
                f"an incomplete live set?)")
        resolved = dict(manifest)
        resolved["leaves"] = leaves
        return resolved

    # ------------------------------------------------------------- trees --
    def put_tree(self, key: str, tree: Any, meta: Optional[dict] = None) -> dict:
        """Serialize a pytree of arrays as a v1 full manifest.
        Returns stats incl. dedup savings. (The delta-aware record path lives
        in checkpoint/pipeline.py; this remains the simple whole-tree API.)"""
        import jax
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        leaves = []
        new_bytes = 0
        total_bytes = 0
        new_chunks = 0
        total_chunks = 0
        for path, leaf in flat:
            arr = _leaf_to_np(leaf)
            raw = arr.tobytes()
            chunks = []
            for off in range(0, max(len(raw), 1), CHUNK):
                piece = raw[off:off + CHUNK]
                h, nb, new = self.put_chunk(piece)
                chunks.append(h)
                new_bytes += nb
                total_bytes += len(piece)
                new_chunks += int(new)
                total_chunks += 1
            leaves.append({
                "path": jax.tree_util.keystr(path),
                "dtype": str(arr.dtype),
                "shape": list(arr.shape),
                "chunks": chunks,
            })
        manifest = {
            "key": key,
            "treedef": str(treedef),
            "leaves": leaves,
            "meta": meta or {},
        }
        self.put_manifest(manifest)
        return {"key": key, "total_bytes": total_bytes, "new_bytes": new_bytes,
                "total_chunks": total_chunks, "new_chunks": new_chunks}

    def get_tree(self, key: str, like: Any = None):
        """Load a checkpoint (delta manifests resolve transparently). If
        `like` (a pytree with the same structure) is given, arrays are
        unflattened into that structure; otherwise a flat {path: array} dict
        is returned. Returned arrays are WRITABLE copies — np.frombuffer
        views are read-only and silently break in-place consumers."""
        import jax
        manifest = self.resolve_manifest(key)
        arrays = []
        for leaf in manifest["leaves"]:
            raw = b"".join(self.get_chunk(h) for h in leaf["chunks"])
            dt = np_dtype(leaf["dtype"])
            nbytes = int(leaf.get("nbytes",
                                  int(np.prod(leaf["shape"], dtype=np.int64))
                                  * dt.itemsize))
            arr = np.frombuffer(raw[:nbytes], dtype=dt).copy()
            arrays.append(arr.reshape(leaf["shape"]))
        if like is not None:
            flat, treedef = jax.tree_util.tree_flatten(like)
            assert len(flat) == len(arrays), \
                f"structure mismatch: {len(flat)} vs {len(arrays)}"
            return jax.tree_util.tree_unflatten(treedef, arrays)
        return {leaf["path"]: a for leaf, a in zip(manifest["leaves"], arrays)}

    def has(self, key: str) -> bool:
        return os.path.exists(self._manifest_path(key))

    def list_keys(self) -> list[str]:
        d = os.path.join(self.root, "manifests")
        return sorted(f[: -len(".msgpack")] for f in os.listdir(d)
                      if f.endswith(".msgpack"))

    # ---------------------------------------------------------------- gc --
    def gc(self, live_keys: Iterable[str]) -> dict:
        """Delete manifests outside the parent-closure of ``live_keys`` and
        every chunk no surviving manifest references. Delta parents of live
        manifests are always retained (deleting them would break resolve).
        Returns {kept_manifests, deleted_manifests, kept_chunks,
        deleted_chunks, deleted_bytes}."""
        with self._lock:
            # work in sanitized-name space throughout: callers pass raw keys
            # ('train@2.0') but list_keys() yields file names ('train_at_2.0')
            live = {_safe(k) for k in live_keys}
            # parent closure: a live delta manifest pins its ancestry
            frontier = list(live)
            while frontier:
                k = frontier.pop()
                try:
                    m = self.get_manifest(k)
                except FileNotFoundError:
                    live.discard(k)
                    continue
                parent = _safe(m["parent"]) if m.get("parent") else None
                if parent and parent not in live:
                    live.add(parent)
                    frontier.append(parent)
            referenced: set[str] = set()
            deleted_manifests = 0
            for key in self.list_keys():
                if key not in live:
                    self.delete_manifest(key)
                    deleted_manifests += 1
                    continue
                referenced.update(_manifest_chunk_hashes(self.get_manifest(key)))
            kept = deleted = deleted_bytes = 0
            obj_root = os.path.join(self.root, "objects")
            for dirpath, _, files in os.walk(obj_root):
                for fn in files:
                    if not fn.endswith(".zst"):
                        continue          # stray .tmp from a crashed writer
                    h = fn[: -len(".zst")]
                    p = os.path.join(dirpath, fn)
                    if h in referenced:
                        kept += 1
                    else:
                        deleted_bytes += os.path.getsize(p)
                        os.remove(p)
                        deleted += 1
            return {"kept_manifests": len(live), "deleted_manifests": deleted_manifests,
                    "kept_chunks": kept, "deleted_chunks": deleted,
                    "deleted_bytes": deleted_bytes}

    # -------------------------------------------------------------- meta --
    def put_meta(self, name: str, obj: dict):
        path = os.path.join(self.root, "meta", _safe(name) + ".json")
        tmp = path + f".tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(obj, f, indent=1, default=str)
        os.replace(tmp, path)

    def get_meta(self, name: str) -> Optional[dict]:
        path = os.path.join(self.root, "meta", _safe(name) + ".json")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)

    def stored_bytes(self) -> int:
        total = 0
        for dirpath, _, files in os.walk(os.path.join(self.root, "objects")):
            for fn in files:
                total += os.path.getsize(os.path.join(dirpath, fn))
        return total


def _manifest_chunk_hashes(manifest: dict):
    """Every chunk hash DIRECTLY listed by a manifest (no chain resolution —
    ancestors list their own)."""
    for leaf in manifest["leaves"]:
        for h in leaf.get("chunks") or []:
            if h is not None:
                yield h
        for h in (leaf.get("delta") or {}).values():
            yield h


def _safe(key: str) -> str:
    return key.replace("/", "_").replace("@", "_at_").replace(":", "_")
