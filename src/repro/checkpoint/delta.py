"""Device-side delta detection for lean checkpointing.

The host-side content-addressed store already avoids STORING unchanged
chunks; this layer avoids TRANSFERRING them. Per leaf it keeps the previous
checkpoint's per-chunk digests on device; at checkpoint time the Pallas
fingerprint kernel (kernels/chunk_delta.py) produces new digests in one read
of the leaf, and only rows with changed digests are gathered and copied to
host. On fine-tuning-shaped workloads (frozen experts/embeddings) this cuts
device->host traffic by the frozen fraction — the same economics as the
paper's lean checkpointing, one level lower.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import CHUNK_WORDS, _as_u32_blocks, changed_chunks, \
    fingerprint_leaf


class DeltaTracker:
    def __init__(self, chunk_words: int = CHUNK_WORDS):
        self.chunk_words = chunk_words
        self._digests: dict[str, jnp.ndarray] = {}

    def delta(self, path: str, leaf) -> dict:
        """Returns {digest, mask (np bool [G]), changed_blocks (np [C, W]),
        transferred_bytes, total_bytes}. Updates the stored digest."""
        digest = fingerprint_leaf(leaf, self.chunk_words)
        prev = self._digests.get(path)
        blocks = _as_u32_blocks(leaf, self.chunk_words)
        if prev is None or prev.shape != digest.shape:
            mask = jnp.ones((digest.shape[0],), jnp.int32)
        else:
            mask = changed_chunks(digest, prev)
        self._digests[path] = digest
        idx = jnp.nonzero(mask)[0]                    # host sync (counts only)
        changed = np.asarray(jax.device_get(jnp.take(blocks, idx, axis=0)))
        g = int(digest.shape[0])
        return {
            "digest": np.asarray(jax.device_get(digest)),
            "mask": np.asarray(jax.device_get(mask)).astype(bool),
            "changed_blocks": changed,
            "changed_idx": np.asarray(jax.device_get(idx)),
            "transferred_bytes": int(changed.nbytes),
            "total_bytes": int(g * self.chunk_words * 4),
        }

    def reset(self):
        self._digests.clear()
