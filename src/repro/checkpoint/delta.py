"""Device-side delta detection for lean checkpointing.

The host-side content-addressed store already avoids STORING unchanged
chunks; this layer avoids TRANSFERRING them. Per leaf it keeps the previous
checkpoint's per-chunk digests on device; at checkpoint time the Pallas
fingerprint kernel (kernels/chunk_delta.py) produces new digests in one read
of the leaf, and only rows with changed digests are gathered and copied to
host. On fine-tuning-shaped workloads (frozen experts/embeddings) this cuts
device->host traffic by the frozen fraction — the same economics as the
paper's lean checkpointing, one level lower.

`CheckpointPipeline` (checkpoint/pipeline.py) is the consumer: it turns the
gathered u32 blocks back into native leaf bytes (`blocks_to_native_bytes`)
and hands them to the writer stage.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import (CHUNK_WORDS, chunk_absmax,
                               fingerprint_and_changed, fingerprint_leaf,
                               gather_changed_blocks, gather_quantize4_blocks,
                               gather_quantize_blocks, native_bytes_per_word)

# Error-bound encoding selector thresholds. The TRUE per-element bound of a
# blockwise codec is half a quantization step: absmax/254 for q8 (scale =
# absmax/127), absmax/14 for q4 (scale = absmax/7). The selector divides by
# smaller figures so f32 scale rounding can never push a chunk past its
# declared atol — the bound it GUARANTEES is absmax/Q8_ATOL_DIV (resp. q4).
Q8_ATOL_DIV = 126.0
Q4_ATOL_DIV = 13.5


def blocks_to_native_bytes(blocks: np.ndarray, dtype) -> list[bytes]:
    """Convert gathered [C, W] uint32 blocks back to the original array's
    byte representation, one bytes object per chunk. Inverts the dtype
    widening of kernels.ops._as_u32_blocks (each word carries
    `native_bytes_per_word(dtype)` original bytes; padding words at the tail
    of the last chunk are zeros and are truncated by the caller)."""
    bpw = native_bytes_per_word(dtype)
    blocks = np.ascontiguousarray(blocks, dtype=np.uint32)
    if bpw == 4:
        rows = blocks
    elif bpw == 2:
        rows = blocks.astype(np.uint16)
    else:
        rows = blocks.astype(np.uint8)
    return [rows[i].tobytes() for i in range(rows.shape[0])]


def _grid_rows(nbytes: int, bpw: int, chunk_words: int) -> int:
    """Rows of the [G, chunk_words] block view a leaf of `nbytes` produces
    (mirrors kernels.ops._as_u32_blocks padding: G is TILE_G-aligned)."""
    n = max(1, nbytes // bpw)
    g = -(-n // chunk_words)
    return -(-g // 8) * 8


class DeltaTracker:
    def __init__(self, chunk_words: int = CHUNK_WORDS):
        self.chunk_words = chunk_words
        self._digests: dict[str, jnp.ndarray] = {}

    def delta_dispatch(self, path: str, leaf, *, quantize: bool = False,
                       enc: str = None, error_bound: float = None) -> dict:
        """Phase 1 of a delta: launch the device work (fused fingerprint +
        changed-mask when a previous digest exists) WITHOUT any host sync,
        and update the stored digest to the new device array. Returns an
        opaque handle for :meth:`finalize`. The overlap-mode pipeline calls
        this on the training thread (dispatch-only cost) and finalizes on
        the writer thread; the synchronous path composes both in
        :meth:`delta`.

        Encoding selection: ``enc`` fixes the wire encoding of every changed
        chunk ("raw" | "q8" | "q4"; ``quantize=True`` is the legacy spelling
        of enc="q8"). ``error_bound`` switches to the ADAPTIVE selector
        instead: a per-chunk absmax pass (``chunk_absmax``, one extra leaf
        read, dispatched async here) lets finalize pick, per changed chunk,
        the cheapest encoding whose guaranteed bound satisfies the atol —
        q4 when absmax/13.5 <= atol, else q8 when absmax/126 <= atol, else
        raw. Float leaves only (the caller gates on quantizable_dtype).

        The handle retains references to `leaf` and the new digest — safe
        for jax arrays because nothing in this codebase donates buffers, so
        a deferred finalize gathers from the exact submitted state even if
        the caller keeps training. Host numpy leaves are retained by
        REFERENCE: a caller that mutates one in place between dispatch and
        finalize would gather post-mutation bytes (functional updates, the
        norm here, are unaffected)."""
        if enc is None:
            enc = "q8" if quantize else "raw"
        if error_bound is not None:
            enc = "auto"
        nbytes = int(leaf.nbytes) if hasattr(leaf, "nbytes") \
            else int(np.asarray(leaf).nbytes)
        dtype = leaf.dtype if hasattr(leaf, "dtype") \
            else np.asarray(leaf).dtype
        bpw = native_bytes_per_word(dtype)
        prev = self._digests.get(path)
        if prev is not None \
                and int(prev.shape[0]) == _grid_rows(nbytes, bpw,
                                                     self.chunk_words):
            digest, mask = fingerprint_and_changed(leaf, prev,
                                                   self.chunk_words)
            first = False
        else:
            digest = fingerprint_leaf(leaf, self.chunk_words)
            mask = None
            first = True                              # first sight: all new
        self._digests[path] = digest
        absmax = chunk_absmax(leaf, self.chunk_words) if enc == "auto" \
            else None
        return {"path": path, "leaf": leaf, "digest": digest, "mask": mask,
                "first": first, "enc": enc, "quantize": (enc == "q8"),
                "error_bound": error_bound, "absmax": absmax,
                "nbytes": nbytes, "bpw": bpw}

    def _gather_group(self, h: dict, enc: str, idx: np.ndarray,
                      n_real: int) -> dict:
        """Gather one encoding group's changed rows off the device. The
        gather width pads to the next power of two (capped at the chunk
        count) so fluctuating change counts compile O(log G) gather variants
        per leaf instead of one per novel count. Returns
        {enc, idx, bytes, <wire arrays per encoding>}."""
        c = int(idx.size)
        cap = min(1 << (c - 1).bit_length(), n_real)
        idx_pad = jnp.asarray(np.concatenate(
            [idx, np.full(cap - c, idx[0], idx.dtype)]), jnp.int32)
        if enc == "q8":
            q, s = gather_quantize_blocks(h["leaf"], idx_pad,
                                          self.chunk_words)
            q = np.ascontiguousarray(np.asarray(jax.device_get(q))[:c])
            s = np.ascontiguousarray(np.asarray(jax.device_get(s))[:c])
            return {"enc": "q8", "idx": idx, "q": q, "scales": s,
                    "bytes": int(q.nbytes + s.nbytes)}
        if enc == "q4":
            p, s = gather_quantize4_blocks(h["leaf"], idx_pad,
                                           self.chunk_words)
            p = np.ascontiguousarray(np.asarray(jax.device_get(p))[:c])
            s = np.ascontiguousarray(np.asarray(jax.device_get(s))[:c])
            return {"enc": "q4", "idx": idx, "packed": p, "scales": s,
                    "bytes": int(p.nbytes + s.nbytes)}
        rows = np.asarray(jax.device_get(gather_changed_blocks(
            h["leaf"], idx_pad, self.chunk_words)))
        rows = np.ascontiguousarray(rows[:c])
        return {"enc": "raw", "idx": idx, "blocks": rows,
                "bytes": int(rows.nbytes)}

    def finalize(self, h: dict) -> dict:
        """Phase 2: sync the change mask, gather the changed rows in wire
        form per the handle's encoding (fixed raw/q8/q4, or the adaptive
        error-bound selector), and return the delta record. Touches no
        tracker state, so it is safe to run on the writer thread while the
        training thread keeps dispatching.

        Returns {digest, mask (np bool [G]), enc_groups ([{enc, idx, ...}]
        — one group per distinct wire encoding chosen), changed_idx,
        transferred_bytes, total_bytes} plus the legacy single-encoding
        fields (changed_blocks for raw handles, changed_q/changed_scales
        for q8) older callers still read."""
        digest = h["digest"]
        g = int(digest.shape[0])
        if h["first"]:
            mask = np.ones((g,), bool)
        else:
            mask = np.asarray(jax.device_get(h["mask"])).astype(bool)
        nbytes, bpw = h["nbytes"], h["bpw"]
        n_real = max(1, -(-nbytes // (self.chunk_words * bpw)))
        idx = np.flatnonzero(mask[:n_real])
        enc = h.get("enc", "q8" if h.get("quantize") else "raw")
        groups: list[dict] = []
        transferred = 0
        if idx.size:
            if enc == "auto":
                # per-chunk selector: the cheapest encoding whose GUARANTEED
                # bound (absmax / divisor) satisfies the slot's atol
                amax = np.asarray(jax.device_get(h["absmax"]))[idx]
                atol = float(h["error_bound"])
                pick = np.where(
                    amax / Q4_ATOL_DIV <= atol, "q4",
                    np.where(amax / Q8_ATOL_DIV <= atol, "q8", "raw"))
                for e in ("q4", "q8", "raw"):
                    sub = idx[pick == e]
                    if sub.size:
                        groups.append(self._gather_group(h, e, sub, n_real))
            else:
                groups.append(self._gather_group(h, enc, idx, n_real))
            transferred = sum(gr["bytes"] for gr in groups)
        # legacy single-encoding view (raw/q8 callers predate enc_groups)
        changed = None
        changed_q = changed_scales = None
        if enc == "raw":
            changed = groups[0]["blocks"] if groups \
                else np.zeros((0, self.chunk_words), np.uint32)
        elif enc == "q8" and groups:
            changed_q = groups[0]["q"]
            changed_scales = groups[0]["scales"]
        return {
            "digest": np.asarray(jax.device_get(digest)),
            "mask": mask,
            "changed_blocks": changed,
            "changed_q": changed_q,
            "changed_scales": changed_scales,
            "enc_groups": groups,
            "changed_idx": idx,
            "transferred_bytes": transferred,
            "total_bytes": int(g * self.chunk_words * 4),
        }

    def delta(self, path: str, leaf, *, quantize: bool = False,
              enc: str = None, error_bound: float = None) -> dict:
        """Synchronous delta: dispatch + finalize in one call (see the two
        phases above). Updates the stored digest — call exactly once per
        MATERIALIZED checkpoint so the mask always means "changed since the
        last stored checkpoint".

        Host traffic per call: the [G] change mask (one small device_get —
        jnp.nonzero's implicit size sync cost more than the mask itself),
        the [G,2] digest, and the changed rows. Rows past the leaf's real
        byte length (block-padding to the kernel tile) are never gathered,
        and a fully-unchanged leaf costs ONLY the fused fingerprint read —
        the u32 block view is never materialized for it.
        """
        return self.finalize(self.delta_dispatch(path, leaf,
                                                 quantize=quantize, enc=enc,
                                                 error_bound=error_bound))

    def seed(self, path: str, leaf):
        """Rehydrate one leaf's device-side digests from restored bytes
        (cross-run warm start): computes exactly the fingerprint submit()
        would via the same Pallas path, so the FIRST delta() of a derived
        run masks only chunks that truly changed since the ancestor run's
        final checkpoint. No mask, no gather — one fingerprint read."""
        self._digests[path] = fingerprint_leaf(leaf, self.chunk_words)

    def forget(self, path: str):
        """Drop one leaf's digests — the next delta() transfers everything
        (used when a leaf's dtype changes without changing its block count,
        which the digest comparison alone cannot flag as a full rewrite)."""
        self._digests.pop(path, None)

    def reset(self):
        self._digests.clear()
