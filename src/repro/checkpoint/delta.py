"""Device-side delta detection for lean checkpointing.

The host-side content-addressed store already avoids STORING unchanged
chunks; this layer avoids TRANSFERRING them. Per leaf it keeps the previous
checkpoint's per-chunk digests on device; at checkpoint time the Pallas
fingerprint kernel (kernels/chunk_delta.py) produces new digests in one read
of the leaf, and only rows with changed digests are gathered and copied to
host. On fine-tuning-shaped workloads (frozen experts/embeddings) this cuts
device->host traffic by the frozen fraction — the same economics as the
paper's lean checkpointing, one level lower.

`CheckpointPipeline` (checkpoint/pipeline.py) is the consumer: it turns the
gathered u32 blocks back into native leaf bytes (`blocks_to_native_bytes`)
and hands them to the writer stage.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import (CHUNK_WORDS, changed_chunks,
                               fingerprint_leaf, gather_changed_blocks,
                               native_bytes_per_word)


def blocks_to_native_bytes(blocks: np.ndarray, dtype) -> list[bytes]:
    """Convert gathered [C, W] uint32 blocks back to the original array's
    byte representation, one bytes object per chunk. Inverts the dtype
    widening of kernels.ops._as_u32_blocks (each word carries
    `native_bytes_per_word(dtype)` original bytes; padding words at the tail
    of the last chunk are zeros and are truncated by the caller)."""
    bpw = native_bytes_per_word(dtype)
    blocks = np.ascontiguousarray(blocks, dtype=np.uint32)
    if bpw == 4:
        rows = blocks
    elif bpw == 2:
        rows = blocks.astype(np.uint16)
    else:
        rows = blocks.astype(np.uint8)
    return [rows[i].tobytes() for i in range(rows.shape[0])]


class DeltaTracker:
    def __init__(self, chunk_words: int = CHUNK_WORDS):
        self.chunk_words = chunk_words
        self._digests: dict[str, jnp.ndarray] = {}

    def delta(self, path: str, leaf) -> dict:
        """Returns {digest, mask (np bool [G]), changed_blocks (np [C, W]),
        changed_idx, transferred_bytes, total_bytes}. Updates the stored
        digest — call exactly once per MATERIALIZED checkpoint so the mask
        always means "changed since the last stored checkpoint".

        Host traffic per call: the [G] change mask (one small device_get —
        jnp.nonzero's implicit size sync cost more than the mask itself),
        the [G,2] digest, and the changed rows. Rows past the leaf's real
        byte length (block-padding to the kernel tile) are never gathered,
        and a fully-unchanged leaf costs ONLY the fingerprint read — the
        u32 block view is never materialized for it.
        """
        digest = fingerprint_leaf(leaf, self.chunk_words)
        prev = self._digests.get(path)
        g = int(digest.shape[0])
        if prev is None or prev.shape != digest.shape:
            mask = np.ones((g,), bool)                # first sight: all new
        else:
            mask = np.asarray(jax.device_get(
                changed_chunks(digest, prev))).astype(bool)
        self._digests[path] = digest
        nbytes = int(leaf.nbytes) if hasattr(leaf, "nbytes") \
            else int(np.asarray(leaf).nbytes)
        bpw = native_bytes_per_word(leaf.dtype)
        n_real = max(1, -(-nbytes // (self.chunk_words * bpw)))
        idx = np.flatnonzero(mask[:n_real])
        if idx.size:
            # pad the gather width to the next power of two (capped at the
            # chunk count) so fluctuating change counts compile O(log G)
            # gather variants per leaf instead of one per novel count
            c = int(idx.size)
            cap = min(1 << (c - 1).bit_length(), n_real)
            idx_pad = np.concatenate(
                [idx, np.full(cap - c, idx[0], idx.dtype)])
            rows = np.asarray(jax.device_get(gather_changed_blocks(
                leaf, jnp.asarray(idx_pad, jnp.int32), self.chunk_words)))
            changed = np.ascontiguousarray(rows[:c])
        else:
            changed = np.zeros((0, self.chunk_words), np.uint32)
        return {
            "digest": np.asarray(jax.device_get(digest)),
            "mask": mask,
            "changed_blocks": changed,
            "changed_idx": idx,
            "transferred_bytes": int(changed.nbytes),
            "total_bytes": int(g * self.chunk_words * 4),
        }

    def seed(self, path: str, leaf):
        """Rehydrate one leaf's device-side digests from restored bytes
        (cross-run warm start): computes exactly the fingerprint submit()
        would via the same Pallas path, so the FIRST delta() of a derived
        run masks only chunks that truly changed since the ancestor run's
        final checkpoint. No mask, no gather — one fingerprint read."""
        self._digests[path] = fingerprint_leaf(leaf, self.chunk_words)

    def forget(self, path: str):
        """Drop one leaf's digests — the next delta() transfers everything
        (used when a leaf's dtype changes without changing its block count,
        which the digest comparison alone cannot flag as a full rewrite)."""
        self._digests.pop(path, None)

    def reset(self):
        self._digests.clear()
