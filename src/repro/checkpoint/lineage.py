"""Run lineage: the registry that turns a shared CheckpointStore into a
versioned system of record across runs.

*Multiversion Hindsight Logging for Continuous Training* (arXiv:2310.07898)
and *Flow with FlorDB* (arXiv:2408.02498) motivate checkpoint lineage ACROSS
runs: a fine-tune of a fine-tune should record only true deltas against its
ancestor, and storage reclamation must reason about every run that can still
reach a chunk. This module owns the run-level half of that:

* ``RunRegistry`` — per-run records persisted as JSON under
  ``<store_root>/runs/<run_id>.json``::

      {"run_id", "parent",        # parent run id (lineage edge) or null
       "namespace",               # manifest namespace in the store (null =
                                  #   legacy flat layout, single-run store)
       "run_dir", "status",       # running | finished
       "created_at", "finished_at",
       "final_keys": {scope: key}}  # tip checkpoint per SkipBlock scope —
                                    #   what a derived run warm-starts from

  with ancestry resolution (``ancestry``) and registry-driven multi-run GC
  (``gc``): the live set is the union of every registered run's manifests;
  ``CheckpointStore.gc`` then retains the cross-run parent closure, so
  unregistering run A reclaims exactly the chunks no surviving descendant
  inherits.

* ``flor.run.json`` helpers — each run directory carries a small metadata
  file binding it to (run_id, store_root, namespace, parent_run), so replay
  reconnects to the shared store without re-passing any of it.

The CLI lives in ``repro/launch/runs.py`` (``python -m repro.launch.runs
list|show|gc|rm``).
"""
from __future__ import annotations

import json
import os
import threading
import time
import uuid
from typing import Optional

from repro.checkpoint.store import _atomic_write

RUN_META_FILE = "flor.run.json"


class RunIdCollision(RuntimeError):
    """An exclusive registration lost the race: the run id already belongs
    to a DIFFERENT run (other run_dir/namespace). Callers with generated
    ids retry with a fresh id; callers with explicit ids surface the
    conflict."""


def generate_run_id() -> str:
    """Sortable-by-creation, collision-safe id: timestamp + random suffix."""
    return time.strftime("%Y%m%d-%H%M%S") + "-" + uuid.uuid4().hex[:6]


def write_run_meta(run_dir: str, meta: dict):
    os.makedirs(run_dir, exist_ok=True)
    _atomic_write(os.path.join(run_dir, RUN_META_FILE),
                  json.dumps(meta, indent=1).encode())


def read_run_meta(run_dir: str) -> dict:
    """The run directory's lineage binding; {} for pre-lineage run dirs."""
    path = os.path.join(run_dir, RUN_META_FILE)
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f)


class RunRegistry:
    """Persistent registry of the runs sharing one store root. Thread/process
    coordination is filesystem-level (atomic JSON replace per run record) —
    matching the store's own crash-safety discipline."""

    def __init__(self, store_root: str):
        self.root = os.path.join(store_root, "runs")
        os.makedirs(self.root, exist_ok=True)

    def _path(self, run_id: str) -> str:
        return os.path.join(self.root, _fsafe(run_id) + ".json")

    # --------------------------------------------------------- lifecycle --
    def register(self, run_id: str, parent: Optional[str] = None,
                 run_dir: Optional[str] = None,
                 namespace: Optional[str] = None,
                 meta: Optional[dict] = None,
                 exclusive: bool = False) -> dict:
        """Create (or replace) a run record at record-init time. A re-record
        into the same (run_dir, namespace) replaces the stale registration —
        its manifests were overwritten anyway, and a dangling record would
        pin dead chunks forever. Parent validation applies only to FIRST
        registration: a resumed run whose parent was since `runs rm`'d must
        still relaunch (its closure survived the rm by design).

        ``exclusive=True`` makes the CREATE atomic on the shared filesystem
        (hard-link publish of a fully-written temp record): of two
        simultaneous recorders racing the same run id, exactly one wins; the
        loser gets :class:`RunIdCollision` and (when its id was generated)
        retries with a fresh one. A record that already belongs to this
        (run_dir, namespace) is a crash-restart/resume, not a collision."""
        if parent is not None and self.get(parent) is None \
                and self.get(run_id) is None:
            raise ValueError(
                f"parent run {parent!r} is not registered in this store "
                f"(known runs: {[r['run_id'] for r in self.list_runs()]})")
        rec = {"run_id": run_id, "parent": parent, "namespace": namespace,
               "run_dir": run_dir, "status": "running",
               "created_at": time.time(), "finished_at": None,
               "final_keys": {},
               "meta": meta or {}}
        # a re-record into the same (run_dir, namespace) under a NEW id must
        # drop the stale registration on BOTH paths — a dangling record
        # would show as a ghost in `runs list` and pin dead chunks through
        # registry-driven gc forever
        self._sweep_stale(run_id, run_dir, namespace)
        if exclusive:
            # loop instead of falling through: under true multi-PROCESS
            # contention a loser of the link race can observe the path
            # vanish again (the winner finished and was unregistered, or a
            # sweep raced us) — re-reading and falling through to the
            # unconditional write below would claim the id NON-atomically,
            # silently clobbering whichever peer re-created it in between.
            # Every exit from this loop is either an atomic create we won,
            # a RunIdCollision, or proof the existing record is OURS.
            for _ in range(64):
                prev = self.get(run_id)
                if prev is None:
                    if self._create_exclusive(rec):
                        return rec
                    continue       # lost the link race: reload and re-check
                if (prev.get("run_dir") != run_dir
                        or prev.get("namespace") != namespace):
                    raise RunIdCollision(
                        f"run id {run_id!r} is already registered for "
                        f"{prev.get('run_dir')!r} "
                        f"(ns {prev.get('namespace')!r})")
                break     # our own stale/resumed registration — replaceable
            else:
                raise RuntimeError(
                    f"exclusive registration of {run_id!r} could not "
                    f"stabilize — registry under pathological churn")
        prev = self.get(run_id)
        if prev:
            # a crash-restart/resume re-registers the same run id: its
            # prior final_keys must survive until finalize() updates
            # them, or a no-op resume would break every descendant's
            # warm start
            rec["final_keys"] = dict(prev.get("final_keys") or {})
        self._write(rec)
        return rec

    def _sweep_stale(self, run_id: str, run_dir: Optional[str],
                     namespace: Optional[str]):
        """Unregister OTHER run ids previously recorded into the same
        (run_dir, namespace) — their manifests were overwritten anyway."""
        if run_dir is None:
            return
        for other in self.list_runs():
            if other["run_id"] != run_id \
                    and other.get("run_dir") == run_dir \
                    and other.get("namespace") == namespace:
                self.unregister(other["run_id"])

    def _create_exclusive(self, rec: dict) -> bool:
        """Atomically publish a NEW run record; False when the path already
        exists (a concurrent recorder won). The record is fully written to a
        temp file first and published via hard link, so a racing reader can
        never observe a torn record under the final name."""
        path = self._path(rec["run_id"])
        tmp = path + f".tmp.{os.getpid()}.{threading.get_ident()}"
        try:
            with open(tmp, "wb") as f:
                f.write(json.dumps(rec, indent=1, default=str).encode())
            try:
                os.link(tmp, path)     # atomic create-if-absent
                return True
            except FileExistsError:
                return False
        finally:
            try:
                os.remove(tmp)
            except OSError:
                pass

    def finalize(self, run_id: str, final_keys: dict,
                 status: str = "finished") -> Optional[dict]:
        """Record the per-scope tip checkpoints when a record run completes —
        the manifests a derived run's warm start resolves against. MERGES
        into the existing keys: a resumed run that re-submitted nothing for
        a scope keeps that scope's previous tip."""
        rec = self.get(run_id)
        if rec is None:
            return None
        rec["final_keys"] = {**(rec.get("final_keys") or {}),
                             **dict(final_keys)}
        rec["status"] = status
        rec["finished_at"] = time.time()
        self._write(rec)
        return rec

    def unregister(self, run_id: str) -> bool:
        """Drop a run's registration. Its manifests stay on disk until the
        next ``gc``, which reclaims whatever no surviving run's closure
        reaches."""
        try:
            os.remove(self._path(run_id))
            return True
        except FileNotFoundError:
            return False

    def _write(self, rec: dict):
        _atomic_write(self._path(rec["run_id"]),
                      json.dumps(rec, indent=1, default=str).encode())

    # ----------------------------------------------------------- queries --
    def get(self, run_id: str) -> Optional[dict]:
        try:
            with open(self._path(run_id)) as f:
                return json.load(f)
        except FileNotFoundError:
            return None

    def list_runs(self) -> list[dict]:
        out = []
        for fn in os.listdir(self.root):
            if not fn.endswith(".json"):
                continue
            try:
                with open(os.path.join(self.root, fn)) as f:
                    out.append(json.load(f))
            except (OSError, json.JSONDecodeError):
                continue      # torn write from a crashed run: skip, not fatal
        return sorted(out, key=lambda r: (r.get("created_at") or 0,
                                          r.get("run_id", "")))

    def ancestry(self, run_id: str) -> list[dict]:
        """Run records from `run_id` back to the root of its lineage
        (cycle-safe; stops at the first unregistered ancestor)."""
        chain = []
        seen = set()
        cur = run_id
        while cur is not None and cur not in seen:
            seen.add(cur)
            rec = self.get(cur)
            if rec is None:
                break
            chain.append(rec)
            cur = rec.get("parent")
        return chain

    # ---------------------------------------------------------------- gc --
    def live_keys(self, store,
                  exclude_run_id: Optional[str] = None) -> list[str]:
        """Qualified manifest keys of every registered run — the multi-run
        live set. ``store.gc`` extends it with the cross-run parent closure,
        so a chunk survives while ANY registered run can still resolve a
        manifest through it. `exclude_run_id` lets a run apply its OWN
        retention policy while keeping every sibling fully live."""
        from repro.checkpoint.store import filter_orphan_members
        live = []
        for rec in self.list_runs():
            if exclude_run_id is not None \
                    and rec.get("run_id") == exclude_run_id:
                continue
            ns = rec.get("namespace")
            # orphan member manifests — shard members whose v4 stitch was
            # never written because a host died between publication and
            # stitch — must not SEED the closure (they'd pin their own
            # chunks forever); members of stitched checkpoints re-enter
            # through the v4's member walk, and incomplete predecessors a
            # later delta inherits from re-enter through per-shard parent
            # chains, so nothing live is lost
            for k in filter_orphan_members(store.list_keys(run=ns)):
                # "::key" = explicit flat namespace, immune to whatever
                # namespace the store handle happens to be bound to
                live.append(f"{ns or ''}::{k}")
        return live

    def gc(self, store) -> dict:
        """Multi-run collection: keep the union of all registered runs'
        manifest closures, delete everything else (manifests of unregistered
        runs, then unreachable chunks)."""
        return store.gc(self.live_keys(store))


def registry_dirsig(store_root: str) -> Optional[list]:
    """Cheap change signature of the registry directory — (mtime_ns, number
    of JSON records) of ``<store_root>/runs/``. The query index stamps its
    runs-table mirror with the signature it was built under; a mismatch at
    query time means registrations/removals/finalizations happened since and
    the mirror must not be trusted. The directory is stat'ed BEFORE it is
    listed so a write racing this read can only make the mirror look stale
    (re-sync), never current with missing rows. None when no registry
    directory exists (legacy pseudo-run stores — never index-served)."""
    root = os.path.join(store_root, "runs")
    try:
        st = os.stat(root)
        n = sum(1 for fn in os.listdir(root) if fn.endswith(".json"))
    except OSError:
        return None
    return [int(st.st_mtime_ns), n]


def _fsafe(run_id: str) -> str:
    return run_id.replace("/", "_").replace(":", "_")
