from repro.checkpoint.store import CheckpointStore  # noqa: F401
from repro.checkpoint.async_writer import AsyncWriter  # noqa: F401
from repro.checkpoint.pipeline import CheckpointPipeline  # noqa: F401
