from repro.checkpoint.store import CheckpointStore  # noqa: F401
from repro.checkpoint.async_writer import AsyncWriter  # noqa: F401
from repro.checkpoint.pipeline import CheckpointPipeline  # noqa: F401
from repro.checkpoint.lineage import (  # noqa: F401
    RunIdCollision, RunRegistry, generate_run_id, read_run_meta,
    write_run_meta)
from repro.checkpoint.mesh import (  # noqa: F401
    mesh_meta, restore_sharded_tree, stitch_tree)
