"""Background materialization (paper section 5.1, adapted to JAX).

The paper forks a child process to snapshot mutable PyTorch tensors with
copy-on-write. JAX arrays are immutable, so a "snapshot" is a reference —
submit() returns after capturing references; a writer thread then performs
the heavy half of materialization. A bounded queue applies backpressure so
record can never run unboundedly ahead of the disk.

AsyncWriter is a generic STAGE: the unit of work is a job callable
``fn(store) -> stat dict`` executed in FIFO order on the writer thread.

* ``submit(key, tree, meta)`` — the classic whole-tree path: the job does
  device->host transfer of every leaf (jax.device_get releases the GIL
  during the DMA), chunking, hashing, compression and I/O.
* ``submit_job(key, fn)`` — the delta pipeline's path: the pipeline has
  already gathered only the CHANGED blocks to host; the job just hashes,
  compresses, writes, and emits the manifest.

Materialization wall time per job is reported to a callback — that is the
M_i the adaptive controller (core/adaptive.py) consumes.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Optional


class AsyncWriter:
    def __init__(self, store, max_queue: int = 2,
                 on_materialized: Optional[Callable] = None):
        self.store = store
        self._q: queue.Queue = queue.Queue(maxsize=max_queue)
        self._on_mat = on_materialized
        self._err: Optional[BaseException] = None
        self._stats: list[dict] = []
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            key, fn = item
            try:
                t0 = time.perf_counter()
                stat = fn(self.store) or {}
                stat.setdefault("key", key)
                stat["materialize_s"] = time.perf_counter() - t0
                self._stats.append(stat)
                if self._on_mat:
                    self._on_mat(stat)
            except BaseException as e:   # surfaced on next submit/drain
                self._err = e
            finally:
                self._q.task_done()

    def submit_job(self, key: str, fn: Callable, block: bool = True) -> bool:
        """Enqueue a materialization job. Returns False if the queue is full
        and block=False (caller may skip this checkpoint — bounded
        overhead)."""
        if self._err:
            raise self._err
        try:
            self._q.put((key, fn), block=block)
            return True
        except queue.Full:
            return False

    def submit(self, key: str, tree, meta: Optional[dict] = None,
               block: bool = True) -> bool:
        """Whole-tree checkpoint (v1 manifest): transfer + store in the
        background."""
        return self.submit_job(key, _full_tree_job(key, tree, meta), block)

    def drain(self):
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        self.drain()
        self._q.put(None)
        self._t.join()

    @property
    def stats(self):
        return list(self._stats)


def _full_tree_job(key: str, tree, meta: Optional[dict]) -> Callable:
    def job(store):
        import jax
        import numpy as np
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)
        return store.put_tree(key, host_tree, meta)
    return job
