"""Background materialization (paper section 5.1, adapted to JAX).

The paper forks a child process to snapshot mutable PyTorch tensors with
copy-on-write. JAX arrays are immutable, so a "snapshot" is a reference —
submit() returns after capturing references; a writer thread then performs
device->host transfer (jax.device_get releases the GIL during the DMA),
chunking, hashing, compression and I/O. A bounded queue applies backpressure
so record can never run unboundedly ahead of the disk.

Materialization wall time per checkpoint is reported to a callback — that is
the M_i the adaptive controller (core/adaptive.py) consumes.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Optional

import jax
import numpy as np


class AsyncWriter:
    def __init__(self, store, max_queue: int = 2,
                 on_materialized: Optional[Callable] = None):
        self.store = store
        self._q: queue.Queue = queue.Queue(maxsize=max_queue)
        self._on_mat = on_materialized
        self._err: Optional[BaseException] = None
        self._stats: list[dict] = []
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            key, tree, meta = item
            try:
                t0 = time.perf_counter()
                host_tree = jax.tree_util.tree_map(
                    lambda x: np.asarray(jax.device_get(x)), tree)
                stat = self.store.put_tree(key, host_tree, meta)
                stat["materialize_s"] = time.perf_counter() - t0
                self._stats.append(stat)
                if self._on_mat:
                    self._on_mat(stat)
            except BaseException as e:   # surfaced on next submit/drain
                self._err = e
            finally:
                self._q.task_done()

    def submit(self, key: str, tree, meta: Optional[dict] = None,
               block: bool = True) -> bool:
        """Enqueue a checkpoint. Returns False if the queue is full and
        block=False (caller may skip this checkpoint — bounded overhead)."""
        if self._err:
            raise self._err
        try:
            self._q.put((key, tree, meta), block=block)
            return True
        except queue.Full:
            return False

    def drain(self):
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        self.drain()
        self._q.put(None)
        self._t.join()

    @property
    def stats(self):
        return list(self._stats)
