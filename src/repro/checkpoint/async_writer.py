"""Background work stages (paper section 5.1, adapted to JAX) — the FIFO
job-stage substrate behind BOTH checkpoints and logs.

The paper forks a child process to snapshot mutable PyTorch tensors with
copy-on-write. JAX arrays are immutable, so a "snapshot" is a reference —
the training thread captures references and returns; a daemon worker thread
then performs the heavy half of the work. A bounded queue applies
backpressure so record can never run unboundedly ahead of the disk.

Two layers live here:

* :class:`AsyncStage` — the generic single-worker FIFO stage: a bounded
  queue, a daemon thread draining it through a ``process(item)`` callable,
  error capture surfaced on the next ``put``/``drain``, and
  ``drain``/``close`` lifecycle. The background LOG writer
  (``repro.logging.stream``) runs its serialize+spill+segment-write work on
  this same stage type — the step path only enqueues.
* :class:`AsyncWriter` — the checkpoint materialization stage built on it.
  The unit of work is a job callable ``fn(store) -> stat dict``:

  - ``submit(key, tree, meta)`` — the classic whole-tree path: the job does
    device->host transfer of every leaf (jax.device_get releases the GIL
    during the DMA), chunking, hashing, compression and I/O.
  - ``submit_job(key, fn)`` — the delta pipeline's path: the pipeline has
    already gathered only the CHANGED blocks to host; the job just hashes,
    compresses, writes, and emits the manifest.

  Materialization wall time per job is reported to a callback — that is the
  M_i the adaptive controller (core/adaptive.py) consumes.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Optional

_STOP = object()


class AsyncStage:
    """A bounded FIFO queue drained by one daemon worker thread.

    ``put`` blocks when the queue is full (backpressure) unless
    ``block=False``, in which case it returns False and the caller decides
    what to skip. A processing exception is captured and re-raised on the
    NEXT ``put``/``drain``/``close`` — same contract the checkpoint writer
    has always had: background failures can't be silent, but they surface
    on the submitting thread, not inside the worker."""

    def __init__(self, process: Callable, max_queue: int = 2):
        self._process = process
        self._q: queue.Queue = queue.Queue(maxsize=max_queue)
        self._err: Optional[BaseException] = None
        self._closed = False
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        while True:
            item = self._q.get()
            try:
                if item is _STOP:
                    return
                self._process(item)
            except BaseException as e:   # surfaced on next put/drain
                self._err = e
            finally:
                self._q.task_done()

    def put(self, item, block: bool = True) -> bool:
        """Enqueue one work item. Returns False when the queue is full and
        ``block=False`` (bounded overhead: the caller may drop the item)."""
        if self._err:
            raise self._err
        try:
            self._q.put(item, block=block)
            return True
        except queue.Full:
            return False

    def drain(self):
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        if self._closed:
            return
        self._closed = True
        self._q.join()
        self._q.put(_STOP)
        self._t.join()
        if self._err:
            raise self._err


class AsyncWriter:
    """Checkpoint materialization stage: FIFO jobs ``fn(store)`` executed on
    the writer thread, per-job wall time reported to ``on_materialized``."""

    def __init__(self, store, max_queue: int = 2,
                 on_materialized: Optional[Callable] = None):
        self.store = store
        self._on_mat = on_materialized
        self._stats: list[dict] = []
        self._stage = AsyncStage(self._run, max_queue=max_queue)

    def _run(self, item):
        key, fn = item
        t0 = time.perf_counter()
        stat = fn(self.store) or {}
        stat.setdefault("key", key)
        stat["materialize_s"] = time.perf_counter() - t0
        self._stats.append(stat)
        if self._on_mat:
            self._on_mat(stat)

    def submit_job(self, key: str, fn: Callable, block: bool = True) -> bool:
        """Enqueue a materialization job. Returns False if the queue is full
        and block=False (caller may skip this checkpoint — bounded
        overhead)."""
        return self._stage.put((key, fn), block=block)

    def submit(self, key: str, tree, meta: Optional[dict] = None,
               block: bool = True) -> bool:
        """Whole-tree checkpoint (v1 manifest): transfer + store in the
        background."""
        return self.submit_job(key, _full_tree_job(key, tree, meta), block)

    def drain(self):
        self._stage.drain()

    def close(self):
        self._stage.close()

    @property
    def stats(self):
        return list(self._stats)


def _full_tree_job(key: str, tree, meta: Optional[dict]) -> Callable:
    def job(store):
        import jax
        import numpy as np
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)
        return store.put_tree(key, host_tree, meta)
    return job
