"""Mesh-sharded record/restore geometry for the checkpoint pipeline.

Record side: ``device_maps`` + ``owned_shards`` enumerate, per pytree leaf,
the disjoint device shards a mesh owns (``addressable_shards`` filtered to
``replica_id == 0`` is an exact cover of the global array) together with
each shard's global index bounds and owning STORE SHARD (simulated host).
The pipeline runs the fused fingerprint+gather pass on each shard's own
device buffer and writes its chunks to that host's pool — bytes never
cross a device boundary except device -> owning host.

Restore side: ``stitch_tree`` rebuilds a tree from a v4 stitching manifest.
Given a target ``NamedSharding`` (a sharded `like` leaf, or a spec
re-resolved on a new mesh via ``parallel.sharding.respec``), each target
shard is assembled via ``jax.make_array_from_callback`` from ONLY the
recorded chunks its index box overlaps — chunk ranges are computed from the
box's byte envelope in the recorded shard's local row-major layout — so an
N-host recording restores onto an M-host (or single-host) mesh reading just
what the new layout needs.
"""
from __future__ import annotations

from typing import Any, Optional

import numpy as np

from repro.checkpoint.store import np_dtype


# ------------------------------------------------------------ record side --
def mesh_meta(mesh, shard_axes=()) -> dict:
    """Serializable description of the recording layout for manifest v4 /
    store meta: mesh axes in order, the store-shard axes, and counts."""
    names = [str(a) for a in mesh.axis_names]
    sa = [str(a) for a in (shard_axes or names)]
    n_store = 1
    for a in sa:
        n_store *= int(mesh.shape[a])
    return {"axes": [[a, int(mesh.shape[a])] for a in names],
            "shard_axes": sa,
            "n_devices": int(mesh.devices.size),
            "n_store_shards": n_store}


def device_maps(mesh, shard_axes=()) -> tuple[dict, dict]:
    """({device_id: device_ordinal}, {device_id: store_shard}).

    The device ordinal is the device's flat index in ``mesh.devices`` (the
    stable shard id manifests record). The store shard is the flat index of
    the device's coordinates restricted to ``shard_axes`` — the default
    ``()`` means ALL mesh axes: one store shard per device, the
    max-parallel simulated-host granularity; a real multi-host deployment
    passes the axes that map onto hosts."""
    names = [str(a) for a in mesh.axis_names]
    sa = [str(a) for a in (shard_axes or names)]
    for a in sa:
        if a not in names:
            raise ValueError(f"ckpt_shard_axes entry {a!r} is not a mesh "
                             f"axis (mesh axes: {names})")
    dims = mesh.devices.shape
    sel = [names.index(a) for a in sa]
    ords: dict[int, int] = {}
    hosts: dict[int, int] = {}
    for flat, idx in enumerate(np.ndindex(*dims)):
        d = mesh.devices[idx]
        ords[d.id] = flat
        h = 0
        for axpos in sel:
            h = h * dims[axpos] + idx[axpos]
        hosts[d.id] = h
    return ords, hosts


def owned_shards(leaf, ords: dict, hosts: dict,
                 process_index: Optional[int] = None,
                 anchor: tuple[int, int] = (0, 0)) -> list[dict]:
    """Disjoint owner shards of one leaf: [{sid, hid, bounds, data}, ...]
    sorted by sid, where ``bounds`` is the shard's global index box
    ``[[lo, hi), ...]`` and ``data`` its single-device buffer.

    jax arrays placed on the mesh cover exactly via their
    ``replica_id == 0`` addressable shards (a replicated leaf has ONE owner
    shard). Host numpy/python leaves — and arrays living off the mesh —
    fall back to a single full shard owned by store shard 0.

    Multi-process mode (``process_index`` set): a mesh leaf contributes
    exactly the replica-0 shards addressable from THIS process — possibly
    none (the union across the fleet is the same exact cover the
    single-process path enumerates). Host / off-mesh leaves are SPMD-
    replicated values, so only process 0 publishes them, under ``anchor``
    — the (sid, hid) of process 0's lowest-ordinal mesh device — keeping
    every byte inside a pool its writer owns."""
    shards = getattr(leaf, "addressable_shards", None)
    if shards is not None:
        out = []
        on_mesh = True
        for sh in shards:
            did = sh.device.id
            if did not in ords:
                on_mesh = False
                break
            if getattr(sh, "replica_id", 0) != 0:
                continue
            bounds = [[int(s.start or 0),
                       int(s.stop if s.stop is not None else dim)]
                      for s, dim in zip(sh.index, leaf.shape)]
            out.append({"sid": ords[did], "hid": hosts[did],
                        "bounds": bounds, "data": sh.data})
        if on_mesh and (out or process_index is not None):
            out.sort(key=lambda e: e["sid"])
            return out
    if process_index is not None and process_index != 0:
        return []
    sid, hid = anchor if process_index is not None else (0, 0)
    full = [[0, int(d)] for d in getattr(leaf, "shape", ())]
    return [{"sid": sid, "hid": hid, "bounds": full, "data": leaf}]


def local_anchor(mesh, ords: dict, hosts: dict,
                 process_index: int) -> tuple[int, int]:
    """(sid, hid) of this process's lowest-ordinal mesh device — the pool
    host/off-mesh leaves are filed under in multi-process record. Falls
    back to (0, 0) for a process with no mesh devices."""
    best = None
    for d in mesh.devices.flat:
        if getattr(d, "process_index", 0) != process_index:
            continue
        cand = (ords[d.id], hosts[d.id])
        if best is None or cand < best:
            best = cand
    return best if best is not None else (0, 0)


def leaf_spec_entries(leaf) -> Optional[list]:
    """The recorded physical PartitionSpec of a jax array under a
    NamedSharding, in ``parallel.sharding.spec_entries`` serialized form
    (None for host / unsharded leaves) — what a resharded restore
    re-resolves on the target mesh."""
    sh = getattr(leaf, "sharding", None)
    spec = getattr(sh, "spec", None)
    if spec is None:
        return None
    from repro.parallel.sharding import spec_entries
    return spec_entries(spec)


# ----------------------------------------------------------------- box math --
def box_intersect(a, b) -> Optional[list]:
    """Intersection of two index boxes ([] = scalar box, full overlap);
    None when empty."""
    out = []
    for (al, ah), (bl, bh) in zip(a, b):
        lo, hi = max(int(al), int(bl)), min(int(ah), int(bh))
        if lo >= hi:
            return None
        out.append([lo, hi])
    return out


def chunk_range(rec_bounds, box, itemsize: int, chunk_bytes: int,
                n_chunks: int) -> tuple[int, int]:
    """Chunk index range [lo, hi) of a recorded shard's chunking that
    covers ``box`` (global coords, inside ``rec_bounds``): the byte
    envelope from the first to the last element of the box in the shard's
    local row-major layout. Exact for leading-dim sharding; a conservative
    superset when the box is a strided sub-block."""
    local = [hi - lo for lo, hi in rec_bounds]
    strides = []
    s = 1
    for d in reversed(local):
        strides.append(s)
        s *= d
    strides.reverse()
    first = sum((bl - rl) * st
                for (bl, _), (rl, _), st in zip(box, rec_bounds, strides))
    last = sum((bh - 1 - rl) * st
               for (_, bh), (rl, _), st in zip(box, rec_bounds, strides))
    lo = (first * itemsize) // chunk_bytes
    hi = -(-((last + 1) * itemsize) // chunk_bytes)
    return max(0, lo), min(n_chunks, hi)


# ---------------------------------------------------------------- restore --
def _member_leaves(resolved_member: dict) -> dict:
    """{member leaf path: leaf} with a one-shot cache on the member."""
    cached = resolved_member.get("_by_path")
    if cached is None:
        cached = {lf["path"]: lf for lf in resolved_member["leaves"]}
        resolved_member["_by_path"] = cached
    return cached


def _chunk_native_bytes(chunk_words: int, dtype: str) -> int:
    from repro.kernels.ops import native_bytes_per_word
    return int(chunk_words) * native_bytes_per_word(dtype)


def _note_read(stats: Optional[dict], hid: int, nbytes: int, n: int):
    if stats is None:
        return
    stats["chunks_read"] = stats.get("chunks_read", 0) + n
    by = stats.setdefault("bytes_by_shard", {})
    by[hid] = by.get(hid, 0) + nbytes


def _read_shard_range(store, mleaf: dict, store_shard: int, c_lo: int,
                      c_hi: int, dt: np.dtype,
                      stats: Optional[dict]) -> bytes:
    """Decoded native bytes of chunks [c_lo, c_hi) of one recorded device
    shard (encoded chunks — q8 / q4 / entropy-compressed — decode
    transparently, as in the flat get_tree)."""
    enc = mleaf.get("enc")
    chunks = mleaf["chunks"]
    parts = []
    for i in range(c_lo, c_hi):
        raw = store.get_chunk(chunks[i], shard=store_shard)
        if enc and enc[i] != "raw":
            from repro.kernels.ops import decode_wire_chunk
            raw = decode_wire_chunk(raw, enc[i], dt)
        parts.append(raw)
    out = b"".join(parts)
    _note_read(stats, store_shard, len(out), c_hi - c_lo)
    return out


def _read_box(store, mleaf: dict, store_shard: int, rec_bounds, box,
              dt: np.dtype, chunk_words: int,
              stats: Optional[dict]) -> np.ndarray:
    """The sub-array ``box`` (global coords) of one recorded device shard,
    reading only the chunks covering the box's byte envelope."""
    cn = _chunk_native_bytes(chunk_words, str(dt))
    nbytes = int(mleaf["nbytes"])
    n_chunks = int(mleaf["n_chunks"])
    c_lo, c_hi = chunk_range(rec_bounds, box, dt.itemsize, cn, n_chunks)
    raw = _read_shard_range(store, mleaf, store_shard, c_lo, c_hi, dt, stats)
    start = c_lo * cn
    flat = np.zeros(nbytes, dtype=np.uint8)
    flat[start:start + len(raw)] = np.frombuffer(raw, np.uint8)[:nbytes - start]
    local = flat.view(dt).reshape([hi - lo for lo, hi in rec_bounds])
    rel = tuple(slice(bl - rl, bh - rl)
                for (bl, bh), (rl, _) in zip(box, rec_bounds))
    # reshape after ascontiguousarray: it promotes 0-d results to (1,),
    # which would break the 0-d assignment for scalar leaves downstream
    return np.ascontiguousarray(local[rel]).reshape(
        tuple(hi - lo for lo, hi in box))


def _stitch_leaf_full(store, resolved: dict, leaf: dict,
                      stats: Optional[dict]) -> np.ndarray:
    """Full numpy stitch of one v4 leaf: every recorded shard's bytes land
    in its global bounds box."""
    dt = np_dtype(leaf["dtype"])
    out = np.empty(tuple(leaf["shape"]), dtype=dt)
    members = resolved["members_resolved"]
    for se in leaf["shards"]:
        mleaf = _member_leaves(members[int(se["hid"])])[
            f"{leaf['path']}::shard{se['sid']}"]
        raw = _read_shard_range(store, mleaf, int(se["hid"]), 0,
                                int(mleaf["n_chunks"]), dt, stats)
        local = np.frombuffer(raw[:int(mleaf["nbytes"])], dtype=dt) \
            .reshape([hi - lo for lo, hi in se["bounds"]])
        out[tuple(slice(lo, hi) for lo, hi in se["bounds"])] = local
    return out


def _resharded_leaf(store, resolved: dict, leaf: dict, sharding,
                    stats: Optional[dict]):
    """One v4 leaf as a jax.Array under ``sharding``: each target shard
    assembles from only the recorded chunks its index box overlaps."""
    import jax
    dt = np_dtype(leaf["dtype"])
    shape = tuple(leaf["shape"])
    chunk_words = int(resolved["chunk_words"])
    members = resolved["members_resolved"]

    def cb(index):
        tbox = [[int(s.start or 0),
                 int(s.stop if s.stop is not None else d)]
                for s, d in zip(index, shape)]
        out = np.empty([hi - lo for lo, hi in tbox], dtype=dt)
        for se in leaf["shards"]:
            ov = box_intersect(se["bounds"], tbox)
            if ov is None:
                continue
            mleaf = _member_leaves(members[int(se["hid"])])[
                f"{leaf['path']}::shard{se['sid']}"]
            piece = _read_box(store, mleaf, int(se["hid"]), se["bounds"],
                              ov, dt, chunk_words, stats)
            out[tuple(slice(l - tl, h - tl)
                      for (l, h), (tl, _) in zip(ov, tbox))] = piece
        return out

    return jax.make_array_from_callback(shape, sharding, cb)


def _target_sharding(x):
    """``x``'s NamedSharding if it has one (the selective-restore trigger);
    None for host arrays / single-device jax arrays."""
    try:
        from jax.sharding import NamedSharding
    except ImportError:                                    # pragma: no cover
        return None
    sh = getattr(x, "sharding", None)
    return sh if isinstance(sh, NamedSharding) else None


def stitch_tree(store, resolved: dict, like: Any = None,
                stats_out: Optional[dict] = None):
    """get_tree for a v4 sharded manifest. A `like` leaf under a
    NamedSharding restores selectively to a sharded jax.Array (reads only
    the chunks the target layout needs); other leaves stitch to full numpy
    arrays. ``stats_out`` receives {chunks_read, bytes_by_shard}."""
    stats: dict = {"chunks_read": 0, "bytes_by_shard": {}}
    like_flat = treedef = None
    if like is not None:
        import jax
        like_flat, treedef = jax.tree_util.tree_flatten(like)
        assert len(like_flat) == len(resolved["leaves"]), \
            f"structure mismatch: {len(like_flat)} vs " \
            f"{len(resolved['leaves'])}"
    arrays = []
    for i, leaf in enumerate(resolved["leaves"]):
        sharding = _target_sharding(like_flat[i]) \
            if like_flat is not None else None
        if sharding is not None:
            arrays.append(_resharded_leaf(store, resolved, leaf, sharding,
                                          stats))
        else:
            arrays.append(_stitch_leaf_full(store, resolved, leaf, stats))
    if stats_out is not None:
        stats_out.update(stats)
    if like is not None:
        import jax
        return jax.tree_util.tree_unflatten(treedef, arrays)
    return {leaf["path"]: a
            for leaf, a in zip(resolved["leaves"], arrays)}


def restore_sharded_tree(store, key: str, mesh,
                         stats_out: Optional[dict] = None) -> dict:
    """Restore a v4 checkpoint RESHARDED onto ``mesh``: each leaf's
    recorded physical spec re-resolves through
    ``parallel.sharding.respec`` (same divisibility / used-axis fallbacks
    as record-time resolution) and assembles selectively. Returns
    {path: jax.Array} — the explicit cross-mesh entry point; implicit
    resharding happens whenever ``get_tree`` receives a sharded `like`."""
    from jax.sharding import NamedSharding
    from repro.parallel.sharding import respec
    resolved = store.resolve_manifest(key)
    if resolved.get("kind") != "sharded":
        raise ValueError(f"{key!r} is not a sharded (v4) manifest")
    stats: dict = {"chunks_read": 0, "bytes_by_shard": {}}
    out = {}
    for leaf in resolved["leaves"]:
        sharding = NamedSharding(
            mesh, respec(leaf.get("spec"), leaf["shape"], mesh))
        out[leaf["path"]] = _resharded_leaf(store, resolved, leaf, sharding,
                                            stats)
    if stats_out is not None:
        stats_out.update(stats)
    return out
