"""Pytree helpers used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def path_str(path) -> str:
    """Render a jax KeyPath as a stable, human-readable string."""
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def tree_leaves_with_paths(tree):
    """[(path_str, leaf), ...] in deterministic order."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(path_str(p), v) for p, v in flat]


def tree_bytes(tree) -> int:
    """Total nbytes of all array leaves (works on ShapeDtypeStruct too)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "dtype") and hasattr(leaf, "shape"):
            total += int(np.prod(leaf.shape, dtype=np.int64)) * np.dtype(leaf.dtype).itemsize
    return total


def tree_size(tree) -> int:
    """Total element count of all array leaves."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "shape"):
            total += int(np.prod(leaf.shape, dtype=np.int64))
    return total


def tree_allclose(a, b, rtol=1e-5, atol=1e-6) -> bool:
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    if len(la) != len(lb):
        return False
    for x, y in zip(la, lb):
        if np.asarray(x).shape != np.asarray(y).shape:
            return False
        if not np.allclose(np.asarray(x, dtype=np.float64),
                           np.asarray(y, dtype=np.float64), rtol=rtol, atol=atol):
            return False
    return True


def cast_floating(tree, dtype):
    """Cast floating-point leaves to `dtype`, leave ints/bools alone."""
    def _cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(_cast, tree)
