"""Optional-dependency codecs with stdlib fallbacks.

The container may not ship ``zstandard`` or ``msgpack``; the store (and the
HLO archive) must keep working anyway. Two codecs live here:

* byte compression — zstd when available, else ``zlib``. Decompression
  sniffs the frame magic (zstd: ``28 B5 2F FD``; zlib: first byte ``0x78``),
  so a store written with one codec is readable by a process that has the
  other *writer* but both readers: reading a zstd frame without the
  zstandard module is the only unrecoverable combination, and it raises a
  clear error instead of garbage.
* manifest serialization — msgpack when available, else compact JSON.
  JSON documents start with ``{``; msgpack maps never do (fixmap/map16/map32
  lead bytes are >= 0x80), so the on-disk format is self-describing and the
  file name can stay ``*.msgpack`` either way.

Thread-safety: zstd (de)compressor objects are NOT safe for concurrent use;
per-thread instances are kept (concurrent writers segfaulted). zlib module
functions are safe as-is.
"""
from __future__ import annotations

import json
import threading
import zlib

try:                                   # optional accelerated codecs
    import zstandard as _zstd
except ImportError:                    # pragma: no cover - env dependent
    _zstd = None

try:
    import msgpack as _msgpack
except ImportError:                    # pragma: no cover - env dependent
    _msgpack = None

ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"
ZLIB_FIRST = 0x78                      # CMF byte for deflate/32K window

have_zstd = _zstd is not None
have_msgpack = _msgpack is not None


class Compressor:
    """Best-available byte compressor with format-sniffing decompression."""

    def __init__(self, level: int = 3):
        self.level = level
        self._tl = threading.local()

    # zstd contexts are per-thread; see module docstring
    @property
    def _cctx(self):
        c = getattr(self._tl, "cctx", None)
        if c is None:
            c = self._tl.cctx = _zstd.ZstdCompressor(level=self.level)
        return c

    @property
    def _dctx(self):
        d = getattr(self._tl, "dctx", None)
        if d is None:
            d = self._tl.dctx = _zstd.ZstdDecompressor()
        return d

    def compress(self, data: bytes) -> bytes:
        if _zstd is not None:
            return self._cctx.compress(data)
        return zlib.compress(data, self.level)

    def decompress(self, payload: bytes) -> bytes:
        if payload[:4] == ZSTD_MAGIC:
            if _zstd is None:
                raise RuntimeError(
                    "payload is zstd-compressed but the 'zstandard' module "
                    "is not installed; install it to read this store")
            return self._dctx.decompress(payload)
        if payload[:1] and payload[0] == ZLIB_FIRST:
            return zlib.decompress(payload)
        # unknown leader: let the best available codec try (covers zstd
        # skippable frames and future formats), error otherwise
        if _zstd is not None:
            return self._dctx.decompress(payload)
        return zlib.decompress(payload)


def pack_obj(obj) -> bytes:
    """Serialize a manifest-like dict (msgpack if available, else JSON)."""
    if _msgpack is not None:
        return _msgpack.packb(obj)
    return json.dumps(obj, separators=(",", ":")).encode()


def unpack_obj(payload: bytes):
    """Inverse of :func:`pack_obj`, sniffing the format."""
    if payload[:1] == b"{":
        return json.loads(payload.decode())
    if _msgpack is None:
        raise RuntimeError(
            "manifest is msgpack-encoded but the 'msgpack' module is not "
            "installed; install it to read this store")
    return _msgpack.unpackb(payload)
