"""Wall-clock instrumentation for the Flor adaptive-checkpointing controller."""
from __future__ import annotations

import time


class Stopwatch:
    """Context-manager stopwatch. `elapsed` in seconds after the block."""

    def __init__(self):
        self.elapsed = 0.0
        self._t0 = None

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed = time.perf_counter() - self._t0
        return False

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> float:
        self.elapsed = time.perf_counter() - self._t0
        return self.elapsed


class EMA:
    """Exponential moving average with bias correction (Flor uses EMAs of
    materialization/compute times so early noisy samples wash out)."""

    def __init__(self, beta: float = 0.7):
        self.beta = beta
        self._v = 0.0
        self._n = 0

    def update(self, x: float) -> float:
        self._v = self.beta * self._v + (1.0 - self.beta) * float(x)
        self._n += 1
        return self.value

    @property
    def value(self) -> float:
        if self._n == 0:
            return 0.0
        return self._v / (1.0 - self.beta ** self._n)

    @property
    def count(self) -> int:
        return self._n
