"""Shared utilities: pytree helpers, timing, formatting."""
from repro.utils.pytree import (tree_bytes, tree_leaves_with_paths, path_str,
                                tree_allclose, tree_size)
from repro.utils.timing import Stopwatch, EMA

__all__ = ["tree_bytes", "tree_leaves_with_paths", "path_str", "tree_allclose",
           "tree_size", "Stopwatch", "EMA", "fmt_bytes"]


def fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}PiB"
