"""Logical-axis sharding rules with divisibility fallback.

Models annotate params and activations with LOGICAL axis names; this module
resolves them to PartitionSpecs against the current mesh. Resolution is
defensive: a mesh axis is used at most once per spec, and a logical axis that
does not divide its dimension falls through to the next candidate (ultimately
replication). This is what makes every (arch x shape x mesh) cell lower
cleanly — kv_heads=8 on a 16-way model axis simply replicates instead of
failing, and a batch of 1 falls back to sequence sharding for long-context
decode.

Physical axes:
  "pod"   — outermost, across pods (multi-pod mesh only)
  "data"  — data parallel / FSDP
  "model" — tensor / expert parallel
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Candidate physical axes per logical axis, in preference order. Each
# candidate is a tuple of mesh axis names that will be combined on that dim.
# () = replicate.
DEFAULT_RULES: dict[str, list[tuple[str, ...]]] = {
    # --- activations ---
    "batch":     [("pod", "data"), ("data",), ()],
    "batch_dp3": [("pod", "data", "model"), ("data", "model"),
                  ("pod", "data"), ("data",), ()],
    "seq":       [()],                       # sequence usually unsharded in train
    "seq_mp":    [("model",), ()],           # decode KV sequence sharding (SP)
    # long-context B=1 decode: spread cache over every axis we can
    "cache_seq": [("pod", "data", "model"), ("data", "model"), ("model",), ()],
    "act_embed": [()],
    "act_heads": [("model",), ()],
    "act_mlp":   [("model",), ()],
    "act_vocab": [("model",), ()],
    # --- params ---
    "vocab":     [("model",), ()],
    "embed":     [("pod", "data"), ("data",), ()],   # FSDP / ZeRO-3 shard dim
    "heads":     [("model",), ()],
    "kv_heads":  [("model",), ()],
    "mlp":       [("model",), ()],
    "expert":    [("model",), ()],
    "dinner":    [("model",), ()],           # mamba inner dim
    "layer":     [()],
    "stage":     [()],                        # pipeline stages (opt-in)
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: dict[str, list[tuple[str, ...]]] = DEFAULT_RULES


_CTX = _Ctx()


def axis_rules_for_mesh(mesh: Mesh, overrides: Optional[dict] = None):
    rules = dict(DEFAULT_RULES)
    if overrides:
        rules.update(overrides)
    return rules


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None):
    """Install mesh + rules for constrain()/param_sharding(). None = no-op mode."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    _CTX.rules = rules or (axis_rules_for_mesh(mesh) if mesh is not None else DEFAULT_RULES)
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _mesh_axis_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def physical_spec(
    logical: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Optional[Mesh] = None,
    rules: Optional[dict] = None,
) -> P:
    """Resolve logical axis names to a PartitionSpec with divisibility and
    used-axis fallbacks."""
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    if mesh is None:
        return P()
    mesh_axes = set(mesh.shape.keys())
    used: set[str] = set()
    out = []
    for name, dim in zip(logical, shape):
        entry: object = None
        if name is not None:
            for cand in rules.get(name, [()]):
                cand = tuple(a for a in cand if a in mesh_axes)
                if not cand:
                    continue
                if any(a in used for a in cand):
                    continue
                if dim % _mesh_axis_size(mesh, cand) != 0:
                    continue
                entry = cand if len(cand) > 1 else cand[0]
                used.update(cand)
                break
        out.append(entry)
    # trailing Nones can be dropped but keeping them is harmless
    return P(*out)


def spec_entries(spec) -> list:
    """Normalize a PartitionSpec (or any sequence of entries) into a
    JSON/msgpack-serializable list: each entry None, a mesh-axis name, or a
    list of names. This is the layout-independent form checkpoint manifests
    record so a restore can re-resolve it on a different mesh."""
    if spec is None:
        return None
    out: list = []
    for e in tuple(spec):
        if e is None:
            out.append(None)
        elif isinstance(e, (tuple, list)):
            out.append([str(a) for a in e])
        else:
            out.append(str(e))
    return out


def respec(entries: Optional[Sequence], shape: Sequence[int],
           mesh: Mesh) -> P:
    """Re-resolve a RECORDED physical spec (``spec_entries`` form) on a
    possibly different mesh, with the same defensive fallbacks as
    ``physical_spec``: axes absent from the new mesh drop out, each mesh
    axis is used at most once, and a combination that does not divide its
    dimension falls back to its longest dividing prefix (ultimately
    replication). This is how an N-host recording reshards onto an M-host
    (or single-host) replay mesh."""
    mesh_axes = set(mesh.shape.keys())
    used: set[str] = set()
    ent = list(entries or [])
    ent += [None] * (len(shape) - len(ent))
    out = []
    for e, dim in zip(ent, shape):
        if e is None:
            axes: tuple[str, ...] = ()
        elif isinstance(e, (tuple, list)):
            axes = tuple(str(a) for a in e)
        else:
            axes = (str(e),)
        axes = tuple(a for a in axes if a in mesh_axes and a not in used)
        while axes and dim % _mesh_axis_size(mesh, axes) != 0:
            axes = axes[:-1]
        used.update(axes)
        out.append(axes if len(axes) > 1 else (axes[0] if axes else None))
    return P(*out)


def param_sharding(logical, shape, mesh: Optional[Mesh] = None) -> Optional[NamedSharding]:
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, physical_spec(logical, shape, mesh))


def constrain(x, logical: Sequence[Optional[str]]):
    """with_sharding_constraint under the installed mesh; identity otherwise."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = physical_spec(logical, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
