"""Gradient compression for cross-pod all-reduce (beyond-paper, opt-in).

Blockwise int8 quantization with error feedback: each gradient leaf is
quantized per 256-value block to int8 + f32 scale (~4x over f32, ~2x over
bf16 on the wire), the quantization residual is carried into the next step
(error feedback keeps SGD/Adam convergence unbiased in practice).

The same codec backs checkpoint compression (kernels/quantize.py holds the
Pallas TPU kernel; this module is the jnp reference/composition layer).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


class CompressedLeaf(NamedTuple):
    q: jnp.ndarray        # int8 [n_blocks, BLOCK]
    scale: jnp.ndarray    # f32  [n_blocks]
    n: int                # original element count


def quantize_leaf(x) -> CompressedLeaf:
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return CompressedLeaf(q=q, scale=scale, n=n)


def dequantize_leaf(c: CompressedLeaf, shape, dtype):
    blocks = c.q.astype(jnp.float32) * c.scale[:, None]
    return blocks.reshape(-1)[: c.n].reshape(shape).astype(dtype)


def compress_grads_with_feedback(grads, error_state):
    """Returns (compressed_pytree, new_error_state). error_state has the
    same structure as grads (zeros at step 0)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e.astype(jnp.float32)
        c = quantize_leaf(g32)
        deq = dequantize_leaf(c, g.shape, jnp.float32)
        new_e = g32 - deq
        return c, new_e
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(error_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    comp = jax.tree_util.tree_unflatten(treedef, [c for c, _ in out])
    err = jax.tree_util.tree_unflatten(treedef, [e for _, e in out])
    return comp, err


def decompress_grads(comp, like):
    flat_c = jax.tree_util.tree_leaves(
        comp, is_leaf=lambda x: isinstance(x, CompressedLeaf))
    flat_l, treedef = jax.tree_util.tree_flatten(like)
    return jax.tree_util.tree_unflatten(
        treedef, [dequantize_leaf(c, l.shape, l.dtype)
                  for c, l in zip(flat_c, flat_l)])


def init_error_state(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
