"""Gradient compression for cross-pod all-reduce (beyond-paper, opt-in),
plus the writer-thread ENTROPY STAGE of the checkpoint wire pipeline.

Blockwise int8 quantization with error feedback: each gradient leaf is
quantized per 256-value block to int8 + f32 scale (~4x over f32, ~2x over
bf16 on the wire), the quantization residual is carried into the next step
(error feedback keeps SGD/Adam convergence unbiased in practice).

The same codec backs checkpoint compression (kernels/quantize.py holds the
Pallas TPU kernel; this module is the jnp reference/composition layer).

Entropy stage (``entropy_encode_bytes``/``entropy_decode_bytes``): a
host-side byte-plane shuffle + high-level compress applied to
already-gathered checkpoint chunks on the WRITER thread (never the step
path — its cost lands in the adaptive controller's ``bg_s`` accumulator).
Transposing an f32 payload into byte planes groups the exponent bytes of
neighboring values, which a generic per-chunk zstd/zlib pass cannot exploit
— that's where the extra shrink over the store's own level-3 compression
comes from. The output is self-describing (magic + stride + raw length),
and the inner codec is ``utils.codec.Compressor`` so the zlib fallback
works where zstandard is absent.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.utils.codec import Compressor

BLOCK = 256

# ---------------------------------------------------------- entropy stage --
# wire header: magic byte, byte-plane stride (1 = no shuffle), u32 raw length
_ENTROPY_MAGIC = 0xE7
_entropy_codec = Compressor(level=9)      # writer-thread time, spent on bytes


def entropy_encode_bytes(data: bytes, itemsize: int = 1) -> bytes:
    """Byte-plane shuffle (stride = ``itemsize``; 1 disables the shuffle,
    right for q8/q4 payloads whose bytes are already homogeneous) then
    compress at a high level. Returns a self-describing payload for
    ``entropy_decode_bytes``."""
    stride = itemsize if itemsize > 1 and len(data) % itemsize == 0 else 1
    body = data
    if stride > 1:
        body = np.frombuffer(data, np.uint8).reshape(-1, stride) \
            .T.tobytes()                  # plane-major: all byte-0s, then 1s…
    head = bytes([_ENTROPY_MAGIC, stride]) \
        + np.uint32(len(data)).tobytes()
    return head + _entropy_codec.compress(body)


def entropy_decode_bytes(payload: bytes) -> bytes:
    """Inverse of :func:`entropy_encode_bytes`."""
    if not payload or payload[0] != _ENTROPY_MAGIC:
        raise ValueError("not an entropy-stage payload (bad magic)")
    stride = payload[1]
    raw_len = int(np.frombuffer(payload[2:6], np.uint32)[0])
    body = _entropy_codec.decompress(payload[6:])
    if stride > 1:
        body = np.frombuffer(body, np.uint8).reshape(stride, -1) \
            .T.tobytes()
    assert len(body) == raw_len, (len(body), raw_len)
    return body


class CompressedLeaf(NamedTuple):
    q: jnp.ndarray        # int8 [n_blocks, BLOCK]
    scale: jnp.ndarray    # f32  [n_blocks]
    n: int                # original element count


def quantize_leaf(x) -> CompressedLeaf:
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return CompressedLeaf(q=q, scale=scale, n=n)


def dequantize_leaf(c: CompressedLeaf, shape, dtype):
    blocks = c.q.astype(jnp.float32) * c.scale[:, None]
    return blocks.reshape(-1)[: c.n].reshape(shape).astype(dtype)


def compress_grads_with_feedback(grads, error_state):
    """Returns (compressed_pytree, new_error_state). error_state has the
    same structure as grads (zeros at step 0)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e.astype(jnp.float32)
        c = quantize_leaf(g32)
        deq = dequantize_leaf(c, g.shape, jnp.float32)
        new_e = g32 - deq
        return c, new_e
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(error_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    comp = jax.tree_util.tree_unflatten(treedef, [c for c, _ in out])
    err = jax.tree_util.tree_unflatten(treedef, [e for _, e in out])
    return comp, err


def decompress_grads(comp, like):
    flat_c = jax.tree_util.tree_leaves(
        comp, is_leaf=lambda x: isinstance(x, CompressedLeaf))
    flat_l, treedef = jax.tree_util.tree_flatten(like)
    return jax.tree_util.tree_unflatten(
        treedef, [dequantize_leaf(c, l.shape, l.dtype)
                  for c, l in zip(flat_c, flat_l)])


def init_error_state(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
