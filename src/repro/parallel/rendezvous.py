"""File-based stitch rendezvous for true multi-process mesh record.

PR 7's sharded pipeline simulated every host inside one process, so the
v4 stitch was a plain function call. Under ``jax.distributed`` each REAL
host records its local shards and publishes member manifests into its own
``store/shards/<hid>/`` pool; the only cross-host coordination is a small
file barrier under ``<store_root>/runs/<run>/.stitch/``:

  * every process ``publish()``-es one JSON marker per checkpoint key
    (``<key>/p<pid>.json``, via the store's crash-safe ``_atomic_write``)
    carrying its member-manifest names and local layout fragment, and
    touches its heartbeat file ``hb.p<pid>``;
  * the LEAD process (process 0) ``gather()``-s all markers, validates the
    member manifests, and writes the global v4 manifest atomically — the
    ONLY writer of the stitch, so there is no election race;
  * a process that dies between member publication and the stitch leaves
    only unreferenced member manifests (GC reclaims them — the v4 was
    never written, so nothing dangles); a straggler past the deadline
    makes ``gather`` return ``None`` and the lead marks the checkpoint
    ``incomplete`` in run meta instead of wedging training.

Heartbeats bound the wait from the OTHER side: every live process runs a
background beater thread that renews its ``hb.p<pid>`` file continuously
(a beat only at publish time would go stale between checkpoints whenever
the cadence exceeds the stitch timeout). A gather measures staleness
RELATIVE TO ITS OWN START — a heartbeat is evidence of death only once it
has been silent for ``timeout_s`` within the current gather — because the
``.stitch/`` dir (and the heartbeat files in it) outlives checkpoints and
even whole runs: replay reuses the record run's dir, and a leftover
record-phase heartbeat must not declare a replay host dead before it had
a chance to start.

Fault injection (tests / the distributed example): set
``FLOR_DIST_CRASH_BEFORE_PUBLISH=<key>`` (optionally scoped with
``FLOR_DIST_CRASH_PROCESS=<pid>``) and the matching process exits with
code 43 after writing its member manifests but before publishing its
marker — the exact window the crash-safety argument is about.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from dataclasses import dataclass
from typing import Optional

from repro.checkpoint.store import _atomic_write

CRASH_EXIT_CODE = 43


def _fsafe(name: str) -> str:
    return re.sub(r"[^A-Za-z0-9._-]", "_", name)


@dataclass(frozen=True)
class ProcessGroup:
    """Identity of this process inside a jax.distributed record fleet."""
    process_id: int
    num_processes: int
    coordinator: Optional[str] = None

    def __post_init__(self):
        if not (0 <= self.process_id < self.num_processes):
            raise ValueError(
                f"process_id {self.process_id} outside fleet of "
                f"{self.num_processes}")

    @property
    def is_lead(self) -> bool:
        return self.process_id == 0


def init_distributed(coordinator: str, process_id: int,
                     num_processes: int) -> ProcessGroup:
    """``jax.distributed.initialize`` + the matching ProcessGroup. A
    single-process fleet skips the jax service entirely (handy for
    launcher smoke paths).

    ``FLOR_DIST_HEARTBEAT_SLACK=<k>`` multiplies the coordination
    service's missing-heartbeat allowance (default interval 10s x 10
    missed). On an oversubscribed box — CI runners, a laptop running the
    whole fleet — concurrent XLA compiles can starve a process past the
    stock 100s window, and the coordinator then aborts the HEALTHY peers;
    the slack keeps a slow-but-alive fleet out of that failure mode. The
    knob rides the internal initialize (the public one does not expose
    heartbeat tuning in this jax line) and falls back to the public API
    when the internals have moved."""
    group = ProcessGroup(process_id, num_processes, coordinator)
    if num_processes > 1:
        import jax
        slack = max(1, int(os.environ.get("FLOR_DIST_HEARTBEAT_SLACK",
                                          "1") or 1))
        if slack > 1:
            try:
                from jax._src.distributed import global_state
                global_state.initialize(
                    coordinator_address=coordinator,
                    num_processes=num_processes,
                    process_id=process_id,
                    service_max_missing_heartbeats=10 * slack,
                    client_max_missing_heartbeats=10 * slack)
                return group
            except (ImportError, TypeError):
                pass
        jax.distributed.initialize(coordinator_address=coordinator,
                                   num_processes=num_processes,
                                   process_id=process_id)
    return group


def current_group() -> ProcessGroup:
    """ProcessGroup of an already-initialized jax runtime (process 0/1
    when jax.distributed was never initialized)."""
    import jax
    return ProcessGroup(int(jax.process_index()), int(jax.process_count()))


def crash_requested(key: str, process_id: int) -> bool:
    """Whether the fault-injection env asks THIS process to die before
    publishing ``key``'s marker."""
    want = os.environ.get("FLOR_DIST_CRASH_BEFORE_PUBLISH")
    if not want or want != key:
        return False
    pid = os.environ.get("FLOR_DIST_CRASH_PROCESS")
    return pid is None or int(pid) == process_id


class StitchRendezvous:
    """Crash-safe file barrier under ``<store_root>/runs/<run>/.stitch/``.

    Every mutation goes through ``_atomic_write`` (tmp + ``os.replace``),
    so a reader never observes a torn marker; a marker either exists whole
    or not at all, which is exactly the publication-ordering guarantee the
    v4 stitch needs.
    """

    POLL_S = 0.02

    def __init__(self, store_root: str, run_id: str, group: ProcessGroup,
                 timeout_s: float = 30.0):
        self.root = os.path.join(str(store_root), "runs", _fsafe(run_id),
                                 ".stitch")
        self.group = group
        self.timeout_s = float(timeout_s)
        os.makedirs(self.root, exist_ok=True)
        # continuous liveness: beat NOW (so a peer's gather never sees only
        # a stale record-phase leftover) and keep beating on a daemon
        # thread until close() — a beat only at publish time goes stale
        # between checkpoints whenever the cadence exceeds timeout_s
        self._beat_interval = min(max(self.timeout_s / 4.0, 0.05), 5.0)
        self._beat_stop = threading.Event()
        self.heartbeat()
        self._beater = threading.Thread(
            target=self._beat_loop, daemon=True,
            name=f"stitch-hb-p{group.process_id}")
        self._beater.start()

    # ------------------------------------------------------------ paths --
    def _key_dir(self, key: str) -> str:
        return os.path.join(self.root, _fsafe(key))

    def _marker(self, key: str, pid: int) -> str:
        return os.path.join(self._key_dir(key), f"p{pid}.json")

    def _hb_path(self, pid: int) -> str:
        return os.path.join(self.root, f"hb.p{pid}")

    # ------------------------------------------------------- publication --
    def heartbeat(self):
        _atomic_write(self._hb_path(self.group.process_id),
                      str(time.time()).encode())

    def _beat_loop(self):
        while not self._beat_stop.wait(self._beat_interval):
            try:
                self.heartbeat()
            except OSError:
                pass    # store dir gone (gc'd run): liveness is moot

    def close(self):
        """Stop the background beater. The rendezvous stays usable (publish
        still beats once per call); only continuous liveness ends — callers
        close when the record/replay session is done with coordination."""
        self._beat_stop.set()
        self._beater.join(timeout=2 * self._beat_interval)

    def publish(self, key: str, payload: dict):
        """Atomically publish this process's marker for ``key`` and renew
        the heartbeat. The fault-injection window sits just above this
        call (see ``crash_requested``) — by the time a marker exists, the
        member manifests it names are durably on disk."""
        d = self._key_dir(key)
        os.makedirs(d, exist_ok=True)
        _atomic_write(self._marker(key, self.group.process_id),
                      json.dumps(payload, sort_keys=True).encode())
        self.heartbeat()

    # ----------------------------------------------------------- gather --
    def _hb_stale(self, pid: int, since: float) -> bool:
        """Dead iff the heartbeat has been silent for ``timeout_s`` WITHIN
        the current gather (``since`` = the gather's wall-clock start).
        Absolute file age is meaningless across invocations: the heartbeat
        file survives in ``.stitch/`` between checkpoints and between the
        record run and a later replay, so an old mtime only proves the
        peer has not STARTED yet — it gets the timeout to show up and its
        beater makes the file fresh the moment it does."""
        try:
            m = os.path.getmtime(self._hb_path(pid))
        except OSError:
            return False          # never beat yet: charge the deadline
        return time.time() - max(m, since) > self.timeout_s

    def gather(self, key: str,
               timeout_s: Optional[float] = None) -> Optional[list]:
        """Lead-only. All processes' payloads for ``key`` ordered by
        process id, or ``None`` once the deadline passes or a missing
        process's heartbeat goes stale (it is dead; waiting longer cannot
        help — the early exit matters when the budget exceeds the
        heartbeat timeout, e.g. a long merge deadline over a short
        liveness window)."""
        budget = self.timeout_s if timeout_s is None else float(timeout_s)
        deadline = time.monotonic() + budget
        start = time.time()
        want = range(self.group.num_processes)
        while True:
            found = {}
            for pid in want:
                try:
                    with open(self._marker(key, pid), "rb") as f:
                        found[pid] = json.loads(f.read())
                except (OSError, ValueError):
                    pass
            if len(found) == self.group.num_processes:
                return [found[p] for p in want]
            if time.monotonic() >= deadline:
                return None
            if any(p not in found and self._hb_stale(p, start)
                   for p in want):
                return None
            time.sleep(self.POLL_S)

    def clear(self, key: str):
        """Drop a stitched key's marker dir (the v4 manifest is the
        durable record; markers are scratch)."""
        shutil.rmtree(self._key_dir(key), ignore_errors=True)

    def retract(self, key: str):
        """Remove this process's OWN marker for ``key`` (no heartbeat).
        Barrier users call it at startup so a stale marker left by a
        crashed previous invocation can never satisfy this round's
        ``await_all`` on their behalf."""
        try:
            os.remove(self._marker(key, self.group.process_id))
        except OSError:
            pass

    # ---------------------------------------------------------- barrier --
    def arrive(self, name: str, payload: Optional[dict] = None):
        """Generic named barrier arrival (e.g. replay-merge handoff):
        publish a marker under the pseudo-key ``name``."""
        self.publish(name, payload if payload is not None
                     else {"process": self.group.process_id})

    def await_all(self, name: str,
                  timeout_s: Optional[float] = None) -> Optional[list]:
        """Lead-side wait for every process's ``arrive(name)``."""
        return self.gather(name, timeout_s)
