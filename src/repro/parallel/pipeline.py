"""GPipe-style pipeline parallelism (opt-in; the baseline cells use DP/TP —
DESIGN.md section 6 records why). Provided as a composable building block so
a "stage" mesh axis can be added for >512-chip deployments where layer-FSDP
gathers would otherwise dominate.

The schedule is the classic skewed scan: with S stages and M microbatches,
time step t lets stage s work on microbatch (t - s). States live in a
[S, mb, ...] buffer that shifts one stage down per step (jnp.roll — lowers
to a collective-permute when the leading dim is sharded over "stage").

Equivalence to the sequential layer scan is tested in
tests/test_pipeline.py; bubble fraction is the usual (S-1)/(M+S-1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel import constrain


def stage_scan(stage_fn, stage_params, x, *, microbatches: int):
    """Run ``x`` through S pipeline stages.

    stage_fn(params_slice, h) -> h  applies ONE stage (a group of layers).
    stage_params: pytree stacked on a leading S axis (logical "stage").
    x: [B, ...] with B % microbatches == 0.

    Returns the result of stage S-1 applied after ... after stage 0.
    """
    S = jax.tree_util.tree_leaves(stage_params)[0].shape[0]
    B = x.shape[0]
    assert B % microbatches == 0, (B, microbatches)
    mb = B // microbatches
    xs = x.reshape(microbatches, mb, *x.shape[1:])

    # state buffer: what each stage is currently holding
    buf = jnp.zeros((S, mb) + x.shape[1:], x.dtype)
    buf = constrain(buf, ("stage",) + (None,) * (buf.ndim - 1))
    outs = jnp.zeros_like(xs)

    total = microbatches + S - 1

    def step(carry, t):
        buf, outs = carry
        # inject the next microbatch into stage 0's slot
        inject = jnp.where(t < microbatches, t, 0)
        buf = buf.at[0].set(
            jnp.where(t < microbatches, xs[inject], buf[0]))
        # every stage processes its current microbatch (garbage lanes are
        # masked out at collection time)
        processed = jax.vmap(stage_fn)(stage_params, buf)
        # stage S-1's output corresponds to microbatch t-(S-1)
        out_idx = jnp.clip(t - (S - 1), 0, microbatches - 1)
        valid = t >= (S - 1)
        outs = outs.at[out_idx].set(
            jnp.where(valid, processed[S - 1], outs[out_idx]))
        # shift: stage s+1 receives stage s's output next step
        buf = jnp.roll(processed, 1, axis=0)
        return (buf, outs), None

    (buf, outs), _ = jax.lax.scan(step, (buf, outs),
                                  jnp.arange(total, dtype=jnp.int32))
    return outs.reshape(B, *x.shape[1:])


def bubble_fraction(n_stages: int, microbatches: int) -> float:
    return (n_stages - 1) / (microbatches + n_stages - 1)
