from repro.parallel.sharding import (  # noqa: F401
    axis_rules_for_mesh,
    constrain,
    current_mesh,
    param_sharding,
    physical_spec,
    use_mesh,
)
