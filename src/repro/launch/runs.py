"""Run-lineage CLI: inspect, QUERY and garbage-collect a multi-run store.

    PYTHONPATH=src python -m repro.launch.runs list --store-root STORE
    PYTHONPATH=src python -m repro.launch.runs show RUN --store-root STORE
    PYTHONPATH=src python -m repro.launch.runs gc   --store-root STORE
    PYTHONPATH=src python -m repro.launch.runs rm RUN --store-root STORE [--gc]
    PYTHONPATH=src python -m repro.launch.runs diff RUN_A RUN_B \
        --store-root STORE
    PYTHONPATH=src python -m repro.launch.runs logs --store-root STORE \
        [--run RUN] [--key loss] [--no-replay] [--where key=loss] \
        [--limit N] [--tail N] [--lineage RUN] [--engine auto|files|index]
    PYTHONPATH=src python -m repro.launch.runs pivot --store-root STORE \
        [loss grad_norm ...] [--run RUN] [--lineage RUN] [--engine ...]
    PYTHONPATH=src python -m repro.launch.runs reindex --store-root STORE

`--store-root` also accepts a RUN DIRECTORY (anything containing
flor.run.json): the CLI follows the binding to the store the run actually
used, so `runs list --store-root /tmp/runB` works on legacy per-run stores
too.

`gc` applies the multi-run live-set policy: the union of every registered
run's manifests, extended by `CheckpointStore.gc` with the cross-run parent
closure — so after `rm A`, `gc` reclaims exactly the checkpoints and chunks
no surviving descendant of A still resolves through.

`logs` streams every fingerprint-log row of every registered run (tagged
run_id/parent/source); `pivot` prints one row per (run, epoch) with log
keys as columns — the cross-run hindsight-logging view (`flor.log_records`
/ `flor.pivot` are the library spellings).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.checkpoint import CheckpointStore, RunRegistry
from repro.core.query import log_records, pivot, resolve_store_root

_resolve_store_root = resolve_store_root      # back-compat alias


def _fmt_ts(ts) -> str:
    if not ts:
        return "-"
    return time.strftime("%Y-%m-%d %H:%M:%S", time.localtime(float(ts)))


def _run_keys(store: CheckpointStore, rec: dict) -> list[str]:
    return store.list_keys(run=rec.get("namespace"))


def cmd_list(store: CheckpointStore, registry: RunRegistry, args) -> int:
    runs = registry.list_runs()
    if not runs:
        print(f"no registered runs under {registry.root}")
        return 0
    print(f"{'RUN':<24} {'PARENT':<24} {'STATUS':<9} {'CKPTS':>5}  "
          f"{'SCOPES':<16} CREATED")
    for rec in runs:
        scopes = ",".join(sorted(rec.get("final_keys") or {})) or "-"
        print(f"{rec['run_id']:<24} {str(rec.get('parent') or '-'):<24} "
              f"{rec.get('status', '?'):<9} {len(_run_keys(store, rec)):>5}  "
              f"{scopes:<16} {_fmt_ts(rec.get('created_at'))}")
    st = store.stats()
    print(f"store: {st['manifests']} manifests "
          f"({st['full_manifests']} full + {st['delta_manifests']} delta), "
          f"max resolve chain {st['max_chain_depth']}, "
          f"{st['chunks']} chunks, {st['stored_bytes'] / 2**20:.1f} MiB")
    return 0


def cmd_show(store: CheckpointStore, registry: RunRegistry, args) -> int:
    rec = registry.get(args.run)
    if rec is None:
        print(f"unknown run {args.run!r} "
              f"(known: {[r['run_id'] for r in registry.list_runs()]})")
        return 1
    print(f"run        {rec['run_id']}")
    print(f"status     {rec.get('status', '?')}  "
          f"(created {_fmt_ts(rec.get('created_at'))}, "
          f"finished {_fmt_ts(rec.get('finished_at'))})")
    print(f"run_dir    {rec.get('run_dir') or '-'}")
    print(f"namespace  {rec.get('namespace') or '(flat)'}")
    chain = registry.ancestry(args.run)
    print("ancestry   " + " <- ".join(r["run_id"] for r in chain))
    for scope, key in sorted((rec.get("final_keys") or {}).items()):
        print(f"final      {scope}: {key}")
    keys = _run_keys(store, rec)
    ns = rec.get("namespace")
    # no chunk fields printed here: skip the O(store) objects-pool walk
    st = store.stats(keys=[f"{ns or ''}::{k}" for k in keys],
                     include_chunks=False, per_key=True)
    print(f"manifests  {st['manifests']} ({st['full_manifests']} full + "
          f"{st['delta_manifests']} delta"
          + (f" + {st['sharded_manifests']} sharded"
             if st.get("sharded_manifests") else "")
          + f"), max resolve chain "
          f"{st['max_chain_depth']} (may cross into ancestor runs)")
    _show_mesh(store, rec, st)
    _show_encodings(store, rec)
    return 0


def _show_mesh(store: CheckpointStore, rec: dict, st: dict) -> None:
    """Mesh shape + per-store-shard breakdown for sharded (v4) recordings —
    read from the recorded mesh meta and the v4 manifests' member chains."""
    rstore = CheckpointStore(store.root, run_id=rec.get("namespace"))
    mesh = rstore.get_meta("mesh")
    per_key = st.get("per_key") or {}
    shard_keys: dict[str, set] = {}    # hid -> sanitized member keys
    for info in per_key.values():
        for hid in (info.get("shards") or {}):
            shard_keys.setdefault(str(hid), set())
    if not mesh and not shard_keys:
        return
    if mesh:
        axes = " ".join(f"{n}={s}" for n, s in mesh.get("axes") or [])
        shard_axes = ",".join(mesh.get("shard_axes") or []) or "(all axes)"
        print(f"mesh       {axes or '-'}  "
              f"(ckpt shard axes: {shard_axes}; "
              f"{mesh.get('n_store_shards', len(shard_keys) or 1)} "
              f"store shards)")
    stored = store.shard_stored_bytes()
    ns = rec.get("namespace")
    print(f"{'  SHARD':<8} {'MANIFESTS':>9} {'CLOSURE CHUNKS':>14} "
          f"{'CLOSURE MiB':>12} {'POOL MiB':>9}")
    for hid in sorted(shard_keys, key=lambda h: int(h)):
        members = [f"{ns or ''}::{k}" for k in store.list_keys(run=ns)
                   if k.endswith(f".shard{hid}")]
        chunks = store.closure_chunks(members)
        print(f"  {hid:<6} {len(members):>9} {len(chunks):>14} "
              f"{store.chunk_bytes(chunks) / 2**20:>12.2f} "
              f"{stored.get(str(hid), 0) / 2**20:>9.2f}")


def _show_encodings(store: CheckpointStore, rec: dict) -> None:
    """Per-chunk wire-encoding mix of each scope's FINAL checkpoint (what a
    restore of it reads, chain-inherited chunks included): chunk counts and
    on-disk bytes per encoding — raw / q8 / q4, "+z" marking payloads the
    writer-thread entropy stage kept compressed. Checkpoints that are all
    raw print nothing (the default exact path has no mix to show)."""
    ns = rec.get("namespace")
    for scope, key in sorted((rec.get("final_keys") or {}).items()):
        try:
            mix = store.encoding_mix(f"{ns or ''}::{key}")
        except Exception:
            continue                       # broken chain: diagnostic only
        if not mix or set(mix) == {"raw"}:
            continue
        parts = ", ".join(
            f"{e} {mix[e]['chunks']} ({mix[e]['stored_bytes'] / 2**20:.2f} "
            f"MiB)" for e in sorted(mix))
        print(f"encodings  {scope}: {parts}")


def cmd_gc(store: CheckpointStore, registry: RunRegistry, args) -> int:
    stats = registry.gc(store)
    print(f"gc: kept {stats['kept_manifests']} manifests / "
          f"{stats['kept_chunks']} chunks; deleted "
          f"{stats['deleted_manifests']} manifests / "
          f"{stats['deleted_chunks']} chunks "
          f"({stats['deleted_bytes'] / 2**20:.2f} MiB)")
    return 0


def cmd_rm(store: CheckpointStore, registry: RunRegistry, args) -> int:
    descendants = [r["run_id"] for r in registry.list_runs()
                   if r.get("parent") == args.run]
    if descendants and not args.force:
        print(f"run {args.run!r} has registered descendants {descendants}; "
              f"their warm-start closure will keep pinning what they "
              f"inherit. Pass --force to unregister anyway.")
        return 1
    if not registry.unregister(args.run):
        print(f"unknown run {args.run!r}")
        return 1
    print(f"unregistered {args.run!r} "
          f"(manifests remain until gc; descendants keep their closure)")
    if args.gc:
        return cmd_gc(store, registry, args)
    return 0


def cmd_diff(store: CheckpointStore, registry: RunRegistry, args) -> int:
    """Chunk-level diff of two runs' manifest CLOSURES (each run's own
    manifests plus every ancestor manifest its delta chains resolve
    through): what lineage sharing actually saves on disk."""
    recs = []
    for rid in (args.run_a, args.run_b):
        rec = registry.get(rid)
        if rec is None:
            print(f"unknown run {rid!r} "
                  f"(known: {[r['run_id'] for r in registry.list_runs()]})")
            return 1
        recs.append(rec)
    closures = []
    for rec in recs:
        ns = rec.get("namespace")
        keys = [f"{ns or ''}::{k}" for k in store.list_keys(run=ns)]
        closures.append(store.closure_chunks(keys))
    ca, cb = closures
    shared, only_a, only_b = ca & cb, ca - cb, cb - ca
    rows = [("shared", shared), (f"only {args.run_a}", only_a),
            (f"only {args.run_b}", only_b)]
    print(f"{'SET':<28} {'CHUNKS':>8} {'MiB':>10}")
    for label, chunks in rows:
        print(f"{label:<28} {len(chunks):>8} "
              f"{store.chunk_bytes(chunks) / 2**20:>10.2f}")
    union = len(ca | cb)
    if union:
        print(f"dedup: {len(shared)}/{union} chunks shared "
              f"({100.0 * len(shared) / union:.1f}% of the union — bytes "
              f"one copy serves both runs)")
    return 0


def _parse_where(pairs) -> dict:
    """--where col=value (repeatable) -> {col: value}. Values parse as JSON
    when they can (epoch=3 is the int 3), else stay strings (key=loss)."""
    out = {}
    for pair in pairs or ():
        if "=" not in pair:
            raise SystemExit(f"--where expects col=value, got {pair!r}")
        col, raw = pair.split("=", 1)
        try:
            out[col.strip()] = json.loads(raw)
        except json.JSONDecodeError:
            out[col.strip()] = raw
    return out


def cmd_logs(store: CheckpointStore, registry: RunRegistry, args) -> int:
    rows = log_records(args.store_root, run=args.run, key=args.key,
                       include_replay=not args.no_replay,
                       inline_spill_bytes=args.inline_spill_bytes,
                       lineage=args.lineage, where=_parse_where(args.where),
                       limit=args.limit, tail=args.tail, engine=args.engine)
    if not rows:
        print("no log records found")
        return 0
    print(f"{'RUN':<24} {'PARENT':<24} {'SOURCE':<10} {'EPOCH':>5} "
          f"{'SEQ':>4}  {'KEY':<18} VALUE")
    for r in rows:
        print(f"{str(r['run_id']):<24} {str(r['parent_run'] or '-'):<24} "
              f"{r['source']:<10} {str(r['epoch']):>5} {str(r['seq']):>4}  "
              f"{str(r['key']):<18} {json.dumps(r['value'], default=str)}")
    print(f"({len(rows)} rows)")
    return 0


def cmd_pivot(store: CheckpointStore, registry: RunRegistry, args) -> int:
    rows = pivot(args.store_root, *args.keys, run=args.run,
                 include_replay=not args.no_replay,
                 inline_spill_bytes=args.inline_spill_bytes,
                 lineage=args.lineage, engine=args.engine)
    if not rows:
        print("no log records found")
        return 0
    cols = []
    for r in rows:
        for k in r:
            if k not in cols and k not in ("run_id", "parent_run", "epoch"):
                cols.append(k)
    header = f"{'RUN':<24} {'PARENT':<24} {'EPOCH':>5}"
    for c in cols:
        header += f" {c:>14}"
    print(header)
    for r in rows:
        line = (f"{str(r['run_id']):<24} {str(r['parent_run'] or '-'):<24} "
                f"{str(r['epoch']):>5}")
        for c in cols:
            v = r.get(c)
            line += f" {v:>14.6g}" if isinstance(v, float) \
                else f" {str(v if v is not None else '-'):>14}"
        print(line)
    print(f"({len(rows)} rows x {len(cols)} keys)")
    return 0


def cmd_reindex(store: CheckpointStore, registry: RunRegistry, args) -> int:
    from repro.querydb import reindex
    stats = reindex(args.store_root)
    print(f"reindexed {args.store_root}: {stats['runs']} runs, "
          f"{stats['segments_ingested']} segments ingested "
          f"({stats['segments_skipped']} already current, "
          f"{stats['segments_pruned']} pruned), {stats['rows']} rows read; "
          f"index now holds {stats['records']} records over "
          f"{stats['segments']} segments ({stats['spilled']} spill refs)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.launch.runs",
                                 description=__doc__.splitlines()[0])
    common = argparse.ArgumentParser(add_help=False)
    common.add_argument("--store-root", required=True,
                        help="shared store root, or a run dir with "
                             "flor.run.json")
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("list", parents=[common],
                   help="registered runs + store summary")
    p_show = sub.add_parser("show", parents=[common],
                            help="one run: lineage, finals, stats")
    p_show.add_argument("run")
    sub.add_parser("gc", parents=[common],
                   help="multi-run live-set garbage collection")
    p_rm = sub.add_parser("rm", parents=[common],
                          help="unregister a run (reclaim via gc)")
    p_rm.add_argument("run")
    p_rm.add_argument("--force", action="store_true",
                      help="unregister even with registered descendants")
    p_rm.add_argument("--gc", action="store_true",
                      help="run gc immediately after unregistering")
    p_diff = sub.add_parser("diff", parents=[common],
                            help="chunks shared vs unique between two "
                                 "runs' manifest closures")
    p_diff.add_argument("run_a")
    p_diff.add_argument("run_b")
    p_logs = sub.add_parser("logs", parents=[common],
                            help="every log row across the lineage")
    p_logs.add_argument("--run", default=None, help="restrict to one run id")
    p_logs.add_argument("--key", default=None, help="restrict to one log key")
    p_logs.add_argument("--no-replay", action="store_true",
                        help="record logs only (skip hindsight replay logs)")
    p_logs.add_argument("--inline-spill-bytes", type=int, default=0,
                        help="resolve spilled values at/below this size "
                             "back to the actual value (0 = keep pointers)")
    p_logs.add_argument("--where", action="append", metavar="COL=VALUE",
                        help="equality filter (repeatable; e.g. key=loss, "
                             "epoch=3, source=record) — pushed into SQL "
                             "when the index serves")
    p_logs.add_argument("--limit", type=int, default=None,
                        help="at most N rows (in global row order)")
    p_logs.add_argument("--tail", type=int, default=None,
                        help="only the LAST N rows after filtering")
    p_logs.add_argument("--lineage", default=None, metavar="RUN",
                        help="restrict to RUN's ancestor chain (inclusive)")
    p_logs.add_argument("--engine", default="auto",
                        choices=("auto", "files", "index"),
                        help="serving path (default auto: index when fresh, "
                             "file scan otherwise)")
    p_piv = sub.add_parser("pivot", parents=[common],
                           help="one row per (run, epoch), keys as columns")
    p_piv.add_argument("keys", nargs="*",
                       help="log keys to pivot (default: all observed)")
    p_piv.add_argument("--run", default=None, help="restrict to one run id")
    p_piv.add_argument("--no-replay", action="store_true",
                       help="record logs only (skip hindsight replay logs)")
    p_piv.add_argument("--inline-spill-bytes", type=int, default=0,
                       help="resolve spilled values at/below this size "
                            "back to the actual value (0 = keep pointers)")
    p_piv.add_argument("--lineage", default=None, metavar="RUN",
                       help="restrict to RUN's ancestor chain (inclusive)")
    p_piv.add_argument("--engine", default="auto",
                       choices=("auto", "files", "index"),
                       help="serving path (default auto: index when fresh, "
                            "file scan otherwise)")
    sub.add_parser("reindex", parents=[common],
                   help="catch the sqlite query index up with the log "
                        "segments on disk")
    args = ap.parse_args(argv)

    root = resolve_store_root(args.store_root)
    store = CheckpointStore(root)
    registry = RunRegistry(root)
    return {"list": cmd_list, "show": cmd_show, "gc": cmd_gc, "rm": cmd_rm,
            "diff": cmd_diff, "logs": cmd_logs, "pivot": cmd_pivot,
            "reindex": cmd_reindex}[args.cmd](store, registry, args)


if __name__ == "__main__":
    sys.exit(main())
