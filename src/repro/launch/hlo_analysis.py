"""Trip-count-aware cost analysis over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE, which
under-reports scanned-layer models by ~num_layers x. This module re-derives
the roofline inputs from the optimized HLO dump:

  * flops       — 2*prod(result)*prod(contracting) per dot, x trip counts
  * bytes       — HloCostAnalysis-style: operands + result per instruction,
                  fusion internals fused away, x trip counts
  * collectives — per-kind algorithmic bytes (all-reduce 2x result,
                  all-gather 1x result, reduce-scatter 1x operand,
                  all-to-all / collective-permute 1x result), x trip counts

Trip counts come from the ``known_trip_count`` backend_config XLA prints on
while ops. The module is backend-agnostic text parsing; the CPU-compiled
SPMD module it consumes is one partition, so every number is PER DEVICE.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e3m4": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
    "s4": 1, "u4": 1, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z]\w*)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(?P<type>.*?)\s"
    r"(?P<op>[a-z][a-z0-9\-]*)\((?P<rest>.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?(%[\w\.\-]+)\s*\(.*\)\s*->.*\{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED_RE = re.compile(
    r"(?:condition|body|to_apply|calls)=(%[\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_ZERO_COST = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id", "iota",
    "copy-start", "copy-done",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_elems_bytes(type_str: str):
    """(elements, bytes) of a (possibly tuple) HLO type string."""
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    # scalars like "f32[]" -> the regex gives dims "" -> n=1 (handled above)
    return elems, nbytes


def _dims_of(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


def _shape_key(type_str: str):
    """Dims tuple ignoring dtype (converts wrap in-place DUS chains)."""
    d = _dims_of(type_str)
    return tuple(d) if d is not None else None


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str                       # everything after the opening paren
    operands: list = field(default_factory=list)


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    symbols: dict = field(default_factory=dict)    # %name -> type_str


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        m = _COMP_RE.match(line)
        if m:
            cur = Computation(m.group(2))
            comps[cur.name] = cur
            if m.group(1):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        ins = Instr(im.group(1), im.group("type"), im.group("op"),
                    im.group("rest"))
        # operand names: %x inside the first (...) — fine to over-collect
        depth, i, args = 1, 0, im.group("rest")
        end = len(args)
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        ins.operands = re.findall(r"%[\w\.\-]+", args[:end])
        cur.instrs.append(ins)
        cur.symbols[ins.name] = ins.type_str
    comps["__entry__"] = comps[entry]
    return comps


def _operand_bytes(comp: Computation, ins: Instr) -> int:
    total = 0
    for op in ins.operands:
        t = comp.symbols.get(op)
        if t is not None:
            total += _shape_elems_bytes(t)[1]
    return total


def _dot_flops(comp: Computation, ins: Instr) -> float:
    out_dims = _dims_of(ins.type_str) or []
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    lhs = ins.operands[0] if ins.operands else None
    lhs_t = comp.symbols.get(lhs, "")
    lhs_dims = _dims_of(lhs_t) or []
    cm = _CDIMS_RE.search(ins.rest)
    k = 1
    if cm:
        for idx in cm.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                k *= lhs_dims[int(idx)]
    return 2.0 * out_elems * k


def _inplace_info(comp: Computation):
    """For a fused computation: DUS result shape-keys -> update bytes, and
    sliced-read operand shape-keys -> 2x slice bytes. TPU executes fused
    dynamic-(update-)slice / gather IN PLACE, so the enclosing fusion's big
    aliased buffers must not be charged at full size."""
    dus = {}          # result shape key -> update bytes
    sliced = {}       # big operand shape key -> charged bytes
    for ins in comp.instrs:
        if ins.op == "dynamic-update-slice" and len(ins.operands) >= 2:
            upd_t = comp.symbols.get(ins.operands[1])
            if upd_t is not None:
                k = _shape_key(ins.type_str)
                dus[k] = dus.get(k, 0) + 2 * _shape_elems_bytes(upd_t)[1]
        elif ins.op in ("dynamic-slice", "gather") and ins.operands:
            big_t = comp.symbols.get(ins.operands[0])
            if big_t is not None:
                k = _shape_key(big_t)
                charged = 2 * _shape_elems_bytes(ins.type_str)[1]
                # charge the slice (never more than the full operand)
                full = _shape_elems_bytes(big_t)[1]
                sliced[k] = min(sliced.get(k, 0) + charged, full)
    return dus, sliced


def analyze(text: str) -> dict:
    comps = parse_hlo(text)
    memo: dict[str, dict] = {}

    def cost(cname: str) -> dict:
        if cname in memo:
            return memo[cname]
        comp = comps.get(cname)
        out = {"flops": 0.0, "bytes": 0.0, "transcendentals": 0.0,
               "coll": {k: 0.0 for k in _COLLECTIVES},
               "coll_counts": {k: 0.0 for k in _COLLECTIVES},
               "unknown_trip": 0}
        if comp is None:
            memo[cname] = out
            return out
        memo[cname] = out          # break cycles defensively
        for ins in comp.instrs:
            op = ins.op
            if op in _ZERO_COST:
                continue
            base_op = op[:-6] if op.endswith("-start") else op
            if op.endswith("-done") or op.endswith("-update-done"):
                continue
            res_bytes = _shape_elems_bytes(ins.type_str)[1]
            if base_op in _COLLECTIVES:
                opb = _operand_bytes(comp, ins)
                if base_op == "all-reduce":
                    moved = 2 * res_bytes
                    # XLA:CPU promotes bf16 all-reduces to f32 (reduction
                    # computation named *_promoted); TPU reduces natively in
                    # bf16 — charge the TPU-equivalent bytes
                    if "_promoted" in ins.rest:
                        moved //= 2
                elif base_op == "reduce-scatter":
                    moved = opb
                else:
                    moved = res_bytes
                out["coll"][base_op] += moved
                out["coll_counts"][base_op] += 1
                out["bytes"] += res_bytes + opb
                continue
            if op == "while":
                tm = _TRIP_RE.search(ins.rest)
                mult = int(tm.group(1)) if tm else 1
                if not tm:
                    out["unknown_trip"] += 1
                for callee in _CALLED_RE.findall(ins.rest):
                    sub = cost(callee)
                    for k in ("flops", "bytes", "transcendentals"):
                        out[k] += mult * sub[k]
                    for k in _COLLECTIVES:
                        out["coll"][k] += mult * sub["coll"][k]
                        out["coll_counts"][k] += mult * sub["coll_counts"][k]
                    out["unknown_trip"] += sub["unknown_trip"]
                # the while boundary itself moves nothing: the carry lives in
                # HBM; per-iteration traffic is counted inside the body
                continue
            if op in ("dynamic-update-slice",):
                # in-place on TPU: write (and read-modify) the slice only
                upd_t = comp.symbols.get(ins.operands[1]) if len(ins.operands) > 1 else None
                out["bytes"] += 2 * _shape_elems_bytes(upd_t)[1] if upd_t else res_bytes
                continue
            if op in ("dynamic-slice", "gather"):
                out["bytes"] += 2 * res_bytes          # slice read + write
                continue
            if op == "scatter":
                upd_t = comp.symbols.get(ins.operands[-1]) if ins.operands else None
                out["bytes"] += 2 * (_shape_elems_bytes(upd_t)[1]
                                     if upd_t else res_bytes)
                continue
            if op in ("fusion", "call", "conditional", "custom-call",
                      "async-start"):
                # bytes at the call boundary; flops from inside (dots only)
                callees = _CALLED_RE.findall(ins.rest)
                bm = _BRANCHES_RE.search(ins.rest)
                if bm:
                    callees += re.findall(r"%[\w\.\-]+", bm.group(1))
                dus_map, sliced_map = {}, {}
                for callee in callees:
                    sub = cost(callee)
                    out["flops"] += sub["flops"]
                    out["transcendentals"] += sub["transcendentals"]
                    for k in _COLLECTIVES:
                        out["coll"][k] += sub["coll"][k]
                        out["coll_counts"][k] += sub["coll_counts"][k]
                    out["unknown_trip"] += sub["unknown_trip"]
                    if op in ("call", "conditional"):
                        out["bytes"] += sub["bytes"]
                    if op == "fusion" and callee in comps:
                        d, s = _inplace_info(comps[callee])
                        dus_map.update(d)
                        sliced_map.update(s)
                res_key = _shape_key(ins.type_str)
                if res_key in dus_map:
                    # in-place DUS fusion: charge the update, not the buffer
                    out["bytes"] += dus_map[res_key]
                    for opnd in ins.operands:
                        t = comp.symbols.get(opnd)
                        if t is None or _shape_key(t) == res_key:
                            continue            # aliased big buffer: free
                        k = _shape_key(t)
                        out["bytes"] += sliced_map.get(k,
                                                       _shape_elems_bytes(t)[1])
                else:
                    out["bytes"] += res_bytes
                    for opnd in ins.operands:
                        t = comp.symbols.get(opnd)
                        if t is None:
                            continue
                        k = _shape_key(t)
                        out["bytes"] += sliced_map.get(k,
                                                       _shape_elems_bytes(t)[1])
                continue
            if op == "dot":
                out["flops"] += _dot_flops(comp, ins)
            elif op == "convolution":
                # rough: 2 * out_elems * (kernel elems per output) — we have
                # no convs in practice; keep a floor of out elems
                out["flops"] += 2.0 * _shape_elems_bytes(ins.type_str)[0]
            elif op in ("exponential", "log", "tanh", "rsqrt", "sqrt",
                        "power", "divide", "logistic"):
                out["transcendentals"] += _shape_elems_bytes(ins.type_str)[0]
            out["bytes"] += res_bytes + _operand_bytes(comp, ins)
        return out

    entry = cost(comps["__entry__"].name)
    entry["coll"]["total"] = sum(entry["coll"][k] for k in _COLLECTIVES)
    return entry


def analyze_compiled(compiled) -> dict:
    return analyze(compiled.as_text())
