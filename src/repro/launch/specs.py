"""Sharding construction for the dry-run and real launches: map every step
input/output (TrainState, batch, caches) to NamedShardings via the
logical-axis resolver."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import build_model
from repro.models.params import shape_tree
from repro.parallel.sharding import physical_spec
from repro.train.state import TrainState


def _shardings_from_axes(axes_tree_, shapes_tree_, mesh):
    def f(ax, shp):
        return NamedSharding(mesh, physical_spec(ax, shp.shape, mesh))
    return jax.tree_util.tree_map(
        f, axes_tree_, shapes_tree_,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def param_shardings(model, mesh, dtype=None, serve=False):
    shapes = (shape_tree(model.param_spec(), dtype) if dtype
              else model.param_shapes())
    axes = model.param_axes()
    if serve and model.cfg.serve_replicate_fsdp:
        # weights-stationary serving: drop the FSDP ("embed") dim so params
        # replicate over pod/data — no per-token weight all-gathers
        def drop_fsdp(ax):
            return tuple(None if a == "embed" else a for a in ax)
        axes = jax.tree_util.tree_map(
            drop_fsdp, axes,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))
    return _shardings_from_axes(axes, shapes, mesh), shapes


def state_shardings(cfg, mesh, state_shapes: TrainState):
    """TrainState shardings: params/mu/nu share the param specs; step/rng
    are replicated."""
    model = build_model(cfg)
    axes = model.param_axes()
    p_sh = _shardings_from_axes(axes, state_shapes.params, mesh)
    mu_sh = _shardings_from_axes(axes, state_shapes.mu, mesh)
    nu_sh = _shardings_from_axes(axes, state_shapes.nu, mesh)
    rep = NamedSharding(mesh, P())
    return TrainState(params=p_sh, mu=mu_sh, nu=nu_sh, step=rep, rng=rep)


def batch_shardings(model, shape, mesh):
    specs = model.input_specs(shape)
    axes = model.input_axes(shape)
    return {k: NamedSharding(mesh, physical_spec(axes[k], specs[k].shape, mesh))
            for k in specs}, specs


def cache_shardings(model, shape, mesh):
    spec = model.cache_spec(shape.global_batch, shape.seq_len)
    axes = model.cache_axes()
    return _shardings_from_axes(axes, spec, mesh), spec
