import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax (device count is now locked) -----------
import argparse       # noqa: E402
import json           # noqa: E402
import re             # noqa: E402
import sys            # noqa: E402
import time           # noqa: E402

import jax            # noqa: E402
import jax.numpy as jnp                        # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P   # noqa: E402

from repro.configs import SHAPES, cell_applicable, get, list_archs   # noqa: E402
from repro.launch.mesh import (                # noqa: E402
    HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh)
from repro.launch.specs import (               # noqa: E402
    batch_shardings, cache_shardings, param_shardings, state_shardings)
from repro.models import build_model           # noqa: E402
from repro.parallel import use_mesh            # noqa: E402
from repro.serve.step import build_decode_step, build_prefill_step   # noqa: E402
from repro.train.step import build_train_step  # noqa: E402

def _apply_overrides(cfg, overrides: dict):
    """--override key=value config surgery for perf experiments."""
    if not overrides:
        return cfg
    kw = {}
    for k, v in overrides.items():
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            kw[k] = v in ("1", "true", "True")
        elif isinstance(cur, int):
            kw[k] = int(v)
        elif isinstance(cur, float):
            kw[k] = float(v)
        else:
            kw[k] = v
    return cfg.replace(**kw)


def lower_cell(arch: str, shape_name: str, mesh, overrides: dict | None = None):
    """Lower the right step function for one (arch, shape) cell. Returns
    (lowered, aux_info)."""
    cfg = _apply_overrides(get(arch), overrides or {})
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    with mesh, use_mesh(mesh):
        if shape.kind == "train":
            init_state, train_step = build_train_step(cfg)
            st_shapes = jax.eval_shape(init_state, jax.random.PRNGKey(0))
            st_sh = state_shardings(cfg, mesh, st_shapes)
            b_sh, b_specs = batch_shardings(model, shape, mesh)
            rep = NamedSharding(mesh, P())
            lowered = jax.jit(
                train_step,
                in_shardings=(st_sh, b_sh),
                out_shardings=(st_sh, rep),
            ).lower(st_shapes, b_specs)
        elif shape.kind == "prefill":
            p_sh, p_shapes = param_shardings(model, mesh, dtype=cfg.dtype,
                                             serve=shape.global_batch >= 16)
            b_sh, b_specs = batch_shardings(model, shape, mesh)
            c_sh, _ = cache_shardings(model, shape, mesh)
            rep = NamedSharding(mesh, P())
            step = build_prefill_step(cfg, shape.seq_len)
            lowered = jax.jit(
                step, in_shardings=(p_sh, b_sh), out_shardings=(c_sh, rep),
            ).lower(p_shapes, b_specs)
        else:  # decode
            # weights-stationary only where batch amortizes the weight reads
            # (B=1 long-context decode regressed 12x: GSPMD's sharded-weight
            # + tiny-activation-psum plan is already optimal there)
            p_sh, p_shapes = param_shardings(model, mesh, dtype=cfg.dtype,
                                             serve=shape.global_batch >= 16)
            b_sh, b_specs = batch_shardings(model, shape, mesh)
            c_sh, c_specs = cache_shardings(model, shape, mesh)
            rep = NamedSharding(mesh, P())
            step = build_decode_step(cfg)
            lowered = jax.jit(
                step,
                in_shardings=(p_sh, c_sh, b_sh["tokens"], rep),
                out_shardings=(rep, rep, c_sh),
            ).lower(p_shapes, c_specs, b_specs["tokens"], b_specs["pos"])
    return lowered


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save_hlo: str | None = None,
             overrides: dict | None = None) -> dict:
    ok, why = cell_applicable(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    ndev = mesh.size
    t0 = time.time()
    lowered = lower_cell(arch, shape_name, mesh, overrides)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    # always archive the optimized HLO (zstd) so the roofline analysis can be
    # re-derived offline without recompiling
    try:
        from repro.utils.codec import Compressor
        os.makedirs("results/hlo", exist_ok=True)
        tag = f"{arch}_{shape_name}_{'multi' if multi_pod else 'single'}"
        if overrides:
            tag += "__" + "_".join(f"{k}-{v}" for k, v in sorted(overrides.items()))
        with open(f"results/hlo/{tag}.hlo.zst", "wb") as f:
            f.write(Compressor(level=9).compress(hlo.encode()))
    except Exception:
        pass
    from repro.launch.hlo_analysis import analyze
    hl = analyze(hlo)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "ndev": ndev,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        # trip-count-aware per-device numbers (launch/hlo_analysis.py)
        "flops_per_device": hl["flops"],
        "bytes_accessed_per_device": hl["bytes"],
        "collective_bytes_per_device": dict(hl["coll"]),
        "collective_counts": dict(hl["coll_counts"]),
        # raw XLA per-while-iteration numbers kept for reference
        "xla_cost_analysis": {
            "flops": float(cost.get("flops", -1.0)),
            "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
        },
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", -1),
            "output_bytes": getattr(mem, "output_size_in_bytes", -1),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", -1),
        },
    }
    # roofline terms (per-device, seconds)
    result["roofline"] = {
        "compute_s": hl["flops"] / PEAK_FLOPS_BF16,
        "memory_s": hl["bytes"] / HBM_BW,
        "collective_s": hl["coll"]["total"] / ICI_BW,
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="all (arch x shape) cells")
    ap.add_argument("--out", default=None, help="write JSON results here")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--override", action="append", default=[],
                    help="config override key=value (repeatable)")
    args = ap.parse_args()
    overrides = dict(kv.split("=", 1) for kv in args.override)

    cells = []
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                print(f"=== {arch} x {shape} x "
                      f"{'multi(2x16x16)' if mp else 'single(16x16)'} ===",
                      flush=True)
                try:
                    r = run_cell(arch, shape, mp, save_hlo=args.save_hlo,
                                 overrides=overrides)
                except Exception as e:  # noqa: BLE001 — report and continue
                    r = {"arch": arch, "shape": shape,
                         "mesh": "multi" if mp else "single",
                         "status": "error", "error": f"{type(e).__name__}: {e}"}
                print(json.dumps(r, indent=1, default=str), flush=True)
                results.append(r)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=str)
    bad = [r for r in results if r["status"] == "error"]
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
