"""Parallel replay launcher (paper section 5.4 + Fig. 8).

Spawns G coordination-free worker processes, each replaying its contiguous
share of the main loop from restored state, re-executing only probed blocks.

    PYTHONPATH=src python -m repro.launch.replay --run-dir /tmp/run1 \
        --arch florbench-100m --smoke --epochs 4 --steps-per-epoch 8 \
        --nworkers 4 --probe train --init-mode strong

Elasticity: G is chosen HERE, at replay time, independent of record — the
paper's point about scale-out on cheap spot capacity. Workers never
communicate; stragglers only delay their own partition.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time


def worker_main(args):
    import jax

    import repro.configs as C
    import repro.flor as flor
    from repro.data import synthetic_batch
    from repro.train.step import build_train_step

    cfg = C.get_smoke(args.arch) if args.smoke else C.get(args.arch)
    init_state, train_step = build_train_step(cfg)
    ts = jax.jit(train_step)
    probed = frozenset(args.probe.split(",")) if args.probe else frozenset()
    with flor.Session(args.run_dir, mode="replay",
                      replay=flor.ReplaySpec(pid=args.pid,
                                             nworkers=args.nworkers,
                                             init_mode=args.init_mode,
                                             probed=probed)) as sess:
        state = jax.jit(init_state)(jax.random.PRNGKey(args.seed))
        if sess.parent_run:
            # derived run (lineage): record started from the ancestor's
            # final checkpoint, so replay must too — flor.run.json carries
            # the binding; restore goes through the parent run's chunks
            import jax.numpy as jnp
            state = jax.tree_util.tree_map(
                jnp.asarray, sess.warm_start("train", like=state))
        steps = sess.arg("steps_per_epoch", args.steps_per_epoch)
        with sess.checkpointing(state=state) as ckpt:
            for epoch in sess.loop("epochs",
                                   range(sess.arg("epochs", args.epochs))):
                for s in sess.loop("train", range(steps)):
                    b = synthetic_batch(cfg, args.batch, args.seq,
                                        epoch * steps + s, args.seed)
                    ckpt.state, m = ts(ckpt.state, b)
                    if args.probe:
                        flor.log("probe_grad_norm", m["grad_norm"])
                if sess.executed("train"):
                    flor.log("loss", m["loss"])


def _print_store_summary(run_dir: str):
    """How the record run's checkpoints are laid out: full vs delta
    manifests and the longest parent chain a restore has to resolve —
    single-pass memoized via CheckpointStore.stats() (also used by the
    `runs` CLI), lineage-aware: a derived run's chains may resolve through
    its ancestor runs' manifests in a shared store."""
    from repro.checkpoint import CheckpointStore
    from repro.checkpoint.lineage import read_run_meta
    meta = read_run_meta(run_dir)
    root = meta.get("store_root") or os.path.join(run_dir, "store")
    store = CheckpointStore(root, run_id=meta.get("namespace"))
    st = store.stats(keys=store.list_keys())
    print(f"store: {st['full_manifests']} full + {st['delta_manifests']} "
          f"delta manifests, max resolve chain {st['max_chain_depth']}, "
          f"{st['stored_bytes'] / 2**20:.1f} MiB chunks"
          + (f" (shared store {root}, run {meta.get('run_id')})"
             if meta.get("store_root") else ""))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--run-dir", required=True)
    ap.add_argument("--arch", default="florbench-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--steps-per-epoch", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--nworkers", type=int, default=1)
    ap.add_argument("--pid", type=int, default=None,
                    help="run as ONE worker (internal)")
    ap.add_argument("--probe", default="",
                    help="comma-separated probed block ids ('train' or '*')")
    ap.add_argument("--init-mode", choices=("strong", "weak"), default="strong")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="run the deferred correctness check after replay")
    args = ap.parse_args()

    if args.pid is not None:
        worker_main(args)
        return

    t0 = time.time()
    procs = []
    for pid in range(args.nworkers):
        cmd = [sys.executable, "-m", "repro.launch.replay",
               "--run-dir", args.run_dir, "--arch", args.arch,
               "--epochs", str(args.epochs),
               "--steps-per-epoch", str(args.steps_per_epoch),
               "--batch", str(args.batch), "--seq", str(args.seq),
               "--nworkers", str(args.nworkers), "--pid", str(pid),
               "--probe", args.probe, "--init-mode", args.init_mode,
               "--seed", str(args.seed)]
        if args.smoke:
            cmd.append("--smoke")
        procs.append(subprocess.Popen(cmd, env=os.environ.copy()))
    rcodes = [p.wait() for p in procs]
    wall = time.time() - t0
    print(f"parallel replay: {args.nworkers} workers, wall {wall:.2f}s, "
          f"rc={rcodes}")
    _print_store_summary(args.run_dir)
    if any(rcodes):
        sys.exit(1)

    if args.check:
        import repro.flor as flor
        rec, reps = flor.run_logs(args.run_dir)
        res = flor.deferred_check(rec, reps)
        print(f"deferred check: ok={res.ok} compared={res.compared} "
              f"hindsight={res.hindsight_only} anomalies={len(res.anomalies)}")
        if not res.ok:
            for a in res.anomalies[:10]:
                print("  anomaly:", a)
            sys.exit(2)


if __name__ == "__main__":
    main()
