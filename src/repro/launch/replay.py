"""Parallel replay launcher — a thin driver over the replay planner and
cost-balanced scheduler (paper section 5.4 + Fig. 8; repro.replay).

    PYTHONPATH=src python -m repro.launch.replay --run-dir /tmp/run1 \
        --arch florbench-100m --smoke --epochs 4 --steps-per-epoch 8 \
        --nworkers 4 --probe train --init-mode strong --check

Flow: PLAN (probe set x checkpoint-manifest metadata -> per-epoch segments
with resume-cost estimates) -> SCHEDULE (LPT cost-balanced shares, dynamic
work-queue over worker processes with failure/straggler re-queue) -> MERGE
(per-segment log merge) -> deferred correctness CHECK.

``--probe auto`` is the paper's section-3.2 source-diff tier: record stored
a copy of the driving script; the current file (or ``--current-src``) is
diffed against it, added lines map to their innermost enclosing loop, and
non-additive edits are surfaced as a HARD WARNING (replay assumes only log
statements were added).

Elasticity is unchanged: G is chosen HERE, at replay time, independent of
record. Workers never communicate; the work queue just stops handing a
straggler's epochs to anyone else. ``--no-plan`` keeps the legacy
contiguous fan-out (deprecated).
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time


# barrier pseudo-key multi-host replay uses for the merge handoff
MERGE_BARRIER = "replay.merge"


def _parse_segments(spec: str) -> list:
    """'0:init,1:exec,...' -> [(0, 'init'), (1, 'exec'), ...]."""
    out = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        e, ph = part.split(":", 1)
        out.append((int(e), ph))
    return out


def _fmt_segments(visits: list) -> str:
    return ",".join(f"{e}:{ph}" for e, ph in visits)


def worker_main(args):
    import jax

    import repro.configs as C
    import repro.flor as flor
    from repro.data import synthetic_batch
    from repro.train.step import build_train_step

    cfg = C.get_smoke(args.arch) if args.smoke else C.get(args.arch)
    init_state, train_step = build_train_step(cfg)
    ts = jax.jit(train_step)
    probed = frozenset(p for p in args.probe.split(",") if p) \
        if args.probe and args.probe != "auto" else frozenset()
    segments = _parse_segments(args.segments) if args.segments else None
    with flor.Session(args.run_dir, mode="replay",
                      replay=flor.ReplaySpec(pid=args.pid,
                                             nworkers=args.nworkers,
                                             init_mode=args.init_mode,
                                             probed=probed,
                                             segments=segments)) as sess:
        state = jax.jit(init_state)(jax.random.PRNGKey(args.seed))
        if sess.parent_run:
            # derived run (lineage): record started from the ancestor's
            # final checkpoint, so replay must too — flor.run.json carries
            # the binding; restore goes through the parent run's chunks
            import jax.numpy as jnp
            state = jax.tree_util.tree_map(
                jnp.asarray, sess.warm_start("train", like=state))
        steps = sess.arg("steps_per_epoch", args.steps_per_epoch)
        with sess.checkpointing(state=state) as ckpt:
            for epoch in sess.loop("epochs",
                                   range(sess.arg("epochs", args.epochs))):
                for s in sess.loop("train", range(steps)):
                    b = synthetic_batch(cfg, args.batch, args.seq,
                                        epoch * steps + s, args.seed)
                    ckpt.state, m = ts(ckpt.state, b)
                    if args.probe:
                        flor.log("probe_grad_norm", m["grad_norm"])
                if sess.executed("train"):
                    flor.log("loss", m["loss"])


def _print_store_summary(run_dir: str):
    """How the record run's checkpoints are laid out: full vs delta
    manifests and the longest parent chain a restore has to resolve —
    single-pass memoized via CheckpointStore.stats() (also used by the
    `runs` CLI), lineage-aware: a derived run's chains may resolve through
    its ancestor runs' manifests in a shared store."""
    from repro.replay import open_run_store
    store, meta = open_run_store(run_dir)
    st = store.stats(keys=store.list_keys())
    print(f"store: {st['full_manifests']} full + {st['delta_manifests']} "
          f"delta manifests, max resolve chain {st['max_chain_depth']}, "
          f"{st['stored_bytes'] / 2**20:.1f} MiB chunks"
          + (f" (shared store {store.root}, run {meta.get('run_id')})"
             if meta.get("store_root") else ""))


def _worker_cmd(args, pid: int, segments: str = "") -> list[str]:
    cmd = [sys.executable, "-m", "repro.launch.replay",
           "--run-dir", args.run_dir, "--arch", args.arch,
           "--epochs", str(args.epochs),
           "--steps-per-epoch", str(args.steps_per_epoch),
           "--batch", str(args.batch), "--seq", str(args.seq),
           "--nworkers", str(args.nworkers), "--pid", str(pid),
           "--probe", "" if args.probe == "auto" else args.probe,
           "--init-mode", args.init_mode, "--seed", str(args.seed)]
    if segments:
        cmd += ["--segments", segments]
    if args.smoke:
        cmd.append("--smoke")
    return cmd


def _legacy_fanout(args) -> None:
    """The pre-planner contiguous fan-out, kept as a deprecation shim
    (``--no-plan``)."""
    t0 = time.time()
    procs = [subprocess.Popen(_worker_cmd(args, pid), env=os.environ.copy())
             for pid in range(args.nworkers)]
    rcodes = [p.wait() for p in procs]
    print(f"parallel replay (legacy contiguous): {args.nworkers} workers, "
          f"wall {time.time() - t0:.2f}s, rc={rcodes}")
    _print_store_summary(args.run_dir)
    if any(rcodes):
        sys.exit(1)
    if args.check:
        import repro.flor as flor
        rec, reps = flor.run_logs(args.run_dir)
        _report_check(flor.deferred_check(rec, reps))


def _report_check(res) -> None:
    print(f"deferred check: ok={res.ok} compared={res.compared} "
          f"hindsight={res.hindsight_only} anomalies={len(res.anomalies)}")
    if not res.ok:
        for a in res.anomalies[:10]:
            print("  anomaly:", a)
        sys.exit(2)


def _report_auto_probes(args):
    """Run --probe auto detection once for user-facing output, HARD-WARNING
    on suspicious non-additive source edits (the plan re-derives the same
    probe set internally)."""
    from repro.replay import detect_probes_for_run
    report = detect_probes_for_run(args.run_dir,
                                   current_src=args.current_src or None)
    if report.suspicious:
        print("=" * 70, file=sys.stderr)
        print(f"WARNING: {len(report.suspicious)} NON-ADDITIVE source "
              f"edit(s) between record and replay — hindsight replay "
              f"assumes only log statements were ADDED; changed or deleted "
              f"lines can invalidate the recorded checkpoints:",
              file=sys.stderr)
        for s in report.suspicious[:5]:
            print(f"  [{s['tag']}] {s['old']!r} -> {s['new']!r}",
                  file=sys.stderr)
        print("=" * 70, file=sys.stderr)
    print(f"probe auto: {len(report.added_lines)} added line(s) -> "
          f"inner blocks {sorted(report.probed_blocks) or '-'} "
          f"outer loops {sorted(report.probed_outer) or '-'}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--run-dir", required=True)
    ap.add_argument("--arch", default="florbench-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--steps-per-epoch", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--nworkers", type=int, default=1)
    ap.add_argument("--pid", type=int, default=None,
                    help="run as ONE worker (internal)")
    ap.add_argument("--segments", default=None,
                    help="planned visit list '0:init,1:exec,...' (internal)")
    ap.add_argument("--probe", default="",
                    help="comma-separated probed block ids ('train', '*'), "
                         "or 'auto' for source-diff detection")
    ap.add_argument("--current-src", default="",
                    help="with --probe auto: the edited script to diff "
                         "against the recorded copy (default: the recorded "
                         "path on disk)")
    ap.add_argument("--init-mode", choices=("strong", "weak"),
                    default="strong")
    ap.add_argument("--partition", choices=("balanced", "contiguous"),
                    default="balanced",
                    help="work partitioning: LPT over segment cost "
                         "estimates (default) or the legacy contiguous "
                         "split")
    ap.add_argument("--tasks-per-worker", type=int, default=1,
                    help="split work finer than one share per worker so "
                         "the dynamic queue can rebalance")
    ap.add_argument("--hosts", type=int, default=1,
                    help="model N replay hosts: tasks are LPT-placed onto "
                         "host queues and workers steal only when their "
                         "home queue drains (sharded-store affinity)")
    ap.add_argument("--coordinator", default=None,
                    help="accepted for launcher symmetry with train; "
                         "replay hosts coordinate through the store "
                         "filesystem, not a jax.distributed service")
    ap.add_argument("--process-id", type=int, default=0,
                    help="this host's id in a TRUE multi-process replay "
                         "fleet (every host runs this launcher)")
    ap.add_argument("--num-processes", type=int, default=1,
                    help="replay fleet size; > 1 partitions the planned "
                         "tasks across real hosts — each host executes "
                         "only its share against its own shard pools, "
                         "host 0 merges after a store-file barrier")
    ap.add_argument("--merge-timeout", type=float, default=600.0,
                    help="seconds host 0 waits for every host's share "
                         "before failing the merge")
    ap.add_argument("--prefer-shards", default=None,
                    help="comma-separated store shard ids this host mounts "
                         "with read affinity (default under "
                         "--num-processes: a contiguous block of the "
                         "recorded shards)")
    ap.add_argument("--straggler-factor", type=float, default=None,
                    help="speculatively re-issue a task running this many "
                         "times longer than expected (0 = off; default: "
                         "measured — on at 3x when every task has a real "
                         "cost estimate from the record profile, else off)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan-only", action="store_true",
                    help="print the plan and assignments, run nothing")
    ap.add_argument("--no-plan", action="store_true",
                    help="legacy contiguous fan-out (deprecated)")
    ap.add_argument("--check", action="store_true",
                    help="run the deferred correctness check after replay")
    args = ap.parse_args()

    if args.pid is not None:
        worker_main(args)
        return
    if args.no_plan:
        if args.probe == "auto":
            # the legacy fan-out has no planner to consume the detection:
            # silently degrading to "no probes" would report a vacuously
            # passing check
            ap.error("--probe auto requires the planner; drop --no-plan")
        _legacy_fanout(args)
        return

    from repro.core.query import merge_replay_logs
    from repro.replay import (DynamicExecutor, Task, TaskFailure,
                              assign_hosts, balanced_shares, build_plan,
                              contiguous_shares, measured_straggler_factor,
                              share_cost)

    # ---- plan ----
    if args.probe == "auto":
        _report_auto_probes(args)
        plan = build_plan(args.run_dir, probed="auto",
                          init_mode=args.init_mode,
                          current_src=args.current_src or None)
    else:
        plan = build_plan(args.run_dir,
                          probed={p for p in args.probe.split(",") if p},
                          init_mode=args.init_mode)
    print(plan.summary())

    # ---- schedule ----
    work = plan.work_segments()
    nshares = max(1, args.nworkers * max(1, args.tasks_per_worker))
    split = balanced_shares if args.partition == "balanced" \
        else contiguous_shares
    shares = [sh for sh in split(work, nshares) if sh]
    tasks = []
    for tid, sh in enumerate(shares):
        tasks.append(Task(task_id=tid, visits=plan.visits_for(sh),
                          epochs=[s.epoch for s in sh],
                          est_cost_s=share_cost(plan, sh)))
    # ---- true multi-host replay (--num-processes > 1): every host runs
    # this launcher against the shared store; the plan and the LPT host
    # assignment are deterministic, so each host independently derives the
    # SAME partition and executes only its share. Host 0 merges once every
    # host has arrived at the store-file barrier.
    fleet = max(1, args.num_processes)
    n_hosts = fleet if fleet > 1 else max(1, args.hosts)
    if n_hosts > 1:
        assign_hosts(tasks, n_hosts)
    for t in tasks:
        print(f"  task {t.task_id}: epochs {t.epochs} "
              f"({len(t.visits)} visits, est {t.est_cost_s:.2f}s"
              + (f", host {t.host}" if n_hosts > 1 else "") + ")")
    assignments = {str(t.task_id): {"epochs": t.epochs, "visits": t.visits,
                                    "est_cost_s": t.est_cost_s,
                                    "host": t.host}
                   for t in tasks}
    rdv = None
    my_tasks = tasks
    if fleet > 1:
        from repro.parallel.rendezvous import ProcessGroup, StitchRendezvous
        from repro.replay import open_run_store
        store, run_meta = open_run_store(args.run_dir)
        rdv = StitchRendezvous(store.root,
                               run_meta.get("run_id") or "replay",
                               ProcessGroup(args.process_id, fleet),
                               timeout_s=args.merge_timeout)
        # a stale marker from a crashed previous invocation must never
        # satisfy this round's barrier on our behalf
        rdv.retract(MERGE_BARRIER)
        my_tasks = [t for t in tasks if t.host == args.process_id]
        print(f"host {args.process_id}/{fleet}: executing "
              f"{len(my_tasks)}/{len(tasks)} task(s)")
        # shard-pool read affinity: mount this host's share of the recorded
        # store shards first (content addressing keeps every pool valid)
        if args.prefer_shards is not None:
            os.environ["FLOR_PREFER_SHARDS"] = args.prefer_shards
        else:
            n_store = int((plan.mesh or {}).get("n_store_shards") or 0)
            mine = [str(h) for h in range(n_store)
                    if h * fleet // n_store == args.process_id]
            if mine:
                os.environ["FLOR_PREFER_SHARDS"] = ",".join(mine)
    elif args.prefer_shards:
        os.environ["FLOR_PREFER_SHARDS"] = args.prefer_shards
    if rdv is None or rdv.group.is_lead:
        plan.save(assignments=assignments)
    if args.plan_only:
        return

    # ---- execute: dynamic work-queue over worker processes ----
    inner_probes = ",".join(sorted(plan.probed))
    # per-(task, attempt) log identity: stride by the task count so retry
    # pids can never collide with first-attempt pids of other tasks
    pid_stride = len(tasks)

    def run_task(task, attempt, cancelled):
        pid = task.task_id + (attempt - 1) * pid_stride
        wargs = argparse.Namespace(**vars(args))
        wargs.probe = inner_probes
        cmd = _worker_cmd(wargs, pid, _fmt_segments(task.visits))
        proc = subprocess.Popen(cmd, env=os.environ.copy())
        while proc.poll() is None:
            if cancelled.is_set():
                proc.terminate()
                proc.wait()
                return None
            time.sleep(0.05)
        if proc.returncode != 0:
            raise RuntimeError(f"worker task {task.task_id} attempt "
                               f"{attempt} exited rc={proc.returncode}")
        return pid

    merged_epochs: set = set()

    def on_complete(task, attempt, pid):
        merged_epochs.update(task.epochs)
        print(f"  task {task.task_id} done (attempt {attempt}): "
              f"{len(merged_epochs)}/{len(work)} work epochs merged",
              flush=True)

    # measured default: with real cost estimates on every task (record-side
    # block profile + learned restore model), speculation turns ON at the
    # scheduler's default horizon; an explicit --straggler-factor (incl. 0)
    # always wins
    straggler = args.straggler_factor if args.straggler_factor is not None \
        else measured_straggler_factor(tasks)
    if args.straggler_factor is None and straggler > 0:
        print(f"  straggler speculation: on (measured estimates, "
              f"{straggler:g}x horizon)")

    t0 = time.time()
    ex = DynamicExecutor(my_tasks, run_task, args.nworkers,
                         straggler_factor=straggler,
                         on_complete=on_complete,
                         n_hosts=1 if fleet > 1 else n_hosts)
    try:
        done = ex.run()
    except TaskFailure as e:
        print(f"parallel replay FAILED: {e}")
        sys.exit(1)
    wall = time.time() - t0
    print(f"parallel replay (planned, {args.partition}): "
          f"{args.nworkers} workers / {len(my_tasks)} tasks, "
          f"wall {wall:.2f}s")
    _print_store_summary(args.run_dir)

    # ---- merge per plan segment ----
    # owner log = the pid run_task RETURNED for the winning attempt
    owners = [(f"replay_p{done[task.task_id][1]}", task.epochs)
              for task in my_tasks if task.task_id in done]
    # drop superseded attempt logs (failed first tries, cancelled straggler
    # duplicates): the query surface globs every replay_*.jsonl, and a
    # partial log from a dead attempt would pollute runs logs/pivot and any
    # later raw-file deferred check. remove_stream handles both layouts
    # (flat file, or the background writer's segment dir at the same path).
    # Task ids are fleet-global, so each host only touches its own logs.
    from repro.logging import remove_stream
    keep = {f"replay_p{done[t.task_id][1]}.jsonl"
            for t in my_tasks if t.task_id in done}
    for t in my_tasks:
        for attempt in range(1, ex.max_attempts + 1):
            fn = f"replay_p{t.task_id + (attempt - 1) * pid_stride}.jsonl"
            if fn not in keep:
                remove_stream(os.path.join(args.run_dir, "logs", fn))

    if rdv is not None:
        # hand this host's owner map to host 0 through the store barrier;
        # only the lead merges (and only after EVERY host arrived, so the
        # merge never reads a log a straggler is still writing)
        rdv.arrive(MERGE_BARRIER,
                   {"process": rdv.group.process_id,
                    "owners": [[src, list(eps)] for src, eps in owners]})
        if not rdv.group.is_lead:
            print(f"host {rdv.group.process_id}: share complete "
                  f"({len(owners)} task log(s)); host 0 merges")
            rdv.close()
            return
        got = rdv.await_all(MERGE_BARRIER, timeout_s=args.merge_timeout)
        rdv.close()
        if got is None:
            print(f"replay merge FAILED: a host missed the merge barrier "
                  f"within {args.merge_timeout:.0f}s")
            sys.exit(1)
        rdv.clear(MERGE_BARRIER)
        owners = [(src, eps) for marker in got
                  for src, eps in (marker.get("owners") or [])]
    merged = merge_replay_logs(args.run_dir, owners, out_path=True)
    print(f"merged {len(merged)} log rows from {len(owners)} task log(s) "
          f"-> logs/merged_replay.jsonl")

    if args.check:
        import repro.flor as flor
        rec, _ = flor.run_logs(args.run_dir)
        _report_check(flor.deferred_check(rec, merged))


if __name__ == "__main__":
    main()
