"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before any jax import
and only then builds the mesh.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e-class hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
