"""Roofline report: dry-run JSON -> per-cell three-term table + markdown.

    PYTHONPATH=src python -m repro.launch.roofline \
        --in results/dryrun_single.json --md results/roofline.md

Terms (seconds, PER DEVICE, from launch/hlo_analysis.py):
    compute    = HLO_dot_FLOPs / 197e12        (bf16 peak, v5e-class)
    memory     = HLO_bytes     / 819e9         (HBM BW)
    collective = coll_bytes    / 50e9          (ICI per-link)

MODEL_FLOPS is the analytic useful compute: 6*N_active*tokens for train
(fwd+bwd), 2*N_active*tokens for prefill/decode. The ratio
MODEL_FLOPS / (HLO_FLOPs * ndev) exposes remat/dispatch/attention overheads.
"""
from __future__ import annotations

import argparse
import json

import repro.configs as C
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

HINTS = {
    "compute": ("compute-bound: reduce recompute (remat policy), use the "
                "paper-faithful fp32->bf16 matmuls, or grow the mesh"),
    "memory": ("HBM-bound: cut activation residency (remat policy / dtype of "
               "saved residuals), fuse attention (flash kernel), or raise "
               "arithmetic intensity with larger per-chip batch"),
    "collective": ("ICI-bound: reshard to cut all-gathers (FSDP axis size), "
                   "overlap collectives with compute (latency hiding), or "
                   "compress cross-pod traffic (int8 + error feedback)"),
}


def model_flops(arch: str, shape_name: str) -> float:
    cfg = C.get(arch)
    shape = C.SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if cfg.family == "audio" and shape.kind != "decode":
        tokens = shape.global_batch * shape.seq_len          # enc+dec halves
    elif shape.kind == "decode":
        tokens = shape.global_batch * 1
    else:
        tokens = shape.global_batch * shape.seq_len
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


def build_rows(results: list[dict]) -> list[dict]:
    rows = []
    for r in results:
        row = {"arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
               "status": r["status"]}
        if r["status"] != "ok":
            row["note"] = r.get("reason", r.get("error", ""))[:90]
            rows.append(row)
            continue
        rl = r["roofline"]
        terms = {"compute": rl["compute_s"], "memory": rl["memory_s"],
                 "collective": rl["collective_s"]}
        dom = max(terms, key=terms.get)
        mf = model_flops(r["arch"], r["shape"])
        hlo_global = r["flops_per_device"] * r["ndev"]
        row.update({
            "compute_s": terms["compute"],
            "memory_s": terms["memory"],
            "collective_s": terms["collective"],
            "dominant": dom,
            "model_flops": mf,
            "hlo_flops_global": hlo_global,
            "useful_ratio": mf / hlo_global if hlo_global else 0.0,
            # roofline fraction: useful compute time / achievable step time
            # (= max of the three terms, the bound a perfect overlap hits)
            "roofline_frac": (mf / r["ndev"] / PEAK_FLOPS_BF16)
            / max(terms.values()) if max(terms.values()) > 0 else 0.0,
            "hint": HINTS[dom],
        })
        rows.append(row)
    return rows


def to_markdown(rows: list[dict]) -> str:
    out = ["| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| useful FLOP ratio | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"{r['status']}: {r.get('note','')} | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4g} | "
            f"{r['memory_s']:.4g} | {r['collective_s']:.4g} | "
            f"{r['dominant']} | {r['useful_ratio']:.3f} | "
            f"{r['roofline_frac']:.3f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="results/dryrun_single.json")
    ap.add_argument("--out", default=None)
    ap.add_argument("--md", default=None)
    args = ap.parse_args()
    with open(args.inp) as f:
        results = json.load(f)
    rows = build_rows(results)
    print(to_markdown(rows))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
    if args.md:
        with open(args.md, "w") as f:
            f.write(to_markdown(rows) + "\n")


if __name__ == "__main__":
    main()
