"""Training driver with Flor record integrated as a first-class feature.

    PYTHONPATH=src python -m repro.launch.train --arch florbench-100m \
        --smoke --epochs 4 --steps-per-epoch 8 --run-dir /tmp/run1

Fault tolerance IS the paper's substrate: on start, if the run dir already
holds checkpoints, training resumes from the latest epoch checkpoint
(weak-init replay of the remainder). Kill the process mid-run and relaunch
with the same command to see it.

Run lineage (continuous training): record several runs into one shared
store and chain them —

    ... train --run-dir /tmp/base --store-root /tmp/store --run-id base
    ... train --run-dir /tmp/ft1  --store-root /tmp/store --run-id ft1 \
        --parent-run base          # warm-starts; 1st ckpt is a cross-run delta

Inspect/reclaim with `python -m repro.launch.runs list|show|gc|rm`.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="florbench-100m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-runnable)")
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--steps-per-epoch", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--run-dir", required=True)
    ap.add_argument("--epsilon", type=float, default=1.0 / 15)
    ap.add_argument("--no-adaptive", action="store_true")
    ap.add_argument("--no-flor", action="store_true",
                    help="vanilla baseline (no record) for overhead benchs")
    ap.add_argument("--sync-log", action="store_true",
                    help="legacy synchronous flor.log (serialize + write on "
                         "the step path) instead of the background log "
                         "stage; for overhead comparisons")
    ap.add_argument("--log-spill-bytes", type=int, default=1 << 20,
                    help="spill logged arrays larger than this many host "
                         "bytes to the checkpoint store, logging a ref row "
                         "(0 disables)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default=None,
                    help="e.g. 1x1; data x model over local devices "
                         "(GLOBAL devices under --num-processes > 1)")
    ap.add_argument("--coordinator", default="127.0.0.1:12355",
                    help="jax.distributed coordinator address "
                         "(host:port) for true multi-process record")
    ap.add_argument("--process-id", type=int, default=0,
                    help="this process's id in the record fleet")
    ap.add_argument("--num-processes", type=int, default=1,
                    help="record fleet size; > 1 turns on distributed "
                         "record: each process checkpoints only its local "
                         "shards, process 0 stitches the v4 manifests")
    ap.add_argument("--stitch-timeout", type=float, default=30.0,
                    help="seconds the stitch rendezvous waits for every "
                         "host before marking a checkpoint incomplete")
    ap.add_argument("--ckpt-shard-axes", default="",
                    help="comma-separated mesh axes mapping onto store "
                         "shards (default: all axes — one shard/device)")
    ap.add_argument("--store-root", default=None,
                    help="SHARED checkpoint store root (multi-run lineage); "
                         "default: private <run-dir>/store")
    ap.add_argument("--run-id", default=None,
                    help="explicit run id in the shared store")
    ap.add_argument("--parent-run", default=None,
                    help="ancestor run id: warm-start from its final "
                         "checkpoint and record cross-run deltas")
    args = ap.parse_args()

    import repro.configs as C
    import repro.flor as flor
    from repro.data import PrefetchLoader, synthetic_batch
    from repro.parallel import use_mesh
    from repro.train.step import build_train_step

    # true multi-process record: join the fleet BEFORE any jax call touches
    # the backend, so jax.devices() spans every host
    group = None
    if args.num_processes > 1:
        from repro.parallel.rendezvous import init_distributed
        group = init_distributed(args.coordinator, args.process_id,
                                 args.num_processes)
        print(f"distributed record: process {group.process_id}/"
              f"{group.num_processes}, {jax.local_device_count()} local / "
              f"{jax.device_count()} global devices", flush=True)

    cfg = C.get_smoke(args.arch) if args.smoke else C.get(args.arch)
    init_state, train_step = build_train_step(cfg)
    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))
    if group is not None and mesh is None:
        ap.error("--num-processes > 1 requires --mesh (the global device "
                 "mesh spanning every process)")
    shard_axes = tuple(a for a in args.ckpt_shard_axes.split(",") if a)

    with use_mesh(mesh):
        ts = jax.jit(train_step)
        state = jax.jit(init_state)(jax.random.PRNGKey(args.seed))

        if args.no_flor:
            t0 = time.time()
            for epoch in range(args.epochs):
                for s in range(args.steps_per_epoch):
                    b = synthetic_batch(cfg, args.batch, args.seq,
                                        epoch * args.steps_per_epoch + s,
                                        args.seed)
                    state, m = ts(state, b)
                jax.block_until_ready(m["loss"])
                print(f"epoch {epoch} loss {float(m['loss']):.4f}", flush=True)
            print(f"vanilla wall {time.time() - t0:.2f}s")
            return

        with flor.Session(
                args.run_dir, mode="record",
                record=flor.RecordSpec(epsilon=args.epsilon,
                                       adaptive=not args.no_adaptive,
                                       async_log=not args.sync_log,
                                       log_spill_bytes=args.log_spill_bytes,
                                       # distributed: sharded checkpoints
                                       # over the global mesh, per-process
                                       # local shards, lead-stitched v4s
                                       mesh=mesh if group is not None
                                       else None,
                                       ckpt_shard_axes=shard_axes
                                       if group is not None else (),
                                       distributed=group or False,
                                       stitch_timeout_s=args.stitch_timeout),
                lineage=flor.LineageSpec(store_root=args.store_root,
                                         run_id=args.run_id,
                                         parent_run=args.parent_run)) as sess:
            ctx = sess.ctx
            if ctx.parent_run and not ctx.store.list_keys():
                # derived run (fine-tune of a fine-tune): start from the
                # ancestor's final state; the first checkpoint is already a
                # cross-run delta against it
                print(f"warm start from run {ctx.parent_run!r}", flush=True)
                state = sess.warm_start("train", like=state)
                state = jax.tree_util.tree_map(jnp.asarray, state)
            # crash-restart: resume from the latest epoch checkpoint if any.
            # Shard MEMBER manifests (<key>.shard<h>) and checkpoints a
            # distributed record marked incomplete never anchor a resume —
            # only stitched (or flat) epoch keys count as done.
            from repro.checkpoint.store import _safe
            inc = {_safe(k) for k in
                   (ctx.store.get_meta("incomplete_ckpts") or {})
                   .get("keys") or ()}
            done = set()
            for k in ctx.store.list_keys():
                if "_at_" in k and ".shard" not in k and k not in inc:
                    try:
                        done.add(int(k.split("_at_")[1].split(".")[0]))
                    except ValueError:
                        pass
            resume_from = max(done) + 1 if done else 0
            if resume_from:
                # physical restore of the latest Loop End Checkpoint, then
                # skip the completed epochs — restart == weak-init replay
                print(f"resuming: restoring epoch {max(done)} checkpoint",
                      flush=True)
                state = ctx.store.get_tree(f"train@{max(done)}.0", like=state)

            t0 = time.time()
            steps = sess.arg("steps_per_epoch", args.steps_per_epoch)
            with sess.checkpointing(state=state) as ckpt:
                for epoch in sess.loop("epochs",
                                       range(sess.arg("epochs", args.epochs))):
                    if epoch < resume_from:
                        continue
                    for s in sess.loop("train", range(steps)):
                        b = synthetic_batch(cfg, args.batch, args.seq,
                                            epoch * steps + s, args.seed)
                        ckpt.state, m = ts(ckpt.state, b)
                    flor.log("loss", m["loss"])
                    print(f"epoch {epoch} done", flush=True)
            state = ckpt.state
        print(f"record wall {time.time() - t0:.2f}s")


if __name__ == "__main__":
    main()
