"""Re-derive roofline numbers from archived HLO (results/hlo/*.hlo.zst)
without recompiling. Used whenever hlo_analysis.py improves.

    PYTHONPATH=src python -m repro.launch.reanalyze \
        --json results/dryrun_single.json
"""
from __future__ import annotations

import argparse
import json
import os

from repro.launch.hlo_analysis import analyze
from repro.utils.codec import Compressor
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def reanalyze_json(path: str, hlo_dir: str = "results/hlo"):
    with open(path) as f:
        results = json.load(f)
    dctx = Compressor()
    for r in results:
        if r.get("status") != "ok":
            continue
        tag = f"{r['arch']}_{r['shape']}_{r['mesh']}"
        hp = os.path.join(hlo_dir, tag + ".hlo.zst")
        if not os.path.exists(hp):
            continue
        with open(hp, "rb") as f:
            hlo = dctx.decompress(f.read()).decode()
        hl = analyze(hlo)
        r["flops_per_device"] = hl["flops"]
        r["bytes_accessed_per_device"] = hl["bytes"]
        r["collective_bytes_per_device"] = dict(hl["coll"])
        r["collective_counts"] = dict(hl["coll_counts"])
        r["roofline"] = {
            "compute_s": hl["flops"] / PEAK_FLOPS_BF16,
            "memory_s": hl["bytes"] / HBM_BW,
            "collective_s": hl["coll"]["total"] / ICI_BW,
        }
    with open(path, "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"reanalyzed {path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="append", required=True)
    ap.add_argument("--hlo-dir", default="results/hlo")
    args = ap.parse_args()
    for p in args.json:
        reanalyze_json(p, args.hlo_dir)


if __name__ == "__main__":
    main()
