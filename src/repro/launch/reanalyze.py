"""Re-derive analysis artifacts without re-running anything: roofline
numbers from archived HLO (results/hlo/*.hlo.zst) whenever hlo_analysis.py
improves, and checkpoint-store summaries for recorded runs — lineage-aware,
so a derived run's chains resolving through ancestor-run manifests in a
shared store are reported correctly.

    PYTHONPATH=src python -m repro.launch.reanalyze \
        --json results/dryrun_single.json
    PYTHONPATH=src python -m repro.launch.reanalyze \
        --store-summary /tmp/runB --store-summary /tmp/runA
    PYTHONPATH=src python -m repro.launch.reanalyze --logs-summary STORE
"""
from __future__ import annotations

import argparse
import json
import os

from repro.launch.hlo_analysis import analyze
from repro.utils.codec import Compressor
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def reanalyze_json(path: str, hlo_dir: str = "results/hlo"):
    with open(path) as f:
        results = json.load(f)
    dctx = Compressor()
    for r in results:
        if r.get("status") != "ok":
            continue
        tag = f"{r['arch']}_{r['shape']}_{r['mesh']}"
        hp = os.path.join(hlo_dir, tag + ".hlo.zst")
        if not os.path.exists(hp):
            continue
        with open(hp, "rb") as f:
            hlo = dctx.decompress(f.read()).decode()
        hl = analyze(hlo)
        r["flops_per_device"] = hl["flops"]
        r["bytes_accessed_per_device"] = hl["bytes"]
        r["collective_bytes_per_device"] = dict(hl["coll"])
        r["collective_counts"] = dict(hl["coll_counts"])
        r["roofline"] = {
            "compute_s": hl["flops"] / PEAK_FLOPS_BF16,
            "memory_s": hl["bytes"] / HBM_BW,
            "collective_s": hl["coll"]["total"] / ICI_BW,
        }
    with open(path, "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"reanalyzed {path}")


def reanalyze_store(run_dir: str):
    """Post-hoc store summary for one run dir (same single-pass
    CheckpointStore.stats() the replay launcher and `runs` CLI use)."""
    from repro.checkpoint import CheckpointStore
    from repro.checkpoint.lineage import read_run_meta
    meta = read_run_meta(run_dir)
    root = meta.get("store_root") or os.path.join(run_dir, "store")
    store = CheckpointStore(root, run_id=meta.get("namespace"))
    st = store.stats(keys=store.list_keys())
    lineage = f", run {meta['run_id']} in shared store {root}" \
        if meta.get("store_root") else ""
    print(f"{run_dir}: {st['manifests']} manifests "
          f"({st['full_manifests']} full + {st['delta_manifests']} delta), "
          f"max resolve chain {st['max_chain_depth']}, "
          f"{st['stored_bytes'] / 2**20:.1f} MiB chunks{lineage}")


def reanalyze_logs(path: str):
    """Cross-run log summary without re-running anything: per registered run,
    how many fingerprint rows / distinct keys / epochs the lineage holds
    (`flor.log_records` is the row-level spelling)."""
    from repro.core.query import log_records
    rows = log_records(path)
    per_run: dict = {}
    for r in rows:
        d = per_run.setdefault(r["run_id"],
                               {"parent": r["parent_run"], "rows": 0,
                                "keys": set(), "epochs": set()})
        d["rows"] += 1
        d["keys"].add(r["key"])
        if r["epoch"] is not None:
            d["epochs"].add(r["epoch"])
    print(f"{path}: {len(rows)} log rows across {len(per_run)} run(s)")
    for rid, d in per_run.items():
        print(f"  {rid} (parent {d['parent'] or '-'}): {d['rows']} rows, "
              f"{len(d['epochs'])} epochs, keys {sorted(d['keys'])}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="append", default=[])
    ap.add_argument("--hlo-dir", default="results/hlo")
    ap.add_argument("--store-summary", action="append", default=[],
                    metavar="RUN_DIR",
                    help="print a lineage-aware checkpoint-store summary "
                         "for a recorded run dir")
    ap.add_argument("--logs-summary", action="append", default=[],
                    metavar="STORE_OR_RUN_DIR",
                    help="print a cross-run fingerprint-log summary "
                         "(rows/keys/epochs per registered run)")
    args = ap.parse_args()
    if not args.json and not args.store_summary and not args.logs_summary:
        ap.error("pass --json, --store-summary and/or --logs-summary")
    for p in args.json:
        reanalyze_json(p, args.hlo_dir)
    for rd in args.store_summary:
        reanalyze_store(rd)
    for p in args.logs_summary:
        reanalyze_logs(p)


if __name__ == "__main__":
    main()
