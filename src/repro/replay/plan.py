"""ReplayPlan: the minimal re-execution that answers a logging query.

The paper's headline claim is hindsight replay "orders of magnitude faster
than restarting from scratch"; FlorDB (arXiv:2408.02498) and Multiversion
Hindsight Logging (arXiv:2310.07898) sharpen it into *query-driven* replay:
given the probe set (what the user wants logged), compute which main-loop
epochs must re-EXECUTE, which only need their checkpoint RESTORED, and what
each costs — then hand the segments to a scheduler instead of fanning out a
blind contiguous split.

Inputs crossed here:

* the probe set — explicit block names, ``"*"``, or ``"auto"`` (the paper's
  section-3.2 source-diff tier: diff the recorded script copy against the
  current file, map added lines to their innermost enclosing loop; see
  ``core/probes.py``). Inner-loop probes force logical re-execution of the
  epochs that RUN that block; outer-loop probes only need every epoch
  restore-visited;
* record-side metadata — store meta ``run`` (epoch list, main-loop name),
  ``block_profile`` (measured per-(block, epoch) execution seconds: the
  honest exec-cost input, which is how skew becomes visible to the
  scheduler), and the manifest keys themselves (which blocks have Loop End
  Checkpoints where);
* ``CheckpointStore.stats(per_key=True)`` — resolve-chain depth and
  directly-listed chunk counts per manifest: per-epoch resume cost is
  wildly non-uniform under delta chains (depth 1 vs K), and the estimates
  here make that visible to LPT partitioning.

A plan's per-worker **visit list** ``[(epoch, "init"|"exec"), ...]`` is what
``core/generator.epoch_iter`` actually iterates (``ReplaySpec(segments=)``):
init visits restore (or logically redo) state continuity per the strong /
weak init mode; exec visits run the epoch with the probed blocks executing.
"""
from __future__ import annotations

import json
import os
from dataclasses import asdict, dataclass, field
from typing import Iterable, Optional, Union

from repro.core.probes import ProbeReport, detect_probes

PLAN_FILE = "replay.plan.json"

# cost-model constants: per parent-hop manifest resolution overhead, the
# fallback store read throughput / exec time when nothing was measured, and
# a nominal on-disk chunk size (the delta pipeline writes 64 KiB native
# chunks; compression varies but only RELATIVE segment cost matters to LPT,
# and a fixed figure avoids an O(store) objects-pool walk at plan time)
RESTORE_HOP_S = 0.002
DEFAULT_READ_BPS = 1e9
DEFAULT_EXEC_S = 1.0
NOMINAL_CHUNK_BYTES = 64 * 1024
# per-encoding DECODE throughputs (bytes of decoded output per second):
# restoring a q8/q4 chunk pays a dequantize pass, an entropy-compressed
# ("+z") one an extra decompress+unshuffle. Nominal figures — as with
# NOMINAL_CHUNK_BYTES only RELATIVE segment cost matters to the planner,
# and the per-chunk counts come from the manifests' recorded encodings.
DECODE_BPS = {"q8": 1.5e9, "q4": 1.2e9}
ENTROPY_DECODE_BPS = 0.8e9


def _decode_cost_s(enc_counts: Optional[dict], avg_chunk: int) -> float:
    """Extra restore seconds a key's encoded chunks cost to decode, from
    the per-encoding chunk counts the store's stats report. An entropy
    suffix ("+z") prices the decompress pass on top of the dequantize."""
    cost = 0.0
    for e, n in (enc_counts or {}).items():
        base = e[:-2] if e.endswith("+z") else e
        if base in DECODE_BPS:
            cost += n * avg_chunk / DECODE_BPS[base]
        if e.endswith("+z"):
            cost += n * avg_chunk / ENTROPY_DECODE_BPS
    return cost


class ReplayPlanError(RuntimeError):
    """The plan cannot be built from what the record run left behind."""


@dataclass(frozen=True)
class Segment:
    """One main-loop epoch in the plan."""
    epoch: int
    action: str                      # "exec" | "restore"
    exec_blocks: tuple = ()          # blocks that will re-execute logically
    exec_cost_s: float = 0.0         # estimated re-execution seconds
    restore_cost_s: float = 0.0      # estimated physical-restore seconds
    chain_depth: int = 0             # max delta-chain hops among its ckpts
    has_ckpt: bool = False           # any Loop End Checkpoint at this epoch
    hosts: int = 1                   # store shards its restores touch

    @property
    def cost(self) -> float:
        return self.exec_cost_s + self.restore_cost_s


@dataclass
class ReplayPlan:
    run_dir: str
    epochs: list                      # main-loop epoch values, in order
    probed: frozenset                 # inner blocks re-executing logically
    init_mode: str                    # strong | weak
    outer_probe: bool                 # outer-loop probes: visit every epoch
    main_loop: Optional[str]
    segments: list                    # [Segment, ...] one per epoch
    probe_source: dict = field(default_factory=dict)   # how probes resolved
    mesh: dict = field(default_factory=dict)   # recorded mesh meta, if any
    incomplete: list = field(default_factory=list)  # dist ckpts never stitched

    # ------------------------------------------------------------ queries --
    def segment(self, epoch) -> Segment:
        return self._by_epoch()[epoch]

    def _by_epoch(self) -> dict:
        return {s.epoch: s for s in self.segments}

    def exec_segments(self) -> list:
        return [s for s in self.segments if s.action == "exec"]

    def work_segments(self) -> list:
        """The segments workers are ASSIGNED (scheduled as work, visited in
        exec phase). Inner probes: only the epochs whose probed blocks
        actually run. Outer probes (or no probes at all): every epoch — the
        restore sweep itself is the work, and it parallelizes too."""
        ex = self.exec_segments()
        if self.outer_probe or not ex:
            return list(self.segments)
        return ex

    def visits_for(self, work: Optional[Iterable[Segment]] = None) -> list:
        """The ordered visit list for ONE worker assigned `work` (default:
        the whole plan): each work segment in epoch order preceded by the
        init visits that give it state continuity — every uncovered earlier
        epoch under strong init, only the nearest-checkpoint suffix under
        weak init. Returns ``[(epoch, "init"|"exec"), ...]``."""
        work = list(self.work_segments() if work is None else work)
        pos = {s.epoch: i for i, s in enumerate(self.segments)}
        work.sort(key=lambda s: pos[s.epoch])
        visits: list = []
        covered = -1
        for seg in work:
            i = pos[seg.epoch]
            if i <= covered:
                continue
            gap = self.segments[covered + 1:i]
            if self.init_mode == "weak" and gap:
                anchors = [g for g in gap if g.has_ckpt]
                if anchors:
                    gap = self.segments[pos[anchors[-1].epoch]:i]
            visits += [(g.epoch, "init") for g in gap]
            visits.append((seg.epoch, "exec"))
            covered = i
        return visits

    def summary(self) -> str:
        ex = self.exec_segments()
        n = len(self.segments)
        cost = sum(s.cost for s in self.work_segments())
        probes = ",".join(sorted(self.probed)) or "-"
        return (f"plan: {len(ex)}/{n} epochs re-execute "
                f"(probed: {probes}{', +outer' if self.outer_probe else ''}"
                f"), {self.init_mode} init, est work "
                f"{cost:.2f}s, max resume chain "
                f"{max((s.chain_depth for s in self.segments), default=0)}")

    # ------------------------------------------------------ serialization --
    def to_dict(self) -> dict:
        d = asdict(self)
        d["probed"] = sorted(self.probed)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ReplayPlan":
        from dataclasses import fields as dc_fields
        seg_keys = {f.name for f in dc_fields(Segment)}
        d = dict(d)
        d["probed"] = frozenset(d.get("probed") or ())
        d["segments"] = [Segment(**{**{k: v for k, v in s.items()
                                       if k in seg_keys},
                                    "exec_blocks":
                                    tuple(s.get("exec_blocks") or ())})
                         for s in d.get("segments") or []]
        d.pop("assignments", None)
        known = {f.name for f in dc_fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})

    def save(self, path: Optional[str] = None,
             assignments: Optional[dict] = None) -> str:
        """Persist the plan (plus the scheduler's worker assignments when
        given) to ``<run_dir>/replay.plan.json`` for the merge step and
        post-hoc inspection."""
        path = path or os.path.join(self.run_dir, PLAN_FILE)
        d = self.to_dict()
        if assignments is not None:
            d["assignments"] = assignments
        with open(path, "w") as f:
            json.dump(d, f, indent=1, default=str)
        return path

    @classmethod
    def load(cls, run_dir: str) -> "ReplayPlan":
        with open(os.path.join(run_dir, PLAN_FILE)) as f:
            return cls.from_dict(json.load(f))


# ---------------------------------------------------------------- helpers --
def open_run_store(run_dir: str):
    """(CheckpointStore bound to the run's namespace, flor.run.json meta) —
    follows a shared-store binding when the run recorded into one."""
    from repro.checkpoint import CheckpointStore
    from repro.checkpoint.lineage import read_run_meta
    meta = read_run_meta(run_dir)
    root = meta.get("store_root") or os.path.join(run_dir, "store")
    return CheckpointStore(root, run_id=meta.get("namespace")), meta


def _parse_ckpt_key(key: str):
    """Sanitized manifest name -> (block, epoch, occurrence) or None."""
    if "_at_" not in key:
        return None
    block, rest = key.rsplit("_at_", 1)
    try:
        e, i = rest.split(".", 1)
        return block, int(e), int(i)
    except ValueError:
        return None


def detect_probes_for_run(run_dir: str, current_src: Optional[str] = None,
                          store=None) -> ProbeReport:
    """The ``--probe auto`` tier: diff the source copy the record run stored
    against the current file (or an explicit `current_src` path) and map
    added lines to loops. Raises ReplayPlanError when the record run stored
    no source copy (pre-snapshot run dirs)."""
    if store is None:
        store, _ = open_run_store(run_dir)
    src_meta = store.get_meta("source")
    if not src_meta or not src_meta.get("src"):
        raise ReplayPlanError(
            f"run {run_dir!r} stored no source copy; --probe auto needs one "
            f"(record with a current build, or pass probes explicitly)")
    cur_path = current_src or src_meta.get("path")
    if not cur_path or not os.path.isfile(cur_path):
        raise ReplayPlanError(
            f"current source {cur_path!r} not found; pass --current-src")
    with open(cur_path) as f:
        return detect_probes(src_meta["src"], f.read())


# ------------------------------------------------------------- build_plan --
def build_plan(run_dir: str,
               probed: Union[str, Iterable[str], None] = frozenset(),
               *, init_mode: str = "strong",
               epochs: Optional[Iterable] = None,
               current_src: Optional[str] = None,
               outer_probe: Optional[bool] = None,
               store=None) -> ReplayPlan:
    """Compute a ReplayPlan for `run_dir`.

    `probed`: an iterable of block names, ``"*"`` (all blocks), or
    ``"auto"`` (source-diff detection against the recorded script copy;
    `current_src` overrides the file to diff against). `epochs` falls back
    to the record run's stored epoch list. `outer_probe` forces (or
    suppresses) the visit-every-epoch restore sweep; by default it is
    inferred: on for auto-detected outer probes and for an empty probe set,
    off otherwise."""
    if init_mode not in ("strong", "weak"):
        raise ValueError(f"init_mode must be 'strong' or 'weak', "
                         f"got {init_mode!r}")
    if store is None:
        store, _ = open_run_store(run_dir)

    probe_source: dict = {"tier": "explicit"}
    report: Optional[ProbeReport] = None
    if isinstance(probed, str) and probed == "auto":
        report = detect_probes_for_run(run_dir, current_src=current_src,
                                       store=store)
        probed = set(report.probed_blocks)
        probe_source = {"tier": "source-diff",
                        "added_lines": len(report.added_lines),
                        "suspicious": len(report.suspicious),
                        "outer": sorted(report.probed_outer)}
    elif isinstance(probed, str):
        probed = {p for p in probed.split(",") if p}
    probed = set(probed or ())

    run_meta = store.get_meta("run") or {}
    if epochs is not None:
        epochs = list(epochs)
    elif run_meta.get("epochs") and all(e is not None
                                        for e in run_meta["epochs"]):
        epochs = list(run_meta["epochs"])
    elif run_meta.get("num_epochs") is not None:
        epochs = list(range(int(run_meta["num_epochs"])))
    else:
        raise ReplayPlanError(
            f"run {run_dir!r} has no recorded epoch list; pass epochs=")
    main_loop = run_meta.get("main_loop")

    # which blocks ran (and for how long) in which epochs: measured profile
    # first, checkpoint keys as the fallback for pre-profile run dirs
    profile = (store.get_meta("block_profile") or {}).get("blocks", {})
    occurrences: dict[str, dict[int, float]] = {}
    for bid, per_epoch in profile.items():
        for e, cell in per_epoch.items():
            occurrences.setdefault(bid, {})[int(e)] = float(cell.get("s", 0))
    # checkpoints a distributed record marked incomplete (a host died or
    # straggled past the stitch deadline): their v4 was never written —
    # usually they are already invisible to the listing, but a key the lead
    # flagged must never anchor a restore even if a partial artifact exists.
    # Meta records raw keys; list_keys() returns sanitized names — compare
    # in sanitized space.
    from repro.checkpoint.store import _safe
    incomplete = {_safe(k) for k in
                  (store.get_meta("incomplete_ckpts") or {})
                  .get("keys") or ()}
    keys_by_epoch: dict[int, list[str]] = {}
    blocks_by_epoch: dict[int, set] = {}
    for k in store.list_keys():
        if k in incomplete:
            continue
        parsed = _parse_ckpt_key(k)
        if parsed is None:
            continue
        bid, e, _i = parsed
        keys_by_epoch.setdefault(e, []).append(k)
        blocks_by_epoch.setdefault(e, set()).add(bid)
        if bid not in occurrences or e not in occurrences[bid]:
            occurrences.setdefault(bid, {}).setdefault(e, 0.0)
    if not occurrences:
        raise ReplayPlanError(
            f"run {run_dir!r} has neither a block profile nor checkpoint "
            f"keys — nothing to plan over (did record finish?)")

    all_blocks = sorted(occurrences)
    if "*" in probed:
        probed = set(all_blocks)
    unknown = probed - set(all_blocks) - ({main_loop} if main_loop else set())
    if unknown:
        # either outer-loop ids or TYPOS: fall back to a full restore sweep
        # so the replay still visits everything, but say so loudly — a
        # misspelled probe silently re-executing nothing would look like a
        # vacuously passing replay
        import warnings
        warnings.warn(
            f"probed block(s) {sorted(unknown)} never ran in the record "
            f"run (known blocks: {all_blocks}"
            + (f", main loop: {main_loop!r}" if main_loop else "")
            + "); treating them as outer probes — no epoch will re-execute "
            "for them", stacklevel=2)
    # probed names the record run never saw are either outer-loop ids or
    # typos; treat them as outer so the user still gets a full restore sweep
    if outer_probe is None:
        outer_probe = (not probed) or bool(unknown) \
            or (main_loop is not None and main_loop in probed) \
            or bool(report and report.probed_outer)
    probed &= set(all_blocks)
    if unknown:
        probe_source = dict(probe_source, unknown=sorted(unknown))

    # exec-cost fallback: the median measured epoch-execution time
    measured = [s for per in occurrences.values() for s in per.values()
                if s > 0]
    fallback_exec = sorted(measured)[len(measured) // 2] if measured \
        else DEFAULT_EXEC_S

    # resume-cost raw material: one memoized per-key stats pass (manifests
    # only — include_chunks would walk the whole shared objects pool)
    all_keys = [k for ks in keys_by_epoch.values() for k in ks]
    st = store.stats(keys=all_keys, include_chunks=False, per_key=True) \
        if all_keys else {"per_key": {}}
    per_key = st.get("per_key", {})
    avg_chunk = NOMINAL_CHUNK_BYTES
    # learned restore cost model: measured read throughput and per-hop
    # latency (fit from observed restores in FlorContext.finish, seeded by
    # the calibration probe's read-back). Older stores only recorded
    # write_bps — use it as a same-medium proxy before falling back to the
    # constants.
    calib = store.get_meta("store_calib") or {}
    read_bps = float(calib.get("read_bps") or calib.get("write_bps")
                     or DEFAULT_READ_BPS)
    hop_s = float(calib["hop_s"]) if calib.get("hop_s") is not None \
        else RESTORE_HOP_S
    # per-store-shard service rates (learned from sharded restores or a
    # calibration probe); absent shards fall back to the global figure
    shard_bps = {str(k): float(v)
                 for k, v in (calib.get("shard_read_bps") or {}).items()
                 if v}

    segments = []
    for e in epochs:
        try:
            ei = int(e)
        except (TypeError, ValueError):
            raise ReplayPlanError(
                f"planned replay needs integer epoch values, got {e!r}")
        here = {b for b, per in occurrences.items() if ei in per}
        if not here:
            # an epoch with NO evidence at all (no profile — e.g. the record
            # crashed before finish() persisted it — and no checkpoint under
            # adaptive sparsity): assume every known block runs there, the
            # legacy re-execute-everything semantics. Skipping it instead
            # would silently drop the probe's rows for that epoch while the
            # deferred check still passed.
            here = set(all_blocks)
        exec_blocks = tuple(sorted(here & probed))
        ckpt_blocks = blocks_by_epoch.get(ei, set())
        # blocks that ran but left no checkpoint re-execute regardless of
        # the probe set (logical redo is the only way to pass through them)
        forced = {b for b in here - set(exec_blocks) if b not in ckpt_blocks}
        exec_cost = sum(occurrences[b].get(ei) or fallback_exec
                        for b in set(exec_blocks) | forced)
        restore_cost = 0.0
        depth = 0
        hosts_touched: set = set()
        for k in keys_by_epoch.get(ei, []):
            parsed = _parse_ckpt_key(k)
            if parsed and parsed[0] in exec_blocks:
                continue          # re-executing blocks don't restore
            info = per_key.get(k) or {}
            shards = info.get("shards") or {}
            if shards:
                # sharded manifest: hosts read their store shards
                # concurrently, so the wall-clock restore is the MAX over
                # hosts of local bytes / that shard's service rate — not the
                # aggregate-bytes figure the flat model would charge
                d_k = max(int(s.get("depth") or 0) for s in shards.values())
                depth = max(depth, d_k)
                restore_cost += hop_s * (1 + d_k)
                restore_cost += max(
                    int(s.get("chunks") or 0) * avg_chunk
                    / (shard_bps.get(str(hid)) or read_bps)
                    for hid, s in shards.items())
                hosts_touched.update(str(hid) for hid in shards)
            else:
                depth = max(depth, int(info.get("depth") or 0))
                restore_cost += hop_s * (1 + int(info.get("depth") or 0))
                restore_cost += int(info.get("direct_chunks") or 0) \
                    * avg_chunk / read_bps
            # encoded chunks (q8/q4, entropy-compressed) pay a decode pass
            # on top of the raw read — priced from the manifests' recorded
            # per-chunk encodings
            restore_cost += _decode_cost_s(info.get("enc_counts"),
                                           avg_chunk)
        segments.append(Segment(
            epoch=ei, action="exec" if exec_blocks else "restore",
            exec_blocks=exec_blocks, exec_cost_s=exec_cost,
            restore_cost_s=restore_cost, chain_depth=depth,
            has_ckpt=bool(ckpt_blocks), hosts=max(1, len(hosts_touched))))

    return ReplayPlan(run_dir=run_dir, epochs=[s.epoch for s in segments],
                      probed=frozenset(probed), init_mode=init_mode,
                      outer_probe=bool(outer_probe), main_loop=main_loop,
                      segments=segments, probe_source=probe_source,
                      mesh=dict(store.get_meta("mesh") or {}),
                      incomplete=sorted(incomplete))
