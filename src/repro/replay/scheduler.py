"""Cost-balanced replay scheduling.

Two halves:

* **Partitioning** — LPT (longest-processing-time-first) over the plan's
  per-segment cost estimates, replacing the blind contiguous
  ``pid``/``nworkers`` split. Delta chains make per-epoch resume cost
  non-uniform (resolve depth 1 vs K) and real workloads make per-epoch
  exec cost non-uniform (measured in the record-side block profile); LPT's
  makespan is within 4/3 of optimal, and on skewed runs it beats the
  contiguous split by exactly the skew (see benchmarks/replay_latency.py).
  ``contiguous_shares`` is kept for the deprecation shim and as the
  benchmark baseline.

* **DynamicExecutor** — a work-queue over worker slots: tasks (one per
  share, or finer with ``tasks_per_worker``) are pulled by up to G
  concurrent runners; a failed task is re-queued (bounded attempts); an
  optional straggler policy speculatively re-issues the longest-running
  task when slots idle — first completion wins, the loser is cancelled.
  ``run_task(task, attempt, cancelled)`` is caller-supplied: the launcher
  spawns worker subprocesses, tests pass stub callables.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

MIN_STRAGGLER_HORIZON_S = 1.0
# default speculation horizon multiplier once tasks carry MEASURED cost
# estimates (record-side block profile + learned restore model): a task
# running 3x its estimate is a straggler worth duplicating. Launchers apply
# this only when estimates are measured — with fallback-constant estimates
# the horizon would be noise, so speculation stays off unless asked for.
DEFAULT_STRAGGLER_FACTOR = 3.0


def measured_straggler_factor(tasks: list) -> float:
    """The measured-default speculation policy: DEFAULT_STRAGGLER_FACTOR
    when every task has a positive cost estimate (the plan had real
    profile/calibration data to set horizons from), else 0.0 (off)."""
    if tasks and all(t.est_cost_s > 0 for t in tasks):
        return DEFAULT_STRAGGLER_FACTOR
    return 0.0


# ------------------------------------------------------------ partitioning --
def contiguous_shares(segments: list, nworkers: int) -> list[list]:
    """The legacy split: contiguous runs of segments, balanced by COUNT
    (not cost) to within one."""
    n = len(segments)
    shares = []
    base, rem = divmod(n, nworkers)
    start = 0
    for pid in range(nworkers):
        size = base + (1 if pid < rem else 0)
        shares.append(list(segments[start:start + size]))
        start += size
    return shares


def balanced_shares(segments: list, nworkers: int) -> list[list]:
    """LPT over segment cost estimates: sort by decreasing cost, place each
    on the least-loaded worker. Shares come back in segment (epoch) order
    so downstream visit derivation stays monotone."""
    order = {id(s): i for i, s in enumerate(segments)}
    shares: list[list] = [[] for _ in range(nworkers)]
    loads = [0.0] * nworkers
    for seg in sorted(segments, key=lambda s: (-s.cost, order[id(s)])):
        w = min(range(nworkers), key=lambda i: (loads[i], i))
        shares[w].append(seg)
        loads[w] += seg.cost
    for sh in shares:
        sh.sort(key=lambda s: order[id(s)])
    return shares


def share_cost(plan, share: list) -> float:
    """Estimated wall seconds for ONE worker running `share`: its exec work
    plus the init restores its visit list actually pays (strong init walks
    the whole prefix; weak jumps to checkpoint anchors)."""
    by_epoch = {s.epoch: s for s in plan.segments}
    total = 0.0
    for epoch, phase in plan.visits_for(share):
        seg = by_epoch[epoch]
        if phase == "exec":
            total += seg.cost
        else:
            # init: restore when a checkpoint exists, logical redo otherwise
            total += seg.restore_cost_s if seg.has_ckpt else seg.exec_cost_s
    return total


# --------------------------------------------------------- dynamic executor --
@dataclass
class Task:
    """One schedulable unit: a worker share plus its derived visit list."""
    task_id: int
    visits: list                     # [(epoch, "init"|"exec"), ...]
    epochs: list = field(default_factory=list)   # work epochs it OWNS
    est_cost_s: float = 0.0
    payload: Any = None              # caller scratch (e.g. argv extras)
    host: int = 0                    # preferred host queue (sharded replay)


def assign_hosts(tasks: list, n_hosts: int) -> list:
    """LPT host placement for sharded replay: heaviest task first onto the
    least-loaded host. Mutates each task's ``host`` in place and returns the
    list; the DynamicExecutor's per-host queues then keep each task near its
    store shard while still allowing idle hosts to steal."""
    n = max(1, int(n_hosts))
    loads = [0.0] * n
    for t in sorted(tasks, key=lambda t: -t.est_cost_s):
        h = min(range(n), key=lambda i: (loads[i], i))
        t.host = h
        loads[h] += t.est_cost_s
    return tasks


class TaskFailure(RuntimeError):
    """One or more tasks exhausted their attempts; `.errors` maps task_id
    to the list of raised exceptions."""

    def __init__(self, errors: dict):
        super().__init__(f"tasks failed after retries: {sorted(errors)}")
        self.errors = errors


class DynamicExecutor:
    """Work-queue execution of tasks over `nworkers` concurrent slots.

    * failure re-queue: a task whose run_task raises is retried on another
      slot up to `max_attempts` total attempts;
    * straggler re-queue: with `straggler_factor` > 0, an idle slot
      speculatively duplicates the longest-running task once it has run
      longer than ``straggler_factor * max(est_cost, median completed)``;
      the first attempt to finish wins and the other is cancelled via the
      per-attempt ``cancelled`` event passed to run_task;
    * incremental completion: `on_complete(task, attempt, result)` fires as
      each task FIRST completes — the launcher merges that task's logs into
      the growing merged view right there, instead of waiting for the
      slowest worker;
    * host affinity: with `n_hosts` > 1 each task carries a preferred host
      (see :func:`assign_hosts`) and workers drain their home host's queue
      before stealing — sharded-store restores stay near their shard.

    ``run()`` returns {task_id: (attempt, result)} and raises
    :class:`TaskFailure` if any task permanently failed.
    """

    def __init__(self, tasks: list, run_task: Callable, nworkers: int, *,
                 max_attempts: int = 2, straggler_factor: float = 0.0,
                 on_complete: Optional[Callable] = None, n_hosts: int = 1):
        self.tasks = list(tasks)
        self.run_task = run_task
        self.nworkers = max(1, int(nworkers))
        self.max_attempts = max(1, int(max_attempts))
        self.straggler_factor = float(straggler_factor)
        self.on_complete = on_complete
        # one queue per host: workers drain their home queue first and only
        # then steal, so sharded-replay tasks mostly run near their store
        # shard while idle hosts still keep the makespan bounded
        self.n_hosts = max(1, int(n_hosts))
        self._qs: list["queue.Queue"] = [queue.Queue()
                                         for _ in range(self.n_hosts)]
        self._lock = threading.Lock()
        self._done: dict[int, tuple[int, Any]] = {}
        self._errors: dict[int, list] = {}
        self._failed: set[int] = set()
        self._attempts: dict[int, int] = {}
        self._running: dict[tuple[int, int], float] = {}
        self._cancels: dict[tuple[int, int], threading.Event] = {}
        self._durations: list[float] = []

    # ------------------------------------------------------------ control --
    def run(self) -> dict:
        for t in self.tasks:
            self._attempts[t.task_id] = 1
            self._qs[t.host % self.n_hosts].put((t, 1))
        # with speculation on, keep ALL slots alive even when tasks <
        # workers: an idle slot is what picks up a straggler's duplicate
        nthreads = self.nworkers if self.straggler_factor > 0 \
            else min(self.nworkers, max(1, len(self.tasks)))
        threads = [threading.Thread(target=self._worker,
                                    args=(i % self.n_hosts,), daemon=True)
                   for i in range(nthreads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        if self._failed:
            raise TaskFailure({tid: self._errors.get(tid, [])
                               for tid in self._failed})
        return dict(self._done)

    def _resolved(self, tid: int) -> bool:
        return tid in self._done or tid in self._failed

    def _all_resolved(self) -> bool:
        return all(self._resolved(t.task_id) for t in self.tasks)

    def _try_get(self, home: int):
        """Pop from the home host's queue first, then steal round-robin from
        the others. Raises queue.Empty when every queue is drained."""
        order = [home] + [i for i in range(len(self._qs)) if i != home]
        for i in order:
            try:
                return self._qs[i].get_nowait()
            except queue.Empty:
                continue
        raise queue.Empty

    def _next(self, home: int = 0):
        """Atomically claim the next (task, attempt, cancelled) for an idle
        slot, or None to exit. Pop and claim happen under ONE lock — the
        same lock the give-up check takes — so a popped-but-unregistered
        task can never be mistaken for an exhausted one."""
        while True:
            with self._lock:
                try:
                    task, attempt = self._try_get(home)
                except queue.Empty:
                    if self._all_resolved():
                        return None
                    dup = self._pick_straggler()
                    if dup is not None:
                        return self._claim(*dup)
                    if not self._running:
                        # nothing running, nothing queued, not all resolved:
                        # tasks exhausted attempts — mark them failed
                        for t in self.tasks:
                            if not self._resolved(t.task_id):
                                self._failed.add(t.task_id)
                        return None
                else:
                    if self._resolved(task.task_id):
                        continue   # a duplicate of an already-finished task
                    return self._claim(task, attempt)
            time.sleep(0.02)

    def _claim(self, task, attempt):
        """Register a claimed attempt as running (lock held)."""
        cancelled = threading.Event()
        self._running[(task.task_id, attempt)] = time.monotonic()
        self._cancels[(task.task_id, attempt)] = cancelled
        return task, attempt, cancelled

    def _pick_straggler(self):
        """Speculatively duplicate the longest-running task (lock held)."""
        if self.straggler_factor <= 0 or not self._running:
            return None
        med = sorted(self._durations)[len(self._durations) // 2] \
            if self._durations else 0.0
        now = time.monotonic()
        best = None
        for (tid, attempt), t0 in self._running.items():
            if self._resolved(tid):
                continue
            if self._attempts[tid] >= self.max_attempts:
                continue
            task = next(t for t in self.tasks if t.task_id == tid)
            # the floor keeps bad (near-zero) estimates from triggering
            # speculation during ordinary startup (e.g. jit warmup)
            horizon = self.straggler_factor * max(task.est_cost_s, med,
                                                  MIN_STRAGGLER_HORIZON_S)
            if now - t0 > horizon and (best is None
                                       or t0 < self._running[best]):
                best = (tid, attempt)
        if best is None:
            return None
        tid, _ = best
        task = next(t for t in self.tasks if t.task_id == tid)
        self._attempts[tid] += 1
        return task, self._attempts[tid]

    # ------------------------------------------------------------- worker --
    def _worker(self, home: int = 0):
        while True:
            item = self._next(home)
            if item is None:
                return
            task, attempt, cancelled = item
            key = (task.task_id, attempt)
            t0 = time.monotonic()
            try:
                result = self.run_task(task, attempt, cancelled)
                err = None
            except Exception as e:          # noqa: BLE001 — task isolation
                result, err = None, e
            dt = time.monotonic() - t0
            callback = None
            with self._lock:
                self._running.pop(key, None)
                self._cancels.pop(key, None)
                if err is None and not cancelled.is_set():
                    self._durations.append(dt)
                    if task.task_id not in self._done:
                        self._done[task.task_id] = (attempt, result)
                        self._failed.discard(task.task_id)
                        callback = self.on_complete
                        # cancel any still-running duplicate attempt
                        for (tid, att), ev in self._cancels.items():
                            if tid == task.task_id:
                                ev.set()
                elif err is not None and task.task_id not in self._done:
                    self._errors.setdefault(task.task_id, []).append(err)
                    if self._attempts[task.task_id] < self.max_attempts:
                        self._attempts[task.task_id] += 1
                        self._qs[task.host % self.n_hosts].put(
                            (task, self._attempts[task.task_id]))
                    else:
                        running_elsewhere = any(
                            tid == task.task_id for tid, _ in self._running)
                        if not running_elsewhere:
                            self._failed.add(task.task_id)
            if callback is not None:
                callback(task, attempt, result)
