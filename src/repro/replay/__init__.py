"""Query-driven hindsight replay: plan the minimal re-execution that
answers the logging query, then schedule it cost-balanced over workers.

    plan.py      — ReplayPlan: probe set (explicit or source-diff `auto`)
                   x checkpoint-manifest metadata -> per-epoch segments
                   annotated with resume-cost estimates
    scheduler.py — LPT cost-balanced partitioning + a dynamic work-queue
                   executor (straggler re-queue, incremental completion)

``launch/replay.py`` is a thin driver over these; tests and benchmarks use
them in-process.
"""
from repro.replay.plan import (  # noqa: F401
    ReplayPlan, ReplayPlanError, Segment, build_plan, detect_probes_for_run,
    open_run_store)
from repro.replay.scheduler import (  # noqa: F401
    DEFAULT_STRAGGLER_FACTOR, DynamicExecutor, Task, TaskFailure,
    assign_hosts, balanced_shares, contiguous_shares,
    measured_straggler_factor, share_cost)
