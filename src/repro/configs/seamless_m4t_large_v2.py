"""SeamlessM4T large v2 [arXiv:2308.11596] — encoder-decoder backbone.

Per the assignment, the modality frontend is a STUB: input_specs() provides
precomputed audio-frame embeddings as the encoder input; we model the
24L encoder + 24L decoder transformer backbone.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,              # encoder layers
    num_decoder_layers=24,
    cross_attention=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    head_dim=64,
    ffn_activation="gelu",
    frontend="audio",
    frontend_tokens=0,          # encoder input IS the frame embeddings
)

SMOKE = CONFIG.replace(
    name="seamless-m4t-large-v2-smoke",
    num_layers=2,
    num_decoder_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
)
