"""Zamba2 7B [arXiv:2411.15242] — Mamba2 backbone + SHARED attention block.

81 blocks total; every 6th block is the (single, weight-shared) attention+MLP
block: 13 groups of [5 mamba2 + shared-attn] + 3 trailing mamba2 blocks
=> 68 mamba2 + 13 applications of one shared transformer block.

At 500k decode the shared attention uses a 4096-token sliding window (Zamba2's
long-context recipe); the Mamba2 state is O(1), making the arch long_500k-OK.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    head_dim=112,               # d_model / num_heads
    ffn_activation="geglu",
    attn_period=6,
    sliding_window=4096,        # applied to the shared attn at long context
    ssm=SSMConfig(
        version=2,
        state_dim=64,
        conv_dim=4,
        expand=2,
        head_dim=64,
        chunk=256,
    ),
    serve_replicate_fsdp=False,
)

SMOKE = CONFIG.replace(
    name="zamba2-7b-smoke",
    num_layers=13,              # 2 groups of [5 mamba + attn] + 1 trailing mamba
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    attn_period=6,
    sliding_window=32,
    ssm=SSMConfig(version=2, state_dim=16, conv_dim=4, expand=2, head_dim=16, chunk=16),
)
