"""Google Gemma 2B [arXiv:2403.08295] — GeGLU, head_dim=256, MQA (kv=1)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    d_ff=16384,
    vocab_size=256000,
    head_dim=256,
    ffn_activation="geglu",
    rope_theta=10000.0,
    tie_embeddings=True,
    embed_scale=True,           # embeddings scaled by sqrt(d_model)
)

SMOKE = CONFIG.replace(
    name="gemma-2b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    d_ff=128,
    vocab_size=256,
    head_dim=32,
)
