"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf].

VLM: the assignment specifies the transformer BACKBONE only; the anyres vision
tower is a STUB — input_specs() provides precomputed patch embeddings
(``frontend_tokens`` prefix positions) alongside text tokens.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    ffn_activation="swiglu",
    rope_theta=1000000.0,
    frontend="vision",
    frontend_tokens=576,        # one 24x24 anyres base tile of patch embeddings
    serve_replicate_fsdp=False,
)

SMOKE = CONFIG.replace(
    name="llava-next-mistral-7b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    frontend_tokens=8,
)
