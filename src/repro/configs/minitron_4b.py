"""NVIDIA Minitron 4B (pruned Nemotron) [arXiv:2407.14679]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=9216,
    vocab_size=256000,
    head_dim=128,
    ffn_activation="relu2",     # nemotron family uses squared ReLU
    rope_theta=10000.0,
    # 24 heads / 8 kv do not divide the 16-way model axis (same situation as
    # qwen3): sequence-parallel residuals avoid replicated attention
    seq_shard=True,
    serve_replicate_fsdp=False,
)

SMOKE = CONFIG.replace(
    name="minitron-4b-smoke",
    num_layers=2,
    d_model=48,
    num_heads=3,
    num_kv_heads=1,
    d_ff=96,
    vocab_size=256,
    head_dim=16,
)
