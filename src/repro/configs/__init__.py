"""Architecture config registry.

``get(name)`` -> full published config (used only by the dry-run, via
ShapeDtypeStructs — never allocated on CPU).
``get_smoke(name)`` -> reduced same-family config for CPU smoke tests.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401  (re-exports)
    MLAConfig,
    ModelConfig,
    MoEConfig,
    SHAPES,
    SSMConfig,
    ShapeSpec,
    LONG_CONTEXT_OK,
    cell_applicable,
)

ARCHS = [
    "granite-3-2b",
    "minitron-4b",
    "gemma-2b",
    "qwen3-14b",
    "falcon-mamba-7b",
    "deepseek-v3-671b",
    "mixtral-8x7b",
    "zamba2-7b",
    "seamless-m4t-large-v2",
    "llava-next-mistral-7b",
]

# extra (non-assigned) configs: the paper-scale end-to-end example model
EXTRA = ["florbench-100m"]


def _module(name: str):
    return importlib.import_module("repro.configs." + name.replace("-", "_"))


def get(name: str) -> ModelConfig:
    if name not in ARCHS + EXTRA:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS + EXTRA}")
    return _module(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    if name not in ARCHS + EXTRA:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS + EXTRA}")
    return _module(name).SMOKE


def list_archs() -> list[str]:
    return list(ARCHS)
