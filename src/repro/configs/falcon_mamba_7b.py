"""Falcon-Mamba 7B [arXiv:2410.05355] — pure Mamba1 (attention-free)."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    head_dim=1,
    ssm=SSMConfig(
        version=1,
        state_dim=16,
        conv_dim=4,
        expand=2,
        dt_rank=256,            # ceil(4096/16)
        chunk=256,
    ),
)

SMOKE = CONFIG.replace(
    name="falcon-mamba-7b-smoke",
    num_layers=2,
    d_model=64,
    vocab_size=256,
    ssm=SSMConfig(version=1, state_dim=8, conv_dim=4, expand=2, dt_rank=8, chunk=16),
)
