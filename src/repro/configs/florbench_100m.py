"""florbench-100m: the paper-scale end-to-end example model (not assigned).

A ~124M-param GPT-2-small-class dense LM used by examples/ and benchmarks/ as
the "model training workload" that Flor records and replays, standing in for
the paper's ResNet/RoBERTa workloads at CPU-runnable scale.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="florbench-100m",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=32768,
    head_dim=64,
    ffn_activation="gelu",
    tie_embeddings=True,
)

# CPU-runnable reduction used by examples and benchmarks (a few M params).
SMOKE = CONFIG.replace(
    name="florbench-100m-smoke",
    num_layers=4,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    d_ff=512,
    vocab_size=1024,
    head_dim=32,
)
