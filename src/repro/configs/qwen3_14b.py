"""Qwen3 14B [hf:Qwen/Qwen3-14B] — qk_norm, GQA kv=8."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    head_dim=128,
    ffn_activation="swiglu",
    qk_norm=True,
    rope_theta=1000000.0,
    # 40 heads / 8 kv do not divide the 16-way model axis -> attention would
    # replicate; sequence-parallel residuals are the hillclimbed layout
    # (EXPERIMENTS.md Perf: 146.5s -> 13.0s step-time bound)
    seq_shard=True,
    serve_replicate_fsdp=False,
)

SMOKE = CONFIG.replace(
    name="qwen3-14b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
)
