"""Configuration system: model configs, input-shape specs, registry.

Every assigned architecture gets a module in this package exporting CONFIG.
`repro.configs.get(name)` returns the full config; `get_smoke(name)` returns a
reduced same-family config for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    first_dense_layers: int = 0          # leading layers that stay dense
    router: str = "softmax"              # softmax | sigmoid (deepseek-v3)
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.0         # load-balance loss coefficient


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek Multi-head Latent Attention."""
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class SSMConfig:
    version: int                          # 1 = Mamba1 selective scan, 2 = Mamba2/SSD
    state_dim: int                        # N
    conv_dim: int = 4
    expand: int = 2
    head_dim: int = 64                    # Mamba2 only
    dt_rank: Optional[int] = None         # Mamba1 only (default ceil(d_model/16))
    chunk: int = 256                      # SSD / chunked-scan chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                           # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None        # default d_model // num_heads
    ffn_activation: str = "swiglu"        # swiglu | geglu | gelu | relu2
    qk_norm: bool = False
    sliding_window: Optional[int] = None  # SWA window (Mixtral / long-ctx Zamba)
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    embed_scale: bool = False             # Gemma-style sqrt(d) embedding scale
    logit_softcap: Optional[float] = None

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None

    # hybrid (Zamba2): every `attn_period`-th block is a *shared-weight*
    # attention+MLP block; the rest are Mamba2 blocks.
    attn_period: Optional[int] = None

    # encoder-decoder (Seamless)
    num_decoder_layers: int = 0
    cross_attention: bool = False

    # modality frontend STUB: "vision" | "audio" | None.  input_specs() emits
    # precomputed patch/frame embeddings for these.
    frontend: Optional[str] = None
    frontend_tokens: int = 0              # patches/frames occupying the prefix

    mtp_depth: int = 0                    # DeepSeek multi-token prediction depth
    dtype: str = "bfloat16"               # compute dtype

    # runtime knobs (not architecture identity)
    scan_layers: bool = True              # scan vs unroll the layer stack
    remat: bool = True                    # per-layer activation checkpointing
    remat_policy: str = "nothing"         # nothing | dots | full  (what to SAVE)
    attention_impl: str = "auto"          # auto | naive | chunked | pallas
    attention_chunk: int = 1024
    attention_probs_dtype: str = "float32"   # float32 | bfloat16 (perf knob:
    #   exp/p tensors and the pv matmul run in bf16; m/l stay fp32)
    attention_remat_chunk: bool = True    # remat the KV-chunk body: backward
    #   recomputes scores/probs instead of saving [nc, ..., Sq, chunk] stacks
    #   (the jnp-level analogue of flash attention's recompute-in-bwd).
    #   Confirmed win on all three hillclimb cells (EXPERIMENTS.md Perf);
    #   set False for the paper-faithful baseline measurements.
    seq_shard: bool = False               # shard the residual stream's SEQ dim
    #   over "model" (sequence parallelism). The win when num_heads doesn't
    #   divide the model axis (qwen3: 40 heads on 16) and attention would
    #   otherwise replicate; k/v are all-gathered per layer (cheap).
    serve_replicate_fsdp: bool = True     # serving layout: replicate params
    #   over the FSDP axes (weights resident per model shard, no per-token
    #   all-gathers). Decode is latency-bound and weights-stationary wins
    #   whenever params/model_axis fits HBM; False for 671B-class models.
    dense_layout: str = "tp"              # tp | dp. "dp" runs dense blocks
    #   pure-data-parallel with batch sharded over ("pod","data","model") and
    #   dense weights FSDP-only (no per-layer TP activation psums); MoE then
    #   all-gathers tokens over "model" and reduce-scatters the combine.
    #   The hillclimbed layout for deepseek-v3 train (EXPERIMENTS.md Perf).
    param_dtype: str = "float32"          # parameter storage dtype
    moment_dtype: str = "float32"         # optimizer moment dtype
    loss_chunk: int = 0                   # 0 = unchunked; else seq-chunked loss

    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def _attn_params(self) -> int:
        """Parameter count of one attention block (projections only)."""
        d, hd = self.d_model, self.resolved_head_dim()
        if self.mla is not None:
            m = self.mla
            qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
            return (d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qk_hd
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    + self.num_heads * m.v_head_dim * d
                    + m.q_lora_rank + m.kv_lora_rank)      # latent norms
        return d * (self.num_heads + 2 * self.num_kv_heads) * hd + self.num_heads * hd * d

    def _mlp_params(self, d_ff: int) -> int:
        gated = self.ffn_activation in ("swiglu", "geglu")
        return self.d_model * d_ff * (3 if gated else 2)

    def _mamba_params(self) -> int:
        """One Mamba block (v1 selective-scan or v2/SSD layout)."""
        d, s = self.d_model, self.ssm
        din = s.expand * d
        if s.version == 1:
            dtr = s.dt_rank or -(-d // 16)
            return (d * 2 * din               # in_proj (x and z)
                    + s.conv_dim * din        # depthwise conv
                    + din * (dtr + 2 * s.state_dim)  # x -> dt,B,C
                    + dtr * din               # dt_proj
                    + din * s.state_dim       # A
                    + din                     # D
                    + din * d                 # out_proj
                    + d)                      # norm
        nheads = din // s.head_dim
        return (d * (2 * din + 2 * s.state_dim + nheads)   # in_proj z,x,B,C,dt
                + s.conv_dim * (din + 2 * s.state_dim)     # conv over x,B,C
                + nheads * 2                               # A, D (scalar/head)
                + din                                      # gated rmsnorm
                + din * d                                  # out_proj
                + d)                                       # pre-norm

    def param_count(self) -> int:
        """Analytic parameter count (embeddings counted once if tied)."""
        d, L = self.d_model, self.num_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = self._attn_params()
        if self.family == "ssm":
            return emb + L * self._mamba_params() + d       # + final norm
        if self.family == "hybrid":
            n_attn = L // self.attn_period
            n_mamba = L - n_attn
            shared_attn = attn + self._mlp_params(self.d_ff) + 2 * d  # shared ONCE
            return emb + n_mamba * self._mamba_params() + shared_attn + d
        if self.moe is not None:
            mo = self.moe
            dense_l = mo.first_dense_layers
            moe_l = L - dense_l
            router = d * mo.num_experts
            per_moe = (attn + router
                       + (mo.num_experts + mo.num_shared_experts)
                       * self._mlp_params(mo.d_ff_expert))
            layers = dense_l * (attn + self._mlp_params(self.d_ff)) + moe_l * per_moe
        else:
            layers = L * (attn + self._mlp_params(self.d_ff))
        dec = 0
        if self.num_decoder_layers:
            # decoder layer = self-attn + cross-attn + mlp (+3 norms)
            dec = self.num_decoder_layers * (2 * attn + self._mlp_params(self.d_ff) + 3 * d)
        norms = L * 2 * d + d
        return emb + layers + dec + norms

    def active_param_count(self) -> int:
        """Activated params per token (MoE counts top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        mo = self.moe
        full = self.param_count()
        gated = 3 if self.ffn_activation in ("swiglu", "geglu") else 2
        per_expert = self.d_model * mo.d_ff_expert * gated
        moe_l = self.num_layers - mo.first_dense_layers
        inactive = moe_l * (mo.num_experts - mo.top_k) * per_expert
        return full - inactive


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k":    ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k":  ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k":   ShapeSpec("long_500k", "decode", 524288, 1),
}

# Archs allowed to run long_500k (sub-quadratic attention); everything else is
# a documented skip (DESIGN.md §5).
LONG_CONTEXT_OK = {"falcon-mamba-7b", "zamba2-7b", "mixtral-8x7b"}


def cell_applicable(arch: str, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and arch not in LONG_CONTEXT_OK:
        return False, "full-attention arch: 500k decode skipped per assignment (DESIGN.md §5)"
    return True, ""
