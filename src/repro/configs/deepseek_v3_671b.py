"""DeepSeek-V3 671B [arXiv:2412.19437] — MLA, 1 shared + 256 routed top-8 MoE.

d_ff=18432 on the 3 leading dense layers; expert d_ff=2048 (assignment's d_ff
field refers to the expert width). MTP implemented as optional mtp_depth=1 but
disabled in the dry-run cells so all archs share the same objective.

param_dtype/moment_dtype bf16: at 671B the fp32 optimizer-state footprint would
exceed 512 x 16GB v5e HBM; bf16 moments are standard practice at this scale and
orthogonal to the paper's technique.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=18432,                 # dense layers (first 3)
    vocab_size=129280,
    ffn_activation="swiglu",
    rope_theta=10000.0,
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        d_ff_expert=2048,
        num_shared_experts=1,
        first_dense_layers=3,
        router="sigmoid",
        router_aux_loss=0.001,
    ),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    mtp_depth=0,
    param_dtype="bfloat16",
    moment_dtype="bfloat16",
    # 671B bf16 / 16 model shards = 84 GB: cannot replicate over the data
    # axis at serve time; keep FSDP-sharded serve params
    serve_replicate_fsdp=False,
)

SMOKE = CONFIG.replace(
    name="deepseek-v3-671b-smoke",
    num_layers=3,               # 1 dense + 2 moe
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    moe=MoEConfig(
        num_experts=4,
        top_k=2,
        d_ff_expert=32,
        num_shared_experts=1,
        first_dense_layers=1,
        router="sigmoid",
        router_aux_loss=0.001,
    ),
    mla=MLAConfig(
        q_lora_rank=32,
        kv_lora_rank=16,
        qk_nope_head_dim=16,
        qk_rope_head_dim=8,
        v_head_dim=16,
    ),
    param_dtype="float32",
    moment_dtype="float32",
)
