"""Mixtral 8x7B [arXiv:2401.04088] — 8 experts top-2, sliding-window attention."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    ffn_activation="swiglu",
    sliding_window=4096,
    rope_theta=1000000.0,
    moe=MoEConfig(
        num_experts=8,
        top_k=2,
        d_ff_expert=14336,
        router="softmax",
        router_aux_loss=0.01,
    ),
)

SMOKE = CONFIG.replace(
    name="mixtral-8x7b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    sliding_window=32,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128,
                  router="softmax", router_aux_loss=0.01),
)
