"""Pallas TPU kernel: blockwise int8 quantize/dequantize.

Backs two subsystems: checkpoint compression (optimizer moments tolerate
blockwise int8; error-bounded) and the cross-pod gradient-compression codec
(parallel/compression.py). One VMEM pass: absmax reduce + scale + round.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_G = 8


def _quant_kernel(x_ref, q_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)               # [TILE_G, B]
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale.astype(jnp.float32)


def quantize_pallas(x: jnp.ndarray, *, interpret: bool = True,
                    tile_g: int = TILE_G):
    """[G, B] float -> (q int8 [G, B], scale f32 [G])."""
    G, B = x.shape
    assert G % tile_g == 0, (G, tile_g)
    return pl.pallas_call(
        _quant_kernel,
        grid=(G // tile_g,),
        in_specs=[pl.BlockSpec((tile_g, B), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((tile_g, B), lambda i: (i, 0)),
                   pl.BlockSpec((tile_g,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((G, B), jnp.int8),
                   jax.ShapeDtypeStruct((G,), jnp.float32)],
        interpret=interpret,
    )(x)


def _dequant_kernel(q_ref, scale_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * scale_ref[...][:, None]


def dequantize_pallas(q: jnp.ndarray, scale: jnp.ndarray, *,
                      interpret: bool = True, tile_g: int = TILE_G):
    G, B = q.shape
    assert G % tile_g == 0
    return pl.pallas_call(
        _dequant_kernel,
        grid=(G // tile_g,),
        in_specs=[pl.BlockSpec((tile_g, B), lambda i: (i, 0)),
                  pl.BlockSpec((tile_g,), lambda i: (i,))],
        out_specs=pl.BlockSpec((tile_g, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((G, B), jnp.float32),
        interpret=interpret,
    )(q, scale)
