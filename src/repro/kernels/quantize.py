"""Pallas TPU kernels: blockwise int8 quantize/dequantize (+ fused gather).

Backs three subsystems: checkpoint compression (optimizer moments tolerate
blockwise int8; error-bounded), the cross-pod gradient-compression codec
(parallel/compression.py), and the fused checkpoint fast path
(``gather_quantize_pallas``: changed chunk rows leave the device already
wire-format, via scalar-prefetch gather + quantize in one VMEM pass).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE_G = 8
Q8_BLOCK = 256
Q4_BLOCK = 256


def _quant_kernel(x_ref, q_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)               # [TILE_G, B]
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale.astype(jnp.float32)


def quantize_pallas(x: jnp.ndarray, *, interpret: bool = True,
                    tile_g: int = TILE_G):
    """[G, B] float -> (q int8 [G, B], scale f32 [G])."""
    G, B = x.shape
    assert G % tile_g == 0, (G, tile_g)
    return pl.pallas_call(
        _quant_kernel,
        grid=(G // tile_g,),
        in_specs=[pl.BlockSpec((tile_g, B), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((tile_g, B), lambda i: (i, 0)),
                   pl.BlockSpec((tile_g,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((G, B), jnp.int8),
                   jax.ShapeDtypeStruct((G,), jnp.float32)],
        interpret=interpret,
    )(x)


def _gather_quant_kernel(idx_ref, x_ref, q_ref, scale_ref, *, block: int):
    del idx_ref  # consumed by the BlockSpec index_map, not the body
    x = x_ref[...].astype(jnp.float32)               # [1, W] selected row
    W = x.shape[-1]
    sub = x.reshape(W // block, block)
    scale = jnp.maximum(jnp.max(jnp.abs(sub), axis=1) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(sub / scale[:, None]), -127, 127)
    q_ref[...] = q.reshape(1, W).astype(jnp.int8)
    scale_ref[...] = scale.reshape(1, W // block).astype(jnp.float32)


def gather_quantize_pallas(x: jnp.ndarray, idx: jnp.ndarray, *,
                           block: int = Q8_BLOCK, interpret: bool = True):
    """Fused gather + blockwise-int8 quantize over CHANGED chunk rows.

    ``x`` is the [G, W] float chunk view of a leaf, ``idx`` the int32 [C]
    changed-row indices. The grid runs one program per changed row; the row
    index is scalar-prefetched so the BlockSpec index_map DMAs only the
    selected rows into VMEM — frozen rows are never read. Each row is
    quantized per ``block``-element sub-block (same codec layout as
    parallel/compression.py). Returns (q int8 [C, W], scales f32
    [C, W // block])."""
    G, W = x.shape
    C = int(idx.shape[0])
    assert W % block == 0, (W, block)
    n_sub = W // block
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(C,),
        in_specs=[pl.BlockSpec((1, W), lambda i, idx_ref: (idx_ref[i], 0))],
        out_specs=[pl.BlockSpec((1, W), lambda i, idx_ref: (i, 0)),
                   pl.BlockSpec((1, n_sub), lambda i, idx_ref: (i, 0))],
    )
    return pl.pallas_call(
        functools.partial(_gather_quant_kernel, block=block),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((C, W), jnp.int8),
                   jax.ShapeDtypeStruct((C, n_sub), jnp.float32)],
        interpret=interpret,
    )(idx, x)


def _gather_quant4_kernel(idx_ref, x_ref, p_ref, scale_ref, *, block: int):
    del idx_ref  # consumed by the BlockSpec index_map, not the body
    x = x_ref[...].astype(jnp.float32)               # [1, W] selected row
    W = x.shape[-1]
    sub = x.reshape(W // block, block)
    scale = jnp.maximum(jnp.max(jnp.abs(sub), axis=1) / 7.0, 1e-12)
    q = jnp.clip(jnp.round(sub / scale[:, None]), -7, 7).astype(jnp.int32)
    q = q.reshape(1, W)
    # half-split nibble pack: low nibble = elements [0, W/2), high nibble =
    # [W/2, W) — contiguous lane slices instead of a stride-2 shuffle, which
    # is what the TPU vector unit can actually do cheaply
    lo = q[:, : W // 2] & 0xF
    hi = q[:, W // 2:] & 0xF
    p_ref[...] = (lo | (hi << 4)).astype(jnp.uint8)
    scale_ref[...] = scale.reshape(1, W // block).astype(jnp.float32)


def gather_quantize4_pallas(x: jnp.ndarray, idx: jnp.ndarray, *,
                            block: int = Q4_BLOCK, interpret: bool = True):
    """Fused gather + blockwise-int4 quantize over CHANGED chunk rows.

    Same scalar-prefetch gather shape as :func:`gather_quantize_pallas`, but
    each row quantizes to signed int4 (clip ±7) and packs two nibbles per
    byte with the half-split layout (element j in the low nibble of byte j,
    element j + W/2 in its high nibble). Returns (packed uint8 [C, W // 2],
    scales f32 [C, W // block])."""
    G, W = x.shape
    C = int(idx.shape[0])
    assert W % block == 0 and W % 2 == 0, (W, block)
    n_sub = W // block
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(C,),
        in_specs=[pl.BlockSpec((1, W), lambda i, idx_ref: (idx_ref[i], 0))],
        out_specs=[pl.BlockSpec((1, W // 2), lambda i, idx_ref: (i, 0)),
                   pl.BlockSpec((1, n_sub), lambda i, idx_ref: (i, 0))],
    )
    return pl.pallas_call(
        functools.partial(_gather_quant4_kernel, block=block),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((C, W // 2), jnp.uint8),
                   jax.ShapeDtypeStruct((C, n_sub), jnp.float32)],
        interpret=interpret,
    )(idx, x)


def _dequant_kernel(q_ref, scale_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * scale_ref[...][:, None]


def dequantize_pallas(q: jnp.ndarray, scale: jnp.ndarray, *,
                      interpret: bool = True, tile_g: int = TILE_G):
    G, B = q.shape
    assert G % tile_g == 0
    return pl.pallas_call(
        _dequant_kernel,
        grid=(G // tile_g,),
        in_specs=[pl.BlockSpec((tile_g, B), lambda i: (i, 0)),
                  pl.BlockSpec((tile_g,), lambda i: (i,))],
        out_specs=pl.BlockSpec((tile_g, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((G, B), jnp.float32),
        interpret=interpret,
    )(q, scale)
