"""Pallas TPU kernel: chunk fingerprint + changed-mask (lean checkpointing).

The async writer wants to know WHICH chunks of a leaf changed since the last
materialized checkpoint without DMA-ing the whole leaf to the host. This
kernel computes a position-mixed 64-bit digest per chunk ON DEVICE; only
chunks whose digest changed are transferred. Integer multiply-add streams at
HBM bandwidth on the VPU, so fingerprinting costs one read of the leaf.

Tiling: the [G, B] uint32 view is processed in (TILE_G, B) VMEM blocks; B is
the checkpoint chunk size in words (4 KiB chunks = 1024 words by default),
TILE_G chosen so the block fits comfortably in VMEM (TILE_G * B * 4 bytes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.ref import FP_PRIME1, FP_PRIME2, FP_PRIME3

TILE_G = 8


def _fingerprint_kernel(x_ref, digest_ref):
    x = x_ref[...]                                   # [TILE_G, B] uint32
    B = x.shape[-1]
    pos = (jax.lax.broadcasted_iota(jnp.uint32, (1, B), 1) * FP_PRIME1)
    v = (x ^ pos) * FP_PRIME2
    d0 = jax.lax.reduce(v, np.uint32(0), jax.lax.bitwise_xor, (1,))
    d1 = jnp.sum(v * FP_PRIME3, axis=1, dtype=jnp.uint32)
    digest_ref[...] = jnp.stack([d0, d1], axis=1)    # [TILE_G, 2]


def fingerprint_pallas(x_u32: jnp.ndarray, *, interpret: bool = True,
                       tile_g: int = TILE_G) -> jnp.ndarray:
    """[G, B] uint32 -> [G, 2] uint32 digests."""
    G, B = x_u32.shape
    assert G % tile_g == 0, (G, tile_g)
    return pl.pallas_call(
        _fingerprint_kernel,
        grid=(G // tile_g,),
        in_specs=[pl.BlockSpec((tile_g, B), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile_g, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((G, 2), jnp.uint32),
        interpret=interpret,
    )(x_u32)


def _fp_changed_kernel(x_ref, prev_ref, digest_ref, mask_ref):
    x = x_ref[...]                                   # [TILE_G, B] uint32
    B = x.shape[-1]
    pos = (jax.lax.broadcasted_iota(jnp.uint32, (1, B), 1) * FP_PRIME1)
    v = (x ^ pos) * FP_PRIME2
    d0 = jax.lax.reduce(v, np.uint32(0), jax.lax.bitwise_xor, (1,))
    d1 = jnp.sum(v * FP_PRIME3, axis=1, dtype=jnp.uint32)
    d = jnp.stack([d0, d1], axis=1)                  # [TILE_G, 2]
    digest_ref[...] = d
    mask_ref[...] = jnp.any(d != prev_ref[...], axis=1).astype(jnp.int32)


def fingerprint_changed_pallas(x_u32: jnp.ndarray, prev: jnp.ndarray, *,
                               interpret: bool = True,
                               tile_g: int = TILE_G):
    """Fused digest + compare: [G, B] uint32 x [G, 2] prev digests ->
    ([G, 2] digests, [G] int32 changed mask) in ONE pass over the leaf.

    The separate ``fingerprint_pallas`` + ``changed_mask_pallas`` pair costs
    a second kernel launch and re-reads the [G, 2] digests from HBM; fusing
    the compare into the fingerprint tile keeps both outputs in registers
    while the leaf streams through VMEM once."""
    G, B = x_u32.shape
    assert G % tile_g == 0, (G, tile_g)
    return pl.pallas_call(
        _fp_changed_kernel,
        grid=(G // tile_g,),
        in_specs=[pl.BlockSpec((tile_g, B), lambda i: (i, 0)),
                  pl.BlockSpec((tile_g, 2), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((tile_g, 2), lambda i: (i, 0)),
                   pl.BlockSpec((tile_g,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((G, 2), jnp.uint32),
                   jax.ShapeDtypeStruct((G,), jnp.int32)],
        interpret=interpret,
    )(x_u32, prev)


def _changed_kernel(digest_ref, prev_ref, mask_ref):
    d = digest_ref[...]
    p = prev_ref[...]
    mask_ref[...] = jnp.any(d != p, axis=1).astype(jnp.int32)


def changed_mask_pallas(digest: jnp.ndarray, prev: jnp.ndarray, *,
                        interpret: bool = True,
                        tile_g: int = TILE_G) -> jnp.ndarray:
    G = digest.shape[0]
    assert G % tile_g == 0
    return pl.pallas_call(
        _changed_kernel,
        grid=(G // tile_g,),
        in_specs=[pl.BlockSpec((tile_g, 2), lambda i: (i, 0)),
                  pl.BlockSpec((tile_g, 2), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile_g,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((G,), jnp.int32),
        interpret=interpret,
    )(digest, prev)
