"""jit'd wrappers around the Pallas kernels.

``interpret`` is selected automatically: True on CPU (kernel body runs in
Python for validation), False on TPU (real Mosaic lowering). All public ops
handle padding/reshaping so callers pass natural shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.chunk_delta import (changed_mask_pallas,
                                       fingerprint_changed_pallas,
                                       fingerprint_pallas)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.quantize import (Q4_BLOCK, Q8_BLOCK, dequantize_pallas,
                                    gather_quantize4_pallas,
                                    gather_quantize_pallas, quantize_pallas)
from repro.kernels.ref import (changed_mask_ref, fingerprint_changed_ref,
                               fingerprint_ref, gather_quantize4_ref,
                               gather_quantize_ref)

CHUNK_WORDS = 1024        # 4 KiB chunks (uint32 words)


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _as_u32_blocks(x: jnp.ndarray, chunk_words: int):
    """View any array as [G, chunk_words] uint32 (zero-padded), G % 8 == 0."""
    raw = x.reshape(-1)
    if raw.dtype == jnp.bfloat16 or raw.dtype == jnp.float16:
        raw = raw.view(jnp.uint16).astype(jnp.uint32)
    elif raw.dtype.itemsize == 4:
        raw = raw.view(jnp.uint32)
    elif raw.dtype.itemsize == 8:
        raw = raw.view(jnp.uint32)
    else:
        raw = raw.view(jnp.uint8).astype(jnp.uint32)
    n = raw.shape[0]
    g = -(-n // chunk_words)
    g = -(-g // 8) * 8                     # TILE_G alignment
    pad = g * chunk_words - n
    raw = jnp.pad(raw, (0, pad))
    return raw.reshape(g, chunk_words)


def native_bytes_per_word(dtype) -> int:
    """How many ORIGINAL-array bytes one uint32 word of `_as_u32_blocks`
    output carries. Must mirror the dtype dispatch above: bf16/f16 widen one
    2-byte element per word; 4- and 8-byte dtypes are raw views (4 bytes per
    word); everything else widens one byte per word."""
    name = dtype if isinstance(dtype, str) else str(np.dtype(dtype))
    if name in ("bfloat16", "float16"):
        return 2
    return 4 if np.dtype(name).itemsize in (4, 8) else 1


def _fingerprint(blocks):
    """Backend dispatch: real Mosaic lowering on TPU; on CPU the vectorized
    jnp oracle (bit-identical math, see test_kernels) — per-tile interpret
    mode is orders of magnitude slower and digests never cross processes."""
    if _interpret():
        return fingerprint_ref(blocks)
    return fingerprint_pallas(blocks, interpret=False)


@functools.partial(jax.jit, static_argnames=("chunk_words",))
def fingerprint_leaf(x, chunk_words: int = CHUNK_WORDS):
    """Per-chunk [G,2] uint32 digest of one array (device-side, one pass)."""
    return _fingerprint(_as_u32_blocks(x, chunk_words))


@functools.partial(jax.jit, static_argnames=("chunk_words",))
def fingerprint_and_changed(x, prev_digest, chunk_words: int = CHUNK_WORDS):
    """Fused fingerprint + compare: one pass over the leaf yielding both the
    new [G,2] digests and the int32 [G] changed mask. Use when a previous
    digest exists; first-sight leaves go through ``fingerprint_leaf`` (there
    is nothing to compare against)."""
    blocks = _as_u32_blocks(x, chunk_words)
    if _interpret():
        return fingerprint_changed_ref(blocks, prev_digest)
    return fingerprint_changed_pallas(blocks, prev_digest, interpret=False)


@jax.jit
def changed_chunks(digest, prev_digest):
    """bool-ish int32 [G] mask of chunks whose digest changed."""
    if _interpret():
        return changed_mask_ref(digest, prev_digest).astype(jnp.int32)
    return changed_mask_pallas(digest, prev_digest, interpret=False)


@functools.partial(jax.jit, static_argnames=("chunk_words",))
def gather_changed_blocks(x, idx, chunk_words: int = CHUNK_WORDS):
    """[C, W] u32 rows of the block view of `x` selected by `idx` — the only
    device->host payload the delta pipeline transfers per leaf. Deliberately
    a SEPARATE traced computation from the fingerprint: a fused
    digest+blocks pass would write a full padded u32 copy of every leaf per
    checkpoint, even when zero chunks changed; callers skip this entirely
    for frozen leaves (empty idx)."""
    return jnp.take(_as_u32_blocks(x, chunk_words), idx, axis=0)


def quantizable_dtype(dtype) -> bool:
    """True for dtypes the fused q8 path supports. Restricted to the float
    dtypes whose `_as_u32_blocks` view carries exactly one element per u32
    word — so the float chunk rows below align 1:1 with fingerprint chunks
    and a changed-row index means the same thing in both views."""
    name = dtype if isinstance(dtype, str) else str(np.dtype(dtype))
    return name in ("float32", "bfloat16", "float16")


@functools.partial(jax.jit, static_argnames=("chunk_words", "block"))
def gather_quantize_blocks(x, idx, chunk_words: int = CHUNK_WORDS,
                           block: int = Q8_BLOCK):
    """Fused gather + blockwise-int8 quantize of the CHANGED chunk rows of a
    float leaf: (q int8 [C, W], scales f32 [C, W // block]). Rows are the
    leaf's [G, chunk_words]-element f32 chunk view (same row indexing as the
    fingerprint view for quantizable dtypes); only rows named by ``idx`` are
    read — the wire-format payload leaves the device in one pass."""
    block = min(block, chunk_words)            # small-chunk configs
    blocks = _padded_float_blocks(x, chunk_words)
    if _interpret():
        return gather_quantize_ref(blocks, idx, block)
    return gather_quantize_pallas(blocks, idx, block=block, interpret=False)


def _padded_float_blocks(x, chunk_words: int):
    """The leaf's [g, chunk_words] f32 chunk view, g TILE_G-aligned — the
    shared row layout of every fused gather variant."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    g = -(-n // chunk_words)
    g = -(-g // 8) * 8
    flat = jnp.pad(flat, (0, g * chunk_words - n))
    return flat.reshape(g, chunk_words)


@functools.partial(jax.jit, static_argnames=("chunk_words", "block"))
def gather_quantize4_blocks(x, idx, chunk_words: int = CHUNK_WORDS,
                            block: int = Q4_BLOCK):
    """Fused gather + blockwise-int4 quantize of the CHANGED chunk rows of a
    float leaf: (packed uint8 [C, chunk_words // 2], scales f32
    [C, chunk_words // block]). Two elements per byte in the half-split
    nibble layout; per-element error bounded by half a quantization step
    (block absmax / 14)."""
    block = min(block, chunk_words)            # small-chunk configs
    blocks = _padded_float_blocks(x, chunk_words)
    if _interpret():
        return gather_quantize4_ref(blocks, idx, block)
    return gather_quantize4_pallas(blocks, idx, block=block, interpret=False)


@functools.partial(jax.jit, static_argnames=("chunk_words",))
def chunk_absmax(x, chunk_words: int = CHUNK_WORDS):
    """Per-chunk-row f32 absmax of a float leaf ([g] over the same padded
    row layout the fused gathers use). The encoding selector turns this into
    a GUARANTEED per-chunk error bound (q4 half-step = a/14, q8 = a/254;
    the selector tests a/13.5 and a/126 to absorb f32 scale rounding), so
    the cheapest encoding satisfying the slot's atol is chosen per chunk
    before any gather runs."""
    return jnp.max(jnp.abs(_padded_float_blocks(x, chunk_words)), axis=1)


# ------------------------------------------------------------- q8 wire codec
# Self-describing quantized chunk payload (little-endian):
#   [u32 n_elems][u32 block][f32 scales[ceil(n_elems/block)]][int8 q[n_elems]]
# The store writes these bytes as the chunk body (enc="q8"); restore
# dequantizes transparently via `q8_decode_chunk`.

def q8_encode_chunk(q_row: np.ndarray, scales: np.ndarray, n_elems: int,
                    block: int = Q8_BLOCK) -> bytes:
    """Pack one quantized chunk row (int8 [W], f32 [W // block]) into the
    q8 wire format, trimming to the chunk's real `n_elems` (the last chunk
    of a leaf is usually partial)."""
    n_sub = -(-n_elems // block)
    head = np.uint32(n_elems).tobytes() + np.uint32(block).tobytes()
    return (head
            + np.ascontiguousarray(scales[:n_sub], np.float32).tobytes()
            + np.ascontiguousarray(q_row[:n_elems], np.int8).tobytes())


def q8_decode_chunk(payload: bytes, dtype) -> bytes:
    """Dequantize one q8 chunk payload back to the leaf's native bytes."""
    n = int(np.frombuffer(payload[:4], np.uint32)[0])
    block = int(np.frombuffer(payload[4:8], np.uint32)[0])
    n_sub = -(-n // block)
    scales = np.frombuffer(payload[8:8 + 4 * n_sub], np.float32)
    q = np.frombuffer(payload[8 + 4 * n_sub:8 + 4 * n_sub + n], np.int8)
    pad = (-n) % block
    qf = np.pad(q.astype(np.float32), (0, pad)).reshape(n_sub, block)
    x = (qf * scales[:, None]).reshape(-1)[:n]
    # bf16 is registered with numpy via ml_dtypes (a jax dependency), so a
    # plain astype covers f32/bf16/f16 alike
    out = x.astype(jnp.dtype(dtype) if isinstance(dtype, str) else dtype)
    return np.ascontiguousarray(out).tobytes()


# ------------------------------------------------------------- q4 wire codec
# Self-describing int4 chunk payload (little-endian):
#   [u32 n_elems][u32 block][f32 scales[W/block]][u8 packed[W/2]]
# scales and packed bytes cover the FULL kernel row W (untrimmed; W is
# recovered from the payload length: bytes after the 8-byte header =
# n_sub * (4 + block/2), so n_sub = after / (4 + block//2), W = n_sub*block).
# Nibbles use the half-split layout: byte j holds element j (low) and
# element j + W/2 (high), signed two's-complement in 4 bits.

def q4_encode_chunk(packed_row: np.ndarray, scales: np.ndarray,
                    n_elems: int, block: int = Q4_BLOCK) -> bytes:
    """Pack one int4-quantized chunk row (uint8 [W // 2], f32 [W // block])
    into the q4 wire format. The packed row is kept whole — the half-split
    nibble layout interleaves elements W/2 apart, so a partial last chunk
    cannot trim bytes the way q8 does; `n_elems` in the header trims on
    decode instead."""
    head = np.uint32(n_elems).tobytes() + np.uint32(block).tobytes()
    return (head
            + np.ascontiguousarray(scales, np.float32).tobytes()
            + np.ascontiguousarray(packed_row, np.uint8).tobytes())


def q4_decode_chunk(payload: bytes, dtype) -> bytes:
    """Dequantize one q4 chunk payload back to the leaf's native bytes."""
    n = int(np.frombuffer(payload[:4], np.uint32)[0])
    block = int(np.frombuffer(payload[4:8], np.uint32)[0])
    after = len(payload) - 8
    n_sub = after // (4 + block // 2)
    W = n_sub * block
    scales = np.frombuffer(payload[8:8 + 4 * n_sub], np.float32)
    packed = np.frombuffer(payload[8 + 4 * n_sub:], np.uint8)
    q = np.empty(W, np.int8)
    lo = (packed & 0xF).astype(np.int8)
    hi = (packed >> 4).astype(np.int8)
    q[: W // 2] = lo - ((lo > 7) << 4)       # sign-extend 4 -> 8 bits
    q[W // 2:] = hi - ((hi > 7) << 4)
    qf = q.astype(np.float32).reshape(n_sub, block)
    x = (qf * scales[:, None]).reshape(-1)[:n]
    out = x.astype(jnp.dtype(dtype) if isinstance(dtype, str) else dtype)
    return np.ascontiguousarray(out).tobytes()


# -------------------------------------------------------- decode dispatch --
def decode_wire_chunk(payload: bytes, enc: str, dtype) -> bytes:
    """Decode one stored chunk body to native leaf bytes given its manifest
    ``enc`` marker. Handles every wire encoding ("raw", "q8", "q4") plus the
    "+z" entropy-stage suffix (byte-plane-shuffled compression applied on
    the writer thread; see parallel/compression.py)."""
    if enc.endswith("+z"):
        from repro.parallel.compression import entropy_decode_bytes
        payload = entropy_decode_bytes(payload)
        enc = enc[:-2]
    if enc == "q8":
        return q8_decode_chunk(payload, dtype)
    if enc == "q4":
        return q4_decode_chunk(payload, dtype)
    return payload


@functools.partial(jax.jit, static_argnames=("block",))
def quantize_blocks(x, block: int = 256):
    """Flat blockwise int8 quantization: returns (q [G,block], scale [G],
    n) for any input shape; G padded to the kernel tile."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    g = -(-n // block)
    g = -(-g // 8) * 8
    flat = jnp.pad(flat, (0, g * block - n))
    q, scale = quantize_pallas(flat.reshape(g, block), interpret=_interpret())
    return q, scale


def dequantize_blocks(q, scale, shape, dtype):
    x = dequantize_pallas(q, scale, interpret=_interpret())
    n = int(np.prod(shape))
    return x.reshape(-1)[:n].reshape(shape).astype(dtype)


def flash_attention(q, k, v, *, causal: bool = True, scale=None,
                    block_q: int = 128, block_k: int = 128):
    return flash_attention_pallas(q, k, v, causal=causal, scale=scale,
                                  block_q=block_q, block_k=block_k,
                                  interpret=_interpret())
