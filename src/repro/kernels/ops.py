"""jit'd wrappers around the Pallas kernels.

``interpret`` is selected automatically: True on CPU (kernel body runs in
Python for validation), False on TPU (real Mosaic lowering). All public ops
handle padding/reshaping so callers pass natural shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.chunk_delta import changed_mask_pallas, fingerprint_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.quantize import dequantize_pallas, quantize_pallas

CHUNK_WORDS = 1024        # 4 KiB chunks (uint32 words)


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _as_u32_blocks(x: jnp.ndarray, chunk_words: int):
    """View any array as [G, chunk_words] uint32 (zero-padded), G % 8 == 0."""
    raw = x.reshape(-1)
    if raw.dtype == jnp.bfloat16 or raw.dtype == jnp.float16:
        raw = raw.view(jnp.uint16).astype(jnp.uint32)
    elif raw.dtype.itemsize == 4:
        raw = raw.view(jnp.uint32)
    elif raw.dtype.itemsize == 8:
        raw = raw.view(jnp.uint32)
    else:
        raw = raw.view(jnp.uint8).astype(jnp.uint32)
    n = raw.shape[0]
    g = -(-n // chunk_words)
    g = -(-g // 8) * 8                     # TILE_G alignment
    pad = g * chunk_words - n
    raw = jnp.pad(raw, (0, pad))
    return raw.reshape(g, chunk_words)


@functools.partial(jax.jit, static_argnames=("chunk_words",))
def fingerprint_leaf(x, chunk_words: int = CHUNK_WORDS):
    """Per-chunk [G,2] uint32 digest of one array (device-side, one pass)."""
    blocks = _as_u32_blocks(x, chunk_words)
    return fingerprint_pallas(blocks, interpret=_interpret())


@jax.jit
def changed_chunks(digest, prev_digest):
    """bool-ish int32 [G] mask of chunks whose digest changed."""
    return changed_mask_pallas(digest, prev_digest, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block",))
def quantize_blocks(x, block: int = 256):
    """Flat blockwise int8 quantization: returns (q [G,block], scale [G],
    n) for any input shape; G padded to the kernel tile."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    g = -(-n // block)
    g = -(-g // 8) * 8
    flat = jnp.pad(flat, (0, g * block - n))
    q, scale = quantize_pallas(flat.reshape(g, block), interpret=_interpret())
    return q, scale


def dequantize_blocks(q, scale, shape, dtype):
    x = dequantize_pallas(q, scale, interpret=_interpret())
    n = int(np.prod(shape))
    return x.reshape(-1)[:n].reshape(shape).astype(dtype)


def flash_attention(q, k, v, *, causal: bool = True, scale=None,
                    block_q: int = 128, block_k: int = 128):
    return flash_attention_pallas(q, k, v, causal=causal, scale=scale,
                                  block_q=block_q, block_k=block_k,
                                  interpret=_interpret())
