"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

FP_PRIME1 = np.uint32(2654435761)
FP_PRIME2 = np.uint32(2246822519)
FP_PRIME3 = np.uint32(3266489917)


def fingerprint_ref(x_u32: jnp.ndarray) -> jnp.ndarray:
    """Per-row fingerprint of a [G, B] uint32 view. Returns [G, 2] uint32.
    Position-mixed so permutations change the digest."""
    G, B = x_u32.shape
    pos = (jnp.arange(B, dtype=jnp.uint32) * FP_PRIME1)[None, :]
    v = (x_u32 ^ pos) * FP_PRIME2
    d0 = jax.lax.reduce(v, np.uint32(0), jax.lax.bitwise_xor, (1,))
    d1 = jnp.sum(v * FP_PRIME3, axis=1, dtype=jnp.uint32)
    return jnp.stack([d0, d1], axis=1)


def changed_mask_ref(digest: jnp.ndarray, prev: jnp.ndarray) -> jnp.ndarray:
    """[G,2] x [G,2] -> bool [G]; True where the chunk changed."""
    return jnp.any(digest != prev, axis=1)


def fingerprint_changed_ref(x_u32: jnp.ndarray, prev: jnp.ndarray):
    """Fused-kernel oracle: ([G,2] digests, int32 [G] changed mask)."""
    d = fingerprint_ref(x_u32)
    return d, changed_mask_ref(d, prev).astype(jnp.int32)


def gather_quantize_ref(x: jnp.ndarray, idx: jnp.ndarray, block: int = 256):
    """Fused gather+quantize oracle over the [G, W] float chunk view:
    returns (q int8 [C, W], scales f32 [C, W // block])."""
    rows = jnp.take(x.astype(jnp.float32), idx, axis=0)
    C, W = rows.shape
    q, s = quantize_ref(rows.reshape(C * (W // block), block))
    return q.reshape(C, W), s.reshape(C, W // block)


def gather_quantize4_ref(x: jnp.ndarray, idx: jnp.ndarray, block: int = 256):
    """Fused gather+int4-quantize oracle over the [G, W] float chunk view:
    returns (packed uint8 [C, W // 2], scales f32 [C, W // block]) with the
    half-split nibble layout (element j in the low nibble of byte j, element
    j + W/2 in its high nibble)."""
    rows = jnp.take(x.astype(jnp.float32), idx, axis=0)
    C, W = rows.shape
    sub = rows.reshape(C * (W // block), block)
    scale = jnp.maximum(jnp.max(jnp.abs(sub), axis=1) / 7.0, 1e-12)
    q = jnp.clip(jnp.round(sub / scale[:, None]), -7, 7).astype(jnp.int32)
    q = q.reshape(C, W)
    lo = q[:, : W // 2] & 0xF
    hi = q[:, W // 2:] & 0xF
    return ((lo | (hi << 4)).astype(jnp.uint8),
            scale.reshape(C, W // block).astype(jnp.float32))


def quantize_ref(x: jnp.ndarray):
    """Blockwise int8 quantization of [G, B] f32. Returns (q int8 [G,B],
    scale f32 [G])."""
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_ref(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale[:, None]


def flash_attention_ref(q, k, v, *, causal: bool = True, scale=None):
    """q [B,H,Sq,d], k/v [B,KV,Sk,d] with H % KV == 0. f32 softmax."""
    B, H, Sq, d = q.shape
    KV = k.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, Sq, d).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k.astype(jnp.float32))
    s = s * (scale if scale is not None else 1.0 / np.sqrt(d))
    if causal:
        Sk = k.shape[2]
        mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None] + (Sk - Sq)
        s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", w, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, d).astype(q.dtype)
