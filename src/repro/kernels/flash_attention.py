"""Pallas TPU kernel: flash attention forward (GQA, causal), MXU-tiled.

Serving prefill hot-spot. Grid (B, KV, G, nq, nk) with the KV-block axis
innermost: the output block for one (query-block) is revisited across nk
steps, carrying the online-softmax state (m, l, acc) in VMEM scratch — the
canonical Pallas flash pattern. Block sizes default to (128, 128): MXU-
aligned and ~(2*128*hd + 128*128)*4 bytes of VMEM per step.

Validated in interpret mode against ref.flash_attention_ref (CPU has no
MXU; on TPU the same code path compiles to the real kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  n_k: int, sq: int, sk: int):
    iq = pl.program_id(3)
    ik = pl.program_id(4)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, 0].astype(jnp.float32)            # [BQ, d]
    k = k_ref[0, 0].astype(jnp.float32)               # [BK, d]
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    row = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    col = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    keep = col < sk
    if causal:
        keep &= col <= (row + (sk - sq))
    s = jnp.where(keep, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == n_k - 1)
    def _finish():
        o_ref[0, 0, 0] = (acc_scr[...]
                          / jnp.maximum(l_scr[...], 1e-30)[:, None]
                          ).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, scale=None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True):
    """q [B,H,Sq,d], k/v [B,KV,Sk,d], H % KV == 0 -> o [B,H,Sq,d]."""
    B, H, Sq, d = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    nq, nk = Sq // block_q, Sk // block_k
    qg = q.reshape(B, KV, G, Sq, d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, n_k=nk, sq=Sq, sk=Sk)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, G, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, block_q, d),
                         lambda b, kv, g, iq, ik: (b, kv, g, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, kv, g, iq, ik: (b, kv, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, kv, g, iq, ik: (b, kv, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, block_q, d),
                               lambda b, kv, g, iq, ik: (b, kv, g, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v)
    return out.reshape(B, H, Sq, d)
