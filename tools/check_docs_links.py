#!/usr/bin/env python3
"""Docs link checker: verify every relative markdown link resolves.

    python tools/check_docs_links.py README.md docs

Checks, for each ``[text](target)`` in the given files/dirs (recursing
into ``*.md``):

* relative file targets exist (resolved against the linking file's dir);
* ``#anchor`` fragments — same-file or cross-file — match a heading in the
  target file (GitHub slugification: lowercase, spaces to dashes,
  punctuation dropped);
* bare ``path:line`` code pointers in backticks are NOT links and are
  ignored; external ``http(s)://`` and ``mailto:`` targets are skipped
  (this is an offline checker).

Exit code 1 with a per-link report when anything dangles — CI runs this
over README.md and docs/ so a refactor can't silently orphan the docs.
"""
from __future__ import annotations

import os
import re
import sys

LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def github_slug(heading: str) -> str:
    h = re.sub(r"`([^`]*)`", r"\1", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def headings_of(path: str) -> set:
    with open(path, encoding="utf-8") as f:
        text = CODE_FENCE_RE.sub("", f.read())
    return {github_slug(m.group(1)) for m in HEADING_RE.finditer(text)}


def md_files(args: list[str]) -> list[str]:
    out = []
    for a in args:
        if os.path.isdir(a):
            for root, _dirs, files in os.walk(a):
                out.extend(os.path.join(root, f) for f in sorted(files)
                           if f.endswith(".md"))
        else:
            out.append(a)
    return out


def check_file(path: str) -> list[str]:
    errors = []
    with open(path, encoding="utf-8") as f:
        text = CODE_FENCE_RE.sub(lambda m: "\n" * m.group(0).count("\n"),
                                 f.read())
    base = os.path.dirname(os.path.abspath(path))
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        frag = None
        if "#" in target:
            target, frag = target.split("#", 1)
        dest = path if not target else os.path.normpath(
            os.path.join(base, target))
        if target and not os.path.exists(dest):
            errors.append(f"{path}: broken link -> {m.group(1)}")
            continue
        if frag is not None and dest.endswith(".md"):
            if github_slug(frag) not in headings_of(dest):
                errors.append(f"{path}: dangling anchor -> {m.group(1)}")
    return errors


def main(argv: list[str]) -> int:
    files = md_files(argv or ["README.md", "docs"])
    errors = []
    for f in files:
        errors.extend(check_file(f))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} file(s): "
          f"{'OK' if not errors else f'{len(errors)} broken link(s)'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
