"""Model correctness: per-arch smoke steps, causality, attention equivalences,
prefill/decode consistency, mamba chunking invariance."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.data import synthetic_batch
from repro.models import build_model
from repro.train.step import build_train_step


def _high_cf(cfg):
    if cfg.moe is None:
        return cfg
    return cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))


@pytest.mark.parametrize("arch", C.ARCHS + C.EXTRA)
def test_smoke_forward_one_train_step(arch):
    """Assigned-arch requirement: reduced config, one train step on CPU,
    output shapes + no NaNs."""
    cfg = C.get_smoke(arch).replace(attention_chunk=32)
    init_state, train_step = build_train_step(cfg)
    state = jax.jit(init_state)(jax.random.PRNGKey(0))
    batch = synthetic_batch(cfg, 2, 64, 0)
    state2, metrics = jax.jit(train_step)(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and 0 < loss < 20
    assert float(metrics["grad_norm"]) > 0
    assert int(state2.step) == 1
    # params changed (exact compare: warmup lr is tiny on purpose)
    changed = any(
        not np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree_util.tree_leaves(state.params),
                        jax.tree_util.tree_leaves(state2.params)))
    assert changed


@pytest.mark.parametrize("arch", ["granite-3-2b", "falcon-mamba-7b",
                                  "zamba2-7b", "mixtral-8x7b"])
def test_causality(arch):
    """Perturbing a future token must not change past logits."""
    cfg = _high_cf(C.get_smoke(arch)).replace(
        attention_impl="naive", dtype="float32", param_dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    S = 16
    b = synthetic_batch(cfg, 1, S, 0)
    from repro.models.transformer import lm_forward
    h1, _ = jax.jit(lambda p, t: lm_forward(cfg, p, t))(params, b["tokens"])
    t2 = np.array(b["tokens"])
    t2[0, -1] = (t2[0, -1] + 7) % cfg.vocab_size
    h2, _ = jax.jit(lambda p, t: lm_forward(cfg, p, t))(params, t2)
    np.testing.assert_allclose(np.asarray(h1[0, : S - 1]),
                               np.asarray(h2[0, : S - 1]), atol=1e-5)
    assert not np.allclose(np.asarray(h1[0, -1]), np.asarray(h2[0, -1]))


def test_gqa_equals_mha_when_kv_equals_heads():
    cfg = C.get_smoke("granite-3-2b").replace(
        num_kv_heads=4, attention_impl="naive", dtype="float32",
        param_dtype="float32")
    from repro.models import attention as A
    from repro.models.params import init_params
    spec = A.attn_spec(cfg)
    p = init_params(spec, jax.random.PRNGKey(1), "float32")
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
    out = A.self_attention(cfg, p, x, pos)
    # reference: dense softmax attention built by hand
    hd = cfg.resolved_head_dim()
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"])
    from repro.models.layers import apply_rope
    q = apply_rope(q, pos[:, :, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, :, None], cfg.rope_theta)
    s = jnp.einsum("bqnh,bknh->bnqk", q, k) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((8, 8), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    o = jnp.einsum("bnqk,bknh->bqnh", jax.nn.softmax(s, -1), v)
    ref = jnp.einsum("bqnh,nhd->bqd", o, p["wo"])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("arch", ["granite-3-2b", "gemma-2b", "qwen3-14b",
                                  "mixtral-8x7b", "deepseek-v3-671b"])
def test_chunked_equals_naive_attention(arch):
    cfg_n = _high_cf(C.get_smoke(arch)).replace(
        attention_impl="naive", dtype="float32", param_dtype="float32")
    cfg_c = cfg_n.replace(attention_impl="chunked", attention_chunk=16)
    mn, mc = build_model(cfg_n), build_model(cfg_c)
    params = mn.init(jax.random.PRNGKey(0))
    b = synthetic_batch(cfg_n, 2, 40, 0)
    ln, _ = jax.jit(mn.loss)(params, b)
    lc, _ = jax.jit(mc.loss)(params, b)
    assert abs(float(ln) - float(lc)) < 1e-5


@pytest.mark.parametrize("arch", ["granite-3-2b", "mixtral-8x7b",
                                  "falcon-mamba-7b", "zamba2-7b",
                                  "deepseek-v3-671b", "seamless-m4t-large-v2",
                                  "llava-next-mistral-7b"])
def test_decode_matches_prefill(arch):
    """Greedy continuation invariance: decode(prefill(x), t) == prefill(x+t)."""
    cfg = _high_cf(C.get_smoke(arch)).replace(
        attention_impl="naive", dtype="float32", param_dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    S = 24
    batch = synthetic_batch(cfg, 2, 2 * S if cfg.family == "audio" else S, 0)
    caches, _ = jax.jit(lambda p, b: m.prefill(p, b, S + 8))(params, batch)
    tok = jnp.full((2, 1), 7, jnp.int32)
    logits_d, _ = jax.jit(m.decode)(params, caches, tok,
                                    jnp.asarray(S, jnp.int32))
    b2 = dict(batch)
    key = {"audio": "dec_tokens"}.get(cfg.family, "tokens")
    b2[key] = np.concatenate([batch[key], np.full((2, 1), 7, np.int32)], 1)
    _, logits_p2 = jax.jit(lambda p, b: m.prefill(p, b, S + 9))(params, b2)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(logits_p2),
                               atol=2e-3)


def test_sliding_window_bounds_cache():
    cfg = C.get_smoke("mixtral-8x7b")
    m = build_model(cfg)
    spec = m.cache_spec(2, 10_000)
    # SWA ring cache: bounded by window (32 in smoke), not 10k
    assert spec["layers"]["k"].shape[2] == cfg.sliding_window


def test_mamba_chunk_size_invariance():
    """The chunked scan must not depend on chunk size."""
    base = C.get_smoke("falcon-mamba-7b").replace(dtype="float32",
                                                  param_dtype="float32")
    m = build_model(base)
    params = m.init(jax.random.PRNGKey(0))
    b = synthetic_batch(base, 2, 48, 0)
    losses = []
    for q in (4, 16, 48):
        cfg = base.replace(ssm=dataclasses.replace(base.ssm, chunk=q))
        losses.append(float(jax.jit(build_model(cfg).loss)(params, b)[0]))
    assert max(losses) - min(losses) < 1e-4, losses


def test_mamba2_chunk_size_invariance():
    base = C.get_smoke("zamba2-7b").replace(dtype="float32",
                                            param_dtype="float32")
    m = build_model(base)
    params = m.init(jax.random.PRNGKey(0))
    b = synthetic_batch(base, 2, 48, 0)
    losses = []
    for q in (8, 16, 48):
        cfg = base.replace(ssm=dataclasses.replace(base.ssm, chunk=q))
        losses.append(float(jax.jit(build_model(cfg).loss)(params, b)[0]))
    assert max(losses) - min(losses) < 1e-4, losses


def test_moe_routing_properties():
    from repro.models.moe import _route
    cfg = C.get_smoke("mixtral-8x7b")
    x = jax.random.normal(jax.random.PRNGKey(0), (64, cfg.d_model))
    w = jax.random.normal(jax.random.PRNGKey(1),
                          (cfg.d_model, cfg.moe.num_experts)) * 0.1
    weights, ids, aux = _route(cfg, w, x)
    assert weights.shape == (64, cfg.moe.top_k)
    np.testing.assert_allclose(np.asarray(weights.sum(-1)), 1.0, atol=1e-5)
    assert (np.asarray(ids) < cfg.moe.num_experts).all()
    # distinct experts per token
    for row in np.asarray(ids):
        assert len(set(row.tolist())) == cfg.moe.top_k
    assert float(aux) >= 1.0 - 1e-6   # Switch aux loss lower bound at balance


def test_moe_capacity_drop_metric():
    from repro.models.moe import _moe_local
    cfg = C.get_smoke("mixtral-8x7b").replace(dtype="float32",
                                              param_dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    p = jax.tree_util.tree_map(lambda x: x[0], params["layers"])["moe"]
    x = jax.random.normal(jax.random.PRNGKey(2), (32, cfg.d_model)) * 0.1
    _, _, drop_hi = _moe_local(cfg, p, x, 0, 4, capacity=64)
    _, _, drop_lo = _moe_local(cfg, p, x, 0, 4, capacity=4)
    assert float(drop_hi) == 0.0
    assert float(drop_lo) > 0.0


def test_vlm_loss_masks_image_prefix():
    cfg = C.get_smoke("llava-next-mistral-7b").replace(dtype="float32",
                                                       param_dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b = synthetic_batch(cfg, 2, 32, 0)
    assert b["embeds"].shape[1] == cfg.frontend_tokens
    loss, _ = jax.jit(m.loss)(params, b)
    assert np.isfinite(float(loss))


def test_seq_shard_loss_invariance():
    """seq_shard is a pure layout knob: identical results on one device."""
    cfg = C.get_smoke("qwen3-14b").replace(dtype="float32",
                                           param_dtype="float32",
                                           seq_shard=False)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b = synthetic_batch(cfg, 2, 64, 0)
    l1, _ = jax.jit(m.loss)(params, b)
    l2, _ = jax.jit(build_model(cfg.replace(seq_shard=True)).loss)(params, b)
    assert abs(float(l1) - float(l2)) < 1e-6


def test_dense_layout_dp_loss_invariance():
    """dense_layout only changes sharding axes, never math."""
    cfg = _high_cf(C.get_smoke("deepseek-v3-671b")).replace(
        dtype="float32", param_dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b = synthetic_batch(cfg, 2, 32, 0)
    l1, _ = jax.jit(m.loss)(params, b)
    m2 = build_model(cfg.replace(dense_layout="dp"))
    l2, _ = jax.jit(m2.loss)(params, b)
    assert abs(float(l1) - float(l2)) < 1e-6


def test_attention_remat_chunk_invariance():
    cfg = C.get_smoke("granite-3-2b").replace(
        dtype="float32", param_dtype="float32", attention_impl="chunked",
        attention_chunk=16)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b = synthetic_batch(cfg, 2, 48, 0)
    l1, _ = jax.jit(build_model(cfg.replace(attention_remat_chunk=False)).loss)(params, b)
    l2, _ = jax.jit(build_model(cfg.replace(attention_remat_chunk=True)).loss)(params, b)
    assert abs(float(l1) - float(l2)) < 1e-6
