"""The replay planner + cost-balanced scheduler subsystem (repro.replay):
plan construction from probe set x manifest metadata, planned-segment
iteration through the session surface, LPT vs contiguous partitioning,
per-segment log merge, and the dynamic work-queue executor."""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

import repro.flor as flor
from repro.core.query import merge_replay_logs
from repro.replay import (DynamicExecutor, ReplayPlan, Segment, Task,
                          TaskFailure, balanced_shares, build_plan,
                          contiguous_shares, share_cost)

EPOCHS = 6
VAL_EPOCHS = [1, 3, 5]         # "val" runs every 2nd epoch only


def _body(sess, execd=None, probe=False):
    """Two-block training loop: 'train' every epoch, 'val' on odd epochs.
    `probe=True` adds the HINDSIGHT log statement inside the val block (the
    log line the record run wishes it had); `execd` collects per-epoch
    executed() flags."""
    state = {"x": jnp.zeros((8,), jnp.float32)}
    with sess.checkpointing(state=state) as ckpt:
        for e in sess.loop("epochs", range(EPOCHS)):
            for _ in sess.loop("train", range(2)):
                ckpt.state = {"x": ckpt.state["x"] + (e + 1)}
            if execd is not None:
                execd.setdefault(e, {})["train"] = sess.executed("train")
            if e in VAL_EPOCHS:
                for _ in sess.loop("val", range(1)):
                    v = float(ckpt.state["x"][0]) * 10
                    if probe:
                        flor.log("val_metric", v)
                if execd is not None:
                    execd[e]["val"] = sess.executed("val")
            if sess.executed("train"):
                flor.log("loss", float(ckpt.state["x"][0]))
    return ckpt.state


@pytest.fixture()
def recorded(tmp_path):
    run = str(tmp_path / "run")
    with flor.Session(run, record=flor.RecordSpec(adaptive=False)) as sess:
        final = _body(sess)
    return run, final


# ------------------------------------------------------------------- plan --
def test_plan_selects_only_probed_block_epochs(recorded):
    run, _ = recorded
    plan = build_plan(run, probed={"val"})
    assert [s.epoch for s in plan.exec_segments()] == VAL_EPOCHS
    assert plan.work_segments() == plan.exec_segments()
    assert not plan.outer_probe
    for s in plan.segments:
        if s.epoch in VAL_EPOCHS:
            assert s.action == "exec" and s.exec_blocks == ("val",)
        else:
            assert s.action == "restore" and not s.exec_blocks
        assert s.has_ckpt
    # delta chains make resume cost non-uniform; the estimates must see it
    depths = [s.chain_depth for s in plan.segments]
    assert depths == sorted(depths) and depths[-1] > depths[0]
    costs = [s.restore_cost_s for s in plan.segments]
    assert costs[-1] > costs[0] > 0


def test_plan_outer_probe_visits_every_epoch(recorded):
    run, _ = recorded
    plan = build_plan(run, probed=set())
    assert plan.outer_probe
    assert [s.epoch for s in plan.work_segments()] == list(range(EPOCHS))
    assert plan.visits_for() == [(e, "exec") for e in range(EPOCHS)]
    # a probe the record run never saw falls back to the full restore
    # sweep — LOUDLY (a typo silently re-executing nothing would look like
    # a vacuously passing replay)
    with pytest.warns(UserWarning, match="no_such_block"):
        plan = build_plan(run, probed={"no_such_block"})
    assert plan.outer_probe
    assert plan.probe_source["unknown"] == ["no_such_block"]


def test_plan_weak_init_jumps_to_anchor(recorded):
    run, _ = recorded
    plan = build_plan(run, probed={"val"}, init_mode="weak")
    share = [plan.segment(5)]
    # every epoch has a checkpoint, so weak init restores ONLY epoch 4
    assert plan.visits_for(share) == [(4, "init"), (5, "exec")]
    strong = build_plan(run, probed={"val"})
    assert strong.visits_for(share) == \
        [(e, "init") for e in range(5)] + [(5, "exec")]


def test_plan_save_load_roundtrip(recorded):
    run, _ = recorded
    plan = build_plan(run, probed={"val"})
    plan.save(assignments={"0": {"epochs": [1]}})
    loaded = ReplayPlan.load(run)
    assert loaded.probed == plan.probed
    assert loaded.segments == plan.segments
    assert loaded.visits_for() == plan.visits_for()


def test_probe_auto_from_stored_source(recorded, tmp_path):
    """The --probe auto tier end-to-end against store meta: diff recorded
    vs edited source, plan from the detected names."""
    from repro.replay import open_run_store
    run, _ = recorded
    store, _meta = open_run_store(run)
    src = (
        'for e in sess.loop("epochs", range(6)):\n'
        '    for s in sess.loop("train", range(2)):\n'
        '        state = step(state)\n'
        '    for s in sess.loop("val", range(1)):\n'
        '        check(state)\n'
    )
    store.put_meta("source", {"path": "train.py", "src": src})
    edited = tmp_path / "edited.py"
    edited.write_text(src.replace("        check(state)\n",
                                  "        check(state)\n"
                                  "        flor.log('v', state)\n"))
    plan = build_plan(run, probed="auto", current_src=str(edited))
    assert plan.probed == frozenset({"val"})
    assert not plan.outer_probe
    assert plan.probe_source["tier"] == "source-diff"
    assert [s.epoch for s in plan.exec_segments()] == VAL_EPOCHS


def test_plan_without_profile_or_ckpt_assumes_block_runs(recorded):
    """Regression: a record run whose block profile was lost (crash before
    finish) under SPARSE checkpointing must not silently drop probed
    epochs — no-evidence epochs conservatively re-execute every block."""
    import os
    import shutil
    from repro.replay import open_run_store
    run, _ = recorded
    store, _meta = open_run_store(run)
    # simulate the lost profile + an adaptive record that skipped epoch 2's
    # checkpoints entirely
    os.remove(store._meta_path("block_profile"))
    for k in list(store.list_keys()):
        if "_at_2." in k:
            store.delete_manifest(k)
    plan = build_plan(run, probed={"train"})
    seg = plan.segment(2)
    assert seg.action == "exec"
    assert set(seg.exec_blocks) >= {"train"}
    assert not seg.has_ckpt
    assert 2 in [s.epoch for s in plan.work_segments()]
    shutil.rmtree(run, ignore_errors=True)


# -------------------------------------------------------- planned replay --
def test_planned_replay_restores_without_executing_skipped_epochs(recorded):
    """The acceptance property: with a probe on ONE inner block, only the
    epochs that RUN that block re-execute; every other epoch restores
    physically without executing anything."""
    run, final = recorded
    plan = build_plan(run, probed={"val"})
    execd = {}
    with flor.Session(run, mode="replay",
                      replay=flor.ReplaySpec(plan=plan)) as sess:
        out = _body(sess, execd, probe=True)
    np.testing.assert_array_equal(np.asarray(out["x"]),
                                  np.asarray(final["x"]))
    for e in range(EPOCHS):
        assert execd[e]["train"] is False, \
            f"epoch {e}: train must restore, not execute"
        if e in VAL_EPOCHS:
            assert execd[e]["val"] is True
    rec, reps = flor.run_logs(run)
    res = flor.deferred_check(rec, reps)
    assert res.ok, res.anomalies
    assert res.hindsight_only == len(VAL_EPOCHS)   # the new probe's rows


def test_two_worker_merge_bit_identical_to_single_worker(recorded):
    run, final = recorded
    plan = build_plan(run, probed={"val"})
    work = plan.work_segments()

    # single-worker baseline (pid 9 -> its own log file)
    with flor.Session(run, mode="replay",
                      replay=flor.ReplaySpec(pid=9, segments=plan.visits_for(),
                                             probed=plan.probed)) as sess:
        _body(sess, probe=True)
    merged_single = merge_replay_logs(
        run, [("replay_p9", [s.epoch for s in work])])
    assert merged_single                       # val_metric rows exist

    for split in (balanced_shares, contiguous_shares):
        shares = [sh for sh in split(work, 2) if sh]
        assert len(shares) == 2
        assert sorted(s.epoch for sh in shares for s in sh) == VAL_EPOCHS
        owners = []
        last = None
        for pid, sh in enumerate(shares):
            spec = flor.ReplaySpec(pid=pid, segments=plan.visits_for(sh),
                                   probed=plan.probed)
            with flor.Session(run, mode="replay", replay=spec) as sess:
                last = _body(sess, probe=True)
            owners.append((f"replay_p{pid}", [s.epoch for s in sh]))
        merged = merge_replay_logs(run, owners, out_path=True)
        assert merged == merged_single
        rec, _ = flor.run_logs(run)
        res = flor.deferred_check(rec, merged)
        assert res.ok, res.anomalies
    # the worker owning the LAST epoch ends at the recorded final state
    np.testing.assert_array_equal(np.asarray(last["x"]),
                                  np.asarray(final["x"]))


def test_replayspec_segment_forms():
    spec = flor.ReplaySpec(segments=[1, (3, "exec"), (0, "init")])
    assert spec.segments == ((1, "exec"), (3, "exec"), (0, "init"))
    with pytest.raises(ValueError):
        flor.ReplaySpec(segments=[(0, "restore")])
    # pid/nworkers validation still applies to the legacy contiguous form
    with pytest.raises(ValueError):
        flor.ReplaySpec(pid=2, nworkers=2)
    # ... but a planned worker's pid is just a log id
    assert flor.ReplaySpec(pid=7, segments=[(0, "exec")]).pid == 7


# -------------------------------------------------------------- scheduler --
def _segs(costs):
    return [Segment(epoch=i, action="exec", exec_cost_s=c)
            for i, c in enumerate(costs)]


def test_lpt_beats_contiguous_on_skew():
    segs = _segs([1, 1, 1, 1, 1, 1, 8, 8])
    cont = contiguous_shares(segs, 2)
    bal = balanced_shares(segs, 2)
    cont_wall = max(sum(s.cost for s in sh) for sh in cont)
    bal_wall = max(sum(s.cost for s in sh) for sh in bal)
    assert cont_wall == 18 and bal_wall == 11
    # shares stay in epoch order and partition the work exactly
    for shares in (cont, bal):
        assert sorted(s.epoch for sh in shares for s in sh) == list(range(8))
        for sh in shares:
            assert [s.epoch for s in sh] == sorted(s.epoch for s in sh)


def test_share_cost_accounts_init_restores(recorded):
    run, _ = recorded
    plan = build_plan(run, probed={"val"})
    lone = [plan.segment(5)]
    # strong init pays 5 restores before the exec visit
    assert share_cost(plan, lone) > plan.segment(5).cost
    weak = build_plan(run, probed={"val"}, init_mode="weak")
    assert share_cost(weak, [weak.segment(5)]) < share_cost(plan, lone)


def test_dynamic_executor_no_false_failure_under_contention():
    """Regression: an idle worker racing another worker's dequeue must not
    mistake the in-flight task for an exhausted one (pop and claim are
    atomic under the give-up check's lock)."""
    def run_task(task, attempt, cancelled):
        time.sleep(0.01 * (task.task_id % 3))
        return task.task_id

    tasks = [Task(task_id=i, visits=[], epochs=[i]) for i in range(12)]
    for _ in range(5):          # hammer the window a few times
        done = DynamicExecutor(tasks, run_task, nworkers=6).run()
        assert sorted(done) == list(range(12))
        assert all(done[t][0] == 1 for t in done)


def test_dynamic_executor_requeues_failures():
    attempts = []

    def run_task(task, attempt, cancelled):
        attempts.append((task.task_id, attempt))
        if task.task_id == 1 and attempt == 1:
            raise RuntimeError("flaky worker")
        return f"ok-{task.task_id}"

    tasks = [Task(task_id=i, visits=[], epochs=[i]) for i in range(3)]
    done = DynamicExecutor(tasks, run_task, nworkers=2).run()
    assert {tid: r for tid, (_a, r) in done.items()} == \
        {0: "ok-0", 1: "ok-1", 2: "ok-2"}
    assert done[1][0] == 2                     # second attempt won
    assert (1, 1) in attempts and (1, 2) in attempts


def test_dynamic_executor_permanent_failure_raises():
    def run_task(task, attempt, cancelled):
        raise RuntimeError("always broken")

    tasks = [Task(task_id=0, visits=[], epochs=[0])]
    ex = DynamicExecutor(tasks, run_task, nworkers=1, max_attempts=2)
    with pytest.raises(TaskFailure) as ei:
        ex.run()
    assert 0 in ei.value.errors and len(ei.value.errors[0]) == 2


def test_dynamic_executor_straggler_speculation():
    """A hung task is speculatively re-issued to an idle worker; the
    duplicate finishes first and wins, and the straggler is cancelled."""
    release = threading.Event()

    def run_task(task, attempt, cancelled):
        if task.task_id == 0 and attempt == 1:
            # straggler: hang until cancelled (or a generous timeout)
            cancelled.wait(timeout=20.0)
            release.set()
            return "straggler"
        return "fast"

    tasks = [Task(task_id=0, visits=[], epochs=[0], est_cost_s=0.01)]
    ex = DynamicExecutor(tasks, run_task, nworkers=2,
                         straggler_factor=2.0, max_attempts=2)
    t0 = time.monotonic()
    done = ex.run()
    assert done[0] == (2, "fast")
    assert release.is_set()                    # straggler was cancelled
    assert time.monotonic() - t0 < 15.0


def test_merge_drops_non_owner_rows(tmp_path):
    import json
    import os
    run = str(tmp_path)
    os.makedirs(os.path.join(run, "logs"))
    rows0 = [{"epoch": 0, "seq": 0, "key": "a", "value": 1},
             {"epoch": 1, "seq": 1, "key": "a", "value": 99}]   # init re-log
    rows1 = [{"epoch": 1, "seq": 0, "key": "a", "value": 2}]
    for pid, rows in ((0, rows0), (1, rows1)):
        with open(os.path.join(run, "logs", f"replay_p{pid}.jsonl"),
                  "w") as f:
            for r in rows:
                f.write(json.dumps(r) + "\n")
    merged = merge_replay_logs(run, [("replay_p0", [0]),
                                     ("replay_p1", [1])])
    assert merged == [{"epoch": 0, "seq": 0, "key": "a", "value": 1},
                      {"epoch": 1, "seq": 1, "key": "a", "value": 2}]
