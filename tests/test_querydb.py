"""The incremental query engine (repro.querydb): seal-hook maintenance off
the step path, watermark freshness (unsealed tails, replay rotation, flat
files), reindex catch-up, WAL reader-during-writer, and — the correctness
contract — bit-identical rows between the index and file-scan engines on
every query shape the surface supports."""
import json
import os
import threading

import numpy as np
import pytest

import repro.flor as flor
from repro.checkpoint.lineage import RunRegistry, registry_dirsig
from repro.core.query import _ancestors, log_records, pivot
from repro.logging import FingerprintLog
from repro.logging.segment import list_segments, segment_path
from repro.querydb import (FLAT_SEG, LogIndex, SegmentIndexer, ensure_index,
                           index_path, open_index, reindex)


def _state(x=0.0):
    return {"w": np.arange(6.0) + x, "b": np.zeros(3) + x}


def _record(run_dir, store, run_id, parent=None, epochs=2, **spec_kw):
    lineage = flor.LineageSpec(store_root=store, run_id=run_id,
                               parent_run=parent)
    with flor.Session(run_dir, record=flor.RecordSpec(adaptive=False,
                                                      **spec_kw),
                      lineage=lineage) as sess:
        with sess.checkpointing(state=_state()) as ckpt:
            for e in sess.loop("epochs", range(epochs)):
                for _ in sess.loop("train", range(2)):
                    ckpt.state = {k: v + 1.0 for k, v in ckpt.state.items()}
                sess.log("loss", 1.0 / (e + 1))
                sess.log("acc", e * 0.125)


def _assert_engines_agree(path, **kw):
    files = log_records(path, engine="files", **kw)
    auto = log_records(path, engine="auto", **kw)
    indexed = log_records(path, engine="index", **kw)
    assert auto == files
    assert indexed == files            # bit-identity: the contract
    return files


# ------------------------------------------------ live seal-hook feeder ----
def test_seal_hook_indexes_rolled_segments_not_tail(tmp_path):
    """Rolled (sealed) segments are ingested the moment they seal; the
    unsealed tail NEVER is — so mid-run queries fall back to the file scan
    and stay bit-identical, and close-time sealing makes the run fully
    index-served."""
    store = str(tmp_path / "store")
    run_dir = str(tmp_path / "run")
    registry = RunRegistry(store)
    registry.register("r1", run_dir=run_dir)
    # give the run dir a query-surface identity (pseudo-meta not needed:
    # the registry record carries run_dir)
    indexer = SegmentIndexer(store, "r1", "record", registry=registry)
    lp = os.path.join(run_dir, "logs", "record.jsonl")
    log = FingerprintLog(lp, async_log=True, store=None,
                         on_seal=indexer.on_seal, roll_bytes=256)
    for i in range(40):
        log.log(i // 10, "loss", float(i))
    log.drain()
    while len(LogIndex(store).stream_segments("r1", "record")) \
            >= len(list_segments(lp)):
        # keep logging until an UNSEALED tail segment exists on disk
        log.log(4, "loss", float(len(list_segments(lp)) * 1000))
        log.drain()                    # all rows durable, rolls done

    idx = LogIndex(store)
    segs_on_disk = list_segments(lp)
    marks = idx.stream_segments("r1", "record")
    assert marks, "rolled segments were not ingested by the seal hook"
    # the tail segment (still open for appends) must not be watermarked
    assert len(marks) < len(segs_on_disk)
    assert all(s["sealed"] for s in (
        dict(zip(("sealed",), row)) for row in idx.conn.execute(
            "SELECT sealed FROM segments WHERE run_id='r1'")))
    # mid-run: index can't cover the stream -> auto falls back, identical
    streams = [("record", lp)]
    assert not idx.covers("r1", streams)
    idx.close()
    mid = _kw_rows(store)
    assert [r["value"] for r in mid["files"][:40]] == \
        [float(i) for i in range(40)]
    assert mid["auto"] == mid["files"]
    with pytest.raises(RuntimeError):
        log_records(store, engine="index")

    log.close()                        # seals the tail -> hook ingests it
    indexer.finish(registry)
    idx = LogIndex(store)
    assert idx.covers("r1", streams)
    idx.close()
    _assert_engines_agree(store)


def _kw_rows(path):
    return {"files": log_records(path, engine="files"),
            "auto": log_records(path, engine="auto")}


def test_seal_hook_reports_overhead_and_degrades_silently(tmp_path):
    store = str(tmp_path / "store")
    seen = []
    indexer = SegmentIndexer(store, "r1", "record",
                             on_overhead=lambda s, b: seen.append((s, b)))
    seg_dir = str(tmp_path / "run" / "logs" / "record.jsonl")
    os.makedirs(seg_dir)
    p = segment_path(seg_dir, 0)
    with open(p, "w") as f:
        f.write(json.dumps({"epoch": 0, "seq": 0, "key": "k",
                            "value": 1}) + "\n")
    indexer.on_seal(p, 0, {})
    assert len(seen) == 1 and seen[0][0] >= 0
    # a failing ingest (missing file) kills the hook, silently
    indexer.on_seal(segment_path(seg_dir, 99), 99, {})
    assert indexer.dead
    indexer.on_seal(p, 0, {})          # dead hook: no-op, no raise
    indexer.finish()


# ------------------------------------------------ replay rotation ----------
def test_replay_reattempt_invalidates_stream(tmp_path):
    store = str(tmp_path / "store")
    run = str(tmp_path / "run")
    _record(run, store, "base", epochs=2)
    for attempt in range(2):           # two replay attempts, same pid
        with flor.Session(run, mode="replay") as sess:
            with sess.checkpointing(state=_state()) as ckpt:
                for e in sess.loop("epochs", range(2)):
                    for _ in sess.loop("train", range(2)):
                        pass
            sess.log("probe", attempt * 100)
    rows = _assert_engines_agree(store)
    probes = [r for r in rows if r["key"] == "probe"]
    # only the LAST attempt's row survives — rotation truncated the stream
    # and invalidation dropped the indexed rows of the previous attempt
    assert [r["value"] for r in probes] == [100]
    idx = LogIndex(store)
    vals = [json.loads(v) for (v,) in idx.conn.execute(
        "SELECT value_json FROM records WHERE key='probe'")]
    idx.close()
    assert vals == [100]


def test_invalidate_stream_drops_rows_and_watermarks(tmp_path):
    store = str(tmp_path / "store")
    idx = ensure_index(store)
    seg_dir = str(tmp_path / "s")
    os.makedirs(seg_dir)
    p = segment_path(seg_dir, 0)
    with open(p, "w") as f:
        f.write(json.dumps({"epoch": 0, "seq": 0, "key": "k",
                            "value": 1}) + "\n")
    idx.ingest_segment("r", "replay_p0", 0, p, sealed=True)
    assert idx.stream_segments("r", "replay_p0")
    idx.invalidate_stream("r", "replay_p0")
    assert idx.stream_segments("r", "replay_p0") == {}
    assert idx.conn.execute("SELECT COUNT(*) FROM records").fetchone()[0] == 0
    idx.close()


# ------------------------------------------------ reindex catch-up ---------
def test_reindex_catches_up_unindexed_runs_and_stale_tails(tmp_path):
    store = str(tmp_path / "store")
    # recorded with the live feeder OFF: no index exists at all
    _record(str(tmp_path / "a"), store, "base", log_index=False)
    _record(str(tmp_path / "b"), store, "ft1", parent="base",
            log_index=False)
    assert open_index(store) is None
    with pytest.raises(RuntimeError):
        log_records(store, engine="index")

    stats = reindex(store)
    assert stats["runs"] == 2 and stats["records"] > 0
    assert os.path.exists(index_path(store))
    _assert_engines_agree(store)
    _assert_engines_agree(store, lineage="ft1")

    # grow a stream past its watermark: covers() must refuse until the
    # next reindex re-ingests under the new size
    rd = str(tmp_path / "a")
    log = FingerprintLog(os.path.join(rd, "logs", "record.jsonl"))
    log.log(9, "late", 3.14)
    log.close()
    kw = _kw_rows(store)                           # auto fell back for base
    assert kw["auto"] == kw["files"]
    assert any(r["key"] == "late" for r in kw["auto"])
    with pytest.raises(RuntimeError):              # stale run: index refuses
        log_records(store, engine="index")
    again = reindex(store)
    assert again["segments_ingested"] >= 1
    assert any(r["key"] == "late"
               for r in log_records(store, engine="index"))

    # idempotent when nothing changed
    third = reindex(store)
    assert third["segments_ingested"] == 0 and third["segments_pruned"] == 0


def test_reindex_flat_file_and_torn_tail(tmp_path):
    """Flat (sync-mode) streams index as one size-watermarked pseudo-
    segment; a torn final line parses identically in both engines (shared
    parser)."""
    store = str(tmp_path / "store")
    run = str(tmp_path / "run")
    _record(run, store, "base", async_log=False, log_index=False)
    lp = os.path.join(run, "logs", "record.jsonl")
    assert os.path.isfile(lp)          # flat layout
    with open(lp, "a") as f:
        f.write('{"epoch": 7, "seq": 99, "key": "torn", "val')  # torn tail
    reindex(store)
    idx = LogIndex(store)
    assert FLAT_SEG in idx.stream_segments("base", "record")
    idx.close()
    rows = _assert_engines_agree(store)
    assert all(r["key"] != "torn" for r in rows)


def test_reindex_prunes_deleted_streams(tmp_path):
    store = str(tmp_path / "store")
    run = str(tmp_path / "run")
    _record(run, store, "base")
    with flor.Session(run, mode="replay") as sess:
        with sess.checkpointing(state=_state()) as ckpt:
            for e in sess.loop("epochs", range(2)):
                for _ in sess.loop("train", range(2)):
                    pass
        sess.log("probe", 1)
    # simulate a cleaned-up replay stream: delete it from disk
    logs = os.path.join(run, "logs")
    victims = [fn for fn in os.listdir(logs) if fn.startswith("replay_")]
    assert victims
    import shutil
    for fn in victims:
        p = os.path.join(logs, fn)
        shutil.rmtree(p) if os.path.isdir(p) else os.remove(p)
    stats = reindex(store)
    assert stats["segments_pruned"] >= 1
    _assert_engines_agree(store)


def test_reindex_legacy_pseudo_run_dir(tmp_path):
    """A bare pre-lineage run dir (no registry, no flor.run.json) queries as
    a pseudo-run; reindex makes even that index-servable, and the runs
    mirror is never trusted for it (its identity depends on the queried
    path)."""
    run = str(tmp_path / "legacy")
    os.makedirs(os.path.join(run, "logs"))
    with open(os.path.join(run, "logs", "record.jsonl"), "w") as f:
        for e in range(3):
            f.write(json.dumps({"epoch": e, "seq": e, "key": "loss",
                                "value": 0.5 * e}) + "\n")
    reindex(run)
    rows = _assert_engines_agree(run)
    assert len(rows) == 3
    assert pivot(run, "loss", engine="index") == \
        pivot(run, "loss", engine="files")


# ------------------------------------------------ freshness: runs mirror ---
def test_runs_mirror_staleness_on_new_registration(tmp_path):
    store = str(tmp_path / "store")
    _record(str(tmp_path / "a"), store, "base")
    sig = registry_dirsig(store)
    idx = LogIndex(store)
    assert idx.runs_listing(sig) is not None      # synced at session close
    idx.close()
    # register another run WITHOUT syncing the mirror: signature moves,
    # the mirror refuses, and the query (JSON fallback) still sees it
    RunRegistry(store).register("ghost", run_dir=str(tmp_path / "g"))
    idx = LogIndex(store)
    assert idx.runs_listing(registry_dirsig(store)) is None
    idx.close()
    assert any(r.get("run_id") == "ghost"
               for r in _runs_of(store))


def _runs_of(store):
    from repro.core.query import _open_engine, _runs_listing
    root, idx = _open_engine(store, "auto")
    try:
        listing, _ = _runs_listing(store, root, idx)
        return listing
    finally:
        if idx is not None:
            idx.close()


# ------------------------------------------------ lineage CTE --------------
def test_lineage_cte_matches_python_walk(tmp_path):
    store = str(tmp_path / "store")
    _record(str(tmp_path / "a"), store, "base")
    _record(str(tmp_path / "b"), store, "mid", parent="base")
    _record(str(tmp_path / "c"), store, "leaf", parent="mid")
    listing = RunRegistry(store).list_runs()
    idx = LogIndex(store)
    for rid in ("base", "mid", "leaf", "nosuch"):
        assert idx.ancestry_ids(rid) == _ancestors(listing, rid)
    idx.close()
    for rid in ("base", "mid", "leaf"):
        rows = _assert_engines_agree(store, lineage=rid)
        chain = {r["run_id"] for r in rows}
        assert chain == {"base", "mid", "leaf"} & _ancestors(listing, rid)
    # pivot over the chain
    assert pivot(store, "loss", lineage="mid", engine="index") == \
        pivot(store, "loss", lineage="mid", engine="files")


# ------------------------------------------------ filters ------------------
def test_where_limit_tail_equivalence(tmp_path):
    store = str(tmp_path / "store")
    _record(str(tmp_path / "a"), store, "base", epochs=3)
    _record(str(tmp_path / "b"), store, "ft1", parent="base", epochs=3)
    cases = [
        {},
        {"key": "loss"},
        {"key": ("loss", "acc")},
        {"where": {"key": "loss"}},
        {"where": {"epoch": 1}},
        {"where": {"epoch": 1, "key": "acc"}},
        {"where": {"source": "record"}},
        {"where": {"run_id": "ft1"}},
        {"where": {"value": 0.5}},               # post-filtered, both paths
        {"limit": 3},
        {"limit": 0},
        {"tail": 4},
        {"limit": 8, "tail": 2},
        {"where": {"key": "loss"}, "limit": 2},
        {"where": {"key": "loss"}, "tail": 2},
        {"run": "base", "where": {"epoch": 2}, "limit": 1},
    ]
    for kw in cases:
        _assert_engines_agree(store, **kw)
    # sanity on semantics, not just equality
    assert len(log_records(store, limit=3, engine="index")) == 3
    t = log_records(store, tail=2, engine="index")
    assert t == log_records(store, engine="index")[-2:]


# ------------------------------------------------ spill refs ---------------
def test_spill_refs_indexed_and_inlined_identically(tmp_path):
    store = str(tmp_path / "store")
    run = str(tmp_path / "run")
    lineage = flor.LineageSpec(store_root=store, run_id="base")
    with flor.Session(run, record=flor.RecordSpec(adaptive=False,
                                                  log_spill_bytes=64),
                      lineage=lineage) as sess:
        with sess.checkpointing(state=_state()) as ckpt:
            for e in sess.loop("epochs", range(2)):
                for _ in sess.loop("train", range(2)):
                    ckpt.state = {k: v + 1.0
                                  for k, v in ckpt.state.items()}
                sess.log("hist", np.arange(64.0) + e)   # 512B > 64B: spills
    idx = LogIndex(store)
    refs = idx.conn.execute(
        "SELECT spill_ref, spill_digest FROM records "
        "WHERE spill_ref IS NOT NULL").fetchall()
    idx.close()
    assert len(refs) == 2 and all(d for _, d in refs)
    # pointer rows identical across engines...
    rows = _assert_engines_agree(store, key="hist")
    assert all(isinstance(r["value"], dict) and "ref" in r["value"]
               for r in rows)
    # ...and resolved values identical too (store touched post-filter only)
    fi = log_records(store, key="hist", inline_spill_bytes=1 << 20,
                     engine="files")
    ix = log_records(store, key="hist", inline_spill_bytes=1 << 20,
                     engine="index")
    assert fi == ix
    assert fi[0]["value"] == list(np.arange(64.0))


# ------------------------------------------------ WAL concurrency ----------
def test_wal_reader_during_writer(tmp_path):
    """A query handle keeps answering while a writer ingests — WAL's one
    writer + N readers. The reader may see older or newer watermarks, never
    an error or a torn transaction."""
    store = str(tmp_path / "store")
    run_dir = str(tmp_path / "run")
    RunRegistry(store).register("r1", run_dir=run_dir)
    seg_dir = os.path.join(run_dir, "logs", "record.jsonl")
    os.makedirs(seg_dir)
    paths = []
    for n in range(30):
        p = segment_path(seg_dir, n)
        with open(p, "w") as f:
            for j in range(20):
                seq = n * 20 + j
                f.write(json.dumps({"epoch": n, "seq": seq, "key": "loss",
                                    "value": float(seq)}) + "\n")
            f.write(json.dumps({"__seal__": 1, "rows": 20,
                                "first_seq": n * 20,
                                "last_seq": n * 20 + 19}) + "\n")
        paths.append(p)
    writer = ensure_index(store)
    errors = []

    def _ingest():
        try:
            for n, p in enumerate(paths):
                writer.ingest_segment("r1", "record", n, p, sealed=True)
        except Exception as e:                    # pragma: no cover
            errors.append(e)

    t = threading.Thread(target=_ingest)
    t.start()
    try:
        for _ in range(50):
            rows = log_records(store)             # reader during writer
            vals = [r["value"] for r in rows]
            assert vals == [float(i) for i in range(len(vals))]
    finally:
        t.join()
        writer.close()
    assert not errors
    reindex(store)                                # runs mirror sync
    assert len(log_records(store, engine="index")) == 600


# ------------------------------------------------ crash safety -------------
def test_watermark_commits_with_rows_atomically(tmp_path):
    """Rows and watermark land in ONE transaction: after a simulated crash
    mid-ingest (rollback), neither is visible and the segment re-ingests
    cleanly."""
    store = str(tmp_path / "store")
    idx = ensure_index(store)
    seg_dir = str(tmp_path / "s")
    os.makedirs(seg_dir)
    p = segment_path(seg_dir, 0)
    with open(p, "w") as f:
        f.write(json.dumps({"epoch": 0, "seq": 0, "key": "k",
                            "value": 1}) + "\n")
    real_conn = idx.conn

    class _CrashAfterRows:
        """Delegate to the real connection, but die right after the row
        insert — between the rows and their watermark."""
        def __getattr__(self, name):
            return getattr(real_conn, name)

        def __enter__(self):
            return real_conn.__enter__()

        def __exit__(self, *exc):
            return real_conn.__exit__(*exc)

        def executemany(self, *a, **k):
            real_conn.executemany(*a, **k)
            raise RuntimeError("crash between rows and watermark")

    idx.conn = _CrashAfterRows()
    with pytest.raises(RuntimeError):
        idx.ingest_segment("r", "record", 0, p, sealed=True)
    idx.conn = real_conn
    assert idx.stream_segments("r", "record") == {}
    assert idx.conn.execute("SELECT COUNT(*) FROM records").fetchone()[0] == 0
    n = idx.ingest_segment("r", "record", 0, p, sealed=True)
    assert n == 1 and idx.stream_segments("r", "record")
    idx.close()


def test_future_schema_degrades_to_file_scan(tmp_path):
    store = str(tmp_path / "store")
    _record(str(tmp_path / "a"), store, "base")
    idx = LogIndex(store)
    with idx.conn:
        idx.conn.execute("UPDATE meta SET v='999' WHERE k='schema_version'")
    idx.close()
    assert open_index(store) is None
    rows = log_records(store)                     # auto: silent fallback
    assert rows == log_records(store, engine="files")
    with pytest.raises(RuntimeError):
        log_records(store, engine="index")


# ------------------------------------------------ existing fixture shapes --
def test_bit_identity_on_lineage_fixture(tmp_path):
    """The exact store shape of test_session_api's lineage fixture
    (warm-started derived run) answers identically from both engines."""
    store = str(tmp_path / "store")
    _record(str(tmp_path / "base"), store, "base")
    with flor.Session(str(tmp_path / "ft1"), mode="record",
                      record=flor.RecordSpec(adaptive=False),
                      lineage=flor.LineageSpec(store_root=store,
                                               run_id="ft1",
                                               parent_run="base")) as sess:
        start = sess.warm_start("train", like={"state": _state()})
        with sess.checkpointing(state=start["state"]) as ckpt:
            for e in sess.loop("epochs", range(2)):
                for _ in sess.loop("train", range(3)):
                    ckpt.state = {k: v + 1.0
                                  for k, v in ckpt.state.items()}
                sess.log("loss", float(ckpt.state["w"][0]))
    _assert_engines_agree(store)
    assert pivot(store, "loss", engine="index") == \
        pivot(store, "loss", engine="files")
    # a run DIR resolves through its binding on both engines
    assert pivot(str(tmp_path / "ft1"), "loss", engine="index") == \
        pivot(str(tmp_path / "ft1"), "loss", engine="files")


def test_bit_identity_on_private_store(tmp_path):
    """A session with no shared store (private <run_dir>/store) still gets
    a live-maintained index beside its private store."""
    run = str(tmp_path / "run")
    with flor.Session(run, record=flor.RecordSpec(adaptive=False)) as sess:
        with sess.checkpointing(state=_state()) as ckpt:
            for e in sess.loop("epochs", range(3)):
                for _ in sess.loop("train", range(2)):
                    ckpt.state = {k: v + 1.0 for k, v in ckpt.state.items()}
                sess.log("loss", float(e))
    assert os.path.exists(index_path(os.path.join(run, "store")))
    _assert_engines_agree(run)
    assert pivot(run, "loss", engine="index") == \
        pivot(run, "loss", engine="files")


# --------------------------------------- multi-process store concurrency ----
REC_CHILD = """
import os, sys
import numpy as np
import repro.flor as flor
store, run_dir, run_id, epochs = (sys.argv[1], sys.argv[2], sys.argv[3],
                                  int(sys.argv[4]))
with flor.Session(run_dir, record=flor.RecordSpec(adaptive=False),
                  lineage=flor.LineageSpec(store_root=store,
                                           run_id=run_id)) as sess:
    state = {"w": np.arange(6.0), "b": np.zeros(3)}
    with sess.checkpointing(state=state) as ckpt:
        for e in sess.loop("epochs", range(epochs)):
            for _ in sess.loop("train", range(2)):
                ckpt.state = {k: v + 1.0 for k, v in ckpt.state.items()}
            sess.log("loss", 1.0 / (e + 1))
            sess.log("acc", e * 0.125)
print("REC_OK", run_id)
"""

QUERY_CHILD = """
import os, sqlite3, sys, time
from repro.core.query import log_records, pivot
from repro.querydb import index_path
store, stopfile = sys.argv[1], sys.argv[2]
n = 0
while not os.path.exists(stopfile):
    rows = log_records(store, engine="auto")
    pivot(store, "loss", engine="auto")
    ip = index_path(store)
    if os.path.exists(ip):
        # WAL must stay structurally sound under two concurrent writers
        conn = sqlite3.connect(ip, timeout=30.0)
        try:
            ok, = conn.execute("PRAGMA integrity_check").fetchone()
            assert ok == "ok", ok
        finally:
            conn.close()
    n += 1
    time.sleep(0.02)
print("QUERY_OK", n)
"""


@pytest.mark.slow
def test_concurrent_recorders_with_live_reader(tmp_path):
    """Two REAL processes record into one store root while a third queries
    the whole time: the shared WAL index never corrupts, no query ever
    fails, and after catch-up both engines agree bit-identically and the
    index covers both runs."""
    import subprocess
    import sys as _sys
    store = str(tmp_path / "store")
    stop = str(tmp_path / "stop")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    q = subprocess.Popen([_sys.executable, "-c", QUERY_CHILD, store, stop],
                         env=env, stdout=subprocess.PIPE,
                         stderr=subprocess.STDOUT, text=True)
    recs = [subprocess.Popen(
                [_sys.executable, "-c", REC_CHILD, store,
                 str(tmp_path / f"run{i}"), f"c{i}", "8"],
                env=env, stdout=subprocess.PIPE,
                stderr=subprocess.STDOUT, text=True)
            for i in (0, 1)]
    outs = [(p.wait(), p.stdout.read()) for p in recs]
    with open(stop, "w") as f:
        f.write("done")
    qrc, qout = q.wait(timeout=120), q.stdout.read()
    assert [rc for rc, _ in outs] == [0, 0], outs
    assert qrc == 0 and "QUERY_OK" in qout, qout
    reindex(store)
    files = _assert_engines_agree(store)
    assert len([r for r in files if r.get("key") == "loss"]) == 16
    assert pivot(store, "loss", engine="index") == \
        pivot(store, "loss", engine="files")
    idx = open_index(store)
    from repro.core.query import _registered_runs, _run_log_files
    for rec in _registered_runs(store):
        assert idx.covers(rec["run_id"],
                          _run_log_files(rec["run_dir"],
                                         include_replay=True)), rec
    idx.close()


def test_staging_absorb_engine_identical(tmp_path):
    """Rows routed through a per-process staging db and absorbed into the
    main index (the multi-process merge path) serve bit-identically to
    rows ingested directly — and a finalized main runs row survives a
    stale 'running' staging row."""
    from repro.logging.segment import _seal_of
    from repro.querydb.index import staging_path
    from repro.querydb.maintain import sweep_staging
    store = str(tmp_path / "store")
    runA = str(tmp_path / "runA")
    _record(runA, store, "rA", epochs=3)
    ref = _assert_engines_agree(store)

    # drop rA's directly-ingested rows, rebuild them via staging + absorb
    idx = open_index(store)
    idx.invalidate_stream("rA", "record")
    assert idx.conn.execute("SELECT count(*) FROM records "
                            "WHERE run_id='rA'").fetchone()[0] == 0
    reg_rec = RunRegistry(store).get("rA")
    assert reg_rec["status"] == "finished"
    stg = LogIndex(store, create=True, db_path=staging_path(store, 5))
    stg.upsert_run({**reg_rec, "status": "running"})   # stale staging row
    for n, seg_path in list_segments(os.path.join(runA, "logs",
                                                  "record.jsonl")):
        stg.ingest_segment("rA", "record", n, seg_path,
                           sealed=_seal_of(seg_path) is not None)
    stg.close()
    assert sweep_staging(store, idx) == 1
    assert not os.path.exists(staging_path(store, 5))
    # absorbed rows are engine-identical to the direct ingest
    assert _assert_engines_agree(store) == ref
    # the finalized main mirror won the runs-row merge
    status, = idx.conn.execute("SELECT status FROM runs WHERE "
                               "run_id='rA'").fetchone()
    assert status == "finished"
    idx.close()


def test_sweep_staging_skips_live_recorder(tmp_path):
    """A staging db whose .alive marker names a running pid is an
    IN-FLIGHT recorder's database: sweeping (deleting) it would orphan
    every row that recorder seals afterwards, so the sweep must leave it
    for the owner's finish()-time merge. A marker naming a dead pid is a
    crash leftover and is swept; the marker goes with it."""
    import json as _json
    import subprocess
    import sys
    from repro.querydb.index import ensure_index, staging_path
    from repro.querydb.maintain import _write_alive_marker, sweep_staging
    store = str(tmp_path / "store")
    # live: marked with THIS process's pid
    live_sp = staging_path(store, 1)
    LogIndex(store, create=True, db_path=live_sp).close()
    _write_alive_marker(live_sp)
    # dead: marker rewritten with the pid of a child that already exited
    dead_sp = staging_path(store, 2)
    LogIndex(store, create=True, db_path=dead_sp).close()
    _write_alive_marker(dead_sp)
    proc = subprocess.Popen([sys.executable, "-c", "pass"])
    proc.wait()
    with open(dead_sp + ".alive") as f:
        mark = _json.load(f)
    mark["pid"] = proc.pid
    with open(dead_sp + ".alive", "w") as f:
        _json.dump(mark, f)
    idx = ensure_index(store)
    try:
        assert sweep_staging(store, idx) == 1
        assert os.path.exists(live_sp)          # live db untouched
        assert os.path.exists(live_sp + ".alive")
        assert not os.path.exists(dead_sp)      # leftover absorbed+removed
        assert not os.path.exists(dead_sp + ".alive")
    finally:
        idx.close()
