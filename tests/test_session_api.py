"""Session-first API: typed specs, Session lifecycle (nested/sequential,
legacy-shim equivalence), flor.loop skip/exec parity with the old
generator+skipblock protocol, flor.arg record->replay round-trips, the
cross-run log query surface, and the satellite fixes (fingerprint-log seq
continuity, replay-log rotation, calibration reuse, init() failure
atomicity)."""
import os
import warnings

import numpy as np
import pytest

import repro.flor as flor
from repro.core.context import FingerprintLog, FlorDeprecationWarning
from repro.core import context as ctx_mod


def _state(x=0.0):
    return {"w": np.arange(6.0) + x, "b": np.zeros(3) + x}


def _step(s):
    return {k: v + 1.0 for k, v in s.items()}


def _leaves_equal(a, b):
    import jax
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        if not np.array_equal(np.asarray(x), np.asarray(y)):
            return False
    return True


def _legacy_record(run, epochs=4, steps=3):
    """A run recorded entirely on the OLD surface (shims)."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", FlorDeprecationWarning)
        flor.init(run, mode="record", adaptive=False)
        s = _state()
        for e in flor.generator(range(epochs)):
            if flor.skipblock.step_into("train"):
                for _ in range(steps):
                    s = _step(s)
                flor.log("loss", float(s["w"][0]))
            s = flor.skipblock.end("train", s)
        flor.finish()
    return s


def _session_record(run, epochs=4, steps=3, **session_kw):
    with flor.Session(run, mode="record",
                      record=flor.RecordSpec(adaptive=False),
                      **session_kw) as sess:
        with sess.checkpointing(state=_state()) as ckpt:
            for e in sess.loop("epochs", range(epochs)):
                for _ in sess.loop("train", range(steps)):
                    ckpt.state = _step(ckpt.state)
                sess.log("loss", float(ckpt.state["w"][0]))
        return ckpt.state


# ------------------------------------------------------------ specs ---------
def test_specs_validate():
    with pytest.raises(ValueError):
        flor.RecordSpec(epsilon=0.0)
    with pytest.raises(ValueError):
        flor.ReplaySpec(init_mode="eager")
    with pytest.raises(ValueError):
        flor.ReplaySpec(pid=2, nworkers=2)
    with pytest.raises(ValueError):
        flor.LineageSpec(parent_run="base")      # needs a shared store
    with pytest.raises(ValueError):
        # run_id alone is not enough: the parent can't live in a private
        # per-run store either
        flor.LineageSpec(parent_run="base", run_id="ft1")
    assert flor.ReplaySpec(probed={"a"}).probed == frozenset({"a"})


def test_session_rejects_mismatched_spec(tmp_path):
    with pytest.raises(ValueError):
        flor.Session(str(tmp_path / "r"), mode="record",
                     replay=flor.ReplaySpec())
    with pytest.raises(ValueError):
        flor.Session(str(tmp_path / "r"), mode="replay",
                     record=flor.RecordSpec())
    with pytest.raises(TypeError):
        from repro.core.session import specs_from_kwargs
        specs_from_kwargs("record", {"bogus_knob": 1})


# ------------------------------------------------- session lifecycle --------
def test_sequential_and_nested_sessions(tmp_path):
    r1, r2 = str(tmp_path / "r1"), str(tmp_path / "r2")
    with flor.Session(r1, record=flor.RecordSpec(adaptive=False)) as s1:
        assert flor.get_context() is s1.ctx
        with flor.Session(r2, record=flor.RecordSpec(adaptive=False)) as s2:
            # innermost session is the ambient context; the outer one is
            # still addressable explicitly
            assert flor.get_context() is s2.ctx
            assert s1.ctx is not s2.ctx
        assert flor.get_context() is s1.ctx
    with pytest.raises(RuntimeError):
        flor.get_context()
    # sequential reuse: a fresh session on the same dir is a resume
    with flor.Session(r1, record=flor.RecordSpec(adaptive=False)) as s3:
        assert flor.get_context() is s3.ctx


def test_session_failure_marks_registry(tmp_path):
    run = str(tmp_path / "run")
    with pytest.raises(RuntimeError, match="boom"):
        with flor.Session(run, record=flor.RecordSpec(adaptive=False)) as s:
            rid = s.run_id
            raise RuntimeError("boom")
    from repro.checkpoint import RunRegistry
    rec = RunRegistry(os.path.join(run, "store")).get(rid)
    assert rec["status"] == "failed"
    with pytest.raises(RuntimeError):
        flor.get_context()                       # unbound despite the raise


def test_shim_equivalence_with_session(tmp_path):
    """The legacy protocol and the session surface record interchangeable
    runs: each replays the other's record dir bit-identically."""
    legacy_run = str(tmp_path / "legacy")
    sess_run = str(tmp_path / "sess")
    final_legacy = _legacy_record(legacy_run)
    final_sess = _session_record(sess_run)
    assert _leaves_equal(final_legacy, {"state": final_sess}["state"])

    # session replay over the LEGACY record dir: every epoch skips
    with flor.Session(legacy_run, mode="replay") as sess:
        with sess.checkpointing(state=_state()) as ckpt:
            for e in sess.loop("epochs", range(4)):
                for _ in sess.loop("train", range(3)):
                    raise AssertionError("must skip")
    assert _leaves_equal(ckpt.state, final_legacy)

    # legacy replay over the SESSION record dir
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", FlorDeprecationWarning)
        flor.init(sess_run, mode="replay")
        s = {"state": _state()}
        for e in flor.generator(range(4)):
            if flor.skipblock.step_into("train"):
                raise AssertionError("must skip")
            s = flor.skipblock.end("train", s)
        flor.finish()
    assert _leaves_equal(s["state"], final_sess)


# -------------------------------------------------- loop semantics ----------
@pytest.mark.parametrize("probed", [frozenset(), frozenset({"train"})])
def test_loop_skip_exec_parity_on_legacy_record(tmp_path, probed):
    """flor.loop replay (both phases) over an OLD-API record dir matches the
    record run exactly; probed blocks re-execute and fingerprints agree."""
    run = str(tmp_path / "run")
    final = _legacy_record(run)
    with flor.Session(run, mode="replay",
                      replay=flor.ReplaySpec(probed=probed)) as sess:
        with sess.checkpointing(state=_state()) as ckpt:
            for e in sess.loop("epochs", range(4)):
                ran = 0
                for _ in sess.loop("train", range(3)):
                    ckpt.state = _step(ckpt.state)
                    ran += 1
                assert sess.executed("train") == bool(probed)
                assert ran == (3 if probed else 0)
                if sess.executed("train"):
                    sess.log("loss", float(ckpt.state["w"][0]))
    assert _leaves_equal(ckpt.state, final)
    if probed:
        rec, reps = flor.run_logs(run)
        res = flor.deferred_check(rec, reps)
        assert res.ok and res.compared == 4


def test_executed_state_is_per_context(tmp_path):
    """sess.executed() must reflect THIS session's blocks, not a sibling's."""
    r1, r2 = str(tmp_path / "r1"), str(tmp_path / "r2")
    with flor.Session(r1, record=flor.RecordSpec(adaptive=False)) as s1:
        with s1.checkpointing(state=_state()) as ckpt:
            for e in s1.loop("epochs", range(1)):
                for _ in s1.loop("train", range(2)):
                    ckpt.state = _step(ckpt.state)
        assert s1.executed("train")
    with flor.Session(r2, record=flor.RecordSpec(adaptive=False)) as s2:
        assert not s2.executed("train")           # fresh context: no leak


def test_loop_without_scope_is_probe_loop(tmp_path):
    """A nested loop with no checkpointing scope always executes (nothing
    declared to restore) — on record AND on replay."""
    run = str(tmp_path / "run")
    with flor.Session(run, record=flor.RecordSpec(adaptive=False)):
        for e in flor.loop("epochs", range(2)):
            n = sum(1 for _ in flor.loop("probe", range(5)))
            assert n == 5 and flor.executed("probe")
    with flor.Session(run, mode="replay"):
        for e in flor.loop("epochs", range(2)):
            n = sum(1 for _ in flor.loop("probe", range(5)))
            assert n == 5


def test_loop_early_exit_aborts_block(tmp_path):
    """break out of an inner loop -> no checkpoint for that occurrence, so
    replay re-executes the block logically instead of restoring garbage."""
    run = str(tmp_path / "run")
    with flor.Session(run, record=flor.RecordSpec(adaptive=False)) as sess:
        store = sess.ctx.store
        with sess.checkpointing(state=_state()) as ckpt:
            with pytest.warns(UserWarning, match="exited early"):
                for e in sess.loop("epochs", range(2)):
                    for i in sess.loop("train", range(3)):
                        ckpt.state = _step(ckpt.state)
                        if e == 0 and i == 1:
                            break                 # partial epoch 0
        final = ckpt.state
    assert not store.has("train@0.0")             # aborted: nothing memoized
    assert store.has("train@1.0")
    with flor.Session(run, mode="replay") as sess:
        with sess.checkpointing(state=_state()) as ckpt:
            for e in sess.loop("epochs", range(2)):
                ran = 0
                for i in sess.loop("train", range(3)):
                    ckpt.state = _step(ckpt.state)
                    ran += 1
                    if e == 0 and i == 1:
                        break
                # epoch 0 re-executes (no ckpt), epoch 1 restores
                assert ran == (2 if e == 0 else 0)
    assert _leaves_equal(ckpt.state, final)


def test_callable_iterable_not_built_on_skip(tmp_path):
    run = str(tmp_path / "run")
    built = []

    def make_loader():
        built.append(1)
        return range(2)

    with flor.Session(run, record=flor.RecordSpec(adaptive=False)) as sess:
        with sess.checkpointing(state=_state()) as ckpt:
            for e in sess.loop("epochs", range(2)):
                for _ in sess.loop("train", make_loader):
                    ckpt.state = _step(ckpt.state)
    assert len(built) == 2
    built.clear()
    with flor.Session(run, mode="replay") as sess:
        with sess.checkpointing(state=_state()) as ckpt:
            for e in sess.loop("epochs", range(2)):
                for _ in sess.loop("train", make_loader):
                    pass
    assert built == []                            # skipped: never constructed


# ----------------------------------------------------- flor.arg -------------
def test_arg_record_replay_roundtrip(tmp_path, monkeypatch):
    run = str(tmp_path / "run")
    monkeypatch.setenv("FLOR_ARGS", "lr=0.5,epochs=7,tag=exp1")
    with flor.Session(run, record=flor.RecordSpec(adaptive=False)) as sess:
        assert sess.arg("lr", 1e-3) == 0.5        # override, float-coerced
        assert sess.arg("epochs", 3) == 7         # override, int-coerced
        assert sess.arg("tag", "base") == "exp1"
        assert sess.arg("beta", 0.9) == 0.9       # code default recorded
    monkeypatch.delenv("FLOR_ARGS")
    with flor.Session(run, mode="replay") as sess:
        # replay returns RECORDED values regardless of new code defaults
        assert sess.arg("lr", 123.0) == 0.5
        assert sess.arg("epochs", 999) == 7
        assert sess.arg("tag", "other") == "exp1"
        assert sess.arg("beta", 0.1) == 0.9
        assert sess.arg("never_recorded", 42) == 42


# ------------------------------------------------- query surface ------------
def test_log_records_and_pivot_across_lineage(tmp_path):
    store = str(tmp_path / "store")
    _session_record(str(tmp_path / "base"), epochs=2,
                    lineage=flor.LineageSpec(store_root=store, run_id="base"))
    with flor.Session(str(tmp_path / "ft1"), mode="record",
                      record=flor.RecordSpec(adaptive=False),
                      lineage=flor.LineageSpec(store_root=store, run_id="ft1",
                                               parent_run="base")) as sess:
        start = sess.warm_start("train", like={"state": _state()})
        with sess.checkpointing(state=start["state"]) as ckpt:
            for e in sess.loop("epochs", range(2)):
                for _ in sess.loop("train", range(3)):
                    ckpt.state = _step(ckpt.state)
                sess.log("loss", float(ckpt.state["w"][0]))

    rows = flor.log_records(store)
    by_run = {}
    for r in rows:
        by_run.setdefault(r["run_id"], []).append(r)
    assert set(by_run) == {"base", "ft1"}
    assert all(r["parent_run"] is None for r in by_run["base"])
    assert all(r["parent_run"] == "base" for r in by_run["ft1"])
    assert {r["key"] for r in rows} == {"loss"}

    piv = flor.pivot(store, "loss")
    assert len(piv) == 4                          # 2 runs x 2 epochs
    assert [(p["run_id"], p["epoch"]) for p in piv] == \
        [("base", 0), ("base", 1), ("ft1", 0), ("ft1", 1)]
    # ft1 warm-started from base's final state: losses continue the curve
    assert piv[2]["loss"] > piv[1]["loss"]
    # a run DIR also resolves (follows flor.run.json to the shared store)
    assert len(flor.pivot(str(tmp_path / "ft1"), "loss")) == 4
    # filters
    assert all(r["run_id"] == "ft1" for r in flor.log_records(store, run="ft1"))


def test_pivot_on_legacy_private_store(tmp_path):
    run = str(tmp_path / "run")
    _legacy_record(run, epochs=3)
    piv = flor.pivot(run, "loss")
    assert len(piv) == 3 and all("loss" in p for p in piv)


# ------------------------------------------------- satellite fixes ----------
def test_fingerprint_log_resumes_seq(tmp_path):
    p = str(tmp_path / "logs" / "record.jsonl")
    log = FingerprintLog(p)
    log.log(0, "a", 1)
    log.log(0, "b", 2)
    log.close()
    log = FingerprintLog(p)                       # record resume: continue
    log.log(1, "a", 3)
    log.close()
    seqs = [r["seq"] for r in FingerprintLog.read(p)]
    assert seqs == [0, 1, 2]                      # no duplicate seq values

    fresh = FingerprintLog(p, fresh=True)         # replay attempt: rotate
    fresh.log(0, "a", 9)
    fresh.close()
    recs = FingerprintLog.read(p)
    assert len(recs) == 1 and recs[0]["seq"] == 0


def test_replay_attempts_rotate_log(tmp_path):
    run = str(tmp_path / "run")
    _legacy_record(run, epochs=2)
    for _ in range(2):                            # two replay attempts
        with flor.Session(run, mode="replay",
                          replay=flor.ReplaySpec(probed=frozenset({"train"}))) \
                as sess:
            with sess.checkpointing(state=_state()) as ckpt:
                for e in sess.loop("epochs", range(2)):
                    for _ in sess.loop("train", range(3)):
                        ckpt.state = _step(ckpt.state)
                    sess.log("loss", float(ckpt.state["w"][0]))
    rec, reps = flor.run_logs(run)
    res = flor.deferred_check(rec, reps)
    assert res.ok, res.anomalies                  # second attempt replaced,
    assert res.compared == 2                      # not appended to, the first


def test_calibration_probe_skipped_on_resume(tmp_path):
    run = str(tmp_path / "run")
    calls = []
    orig = ctx_mod.FlorContext._calibrate_store

    def counting(self):
        calls.append(1)
        return orig(self)

    ctx_mod.FlorContext._calibrate_store = counting
    try:
        with flor.Session(run, record=flor.RecordSpec()) as s1:
            bps = s1.ctx.controller.write_bps
        assert calls == [1]                       # fresh store: one probe
        with flor.Session(run, record=flor.RecordSpec()) as s2:
            assert s2.ctx.controller.write_bps == bps
        assert calls == [1]                       # resume: probe skipped
    finally:
        ctx_mod.FlorContext._calibrate_store = orig


def test_init_failure_leaves_no_closed_context(tmp_path):
    """Satellite: a failing re-init must not leave the FINISHED old context
    bound — get_context() should say 'no context', not hand out a corpse."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", FlorDeprecationWarning)
        flor.init(str(tmp_path / "ok"), mode="record", adaptive=False)
        with pytest.raises(Exception):
            flor.init(str(tmp_path / "bad"), mode="neither")   # bad mode
        with pytest.raises(RuntimeError, match="no active Flor context"):
            flor.get_context()
        flor.finish()                             # idempotent no-op


def test_strict_deprecations_raise(tmp_path, monkeypatch):
    monkeypatch.setenv("FLOR_STRICT_DEPRECATIONS", "1")
    with pytest.raises(FlorDeprecationWarning):
        flor.init(str(tmp_path / "run"), mode="record", adaptive=False)
