"""Config registry: published param counts, smoke instantiation, cell skips."""
import pytest

import repro.configs as C

PUBLISHED = {
    # arch: (total params, active params), tolerance 5%
    "granite-3-2b": (2.5e9, 2.5e9),
    "minitron-4b": (4.2e9, 4.2e9),
    "gemma-2b": (2.5e9, 2.5e9),
    "qwen3-14b": (14.8e9, 14.8e9),
    "falcon-mamba-7b": (7.3e9, 7.3e9),
    "deepseek-v3-671b": (671e9, 37e9),
    "mixtral-8x7b": (46.7e9, 12.9e9),
    "seamless-m4t-large-v2": (1.6e9, 1.6e9),
    "llava-next-mistral-7b": (7.2e9, 7.2e9),
}


def test_registry_complete():
    assert len(C.ARCHS) == 10
    for a in C.ARCHS:
        cfg = C.get(a)
        sm = C.get_smoke(a)
        assert cfg.family == sm.family
        assert sm.param_count() < 5e6, f"{a} smoke too large"


@pytest.mark.parametrize("arch", sorted(PUBLISHED))
def test_param_counts_match_published(arch):
    total, active = PUBLISHED[arch]
    cfg = C.get(arch)
    assert abs(cfg.param_count() - total) / total < 0.06, cfg.param_count()
    assert abs(cfg.active_param_count() - active) / active < 0.06


def test_zamba2_param_count_documented_divergence():
    # assignment specifies a single shared attention block; real Zamba2-7B
    # (two alternating shared blocks + per-invocation LoRA) is ~7.4B. Our
    # config follows the assignment -> ~5.7B (DESIGN.md section 5 note).
    cfg = C.get("zamba2-7b")
    assert 5.0e9 < cfg.param_count() < 6.5e9


def test_shapes_table():
    assert set(C.SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                             "long_500k"}
    assert C.SHAPES["train_4k"].kind == "train"
    assert C.SHAPES["long_500k"].kind == "decode"


def test_long_context_applicability():
    ok, _ = C.cell_applicable("falcon-mamba-7b", "long_500k")
    assert ok
    ok, why = C.cell_applicable("qwen3-14b", "long_500k")
    assert not ok and "full-attention" in why
    # 40-cell accounting: 10 archs x 4 shapes, 7 documented long_500k skips
    cells = [(a, s) for a in C.ARCHS for s in C.SHAPES]
    runnable = [c for c in cells if C.cell_applicable(*c)[0]]
    assert len(cells) == 40
    assert len(runnable) == 33
