"""Optimizer / schedule / loss behaviour."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.data import synthetic_batch
from repro.train.optimizer import adamw, clip_by_global_norm, global_norm
from repro.train.schedule import warmup_cosine
from repro.train.step import build_train_step


def test_adamw_matches_numpy_reference():
    sched = lambda step: jnp.asarray(0.1, jnp.float32)
    init, update = adamw(sched, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0)
    p = {"w": jnp.asarray([[1.0, -2.0]])}
    g = {"w": jnp.asarray([[0.5, 0.5]])}
    st = init(p)
    p1, st1 = update(g, st, p, 0)
    # numpy reference
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    expect = 1.0 - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(float(p1["w"][0, 0]), expect, rtol=1e-6)


def test_weight_decay_only_on_matrices():
    sched = lambda step: jnp.asarray(0.1, jnp.float32)
    init, update = adamw(sched, weight_decay=0.5)
    p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    g = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
    st = init(p)
    p1, _ = update(g, st, p, 0)
    assert float(p1["w"][0, 0]) < 1.0          # decayed
    np.testing.assert_allclose(np.asarray(p1["b"]), 1.0)   # not decayed


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(gn), 20.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)


def test_schedule_shape():
    s = warmup_cosine(1e-3, warmup_steps=10, total_steps=100)
    assert float(s(0)) > 0
    assert float(s(9)) <= 1e-3 + 1e-9
    np.testing.assert_allclose(float(s(10)), 1e-3, rtol=1e-2)
    assert float(s(99)) < float(s(50)) < float(s(10))
    assert float(s(1000)) >= 1e-4 - 1e-9       # final_frac floor


def test_loss_decreases_over_training():
    cfg = C.get_smoke("florbench-100m")
    init_state, train_step = build_train_step(cfg, peak_lr=3e-3, warmup=5)
    ts = jax.jit(train_step)
    state = jax.jit(init_state)(jax.random.PRNGKey(0))
    first = last = None
    for i in range(30):
        state, m = ts(state, synthetic_batch(cfg, 4, 64, i))
        if i < 3:
            first = float(m["loss"]) if first is None else first
        last = float(m["loss"])
    assert last < first - 0.3, (first, last)


def test_loss_chunking_invariance():
    cfg = C.get_smoke("florbench-100m").replace(dtype="float32",
                                                param_dtype="float32")
    from repro.models import build_model
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b = synthetic_batch(cfg, 2, 64, 0)
    l1, _ = jax.jit(build_model(cfg.replace(loss_chunk=0)).loss)(params, b)
    l2, _ = jax.jit(build_model(cfg.replace(loss_chunk=16)).loss)(params, b)
    assert abs(float(l1) - float(l2)) < 1e-5


def test_synthetic_data_deterministic_and_seekable():
    cfg = C.get_smoke("florbench-100m")
    a = synthetic_batch(cfg, 4, 32, step=7, seed=1)
    b = synthetic_batch(cfg, 4, 32, step=7, seed=1)
    c = synthetic_batch(cfg, 4, 32, step=8, seed=1)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].max() < cfg.vocab_size
