"""Mesh-sharded record-replay: v4 manifests, resharding math, host-aware
planning/scheduling. In-process tests run on the default 1-device CPU; the
cross-mesh cases run in subprocesses with 8 forced host-platform devices
(conftest strips XLA_FLAGS from THIS process)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.checkpoint.mesh import box_intersect, chunk_range
from repro.parallel.sharding import respec, spec_entries
from repro.replay import DynamicExecutor, Task, assign_hosts


# ------------------------------------------------------- pure-unit helpers --
def test_box_intersect():
    assert box_intersect([[0, 4], [0, 8]], [[2, 6], [4, 12]]) \
        == [[2, 4], [4, 8]]
    assert box_intersect([[0, 4]], [[4, 8]]) is None
    # scalars: full (empty-box) overlap, not None
    assert box_intersect([], []) == []


def test_chunk_range_envelope():
    # local 4x8 f32 leaf, 2 rows per 64-byte chunk -> 2 chunks
    lo, hi = chunk_range([[0, 4], [0, 8]], [[1, 2], [0, 8]], 4, 64, 2)
    assert (lo, hi) == (0, 1)
    lo, hi = chunk_range([[0, 4], [0, 8]], [[0, 4], [0, 8]], 4, 64, 2)
    assert (lo, hi) == (0, 2)


def test_spec_entries_json_form():
    from jax.sharding import PartitionSpec as P
    assert spec_entries(P("data", ("data", "model"), None)) \
        == ["data", ["data", "model"], None]
    assert spec_entries(None) is None


def test_respec_resolves_and_falls_back():
    import jax
    from jax.sharding import PartitionSpec as P
    mesh = jax.sharding.AbstractMesh((("data", 2), ("model", 4)))
    # recorded spec re-resolves verbatim when divisible
    assert respec(["data", "model"], (8, 8), mesh) == P("data", "model")
    # non-divisible dim drops the offending axis (replicates)
    assert respec(["data", "model"], (8, 6), mesh) == P("data", None)
    # axis missing from the target mesh is filtered out
    assert respec(["pod", "model"], (8, 8), mesh) == P(None, "model")
    # an axis never shards two dims
    sp = respec(["data", "data"], (8, 8), mesh)
    assert sp == P("data", None)


# -------------------------------------------------- host-aware scheduling --
def test_assign_hosts_lpt_balances():
    tasks = [Task(task_id=i, visits=[], est_cost_s=c)
             for i, c in enumerate([10.0, 9.0, 8.0, 2.0, 1.0])]
    assign_hosts(tasks, 2)
    loads = {0: 0.0, 1: 0.0}
    for t in tasks:
        loads[t.host] += t.est_cost_s
    # LPT keeps the spread under one task's cost; heaviest goes first
    assert abs(loads[0] - loads[1]) <= 10.0
    assert {t.host for t in tasks} == {0, 1}
    assert tasks[0].host != tasks[1].host   # two heaviest split


def test_executor_per_host_queues_complete_and_steal():
    ran = []
    tasks = [Task(task_id=i, visits=[], est_cost_s=1.0, host=1)
             for i in range(4)]          # every task homed on host 1
    ex = DynamicExecutor(tasks, lambda t, a, c: ran.append(t.task_id),
                         nworkers=2, n_hosts=2)
    done = ex.run()                      # host-0 workers must steal
    assert sorted(done) == [0, 1, 2, 3]
    assert sorted(ran) == [0, 1, 2, 3]


def test_executor_retry_requeues_to_home_host():
    attempts = {}

    def flaky(t, a, c):
        attempts[t.task_id] = a
        if t.task_id == 1 and a == 1:
            raise RuntimeError("boom")
        return a

    tasks = [Task(task_id=i, visits=[], host=i % 2) for i in range(3)]
    done = DynamicExecutor(tasks, flaky, 2, n_hosts=2).run()
    assert done[1][0] == 2               # second attempt won


# ------------------------------------------- v4 manifests on a tiny mesh --
def _mesh1():
    import jax
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))


def _sharded_store(tmp_path, n_ckpts=2):
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.checkpoint import CheckpointPipeline, CheckpointStore
    store = CheckpointStore(os.path.join(tmp_path, "store"))
    mesh = _mesh1()
    pipe = CheckpointPipeline(store, async_stage=False, mesh=mesh)
    trees = []
    for i in range(n_ckpts):
        w = jax.device_put(
            jnp.arange(64, dtype=jnp.float32).reshape(8, 8) + i,
            NamedSharding(mesh, P("data", None)))
        tree = {"w": w, "step": i}
        pipe.submit(f"train@{i}.0", tree, block=True)
        trees.append({"w": np.asarray(jax.device_get(w)),
                      "step": np.int64(i)})
    pipe.close()
    return store, mesh, trees


def test_sharded_record_roundtrip_and_delta_chain(tmp_path):
    store, mesh, trees = _sharded_store(str(tmp_path))
    m0 = store.resolve_manifest("train@0.0")
    m1 = store.resolve_manifest("train@1.0")
    assert m0["kind"] == "sharded" and m0["ckpt_kind"] == "full"
    assert m1["ckpt_kind"] == "delta" and m1["parent"] == "train@0.0"
    # member manifests chain per shard
    mem1 = m1["members_resolved"][0]
    assert mem1["parent"] == "train@0.0.shard0"
    assert mem1["store_shard"] == 0
    for i, truth in enumerate(trees):
        like = {"w": np.empty((8, 8), np.float32), "step": np.int64(0)}
        out = store.get_tree(f"train@{i}.0", like=like)
        assert np.array_equal(out["w"], truth["w"])
        assert int(out["step"]) == i


def test_restore_sharded_tree_same_mesh(tmp_path):
    from repro.checkpoint import restore_sharded_tree
    store, mesh, trees = _sharded_store(str(tmp_path))
    out = restore_sharded_tree(store, "train@1.0", mesh)
    assert np.array_equal(np.asarray(out["['w']"]), trees[1]["w"])


def test_stats_report_sharded_members(tmp_path):
    store, _, _ = _sharded_store(str(tmp_path))
    st = store.stats(keys=store.list_keys(), per_key=True)
    assert st["sharded_manifests"] == 2
    info = st["per_key"]["train_at_1.0"]
    assert 0 in {int(h) for h in info["shards"]}
    assert info["shards"][list(info["shards"])[0]]["chunks"] >= 1


def test_gc_keeps_live_shard_member_closure(tmp_path):
    """Satellite fix: shard members are part of the global manifest's
    closure — GC with only the DELTA tip live must keep the parent full's
    member chunks alive too."""
    store, mesh, trees = _sharded_store(str(tmp_path))
    res = store.gc(live_keys=["train@1.0"])
    assert res["deleted_chunks"] == 0, res
    like = {"w": np.empty((8, 8), np.float32), "step": np.int64(0)}
    out = store.get_tree("train@1.0", like=like)
    assert np.array_equal(out["w"], trees[1]["w"])
    # dropping the tip reclaims the whole chain, shard pools included
    res = store.gc(live_keys=[])
    assert res["deleted_chunks"] > 0


def test_sharded_restore_read_stats(tmp_path):
    store, _, _ = _sharded_store(str(tmp_path))
    stats = {}
    like = {"w": np.empty((8, 8), np.float32), "step": np.int64(0)}
    store.get_tree("train@1.0", like=like, stats_out=stats)
    assert stats["chunks_read"] >= 1
    assert sum(stats["bytes_by_shard"].values()) > 0


def test_warm_start_from_sharded_manifest_raises(tmp_path):
    from repro.checkpoint import CheckpointPipeline
    store, _, _ = _sharded_store(str(tmp_path))
    pipe = CheckpointPipeline(store, async_stage=False)
    manifest = store.resolve_manifest("train@1.0")
    with pytest.raises(ValueError):
        pipe.warm_start("train", "train@1.0", manifest, {})
    pipe.close()


# -------------------------------------------------- host-aware plan costs --
def test_plan_uses_per_shard_read_rates(tmp_path):
    from repro.replay import build_plan
    store, _, _ = _sharded_store(str(tmp_path))
    store.put_meta("run", {"epochs": [0, 1], "main_loop": "epochs",
                           "num_epochs": 2})

    def plan_with(bps):
        calib = {"read_bps": 1e9, "hop_s": 0.0}
        if bps is not None:
            calib["shard_read_bps"] = {"0": bps}
        store.put_meta("store_calib", calib)
        return build_plan(str(tmp_path), probed=frozenset(), store=store,
                          epochs=[0, 1])

    slow = plan_with(1e3)
    fast = plan_with(1e9)
    s_cost = sum(s.restore_cost_s for s in slow.segments)
    f_cost = sum(s.restore_cost_s for s in fast.segments)
    assert s_cost > f_cost * 100       # slow shard dominates the estimate
    assert all(s.hosts >= 1 for s in slow.segments)
    assert slow.mesh.get("n_store_shards") == 1   # from recorded mesh meta
    # round-trips through save/load (tolerant from_dict)
    loaded = type(slow).from_dict(slow.to_dict())
    assert loaded.mesh == slow.mesh
    assert [s.hosts for s in loaded.segments] \
        == [s.hosts for s in slow.segments]


# ----------------------------------------------- encodings on a mesh --------
def test_sharded_error_bound_slots(tmp_path):
    """The adaptive encoding selector composes with the sharded (v4) path:
    bounded slots land as q4/q8 wire chunks in the member manifests, deltas
    carry denc, the resolved member chain inherits enc, and restores stay
    within the declared bound (exact slots bit-identical)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.checkpoint import (CheckpointPipeline, CheckpointStore,
                                  restore_sharded_tree)
    store = CheckpointStore(os.path.join(str(tmp_path), "store"))
    mesh = _mesh1()
    pipe = CheckpointPipeline(store, async_stage=False, mesh=mesh,
                              chunk_words=16, error_bounds={"mu": 1e-2})
    rng = np.random.default_rng(7)
    mus, ws = [], []
    for i in range(2):
        mu = (0.02 * rng.normal(size=(8, 8))).astype(np.float32)
        w = rng.normal(size=(8, 8)).astype(np.float32)
        sh = NamedSharding(mesh, P("data", None))
        pipe.submit(f"train@{i}.0", {
            "mu": jax.device_put(jnp.asarray(mu), sh),
            "w": jax.device_put(jnp.asarray(w), sh)}, block=True)
        mus.append(mu)
        ws.append(w)
    pipe.close()
    for i in range(2):
        like = {"mu": np.empty((8, 8), np.float32),
                "w": np.empty((8, 8), np.float32)}
        out = store.get_tree(f"train@{i}.0", like=like)
        assert np.max(np.abs(out["mu"] - mus[i])) <= 1e-2
        assert np.array_equal(out["w"], ws[i])
    # member manifests carry the wire encodings (paths gain ::shard<h>)
    m0 = store.resolve_manifest("train@0.0")
    lf0 = {l["path"]: l for l in m0["members_resolved"][0]["leaves"]}
    assert lf0["['mu']::shard0"]["leaf_enc"] == "eb:0.01"
    assert set(lf0["['mu']::shard0"]["enc"]) <= {"q4", "q8", "q4+z", "q8+z"}
    assert all(e == "raw" for e in lf0["['w']::shard0"].get("enc", []))
    # the delta member records denc; the resolved chain inherits enc
    raw1 = store.get_manifest("train@1.0.shard0")
    rlf = {l["path"]: l for l in raw1["leaves"]}["['mu']::shard0"]
    assert rlf.get("delta") and rlf.get("denc")
    assert set(rlf["denc"].values()) <= {"q4", "q8", "q4+z", "q8+z"}
    m1 = store.resolve_manifest("train@1.0")
    lf1 = {l["path"]: l for l in m1["members_resolved"][0]["leaves"]}
    assert set(lf1["['mu']::shard0"]["enc"]) <= {"q4", "q8", "q4+z", "q8+z"}
    # mesh-placed restore decodes the wire chunks too
    out = restore_sharded_tree(store, "train@1.0", mesh)
    assert np.max(np.abs(np.asarray(out["['mu']"]) - mus[1])) <= 1e-2
    assert np.array_equal(np.asarray(out["['w']"]), ws[1])
    # stats/encoding_mix see through the v4 indirection
    mix = store.encoding_mix("train@1.0")
    assert any(e.startswith("q") for e in mix)
    st = store.stats(keys=store.list_keys(), per_key=True)
    encc = st["per_key"]["train_at_1.0"]["enc_counts"]
    assert any(e.startswith("q") for e in encc)


@pytest.mark.slow
def test_encoded_slots_cross_mesh_restore_within_bound():
    """q4/q8-encoded slots recorded on a (2, 4) mesh restore within their
    declared bound on (4, 2), (1, 8) and unsharded; the exact slot stays
    bit-identical across every resharding."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.checkpoint import (CheckpointPipeline, CheckpointStore,
                                      restore_sharded_tree)
        devs = jax.devices()
        mesh = Mesh(np.array(devs).reshape(2, 4), ("data", "model"))
        store = CheckpointStore("/tmp/t_sh8enc/store")
        pipe = CheckpointPipeline(store, async_stage=False, mesh=mesh,
                                  chunk_words=64,
                                  error_bounds={"mu": 1e-2})
        rng = np.random.default_rng(11)
        def state(i):
            mu = (0.02 * rng.normal(size=(64, 32))).astype(np.float32)
            w = rng.normal(size=(64, 32)).astype(np.float32)
            sh = NamedSharding(mesh, P("data", "model"))
            return ({"mu": jax.device_put(jnp.asarray(mu), sh),
                     "w": jax.device_put(jnp.asarray(w), sh)},
                    {"mu": mu, "w": w})
        truth = None
        for i in range(2):
            tree, truth = state(i)
            pipe.submit(f"train@{i}.0", tree, block=True)
        assert store.resolve_manifest("train@1.0")["ckpt_kind"] == "delta"
        m1 = store.resolve_manifest("train@1.0")
        for mem in m1["members_resolved"].values():
            for l in mem["leaves"]:
                if l["path"].startswith("['mu']"):
                    assert set(l["enc"]) <= {"q4", "q8",
                                             "q4+z", "q8+z"}, l
        like = {k: np.empty_like(v) for k, v in truth.items()}
        got = store.get_tree("train@1.0", like=like)
        assert np.max(np.abs(got["mu"] - truth["mu"])) <= 1e-2
        assert np.array_equal(got["w"], truth["w"])
        for shape in ((4, 2), (1, 8)):
            m2 = Mesh(np.array(devs).reshape(shape), ("data", "model"))
            out = restore_sharded_tree(store, "train@1.0", m2)
            mu = np.asarray(jax.device_get(out["['mu']"]))
            assert np.max(np.abs(mu - truth["mu"])) <= 1e-2, shape
            w = np.asarray(jax.device_get(out["['w']"]))
            assert np.array_equal(w, truth["w"]), shape
        pipe.close()
        print("SH8ENC_OK")
    """)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    subprocess.run([sys.executable, "-c", "import shutil; "
                    "shutil.rmtree('/tmp/t_sh8enc', ignore_errors=True)"])
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600, env=env)
    assert "SH8ENC_OK" in out.stdout, out.stderr[-3000:]


# ----------------------------------------------- 8-device cross-mesh cases --
@pytest.mark.slow
def test_record_2x4_restores_bitwise_on_other_meshes():
    """Record on (2, 4); restore bit-identically on (4, 2), (1, 8) and
    unsharded; resharding a leaf mid-run forces a FULL manifest."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.checkpoint import (CheckpointPipeline, CheckpointStore,
                                      restore_sharded_tree)
        devs = jax.devices()
        mesh = Mesh(np.array(devs).reshape(2, 4), ("data", "model"))
        store = CheckpointStore("/tmp/t_sh8/store")
        pipe = CheckpointPipeline(store, async_stage=False, mesh=mesh)
        base = jnp.sin(jnp.arange(64 * 32, dtype=jnp.float32)
                       ).reshape(64, 32)
        def state(i, spec=P("data", "model")):
            return {"w": jax.device_put(base * (1.0 + 0.001 * i),
                                        NamedSharding(mesh, spec)),
                    "b": jax.device_put(base[0] * (2.0 + 0.001 * i),
                                        NamedSharding(mesh, P("model")))}
        for i in range(2):
            pipe.submit(f"train@{i}.0", state(i), block=True)
        assert store.resolve_manifest("train@1.0")["ckpt_kind"] == "delta"
        truth = {k: np.asarray(jax.device_get(v))
                 for k, v in state(1).items()}
        like = {k: np.empty_like(v) for k, v in truth.items()}
        got = store.get_tree("train@1.0", like=like)
        assert all(np.array_equal(got[k], truth[k]) for k in truth)
        for shape in ((4, 2), (1, 8)):
            m2 = Mesh(np.array(devs).reshape(shape), ("data", "model"))
            out = restore_sharded_tree(store, "train@1.0", m2)
            for k in truth:
                arr = np.asarray(jax.device_get(out[f"['{k}']"]))
                assert np.array_equal(arr, truth[k]), (shape, k)
        # selective reads: a same-layout sharded restore touches every
        # store shard but reads each byte once
        stats = {}
        st_like = state(1)
        store.get_tree("train@1.0", like=st_like, stats_out=stats)
        assert len(stats["bytes_by_shard"]) == 8
        total = sum(v.nbytes for v in truth.values())
        assert sum(stats["bytes_by_shard"].values()) <= 2 * total
        # resharding a leaf mid-run changes the layout -> forced FULL
        pipe.submit("train@2.0", state(2, spec=P(None, "model")),
                    block=True)
        assert store.resolve_manifest("train@2.0")["ckpt_kind"] == "full"
        pipe.close()
        print("SH8_OK")
    """)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    subprocess.run([sys.executable, "-c", "import shutil; "
                    "shutil.rmtree('/tmp/t_sh8', ignore_errors=True)"])
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600, env=env)
    assert "SH8_OK" in out.stdout, out.stderr[-3000:]
