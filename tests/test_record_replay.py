"""End-to-end Flor behaviour: record -> probe -> replay, exactness, weak vs
strong init, deferred checks catching injected corruption, script tier."""
import os
import shutil
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
import repro.flor as flor
from repro.data import synthetic_batch
from repro.train.step import build_train_step

EPOCHS, STEPS = 5, 2


@pytest.fixture(scope="module")
def tiny():
    cfg = C.get_smoke("florbench-100m").replace(
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, d_ff=128,
        vocab_size=512, head_dim=32)
    init_state, train_step = build_train_step(cfg)
    return cfg, jax.jit(init_state), jax.jit(train_step)


def _loop(cfg, init_state, ts, probe=False):
    state = init_state(jax.random.PRNGKey(0))
    for epoch in flor.generator(range(EPOCHS)):
        if flor.skipblock.step_into("train"):
            for s in range(STEPS):
                state, m = ts(state, synthetic_batch(cfg, 2, 32,
                                                     epoch * STEPS + s))
                if probe:
                    flor.log("probe_gnorm", m["grad_norm"])
            flor.log("loss", m["loss"])
        state = flor.skipblock.end("train", state)
    return state


def _leaves_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        if not np.array_equal(np.asarray(x), np.asarray(y)):
            return False
    return True


def _record(run_dir, tiny, adaptive=False):
    cfg, init_state, ts = tiny
    flor.init(run_dir, mode="record", adaptive=adaptive)
    final = _loop(cfg, init_state, ts)
    flor.finish()
    return final


def test_record_then_skip_replay_exact(tmp_path, tiny):
    run = str(tmp_path / "run")
    final = _record(run, tiny)
    cfg, init_state, ts = tiny
    flor.init(run, mode="replay", probed=set())
    out = _loop(cfg, init_state, ts)
    flor.finish()
    assert _leaves_equal(final, out)


def test_probed_replay_reexecutes_and_matches(tmp_path, tiny):
    run = str(tmp_path / "run")
    final = _record(run, tiny)
    cfg, init_state, ts = tiny
    flor.init(run, mode="replay", probed={"train"})
    out = _loop(cfg, init_state, ts, probe=True)
    flor.finish()
    assert _leaves_equal(final, out)
    rec, reps = flor.run_logs(run)
    res = flor.deferred_check(rec, reps)
    assert res.ok and res.hindsight_only == EPOCHS * STEPS


@pytest.mark.parametrize("init_mode", ["strong", "weak"])
@pytest.mark.parametrize("nworkers", [2, 3])
def test_parallel_replay_partitions_match(tmp_path, tiny, init_mode, nworkers):
    run = str(tmp_path / f"run_{init_mode}_{nworkers}")
    final = _record(run, tiny)
    cfg, init_state, ts = tiny
    last = None
    for pid in range(nworkers):
        flor.init(run, mode="replay", pid=pid, nworkers=nworkers,
                  init_mode=init_mode, probed={"train"})
        last = _loop(cfg, init_state, ts)
        flor.finish()
    assert _leaves_equal(final, last)          # final partition ends at truth
    rec, reps = flor.run_logs(run)
    res = flor.deferred_check(rec, reps)
    assert res.ok, res.anomalies


def test_weak_init_uses_nearest_checkpoint_under_sparsity(tmp_path, tiny):
    """Adaptive record may skip checkpoints; weak init must re-execute the
    gap from the nearest one instead of silently starting from garbage."""
    run = str(tmp_path / "run")
    cfg, init_state, ts = tiny
    # force sparse: adaptive on, huge fake materialization cost
    flor.init(run, mode="record", adaptive=True)
    ctx = flor.get_context()
    ctx.controller.epsilon = 1e-6              # nothing passes after epoch 0
    final = _loop(cfg, init_state, ts)
    flor.finish()
    keys = [k for k in ctx.store.list_keys()]
    assert len(keys) < EPOCHS                  # sparse indeed

    flor.init(run, mode="replay", pid=1, nworkers=2, init_mode="weak",
              probed={"train"})
    out = _loop(cfg, init_state, ts)
    flor.finish()
    assert _leaves_equal(final, out)


def test_deferred_check_catches_corruption(tmp_path, tiny):
    """Tamper with a stored checkpoint chunk; replay from it must produce a
    fingerprint anomaly (paper section 5.2.2)."""
    run = str(tmp_path / "run")
    _record(run, tiny)
    cfg, init_state, ts = tiny
    # corrupt epoch-2 checkpoint: rewrite manifest to point at a chunk of
    # zeros (simulates a missed side-effect / bad dedup)
    from repro.checkpoint import CheckpointStore
    store = CheckpointStore(os.path.join(run, "store"))
    # resolve first: the record pipeline may have written a sparse delta
    # manifest, and the tamper needs a concrete chunk list to rewrite
    man = store.resolve_manifest("train@2.0")
    victim = man["leaves"][2]
    z = np.zeros(int(np.prod(victim["shape"]) or 1),
                 np.dtype(victim["dtype"]))
    h, _, _ = store._put_chunk(z.tobytes())
    victim["chunks"] = [h] * len(victim["chunks"])
    store.put_manifest(man)        # codec-agnostic (msgpack or json)

    # worker 1 weak-inits from the corrupted epoch-2 checkpoint
    flor.init(run, mode="replay", pid=1, nworkers=2, init_mode="weak",
              probed={"train"})
    _loop(cfg, init_state, ts)
    flor.finish()
    rec, reps = flor.run_logs(run)
    res = flor.deferred_check(rec, reps)
    assert not res.ok and len(res.anomalies) >= 1


def test_script_tier_end_to_end(tmp_path):
    """`import flor` is the only user-visible change (paper section 3)."""
    script = tmp_path / "train_script.py"
    script.write_text(textwrap.dedent("""
        import jax
        import repro.configs as C
        from repro.data import synthetic_batch
        from repro.train.step import build_train_step
        cfg = C.get_smoke('florbench-100m').replace(
            num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, d_ff=128,
            vocab_size=512, head_dim=32)
        init_state, train_step = build_train_step(cfg)
        ts = jax.jit(train_step)
        state = jax.jit(init_state)(jax.random.PRNGKey(0))
        metrics = {}
        for epoch in range(3):
            for s in range(2):
                batch = synthetic_batch(cfg, 2, 32, epoch * 2 + s)
                state, metrics = ts(state, batch)
            flor.log('loss', metrics['loss'])
    """))
    from repro.core.instrument import exec_instrumented
    from repro.core.probes import detect_probes
    run = str(tmp_path / "run")
    ns, report = exec_instrumented(str(script), run_dir=run, mode="record")
    assert report.instrumented           # the inner loop got a SkipBlock

    probed_src = script.read_text().replace(
        "state, metrics = ts(state, batch)",
        "state, metrics = ts(state, batch)\n        "
        "flor.log('probe', metrics['grad_norm'])")
    probed_path = tmp_path / "probed.py"
    probed_path.write_text(probed_src)

    from repro.checkpoint import CheckpointStore
    store = CheckpointStore(os.path.join(run, "store"))
    rep = detect_probes(store.get_meta("source")["src"], probed_src)
    assert rep.probed_blocks
    exec_instrumented(str(probed_path), run_dir=run, mode="replay",
                      probed=rep.probed_blocks)
    rec, reps = flor.run_logs(run)
    res = flor.deferred_check(rec, reps)
    assert res.ok and res.hindsight_only == 6


def test_sampling_replay_random_access(tmp_path, tiny):
    """Paper section 8 POC: probe a random SUBSET of epochs; each sampled
    epoch re-executes from the nearest checkpoint and its probe values match
    a full sequential replay."""
    run = str(tmp_path / "run")
    _record(run, tiny)
    cfg, init_state, ts = tiny
    flor.init(run, mode="replay", probed={"train"})
    state = init_state(jax.random.PRNGKey(0))
    sampled_losses = {}
    for epoch in flor.sampling_generator(range(EPOCHS), sample=[1, 3]):
        if flor.skipblock.step_into("train"):
            for s in range(STEPS):
                state, m = ts(state, synthetic_batch(cfg, 2, 32,
                                                     epoch * STEPS + s))
            if flor.get_context().replay_phase == "exec":
                sampled_losses[epoch] = float(m["loss"])
                flor.log("loss", m["loss"])
        state = flor.skipblock.end("train", state)
    flor.finish()
    assert set(sampled_losses) == {1, 3}
    rec, reps = flor.run_logs(run)
    res = flor.deferred_check(rec, reps)
    assert res.ok, res.anomalies
