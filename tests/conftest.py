import os
import sys

# tests must see exactly ONE device (the dry-run sets its own XLA_FLAGS in a
# separate process); make sure nothing leaked into the environment
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.dirname(__file__))   # for `import proptest`
