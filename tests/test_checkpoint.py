"""Checkpoint store: roundtrip fidelity, chunk dedup (lean checkpointing),
async writer, crash-atomicity, device-side delta tracker."""
import os
import shutil
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import given, st

from repro.checkpoint import AsyncWriter, CheckpointStore
from repro.checkpoint.delta import DeltaTracker


@pytest.fixture()
def store(tmp_path):
    return CheckpointStore(str(tmp_path / "store"))


def test_roundtrip_fidelity_dtypes(store):
    tree = {
        "f32": jax.random.normal(jax.random.PRNGKey(0), (33, 7)),
        "bf16": jax.random.normal(jax.random.PRNGKey(1), (128,)).astype(jnp.bfloat16),
        "i32": jnp.arange(10, dtype=jnp.int32),
        "nested": {"u8": jnp.asarray([1, 2, 3], jnp.uint8),
                   "scalar": jnp.asarray(3.5)},
    }
    store.put_tree("ck", tree)
    back = store.get_tree("ck", like=tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        assert str(a.dtype) == str(np.asarray(b).dtype)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_chunk_dedup_is_lean_checkpointing(store):
    """Unchanged leaves cost ~zero marginal bytes — the fine-tuning win."""
    frozen = jax.random.normal(jax.random.PRNGKey(0), (1 << 20,))   # 4 MB
    head = jax.random.normal(jax.random.PRNGKey(1), (1000,))
    s1 = store.put_tree("e0", {"frozen": frozen, "head": head})
    s2 = store.put_tree("e1", {"frozen": frozen, "head": head + 1})
    assert s1["new_bytes"] > 0
    # second checkpoint: only the small head leaf is new
    assert s2["new_chunks"] <= 2
    assert s2["new_bytes"] < s1["new_bytes"] * 0.05


def test_identical_epochs_share_everything(store):
    t = {"w": jnp.ones((100_000,))}
    store.put_tree("a", t)
    s = store.put_tree("b", t)
    assert s["new_bytes"] == 0 and s["new_chunks"] == 0
    assert store.has("a") and store.has("b")


def test_async_writer_correct_and_ordered(store):
    w = AsyncWriter(store)
    trees = []
    for i in range(5):
        t = {"x": jnp.full((1000,), float(i))}
        trees.append(t)
        w.submit(f"ck{i}", t)
    w.close()
    for i, t in enumerate(trees):
        back = store.get_tree(f"ck{i}", like=t)
        np.testing.assert_array_equal(np.asarray(back["x"]),
                                      np.asarray(t["x"]))
    assert len(w.stats) == 5
    assert all(s["materialize_s"] > 0 for s in w.stats)


def test_async_writer_reports_to_callback(store):
    seen = []
    w = AsyncWriter(store, on_materialized=seen.append)
    w.submit("k", {"x": jnp.zeros((10,))})
    w.close()
    assert len(seen) == 1 and seen[0]["key"] == "k"


def test_crash_atomicity_partial_tmp_ignored(store):
    t = {"x": jnp.arange(100.0)}
    store.put_tree("good", t)
    # simulate a crash mid-write: stray tmp files must not corrupt reads
    obj_dir = os.path.join(store.root, "objects", "zz")
    os.makedirs(obj_dir, exist_ok=True)
    with open(os.path.join(obj_dir, "deadbeef.zst.tmp.123"), "wb") as f:
        f.write(b"garbage")
    with open(os.path.join(store.root, "manifests", "bad.msgpack.tmp.1"),
              "wb") as f:
        f.write(b"garbage")
    assert not store.has("bad")
    back = store.get_tree("good", like=t)
    np.testing.assert_array_equal(np.asarray(back["x"]), np.asarray(t["x"]))


@given(n=st.integers(1, 3000))
def test_roundtrip_any_size(n, tmp_path_factory):
    store = CheckpointStore(str(tmp_path_factory.mktemp("s")))
    t = {"x": jnp.arange(n, dtype=jnp.float32)}
    store.put_tree("k", t)
    back = store.get_tree("k", like=t)
    np.testing.assert_array_equal(np.asarray(back["x"]), np.asarray(t["x"]))


def test_delta_tracker_transfers_only_changes():
    dt = DeltaTracker(chunk_words=256)
    x = jax.random.normal(jax.random.PRNGKey(0), (64 * 256,))
    d1 = dt.delta("p", x)
    assert d1["mask"].all()                    # first sight: everything new
    x2 = x.at[0].add(1.0)                      # touch exactly one chunk
    d2 = dt.delta("p", x2)
    assert d2["mask"].sum() == 1
    assert d2["transferred_bytes"] == 256 * 4
    # unchanged resubmission transfers nothing
    d3 = dt.delta("p", x2)
    assert d3["transferred_bytes"] == 0


def test_store_concurrent_writers(tmp_path):
    """Two threads writing overlapping content must not corrupt the store."""
    store = CheckpointStore(str(tmp_path / "s"))
    t = {"x": jnp.arange(200_000, dtype=jnp.float32)}
    errs = []

    def work(k):
        try:
            store.put_tree(k, t)
        except Exception as e:      # noqa: BLE001
            errs.append(e)

    ths = [threading.Thread(target=work, args=(f"k{i}",)) for i in range(4)]
    [th.start() for th in ths]
    [th.join() for th in ths]
    assert not errs
    for i in range(4):
        back = store.get_tree(f"k{i}", like=t)
        np.testing.assert_array_equal(np.asarray(back["x"]),
                                      np.asarray(t["x"]))
