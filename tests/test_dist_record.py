"""True multi-process mesh record: two REAL processes join a
``jax.distributed`` fleet, each checkpoints only its local shards, and the
lead stitches v4 manifests through the crash-safe file rendezvous.

The cross-process cases run in subprocesses (4 forced host-platform devices
per process -> a 2x4 global mesh; conftest strips XLA_FLAGS from THIS
process). The CPU backend cannot jit multi-process computations, so the
children compute their SPMD-replicated state locally and place it on the
global mesh with ``make_array_from_callback`` — exactly the layout a real
multi-host training step leaves behind, and the only part the checkpoint
path sees.

Fault injection: ``FLOR_DIST_CRASH_BEFORE_PUBLISH=<key>`` kills the matching
process (exit 43) after its member manifests are durable but before its
rendezvous marker — the exact window the crash-safety argument is about.
"""
import json
import os
import socket
import subprocess
import sys
import textwrap
import threading
import time

import numpy as np
import pytest

from repro.parallel.rendezvous import (CRASH_EXIT_CODE, ProcessGroup,
                                       StitchRendezvous, crash_requested)


# ------------------------------------------------------------- rendezvous --
def test_process_group_validates_and_leads():
    g0 = ProcessGroup(0, 2)
    g1 = ProcessGroup(1, 2)
    assert g0.is_lead and not g1.is_lead
    with pytest.raises(ValueError):
        ProcessGroup(2, 2)
    with pytest.raises(ValueError):
        ProcessGroup(-1, 1)


def test_rendezvous_publish_gather_clear(tmp_path):
    root = str(tmp_path / "store")
    r0 = StitchRendezvous(root, "r", ProcessGroup(0, 2), timeout_s=5.0)
    r1 = StitchRendezvous(root, "r", ProcessGroup(1, 2), timeout_s=5.0)
    r0.publish("train@0.0", {"process": 0, "members": {"0": "a"}})
    r1.publish("train@0.0", {"process": 1, "members": {"1": "b"}})
    got = r0.gather("train@0.0")
    assert [m["process"] for m in got] == [0, 1]
    r0.clear("train@0.0")
    # cleared markers are gone: a fresh gather times out
    assert r0.gather("train@0.0", timeout_s=0.1) is None


def test_rendezvous_deadline_and_stale_heartbeat(tmp_path):
    root = str(tmp_path / "store")
    r0 = StitchRendezvous(root, "r", ProcessGroup(0, 2), timeout_s=0.3)
    r1 = StitchRendezvous(root, "r", ProcessGroup(1, 2), timeout_s=0.3)
    r0.publish("k", {"process": 0})
    # the missing process is alive (its beater renews the heartbeat):
    # gather charges the full deadline
    assert r0.gather("k", timeout_s=0.3) is None
    # a heartbeat that stays silent for the timeout WITHIN the gather
    # short-circuits a longer budget (the peer is dead): stop r1's beater
    # and age its heartbeat, then gather with a 30s budget — the stale
    # check must fire at ~timeout_s, not burn the budget
    r1.close()
    os.utime(r1._hb_path(1), (1, 1))
    t0 = time.monotonic()
    assert r0.gather("k", timeout_s=30.0) is None
    assert time.monotonic() - t0 < 5.0
    # a marker arriving late still satisfies a fresh gather
    r1.publish("k", {"process": 1})
    assert len(r0.gather("k")) == 2
    r0.close()


def test_rendezvous_slow_cadence_not_declared_dead(tmp_path):
    """A live peer whose LAST beat predates the stitch timeout (checkpoint
    cadence longer than timeout_s) must not be declared dead at the start
    of the next gather — staleness is relative to the gather, not the
    heartbeat file's absolute age."""
    root = str(tmp_path / "store")
    r0 = StitchRendezvous(root, "r", ProcessGroup(0, 2), timeout_s=0.3)
    r1 = StitchRendezvous(root, "r", ProcessGroup(1, 2), timeout_s=0.3)
    # simulate a long gap since r1's previous publish: freeze its beater
    # and age the heartbeat WAY past timeout_s
    r1.close()
    os.utime(r1._hb_path(1), (1, 1))
    r0.publish("k1", {"process": 0})
    late = threading.Timer(0.1, lambda: r1.publish("k1", {"process": 1}))
    late.start()
    try:
        got = r0.gather("k1", timeout_s=5.0)
    finally:
        late.join()
    assert got is not None and [m["process"] for m in got] == [0, 1]
    r0.close()


def test_rendezvous_record_leftover_heartbeats_ignored_by_replay(tmp_path):
    """Replay reuses the record run's .stitch/ dir, where record-phase
    hb.p* files persist. A replay merge starting long after the record
    ended must give every host the full merge timeout, not fail the
    barrier because the leftover heartbeats look stale."""
    root = str(tmp_path / "store")
    # record phase: both processes beat, then the run ends
    rec0 = StitchRendezvous(root, "r", ProcessGroup(0, 2), timeout_s=0.3)
    rec1 = StitchRendezvous(root, "r", ProcessGroup(1, 2), timeout_s=0.3)
    rec0.close()
    rec1.close()
    # ... much later: replay. Age BOTH leftover heartbeats far past the
    # merge timeout before any replay host constructs its rendezvous.
    os.utime(rec0._hb_path(0), (1, 1))
    os.utime(rec1._hb_path(1), (1, 1))
    rep0 = StitchRendezvous(root, "r", ProcessGroup(0, 2), timeout_s=1.0)
    rep0.retract("replay.merge")
    rep0.arrive("replay.merge", {"process": 0})

    def late_host():
        rep1 = StitchRendezvous(root, "r", ProcessGroup(1, 2),
                                timeout_s=1.0)
        rep1.retract("replay.merge")
        rep1.arrive("replay.merge", {"process": 1})
        rep1.close()

    late = threading.Timer(0.2, late_host)
    late.start()
    try:
        got = rep0.await_all("replay.merge", timeout_s=5.0)
    finally:
        late.join()
    assert got is not None and [m["process"] for m in got] == [0, 1]
    rep0.close()


def test_rendezvous_retract_own_marker(tmp_path):
    root = str(tmp_path / "store")
    r1 = StitchRendezvous(root, "r", ProcessGroup(1, 2), timeout_s=1.0)
    r1.arrive("replay.merge")
    r1.retract("replay.merge")
    r0 = StitchRendezvous(root, "r", ProcessGroup(0, 2), timeout_s=1.0)
    r0.arrive("replay.merge")
    assert r0.await_all("replay.merge", timeout_s=0.2) is None


def test_crash_requested_env_scoping(monkeypatch):
    assert not crash_requested("train@2.0", 0)
    monkeypatch.setenv("FLOR_DIST_CRASH_BEFORE_PUBLISH", "train@2.0")
    assert crash_requested("train@2.0", 0)
    assert crash_requested("train@2.0", 1)
    assert not crash_requested("train@1.0", 0)
    monkeypatch.setenv("FLOR_DIST_CRASH_PROCESS", "1")
    assert crash_requested("train@2.0", 1)
    assert not crash_requested("train@2.0", 0)


# ----------------------------------------------------- 2-process children --
# Each child joins the fleet, records 3 epochs of a deterministic state
# through the full Session path (staging index dbs, per-process log streams,
# distributed stitch), then waits at a file barrier so neither process tears
# down the jax coordinator while its peer is still closing.
CHILD = textwrap.dedent("""
    import os, sys
    run_dir, port, pid = sys.argv[1], sys.argv[2], int(sys.argv[3])
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import numpy as np
    import jax
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.parallel.rendezvous import StitchRendezvous, init_distributed
    group = init_distributed(f"127.0.0.1:{port}", pid, 2)
    assert jax.device_count() == 8 and jax.local_device_count() == 4
    import repro.flor as flor
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    def host_state(epoch):
        rng = np.random.default_rng(7)
        w = (rng.normal(size=(64, 32)).astype(np.float32)
             * (1.0 + 0.001 * epoch))
        b = np.arange(32, dtype=np.float32) * (2.0 + 0.001 * epoch)
        return {"w": w, "b": b}
    def global_tree(epoch):
        h = host_state(epoch)
        specs = {"w": P("data", "model"), "b": P("model")}
        return {k: jax.make_array_from_callback(
                    h[k].shape, NamedSharding(mesh, specs[k]),
                    lambda idx, a=h[k]: a[idx])
                for k in h}
    timeout = float(os.environ.get("T_STITCH", "30"))
    with flor.Session(run_dir, mode="record",
                      record=flor.RecordSpec(adaptive=False, mesh=mesh,
                                             distributed=group,
                                             stitch_timeout_s=timeout)) as s:
        state = global_tree(0)
        with s.checkpointing(state=state) as ckpt:
            for epoch in s.loop("epochs", range(3)):
                for _ in s.loop("train", range(2)):
                    pass
                ckpt.state = global_tree(epoch + 1)
                flor.log("epoch", epoch)
    rdv = StitchRendezvous(os.path.join(run_dir, "store"),
                           "dist-" + os.path.basename(run_dir.rstrip("/")),
                           group, timeout_s=timeout)
    rdv.arrive("exit")
    rdv.await_all("exit")
    print(f"CHILD_OK p{pid}", flush=True)
    os._exit(0)
""")

RESTORE_CHECK = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import sys
    import numpy as np, jax
    from jax.sharding import Mesh
    from repro.checkpoint import CheckpointStore, restore_sharded_tree
    store = CheckpointStore(os.path.join(sys.argv[1], "store"))
    rng = np.random.default_rng(7)
    w = rng.normal(size=(64, 32)).astype(np.float32) * 1.002
    truth = {"w": w, "b": np.arange(32, dtype=np.float32) * 2.002}
    like = {"state": {k: np.empty_like(v) for k, v in truth.items()}}
    got = store.get_tree("train@2.0", like=like)["state"]
    assert all(np.array_equal(got[k], truth[k]) for k in truth)
    for shape in ((4, 2), (1, 8), (8, 1)):
        mesh = Mesh(np.array(jax.devices()).reshape(shape),
                    ("data", "model"))
        out = restore_sharded_tree(store, "train@2.0", mesh)
        for k in truth:
            arr = np.asarray(jax.device_get(out[f"['state']['{k}']"]))
            assert np.array_equal(arr, truth[k]), (shape, k)
    print("DREC_RESTORE_OK")
""")


def _free_port() -> int:
    s = socket.socket()
    s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _fleet(run_dir: str, env_extra=None) -> list:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env.pop("JAX_PLATFORMS", None)
    env.update(env_extra or {})
    port = _free_port()
    procs = [subprocess.Popen(
                 [sys.executable, "-c", CHILD, run_dir, str(port), str(p)],
                 env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
                 text=True)
             for p in (0, 1)]
    return [(p.wait(), p.stdout.read()) for p in procs]


def _host_state(epoch):
    rng = np.random.default_rng(7)
    w = (rng.normal(size=(64, 32)).astype(np.float32) * (1.0 + 0.001 * epoch))
    b = np.arange(32, dtype=np.float32) * (2.0 + 0.001 * epoch)
    return {"w": w, "b": b}


@pytest.mark.slow
def test_two_process_record_replays_bit_identical(tmp_path):
    """2 processes x 4 devices record a (2, 4)-mesh run; the stitched v4s
    replay bit-identically on (4, 2), (1, 8), (8, 1) and single-process
    unsharded."""
    run = str(tmp_path / "drun")
    rcs = _fleet(run)
    assert [rc for rc, _ in rcs] == [0, 0], rcs
    assert all("CHILD_OK" in out for _, out in rcs), rcs

    from repro.checkpoint import CheckpointStore
    store = CheckpointStore(os.path.join(run, "store"))
    keys = set(store.list_keys())
    # every epoch stitched (v4 + 8 members each)
    for e in range(3):
        assert f"train_at_{e}.0" in keys
        assert {f"train_at_{e}.0.shard{h}" for h in range(8)} <= keys
        m = store.get_manifest(f"train@{e}.0")
        assert m["version"] == 4 and len(m["members"]) == 8
    assert store.get_meta("incomplete_ckpts") in (None, {"keys": []})
    # both processes' markers were consumed by the stitch
    sdir = os.path.join(run, "store", "runs", "dist-drun", ".stitch")
    assert not [d for d in os.listdir(sdir) if d.startswith("train")]
    # per-process log streams: the lead's record.jsonl is the query
    # surface's copy; the peer's SPMD-identical rows live beside it
    logs = set(os.listdir(os.path.join(run, "logs")))
    assert {"record.jsonl", "record_p1.jsonl"} <= logs
    # staging index dbs merged and removed at close
    assert os.listdir(os.path.join(run, "store", "index", "staging")) == []
    # deterministic distributed run id; lead finalized the registry
    from repro.checkpoint.lineage import RunRegistry
    recs = {r["run_id"]: r
            for r in RunRegistry(os.path.join(run, "store")).list_runs()}
    assert recs["dist-drun"]["status"] == "finished"
    assert recs["dist-drun"]["final_keys"] == {"train": "train@2.0"}

    # single-process, unsharded restore in THIS process (1 device)
    truth = _host_state(2)
    like = {"state": {k: np.empty_like(v) for k, v in truth.items()}}
    got = store.get_tree("train@2.0", like=like)["state"]
    assert all(np.array_equal(got[k], truth[k]) for k in truth)

    # cross-mesh restores need 8 devices -> subprocess
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", RESTORE_CHECK, run],
                         capture_output=True, text=True, timeout=600,
                         env=env)
    assert "DREC_RESTORE_OK" in out.stdout, out.stderr[-3000:]


@pytest.mark.slow
def test_crash_between_publish_and_stitch(tmp_path):
    """Kill process 1 after its final-epoch member manifests are durable
    but before its marker: the store is never corrupted — the lead marks
    the checkpoint incomplete, the run finalizes at the last COMPLETE
    checkpoint, replay plans skip the incomplete key, and GC reclaims the
    orphan members."""
    run = str(tmp_path / "crun")
    rcs = _fleet(run, env_extra={
        "T_STITCH": "6",
        "FLOR_DIST_CRASH_BEFORE_PUBLISH": "train@2.0",
        "FLOR_DIST_CRASH_PROCESS": "1",
    })
    assert rcs[0][0] == 0, rcs[0][1]
    assert rcs[1][0] == CRASH_EXIT_CODE, rcs[1][1]

    from repro.checkpoint import CheckpointStore
    store = CheckpointStore(os.path.join(run, "store"))
    keys = set(store.list_keys())
    # publication-ordering invariant: orphan members, NO v4 naming them
    assert "train_at_2.0" not in keys
    orphans = {k for k in keys if k.startswith("train_at_2.0.shard")}
    assert orphans, keys
    assert "train_at_1.0" in keys
    # the lead recorded the failed stitch
    assert store.get_meta("incomplete_ckpts") == {"keys": ["train@2.0"]}
    # the run finalized at the last complete checkpoint
    from repro.checkpoint.lineage import RunRegistry
    reg = RunRegistry(os.path.join(run, "store"))
    rec = {r["run_id"]: r for r in reg.list_runs()}["dist-crun"]
    assert rec["status"] == "finished"
    assert rec["final_keys"] == {"train": "train@1.0"}
    # replay planner skips the incomplete key
    from repro.replay.plan import build_plan
    plan = build_plan(run)
    assert plan.incomplete == ["train_at_2.0"]
    # last complete checkpoint replays bit-identically
    truth = _host_state(1)
    like = {"state": {k: np.empty_like(v) for k, v in truth.items()}}
    got = store.get_tree("train@1.0", like=like)["state"]
    assert all(np.array_equal(got[k], truth[k]) for k in truth)
    # GC reclaims the orphans (they are unreferenced by construction)
    res = reg.gc(store)
    assert res["deleted_manifests"] == len(orphans)
    keys_after = set(store.list_keys())
    assert not [k for k in keys_after if "2.0" in k]
    assert "train_at_1.0" in keys_after
    # ...and the restore still works afterwards
    got = store.get_tree("train@1.0", like=like)["state"]
    assert all(np.array_equal(got[k], truth[k]) for k in truth)
    # a dead process's staging index db is swept (absorbed) on reindex.
    # Whether the crash itself leaves one depends on seal timing, so plant
    # one the way a crashed recorder would have: created, never merged.
    from repro.querydb.index import LogIndex, staging_path
    root = os.path.join(run, "store")
    LogIndex(root, create=True, db_path=staging_path(root, 9)).close()
    staging = os.path.join(root, "index", "staging")
    assert any(f.endswith(".db") for f in os.listdir(staging))
    from repro.querydb.maintain import reindex
    stats = reindex(run)
    assert stats["staging_swept"] >= 1
    assert not any(f.endswith(".db") for f in os.listdir(staging))
