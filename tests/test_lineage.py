"""Multiversion run lineage: shared content-addressed store, cross-run
warm-start deltas, registry-driven multi-run GC, and gc edge cases."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.flor as flor
from repro.checkpoint import (CheckpointPipeline, CheckpointStore,
                              RunRegistry)
from repro.checkpoint.lineage import read_run_meta
from repro.core.context import FlorContext
from proptest import given, st


def _tree(step: float):
    """Frozen-majority state: one big frozen leaf, one small hot head."""
    frozen = jax.random.normal(jax.random.PRNGKey(0), (64 * 256,))
    head = jnp.full((256,), step, jnp.float32)
    return {"frozen": frozen, "head": head}


def _leaves_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               and str(np.asarray(x).dtype) == str(np.asarray(y).dtype)
               for x, y in zip(la, lb))


def _record_run(run_dir, store_root, run_id, n_ckpts, *, parent=None,
                full_every=2, start=None):
    """Record one run of the lineage chain through the real flor API;
    returns the final state."""
    flor.init(str(run_dir), mode="record", adaptive=False,
              async_materialize=False, store_root=str(store_root),
              run_id=run_id, parent_run=parent,
              full_manifest_every=full_every)
    ctx = flor.get_context()
    t = start if start is not None else _tree(1.0)
    if parent is not None:
        t = flor.warm_start("train", like=t)
    for e in range(n_ckpts):
        t = dict(t, head=np.asarray(t["head"]) + 1)
        ctx.submit_checkpoint("train", f"train@{e}.0", t, meta={})
    flor.finish()
    return t


# ------------------------------------------------------------- registry --
def test_registry_lifecycle_and_ancestry(tmp_path):
    reg = RunRegistry(str(tmp_path))
    reg.register("A", namespace="A", run_dir="/r/a")
    reg.register("B", parent="A", namespace="B", run_dir="/r/b")
    reg.register("C", parent="B", namespace="C", run_dir="/r/c")
    assert [r["run_id"] for r in reg.list_runs()] == ["A", "B", "C"]
    assert reg.get("B")["parent"] == "A"
    assert [r["run_id"] for r in reg.ancestry("C")] == ["C", "B", "A"]
    reg.finalize("A", final_keys={"train": "train@4.0"})
    assert reg.get("A")["status"] == "finished"
    assert reg.get("A")["final_keys"] == {"train": "train@4.0"}
    assert reg.unregister("B") and not reg.unregister("B")
    # ancestry stops at the first unregistered ancestor (no crash)
    assert [r["run_id"] for r in reg.ancestry("C")] == ["C"]


def test_registry_rejects_unknown_parent(tmp_path):
    reg = RunRegistry(str(tmp_path))
    with pytest.raises(ValueError, match="not registered"):
        reg.register("B", parent="ghost")


def test_registry_rerecord_replaces_stale_registration(tmp_path):
    """Re-recording into the same (run_dir, namespace) must not leave a
    dangling record pinning dead chunks forever."""
    reg = RunRegistry(str(tmp_path))
    reg.register("old", namespace=None, run_dir="/r/x")
    reg.register("new", namespace=None, run_dir="/r/x")
    assert [r["run_id"] for r in reg.list_runs()] == ["new"]


def test_noop_resume_preserves_final_keys_and_parent(tmp_path):
    """Re-launching an already-completed run (the documented idempotent
    crash-restart flow) must not wipe its registry final_keys or its
    lineage edge — descendants' warm starts depend on both."""
    root = str(tmp_path / "store")
    _record_run(tmp_path / "runA", root, "A", 2)
    _record_run(tmp_path / "runB", root, "B", 2, parent="A")
    reg = RunRegistry(root)
    assert reg.get("B")["final_keys"] == {"train": "train@1.0"}

    # no-op resume with EXPLICIT run_id and no parent_run argument
    flor.init(str(tmp_path / "runB"), mode="record", adaptive=False,
              async_materialize=False, store_root=root, run_id="B")
    ctx = flor.get_context()
    assert ctx.parent_run == "A"          # lineage edge restored from meta
    flor.finish()                         # zero submits this session
    rec = reg.get("B")
    assert rec["final_keys"] == {"train": "train@1.0"}   # tips survive
    assert rec["parent"] == "A"
    # a derived run can still warm-start from B after the no-op resume
    flor.init(str(tmp_path / "runC"), mode="record", adaptive=False,
              async_materialize=False, store_root=root, run_id="C",
              parent_run="B")
    state = flor.warm_start("train", like=_tree(0.0))
    assert state is not None
    flor.finish()


# -------------------------------------------------- namespaces & binding --
def test_shared_store_namespaces_do_not_collide(tmp_path):
    """Two runs writing the SAME checkpoint keys into one store root."""
    root = str(tmp_path / "store")
    ta, tb = _tree(1.0), _tree(500.0)
    for rid, t in (("A", ta), ("B", tb)):
        s = CheckpointStore(root, run_id=rid)
        p = CheckpointPipeline(s, chunk_words=256, async_stage=False)
        p.submit("train@0.0", t, scope="train")
        p.close()
    sa = CheckpointStore(root, run_id="A")
    assert _leaves_equal(ta, sa.get_tree("train@0.0", like=ta))
    assert _leaves_equal(tb, sa.get_tree("B::train@0.0", like=tb))
    # the frozen leaf's chunks dedup across namespaces: one shared pool
    assert sa.stats()["manifests"] == 2
    assert sa.stats()["chunks"] < 2 * (64 + 1) + 2


def test_run_meta_binding_survives_replay(tmp_path):
    root = str(tmp_path / "store")
    run_b = tmp_path / "runB"
    _record_run(tmp_path / "runA", root, "A", 2)
    _record_run(run_b, root, "B", 2, parent="A")
    meta = read_run_meta(str(run_b))
    assert meta["run_id"] == "B" and meta["parent_run"] == "A"
    assert meta["store_root"] == os.path.abspath(root)
    # replay reconnects to the shared store with zero extra arguments
    flor.init(str(run_b), mode="replay")
    ctx = flor.get_context()
    assert ctx.store.root == os.path.abspath(root)
    assert ctx.namespace == "B" and ctx.parent_run == "A"
    assert ctx.store.has("train@1.0")
    flor.finish()


# ------------------------------------------------------------ warm start --
def test_warm_start_first_checkpoint_is_cross_run_delta(tmp_path):
    root = str(tmp_path / "store")
    final_a = _record_run(tmp_path / "runA", root, "A", 3)

    flor.init(str(tmp_path / "runB"), mode="record", adaptive=False,
              async_materialize=False, store_root=root, run_id="B",
              parent_run="A", full_manifest_every=4)
    ctx = flor.get_context()
    state = flor.warm_start("train", like=_tree(0.0))
    assert _leaves_equal(state, final_a)
    info = ctx.warmstart_stats["train"]
    assert info["seeded"] and info["parent_key"] == "A::train@2.0"

    state = dict(state, head=np.asarray(state["head"]) + 1)
    ctx.submit_checkpoint("train", "train@0.0", state, meta={})
    stat = ctx.pipeline.stats[-1]
    # the FIRST checkpoint of the derived run: a delta against the ancestor,
    # transferring only the hot head (2 changed chunks out of 66)
    assert stat["kind"] == "delta" and stat["parent"] == "A::train@2.0"
    assert stat["transferred_bytes"] <= 3 * 256 * 4
    assert stat["transferred_bytes"] < 0.05 * stat["logical_bytes"]
    flor.finish()

    # replay-side: B's chain resolves through A's chunks transparently
    flor.init(str(tmp_path / "runB"), mode="replay")
    back, _ = flor.get_context().restore_checkpoint("train@0.0",
                                                    like=_tree(0.0))
    assert _leaves_equal(back, state)
    flor.finish()


def test_warm_start_without_pipeline_seed_falls_back_cold(tmp_path):
    """An ancestor whose final checkpoint is a v1 (put_tree) manifest can't
    seed digests — warm_start still restores the state; the first
    checkpoint records cold instead of failing."""
    root = str(tmp_path / "store")
    t = _tree(7.0)
    sa = CheckpointStore(root, run_id="A")
    sa.put_tree("train@0.0", t)
    reg = RunRegistry(root)
    reg.register("A", namespace="A")
    reg.finalize("A", final_keys={"train": "train@0.0"})

    flor.init(str(tmp_path / "runB"), mode="record", adaptive=False,
              async_materialize=False, store_root=root, run_id="B",
              parent_run="A")
    ctx = flor.get_context()
    state = flor.warm_start("train", like=_tree(0.0))
    assert _leaves_equal(state, t)
    info = ctx.warmstart_stats["train"]
    assert not info["seeded"] and "v1" in info["reason"]
    ctx.submit_checkpoint("train", "train@0.0", state, meta={})
    assert ctx.pipeline.stats[-1]["kind"] == "full"   # cold, but correct
    assert _leaves_equal(state, ctx.store.get_tree("train@0.0", like=state))
    flor.finish()


def test_warm_start_requires_lineage_config(tmp_path):
    flor.init(str(tmp_path / "run"), mode="record", adaptive=False,
              async_materialize=False)
    with pytest.raises(RuntimeError, match="parent_run"):
        flor.warm_start("train")
    flor.finish()


def test_warm_start_from_flat_namespace_parent(tmp_path):
    """A legacy run (private flat store, no store_root) can parent a
    namespaced derived run sharing its store: the '::key' explicit-flat
    form must keep the parent addressable, and the child's gc must never
    treat the flat sibling's manifests as dead."""
    run_a = tmp_path / "runA"
    final_a = None
    flor.init(str(run_a), mode="record", adaptive=False,
              async_materialize=False, full_manifest_every=2)
    ctx = flor.get_context()
    t = _tree(1.0)
    for e in range(3):
        t = dict(t, head=np.asarray(t["head"]) + 1)
        ctx.submit_checkpoint("train", f"train@{e}.0", t, meta={})
    flor.finish()
    final_a = t
    run_a_id = read_run_meta(str(run_a))["run_id"]

    root = str(run_a / "store")              # share A's private store
    flor.init(str(tmp_path / "runB"), mode="record", adaptive=False,
              async_materialize=False, store_root=root, run_id="B",
              parent_run=run_a_id, full_manifest_every=8)
    ctx = flor.get_context()
    state = flor.warm_start("train", like=_tree(0.0))
    assert _leaves_equal(state, final_a)
    assert ctx.warmstart_stats["train"]["parent_key"] == "::train@2.0"
    state = dict(state, head=np.asarray(state["head"]) + 1)
    ctx.submit_checkpoint("train", "train@0.0", state, meta={})
    assert ctx.pipeline.stats[-1]["kind"] == "delta"
    # B's run-local retention must not collect A's flat manifests
    stats = ctx.gc(keep_keys=["train@0.0"])
    sa = CheckpointStore(root)
    for e in range(3):
        assert sa.has(f"train@{e}.0"), f"flat sibling lost train@{e}.0"
    assert _leaves_equal(final_a, sa.get_tree("train@2.0", like=final_a))
    assert _leaves_equal(state, ctx.store.get_tree("train@0.0", like=state))
    flor.finish()


def test_derived_run_replays_after_parent_unregistered(tmp_path):
    """`runs rm A` keeps descendants' chunk closure — replay of B must not
    need A's registry record either (the warm-start key is persisted in
    B's own flor.run.json at record time)."""
    root = str(tmp_path / "store")
    _record_run(tmp_path / "runA", root, "A", 4, full_every=2)
    final_b = _record_run(tmp_path / "runB", root, "B", 1, parent="A",
                          full_every=8)
    meta = read_run_meta(str(tmp_path / "runB"))
    assert meta["warm_start_keys"] == {"train": "A::train@3.0"}
    reg = RunRegistry(root)
    reg.unregister("A")
    reg.gc(CheckpointStore(root))
    flor.init(str(tmp_path / "runB"), mode="replay")
    state = flor.warm_start("train", like=_tree(0.0))   # no registry lookup
    back, _ = flor.get_context().restore_checkpoint("train@0.0",
                                                    like=_tree(0.0))
    assert _leaves_equal(back, final_b)
    flor.finish()


# -------------------------------------------------------- multi-run gc --
def test_registry_gc_reclaims_only_unreachable(tmp_path):
    """The acceptance scenario: drop run A's registration; gc keeps exactly
    what run B's closure still resolves through."""
    root = str(tmp_path / "store")
    _record_run(tmp_path / "runA", root, "A", 4, full_every=2)
    # A: ck0 full, ck1 delta, ck2 full, ck3 delta; B chains onto ck3
    final_b = _record_run(tmp_path / "runB", root, "B", 1, parent="A",
                          full_every=8)
    store = CheckpointStore(root)
    reg = RunRegistry(root)
    assert reg.gc(store)["deleted_manifests"] == 0    # both runs live: no-op
    reg.unregister("A")
    stats = reg.gc(store)
    # A's final chain (ck3 -> ck2 full) survives via B's closure; ck0/ck1 die
    assert stats["deleted_manifests"] == 2
    assert store.has("A::train@3.0") and store.has("A::train@2.0")
    assert not store.has("A::train@0.0") and not store.has("A::train@1.0")
    assert stats["deleted_chunks"] >= 1
    sb = CheckpointStore(root, run_id="B")
    assert _leaves_equal(final_b, sb.get_tree("train@0.0", like=final_b))
    # second pass is a no-op
    stats2 = reg.gc(store)
    assert stats2["deleted_manifests"] == 0 and stats2["deleted_chunks"] == 0


def test_ctx_gc_in_shared_store_keeps_other_runs_live(tmp_path):
    """Run-local rolling retention must never collect a sibling run."""
    root = str(tmp_path / "store")
    final_a = _record_run(tmp_path / "runA", root, "A", 3, full_every=2)
    flor.init(str(tmp_path / "runB"), mode="record", adaptive=False,
              async_materialize=False, store_root=root, run_id="B",
              full_manifest_every=2)
    ctx = flor.get_context()
    t = _tree(100.0)
    for e in range(4):
        t = dict(t, head=np.asarray(t["head"]) + 1)
        ctx.submit_checkpoint("train", f"train@{e}.0", t, meta={})
    stats = ctx.gc(keep_keys=["train@3.0"])
    assert stats["deleted_manifests"] >= 1            # B's own early ckpts
    sa = CheckpointStore(root, run_id="A")
    for e in range(3):
        assert sa.has(f"train@{e}.0")                 # A untouched
    assert _leaves_equal(final_a, sa.get_tree("train@2.0", like=final_a))
    assert _leaves_equal(t, ctx.store.get_tree("train@3.0", like=t))
    flor.finish()


# ------------------------------------------------------- gc edge cases --
def test_gc_survives_externally_deleted_parent_manifest(tmp_path):
    """A delta manifest whose parent was deleted OUTSIDE gc: gc must not
    crash, must keep the live manifest, and resolve must fail loudly."""
    store = CheckpointStore(str(tmp_path / "s"))
    pipe = CheckpointPipeline(store, chunk_words=256, full_every=100,
                              async_stage=False)
    t = _tree(1.0)
    for i in range(4):
        t = dict(t, head=np.asarray(t["head"]) + 1)
        pipe.submit(f"ck{i}", t, scope="s")
    pipe.close()
    store.delete_manifest("ck1")                      # simulated vandalism
    stats = store.gc(["ck3"])                         # must not raise
    assert store.has("ck3") and store.has("ck2")
    assert not store.has("ck0")      # unreachable once the chain is cut
    with pytest.raises(RuntimeError, match="missing parent"):
        store.resolve_manifest("ck3")
    # idempotent second pass
    store.gc(["ck3"])


def test_gc_with_inflight_async_writer_jobs(tmp_path):
    """ctx.gc during record drains the writer first — in-flight manifests
    must not be collected out from under the pipeline."""
    ctx = FlorContext(str(tmp_path / "run"), "record", adaptive=False,
                      async_materialize=True, full_manifest_every=2)
    t = _tree(1.0)
    for e in range(6):
        t = dict(t, head=np.asarray(t["head"]) + 1)
        ctx.submit_checkpoint("train", f"train@{e}.0", t, meta={})
    stats = ctx.gc(keep_keys=["train@5.0"])           # no explicit drain
    assert stats["deleted_manifests"] >= 1
    assert ctx.store.has("train@5.0") and ctx.store.has("train@4.0")
    back = ctx.store.get_tree("train@5.0", like=t)
    assert _leaves_equal(t, back)
    # the pipeline keeps recording correctly after the collection
    t = dict(t, head=np.asarray(t["head"]) + 1)
    ctx.submit_checkpoint("train", "train@6.0", t, meta={})
    ctx.pipeline.drain()
    assert _leaves_equal(t, ctx.store.get_tree("train@6.0", like=t))
    ctx.finish()


def test_gc_interleaved_scopes_keep_both_chains(tmp_path):
    """Retention across interleaved SkipBlock scopes: each scope's tip and
    its closure survive independently."""
    store = CheckpointStore(str(tmp_path / "s"))
    pipe = CheckpointPipeline(store, chunk_words=256, full_every=2,
                              async_stage=False)
    ta, tb = _tree(1.0), _tree(50.0)
    for i in range(4):
        ta = dict(ta, head=np.asarray(ta["head"]) + 1)
        tb = dict(tb, head=np.asarray(tb["head"]) + 2)
        pipe.submit(f"a{i}", ta, scope="A")
        pipe.submit(f"b{i}", tb, scope="B")
    pipe.close()
    stats = store.gc(["a3", "b3"])
    assert store.has("a3") and store.has("a2")        # A closure (full at 2)
    assert store.has("b3") and store.has("b2")        # B closure
    assert not store.has("a0") and not store.has("b0")
    assert stats["deleted_manifests"] == 4
    assert _leaves_equal(ta, store.get_tree("a3", like=ta))
    assert _leaves_equal(tb, store.get_tree("b3", like=tb))


def test_default_gc_keeps_warmstart_tip_before_first_submit(tmp_path):
    """ctx.gc() with no keep_keys, called after warm_start but before the
    first submit, must keep the ancestor tip the pipeline will chain to —
    even when the ancestor run was unregistered."""
    root = str(tmp_path / "store")
    _record_run(tmp_path / "runA", root, "A", 2, full_every=8)
    flor.init(str(tmp_path / "runB"), mode="record", adaptive=False,
              async_materialize=False, store_root=root, run_id="B",
              parent_run="A", full_manifest_every=8)
    ctx = flor.get_context()
    state = flor.warm_start("train", like=_tree(0.0))
    RunRegistry(root).unregister("A")
    ctx.gc()                      # default live set; B's namespace is empty
    assert ctx.store.has("A::train@1.0")          # pipeline tip survives
    state = dict(state, head=np.asarray(state["head"]) + 1)
    ctx.submit_checkpoint("train", "train@0.0", state, meta={})
    assert _leaves_equal(state, ctx.store.get_tree("train@0.0", like=state))
    flor.finish()


def test_derived_run_resumes_after_parent_unregistered(tmp_path):
    """Crash-restart of a derived record run must work after `runs rm` of
    its parent: parent validation only applies to FIRST registration."""
    root = str(tmp_path / "store")
    _record_run(tmp_path / "runA", root, "A", 2)
    _record_run(tmp_path / "runB", root, "B", 2, parent="A")
    RunRegistry(root).unregister("A")
    # relaunch with the same arguments — must not raise
    flor.init(str(tmp_path / "runB"), mode="record", adaptive=False,
              async_materialize=False, store_root=root, run_id="B",
              parent_run="A")
    ctx = flor.get_context()
    assert ctx.store.has("train@1.0")             # own checkpoints intact
    flor.finish()


def test_gc_reclaims_aged_tmp_files_only(tmp_path):
    """Stray tmp files from KILLED writers are reclaimed once aged; a
    fresh tmp (possibly an in-flight write) is left alone."""
    store = CheckpointStore(str(tmp_path / "s"))
    t = {"x": np.arange(2048, dtype=np.float32)}
    store.put_tree("keep", t)
    obj_dir = os.path.join(store.root, "objects", "zz")
    os.makedirs(obj_dir, exist_ok=True)
    old = os.path.join(obj_dir, "dead.zst.tmp.1.1")
    fresh = os.path.join(obj_dir, "live.zst.tmp.2.2")
    stale_man = os.path.join(store.root, "manifests", "x.msgpack.tmp.1.1")
    for p in (old, fresh, stale_man):
        with open(p, "wb") as f:
            f.write(b"garbage")
    past = os.path.getmtime(old) - 3600
    os.utime(old, (past, past))
    os.utime(stale_man, (past, past))
    stats = store.gc(["keep"])
    assert stats["deleted_tmp_files"] == 2
    assert not os.path.exists(old) and not os.path.exists(stale_man)
    assert os.path.exists(fresh)                  # age-gated: not raced
    back = store.get_tree("keep", like=t)
    assert _leaves_equal(t, back)


# ------------------------------------------------------------ stats --
def test_store_stats_per_key_whole_store(tmp_path):
    """stats(per_key=True) without keys= must cover every manifest under
    its qualified name, not return an empty map."""
    store = CheckpointStore(str(tmp_path / "s"))
    pipe = CheckpointPipeline(store, chunk_words=256, full_every=4,
                              async_stage=False)
    t = _tree(1.0)
    for i in range(3):
        t = dict(t, head=np.asarray(t["head"]) + 1)
        pipe.submit(f"ck{i}", t, scope="s")
    pipe.close()
    st = store.stats(per_key=True, include_chunks=False)
    assert len(st["per_key"]) == 3
    assert st["per_key"]["::ck2"]["depth"] == 2
    # restricted form keys the map by the caller's input strings
    st = store.stats(keys=["ck1"], per_key=True, include_chunks=False)
    assert set(st["per_key"]) == {"ck1"} and st["per_key"]["ck1"]["depth"] == 1


def test_store_stats_single_pass_chain_depth(tmp_path):
    store = CheckpointStore(str(tmp_path / "s"))
    pipe = CheckpointPipeline(store, chunk_words=256, full_every=4,
                              async_stage=False)
    t = _tree(1.0)
    for i in range(6):
        t = dict(t, head=np.asarray(t["head"]) + 1)
        pipe.submit(f"ck{i}", t, scope="s")
    pipe.close()
    st = store.stats()
    # cadence 4: ck0 full, ck1-3 delta, ck4 full, ck5 delta
    assert st["manifests"] == 6
    assert st["full_manifests"] == 2 and st["delta_manifests"] == 4
    assert st["max_chain_depth"] == 3
    assert st["chunks"] >= 1 and st["stored_bytes"] > 0


# ----------------------------------------------------------- runs CLI --
def test_runs_cli_list_show_rm_gc(tmp_path, capsys):
    from repro.launch.runs import main as runs_main
    root = str(tmp_path / "store")
    _record_run(tmp_path / "runA", root, "A", 4, full_every=2)
    final_b = _record_run(tmp_path / "runB", root, "B", 1, parent="A")
    assert runs_main(["list", "--store-root", root]) == 0
    out = capsys.readouterr().out
    assert "A" in out and "B" in out and "delta" in out
    assert runs_main(["show", "B", "--store-root", root]) == 0
    assert "ancestry   B <- A" in capsys.readouterr().out
    # rm refuses while descendants are registered
    assert runs_main(["rm", "A", "--store-root", root]) == 1
    assert runs_main(["rm", "A", "--force", "--gc",
                      "--store-root", root]) == 0
    assert "deleted 2 manifests" in capsys.readouterr().out
    # run-dir form resolves through flor.run.json
    assert runs_main(["list", "--store-root", str(tmp_path / "runB")]) == 0
    sb = CheckpointStore(root, run_id="B")
    assert _leaves_equal(final_b, sb.get_tree("train@0.0", like=final_b))


def test_runs_cli_diff_chunks_shared_vs_unique(tmp_path, capsys):
    """`runs diff A B`: a warm-started child shares its parent's frozen
    chunks; the diff exposes exactly that."""
    from repro.launch.runs import main as runs_main
    root = str(tmp_path / "store")
    _record_run(tmp_path / "runA", root, "A", 2, full_every=2)
    _record_run(tmp_path / "runB", root, "B", 2, parent="A")
    assert runs_main(["diff", "A", "B", "--store-root", root]) == 0
    out = capsys.readouterr().out
    assert "shared" in out and "only A" in out and "only B" in out
    store = CheckpointStore(root)
    ca = store.closure_chunks([f"A::{k}" for k in store.list_keys(run="A")])
    cb = store.closure_chunks([f"B::{k}" for k in store.list_keys(run="B")])
    # B warm-started from A: its closure resolves THROUGH A's chunks
    assert ca & cb, "warm-started child must share parent chunks"
    assert cb - ca, "child's own mutations must be unique"
    assert runs_main(["diff", "A", "nope", "--store-root", root]) == 1


# ------------------------------------------- registry concurrency ------
def test_register_exclusive_detects_collision(tmp_path):
    from repro.checkpoint import RunIdCollision
    reg = RunRegistry(str(tmp_path / "store"))
    reg.register("X", run_dir="/a", namespace="X", exclusive=True)
    with pytest.raises(RunIdCollision):
        reg.register("X", run_dir="/b", namespace="X", exclusive=True)
    # same (run_dir, namespace) = crash-restart/resume, not a collision
    reg.finalize("X", final_keys={"train": "k"})
    rec = reg.register("X", run_dir="/a", namespace="X", exclusive=True)
    assert rec["final_keys"] == {"train": "k"}   # resume keeps finals


def test_exclusive_rerecord_sweeps_stale_registration(tmp_path):
    """Regression: a re-record into the same (run_dir, namespace) under a
    fresh GENERATED id (exclusive path) must still unregister the stale
    record — a ghost entry would pin dead chunks through registry gc."""
    reg = RunRegistry(str(tmp_path / "store"))
    reg.register("R1", run_dir="/d", namespace=None, exclusive=True)
    reg.register("R2", run_dir="/d", namespace=None, exclusive=True)
    assert [r["run_id"] for r in reg.list_runs()] == ["R2"]


def test_context_retries_generated_id_on_collision(tmp_path, monkeypatch):
    """Two simultaneous recorders racing one generated id: the loser must
    retry with a fresh id instead of clobbering the winner's entry."""
    import repro.core.context as ctx_mod
    root = str(tmp_path / "store")
    reg = RunRegistry(root)
    reg.register("dup-id", run_dir=str(tmp_path / "other"),
                 namespace="dup-id", exclusive=True)
    ids = iter(["dup-id", "dup-id", "fresh-id"])
    monkeypatch.setattr(ctx_mod, "generate_run_id", lambda: next(ids))
    ctx = FlorContext(str(tmp_path / "mine"), "record", adaptive=False,
                      async_materialize=False, store_root=root)
    assert ctx.run_id == "fresh-id"
    assert ctx.namespace == "fresh-id"
    assert read_run_meta(str(tmp_path / "mine"))["run_id"] == "fresh-id"
    # the other recorder's entry survived untouched
    other = reg.get("dup-id")
    assert other["run_dir"] == str(tmp_path / "other")
    ctx.finish()
    assert reg.get("fresh-id")["status"] == "finished"


def test_explicit_run_id_conflict_surfaces(tmp_path):
    """Two recorders given the SAME explicit run id on a shared store: the
    second must fail loudly instead of clobbering the first's record."""
    from repro.checkpoint import RunIdCollision
    root = str(tmp_path / "store")
    ctx_a = FlorContext(str(tmp_path / "a"), "record", adaptive=False,
                        async_materialize=False, store_root=root,
                        run_id="ft1")
    with pytest.raises(RunIdCollision):
        FlorContext(str(tmp_path / "b"), "record", adaptive=False,
                    async_materialize=False, store_root=root, run_id="ft1")
    ctx_a.finish()
    rec = RunRegistry(root).get("ft1")
    assert rec["run_dir"] == str(tmp_path / "a")
    assert rec["status"] == "finished"
    # crash-restart/resume of the SAME (run_dir, namespace) still works
    ctx_a2 = FlorContext(str(tmp_path / "a"), "record", adaptive=False,
                         async_materialize=False, store_root=root,
                         run_id="ft1")
    ctx_a2.finish()


def test_interleaved_writers_never_clobber(tmp_path):
    """Regression: N threads registering + finalizing distinct runs against
    one registry concurrently; every record must survive intact."""
    import threading
    reg = RunRegistry(str(tmp_path / "store"))
    errors = []

    def writer(n):
        try:
            for i in range(10):
                rid = f"run-{n}-{i}"
                reg.register(rid, run_dir=f"/d{n}/{i}", namespace=rid,
                             exclusive=True)
                reg.finalize(rid, final_keys={"train": f"k{i}"})
        except Exception as e:            # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(n,)) for n in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    recs = reg.list_runs()
    assert len(recs) == 40
    assert all(r["status"] == "finished"
               and r["final_keys"] == {"train": f"k{r['run_id'][-1]}"}
               for r in recs)


def test_exclusive_create_race_single_winner(tmp_path):
    """The atomic create itself: many threads racing the SAME id — exactly
    one _create_exclusive wins."""
    reg = RunRegistry(str(tmp_path / "store"))
    rec = {"run_id": "raced", "parent": None, "namespace": "raced",
           "run_dir": "/r", "status": "running", "created_at": 0,
           "finished_at": None, "final_keys": {}, "meta": {}}
    import threading
    wins = []
    barrier = threading.Barrier(8)

    def racer():
        barrier.wait()
        if reg._create_exclusive(dict(rec)):
            wins.append(1)

    threads = [threading.Thread(target=racer) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(wins) == 1
    assert reg.get("raced")["run_id"] == "raced"


# ------------------------------------------------------- property test --
@st.composite
def _lineage_plan(draw):
    """Per-run checkpoint plans for a 3-run chain: each checkpoint mutates a
    random subset of the 16 chunks of `w` (and sometimes `b`)."""
    plan = []
    for _ in range(3):
        n_ckpts = draw(st.integers(1, 3))
        ckpts = []
        for _ in range(n_ckpts):
            idx = draw(st.lists(st.integers(0, 15), min_size=0, max_size=4))
            ckpts.append((sorted(set(idx)), draw(st.booleans())))
        plan.append(ckpts)
    return plan


@given(plan=_lineage_plan())
def test_lineage_chain_restores_bit_identically(tmp_path_factory, plan):
    """Random tree mutations across a 3-run lineage chain always restore
    bit-identically from the shared store — before and after a full-liveness
    gc."""
    root = str(tmp_path_factory.mktemp("lineage_prop"))
    reg = RunRegistry(root)
    rng = np.random.default_rng(0)
    state = {"w": rng.standard_normal(16 * 64).astype(np.float32),
             "b": rng.standard_normal(64).astype(np.float32)}
    truth = {}
    prev_rid = None
    for r, ckpts in enumerate(plan):
        rid = f"r{r}"
        store = CheckpointStore(root, run_id=rid)
        pipe = CheckpointPipeline(store, chunk_words=64, full_every=3,
                                  async_stage=False)
        reg.register(rid, parent=prev_rid, namespace=rid)
        if prev_rid is not None:
            parent_key = reg.get(prev_rid)["final_keys"]["train"]
            qual = f"{prev_rid}::{parent_key}"
            manifest = store.resolve_manifest(qual)
            restored = store.get_tree(qual, manifest=manifest)
            pipe.warm_start("train", qual, manifest, restored)
            state = {"w": restored["['w']"], "b": restored["['b']"]}
        last = None
        for c, (w_idx, bump_b) in enumerate(ckpts):
            state = {"w": state["w"].copy(), "b": state["b"].copy()}
            for i in w_idx:
                state["w"][i * 64] += 1.0
            if bump_b:
                state["b"] += 0.5
            key = f"ck{c}"
            stat = pipe.submit(key, state, scope="train")
            if stat["kind"] == "delta":
                # never transfers more than the mutated chunks
                assert stat["changed_chunks"] <= len(w_idx) + 1
            truth[(rid, key)] = {k: v.copy() for k, v in state.items()}
            last = key
        pipe.close()
        reg.finalize(rid, final_keys={"train": last})
        prev_rid = rid
    store = CheckpointStore(root)
    for (rid, key), t in truth.items():
        got = store.get_tree(f"{rid}::{key}", like=t)
        assert _leaves_equal(t, got), (rid, key)
    # gc with every run registered is content-preserving
    reg.gc(store)
    for (rid, key), t in truth.items():
        got = store.get_tree(f"{rid}::{key}", like=t)
        assert _leaves_equal(t, got), (rid, key)


# ------------------------------------- true multi-process registry races ----
RACE_CHILD = """
import os, sys, time
store, rid, rdir, go, mode, rounds = (sys.argv[1], sys.argv[2], sys.argv[3],
                                      sys.argv[4], sys.argv[5],
                                      int(sys.argv[6]))
from repro.checkpoint.lineage import RunIdCollision, RunRegistry
reg = RunRegistry(store)
deadline = time.time() + 30
while not os.path.exists(go):
    if time.time() > deadline:
        sys.exit(3)
    time.sleep(0.001)
wins = colls = 0
for _ in range(rounds):
    try:
        reg.register(rid, run_dir=rdir, namespace=None, exclusive=True)
        wins += 1
        if mode == "churn":
            # vanish-and-reappear churn: the exact window where a loser of
            # the link race used to fall through to a non-atomic clobber
            reg.unregister(rid)
    except RunIdCollision:
        colls += 1
print("RACE", wins, colls)
"""


def _race_fleet(tmp_path, mode, n=4, rounds=40, same_dir=False):
    import subprocess
    import sys as _sys
    store = str(tmp_path / "store")
    os.makedirs(store, exist_ok=True)
    go = str(tmp_path / "go")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    procs = [subprocess.Popen(
                 [_sys.executable, "-c", RACE_CHILD, store, "shared-id",
                  str(tmp_path / ("dir" if same_dir else f"dir{i}")),
                  go, mode, str(rounds)],
                 env=env, stdout=subprocess.PIPE,
                 stderr=subprocess.STDOUT, text=True)
             for i in range(n)]
    with open(go, "w") as f:
        f.write("go")
    outs = [(p.wait(), p.stdout.read()) for p in procs]
    assert [rc for rc, _ in outs] == [0] * n, outs
    stats = []
    for _, out in outs:
        tok = out.strip().splitlines()[-1].split()
        assert tok[0] == "RACE", out
        stats.append((int(tok[1]), int(tok[2])))
    return store, stats


@pytest.mark.slow
def test_registry_exclusive_race_one_winner(tmp_path):
    """N processes race the same run id for DIFFERENT run dirs: exactly one
    ever owns it; everyone else gets RunIdCollision every round."""
    rounds = 40
    store, stats = _race_fleet(tmp_path, "keep", rounds=rounds)
    assert all(w + c == rounds for w, c in stats), stats
    winners = [i for i, (w, _) in enumerate(stats) if w > 0]
    assert len(winners) == 1, stats
    assert stats[winners[0]][0] == rounds       # resume path, every round
    rec = RunRegistry(store).get("shared-id")
    assert rec and rec["run_dir"].endswith(f"dir{winners[0]}")


@pytest.mark.slow
def test_registry_exclusive_race_under_churn(tmp_path):
    """Winners unregister immediately, so losers observe the record vanish
    mid-race — the loop must re-attempt the atomic create, never fall
    through to a non-atomic write. Every attempt resolves to a win or a
    clean collision, and the registry ends structurally sound."""
    rounds = 40
    store, stats = _race_fleet(tmp_path, "churn", rounds=rounds)
    assert all(w + c == rounds for w, c in stats), stats
    assert sum(w for w, _ in stats) >= 1
    reg = RunRegistry(store)
    rec = reg.get("shared-id")
    assert rec is None or rec["run_id"] == "shared-id"
    reg.list_runs()                             # no torn records
