"""Flor core: adaptive invariants (property), generator partitioning
(property), Table-1 changeset rules, instrumenter, probes, deferred checks."""
import ast
import os
import shutil
import textwrap

import numpy as np
import pytest

from proptest import given, st

from repro.core.adaptive import AdaptiveController
from repro.core.changeset import analyze_loop, outer_assignments
from repro.core.generator import partition
from repro.core.instrument import instrument_source
from repro.core.probes import detect_probes


# ------------------------------------------------------- adaptive (5.3) ----

@given(epochs=st.integers(3, 60),
       c_time=st.floats(0.01, 5.0),
       m_time=st.floats(0.001, 5.0),
       eps=st.sampled_from([1 / 15, 0.02, 0.2]))
def test_record_overhead_invariant_holds(epochs, c_time, m_time, eps):
    """Eq. 1: total materialization time never exceeds eps * total compute
    (modulo the single bootstrap checkpoint, per the paper's k+1 test)."""
    ctrl = AdaptiveController(epsilon=eps)
    mat_total = 0.0
    comp_total = 0.0
    for _ in range(epochs):
        ctrl.observe_execution("b", c_time)
        comp_total += c_time
        if ctrl.should_materialize("b", est_bytes=int(m_time * 1e9)):
            ctrl.note_submitted("b")
            ctrl.observe_materialization("b", m_time)
            mat_total += m_time
    # allow the bootstrap checkpoint (decision made before M was observed)
    assert mat_total - m_time <= eps * comp_total + 1e-9


@given(epochs=st.integers(5, 50), ratio=st.floats(0.0001, 0.01))
def test_cheap_checkpoints_always_materialize(epochs, ratio):
    """Model-training regime (paper: 'memoized every time'): M << eps*C."""
    ctrl = AdaptiveController(epsilon=1 / 15)
    k = 0
    for _ in range(epochs):
        ctrl.observe_execution("b", 1.0)
        if ctrl.should_materialize("b", est_bytes=int(ratio * 1e9)):
            ctrl.note_submitted("b")
            ctrl.observe_materialization("b", ratio)
            k += 1
    assert k == epochs


def test_expensive_checkpoints_go_sparse():
    """Fine-tuning regime (paper: RTE/CoLA): M comparable to C -> periodic."""
    ctrl = AdaptiveController(epsilon=1 / 15)
    k = 0
    for _ in range(100):
        ctrl.observe_execution("b", 1.0)
        if ctrl.should_materialize("b", est_bytes=int(0.5 * 1e9)):
            ctrl.note_submitted("b")
            ctrl.observe_materialization("b", 0.5)
            k += 1
    assert 1 <= k <= 100 * (1 / 15) / 0.5 + 2   # bounded by the invariant
    assert ctrl.record_overhead_bound_ok("b")


def test_replay_latency_invariant_threshold():
    """Eq. 3/4: with c refined online the threshold uses min(1/(1+c), eps)."""
    ctrl = AdaptiveController(epsilon=0.9)   # eps large: Eq. 3 binds
    ctrl.observe_execution("b", 1.0)
    ctrl.note_submitted("b")
    ctrl.observe_materialization("b", 0.4)
    # c = 1.0 -> threshold n/(k+1) * 1/2 = 1/2 * ... with n=1,k=1: 0.25
    assert not ctrl.should_materialize("b")   # 0.4/1.0 > 0.25
    for _ in range(3):
        ctrl.observe_execution("b", 1.0)
    assert ctrl.should_materialize("b")       # n=4,k=1: thr = 1.0


def test_online_c_refinement():
    ctrl = AdaptiveController(epsilon=1 / 15)
    ctrl.observe_execution("b", 1.0)
    ctrl.note_submitted("b")
    ctrl.observe_materialization("b", 0.1)
    ctrl.observe_restore("b", 0.25)
    assert ctrl.c.value > 1.0                 # moved toward 2.5


# ------------------------------------------------------ generator (5.4) ----

@given(n=st.integers(0, 200), g=st.integers(1, 17))
def test_partition_disjoint_cover_balanced(n, g):
    items = list(range(n))
    segs = [partition(items, g, pid)[1] for pid in range(g)]
    flat = [x for s in segs for x in s]
    assert flat == items                       # disjoint, ordered, complete
    sizes = [len(s) for s in segs]
    assert max(sizes) - min(sizes) <= 1        # balanced to within one epoch
    for pid in range(g):
        before, mine = partition(items, g, pid)
        assert before == items[: len(before)]
        assert before + mine == items[: len(before) + len(mine)]


# ------------------------------------------------------ changeset (5.2) ----

def _loop(src):
    tree = ast.parse(textwrap.dedent(src))
    for node in ast.walk(tree):
        if isinstance(node, (ast.For, ast.While)):
            return node, tree
    raise AssertionError("no loop")


def test_rule1_method_call_assignment():
    loop, _ = _loop("""
    for batch in data:
        preds = net.forward(batch)
    """)
    res = analyze_loop(loop, outer_assigned={"net", "data"})
    assert res.ok
    assert res.changeset == ["net"]            # preds/batch loop-scoped


def test_rule2_function_call_assignment():
    loop, _ = _loop("""
    for batch in data:
        state = step(state, batch)
    """)
    res = analyze_loop(loop, outer_assigned={"state", "step", "data"})
    assert res.ok and res.changeset == ["state"]


def test_rule4_method_call_statement():
    loop, _ = _loop("""
    for batch in data:
        optimizer.step()
    """)
    res = analyze_loop(loop, outer_assigned={"optimizer", "data"})
    assert res.ok and res.changeset == ["optimizer"]


def test_rule5_refuses_bare_call():
    loop, _ = _loop("""
    for epoch in range(10):
        train()
        evaluate(net)
    """)
    res = analyze_loop(loop, outer_assigned={"net"})
    assert not res.ok and "rule 5" in res.refused_reason


def test_rule0_refuses_reassignment_of_changed_var():
    loop, _ = _loop("""
    for i in data:
        x = f(i)
        x = y
    """)
    res = analyze_loop(loop, outer_assigned={"x", "y", "data"})
    assert not res.ok and "rule 0" in res.refused_reason


def test_figure6_example():
    """The paper's Fig. 6 inner loop: changeset {optimizer} after filtering
    (net added later by runtime augmentation)."""
    loop, _ = _loop("""
    for batch in training_data_loader:
        preds = net(batch.X)
        avg_loss = loss(preds, batch.Y)
        avg_loss.backward()
        optimizer.step()
    """)
    res = analyze_loop(loop, outer_assigned={"net", "loss", "optimizer",
                                             "training_data_loader"})
    assert res.ok
    assert res.changeset == ["avg_loss", "optimizer"] or \
        res.changeset == ["optimizer", "avg_loss"] or \
        res.changeset == ["optimizer"], res.changeset
    assert "batch" in res.loop_scoped and "preds" in res.loop_scoped


def test_runtime_augmentation_optimizer_implies_model():
    from repro.core.changeset import augment_changeset

    class Opt:
        def flor_tracks(self):
            return ["net"]

    ns = {"optimizer": Opt(), "net": object()}
    out = augment_changeset(["optimizer"], ns)
    assert out == ["optimizer", "net"]


# ---------------------------------------------------- instrumenter (4.2) ----

def test_instrument_wraps_inner_loop_and_main_generator():
    src = textwrap.dedent("""
    state = init()
    metrics = {}
    for epoch in range(4):
        for s in range(3):
            state, metrics = step(state, s)
        report(metrics)
    """)
    out, rep = instrument_source(src)
    # session surface: outer loop wraps the main iterator, inner loop is a
    # named flor.loop inside a flor.checkpointing scope
    assert "flor.loop('main_L4', range(4))" in out
    assert "flor.loop('L5'" in out
    assert "flor.checkpointing(" in out
    assert "flor.skipblock" not in out
    assert list(rep.instrumented.values()) == [["state", "metrics"]]
    # main loop itself is not skippable (report() is rule 5 anyway)
    assert len(rep.main_loops) == 1


def test_instrument_refuses_rule5_inner_loop():
    src = textwrap.dedent("""
    for epoch in range(4):
        for s in range(3):
            do_stuff(s)
    """)
    out, rep = instrument_source(src)
    assert rep.instrumented == {}
    assert len(rep.refused) == 1


# --------------------------------------------------------- probes (3.2) ----

def test_probe_detection_maps_added_line_to_loop():
    old = textwrap.dedent("""
    for epoch in range(4):
        for s in range(3):
            state = step(state, s)
    """)
    new = textwrap.dedent("""
    for epoch in range(4):
        for s in range(3):
            state = step(state, s)
            flor.log('g', state.g)
    """)
    rep = detect_probes(old, new)
    assert rep.probed_blocks == {"L3"}         # inner loop line in OLD source
    assert not rep.suspicious


def test_probe_detection_flags_non_additive_edit():
    old = "for i in range(3):\n    x = f(i)\n"
    new = "for i in range(3):\n    x = g(i)\n"
    rep = detect_probes(old, new)
    assert rep.suspicious
