"""Pipeline stage-scan: equivalence with sequential execution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel.pipeline import bubble_fraction, stage_scan


def _mk(S, d, key):
    return {"w": jax.random.normal(key, (S, d, d)) * 0.1,
            "b": jax.random.normal(jax.random.fold_in(key, 1), (S, d)) * 0.1}


def _stage(p, h):
    return jnp.tanh(h @ p["w"] + p["b"])


@pytest.mark.parametrize("S,M", [(2, 2), (4, 4), (4, 8), (3, 6)])
def test_stage_scan_matches_sequential(S, M):
    d, B = 16, 8 * M // np.gcd(8, M)
    B = M * 2
    params = _mk(S, d, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (B, d))

    seq = x
    for s in range(S):
        seq = _stage(jax.tree_util.tree_map(lambda a: a[s], params), seq)

    pipe = jax.jit(lambda p, x: stage_scan(_stage, p, x, microbatches=M))(
        params, x)
    np.testing.assert_allclose(np.asarray(pipe), np.asarray(seq), atol=1e-5)


def test_bubble_fraction():
    assert bubble_fraction(4, 4) == 3 / 7
    assert bubble_fraction(1, 8) == 0.0
    assert bubble_fraction(4, 60) < 0.05
