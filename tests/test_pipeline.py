"""Pipeline stage-scan: equivalence with sequential execution."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel.pipeline import bubble_fraction, stage_scan


def _mk(S, d, key):
    return {"w": jax.random.normal(key, (S, d, d)) * 0.1,
            "b": jax.random.normal(jax.random.fold_in(key, 1), (S, d)) * 0.1}


def _stage(p, h):
    return jnp.tanh(h @ p["w"] + p["b"])


@pytest.mark.parametrize("S,M", [(2, 2), (4, 4), (4, 8), (3, 6)])
def test_stage_scan_matches_sequential(S, M):
    d, B = 16, 8 * M // np.gcd(8, M)
    B = M * 2
    params = _mk(S, d, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (B, d))

    seq = x
    for s in range(S):
        seq = _stage(jax.tree_util.tree_map(lambda a: a[s], params), seq)

    pipe = jax.jit(lambda p, x: stage_scan(_stage, p, x, microbatches=M))(
        params, x)
    np.testing.assert_allclose(np.asarray(pipe), np.asarray(seq), atol=1e-5)


def test_bubble_fraction():
    assert bubble_fraction(4, 4) == 3 / 7
    assert bubble_fraction(1, 8) == 0.0
    assert bubble_fraction(4, 60) < 0.05


@pytest.mark.slow
def test_stage_scan_matches_sequential_on_8dev_mesh():
    """Forced 8-device CPU mesh with a real 'stage' axis: stage_scan's
    jnp.roll lowers to a cross-device permute when the [S, ...] buffer is
    sharded over 'stage', and the result must still match the sequential
    layer loop bit-for-bit (same dtype, same op order per lane)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.parallel import use_mesh
        from repro.parallel.pipeline import stage_scan

        devs = jax.devices()
        assert len(devs) == 8, devs
        mesh = Mesh(np.array(devs).reshape(4, 2), ("stage", "data"))
        rules = {"stage": [("stage",), ()], "batch": [("data",), ()]}

        S, M, d = 4, 8, 16
        B = M * 2
        k = jax.random.PRNGKey(0)
        params = {"w": jax.random.normal(k, (S, d, d)) * 0.1,
                  "b": jax.random.normal(jax.random.fold_in(k, 1),
                                         (S, d)) * 0.1}
        x = jax.random.normal(jax.random.PRNGKey(1), (B, d))

        def stage(p, h):
            return jnp.tanh(h @ p["w"] + p["b"])

        seq = x
        for s in range(S):
            seq = stage(jax.tree_util.tree_map(lambda a: a[s], params), seq)

        sh = NamedSharding(mesh, P("stage"))
        params_sh = {kk: jax.device_put(v, sh) for kk, v in params.items()}
        with mesh, use_mesh(mesh, rules=rules):
            pipe = jax.jit(lambda p, x: stage_scan(
                stage, p, x, microbatches=M))(params_sh, x)
        np.testing.assert_allclose(np.asarray(pipe), np.asarray(seq),
                                   atol=1e-5)
        print("STAGE_SCAN_OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300)
    assert "STAGE_SCAN_OK" in out.stdout, out.stderr[-2000:]
