"""End-to-end system behaviour: launcher subprocesses (record -> parallel
replay -> deferred check; crash-restart), greedy generation."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(args, timeout=420):
    env = dict(os.environ, PYTHONPATH=SRC)
    return subprocess.run([sys.executable, "-m"] + args, env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_record_replay_launchers_end_to_end(tmp_path):
    run = str(tmp_path / "run")
    r = _run(["repro.launch.train", "--arch", "florbench-100m", "--smoke",
              "--epochs", "3", "--steps-per-epoch", "2", "--batch", "2",
              "--seq", "64", "--run-dir", run, "--no-adaptive"])
    assert r.returncode == 0, r.stderr[-2000:]
    r = _run(["repro.launch.replay", "--run-dir", run, "--arch",
              "florbench-100m", "--smoke", "--epochs", "3",
              "--steps-per-epoch", "2", "--batch", "2", "--seq", "64",
              "--nworkers", "2", "--probe", "train", "--check"])
    assert r.returncode == 0, r.stdout[-500:] + r.stderr[-2000:]
    assert "ok=True" in r.stdout


@pytest.mark.slow
def test_crash_restart_resumes(tmp_path):
    run = str(tmp_path / "run")
    args = ["repro.launch.train", "--arch", "florbench-100m", "--smoke",
            "--epochs", "4", "--steps-per-epoch", "2", "--batch", "2",
            "--seq", "64", "--run-dir", run, "--no-adaptive"]
    r = _run(args)
    assert r.returncode == 0
    r2 = _run(args)
    assert r2.returncode == 0
    assert "resuming" in r2.stdout


def test_greedy_generate_runs():
    import repro.configs as C
    from repro.data import synthetic_batch
    from repro.models import build_model
    from repro.serve.step import greedy_generate
    cfg = C.get_smoke("granite-3-2b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    prompt = synthetic_batch(cfg, 2, 16, 0)
    out = greedy_generate(cfg, params, prompt, steps=5, max_len=32)
    assert out.shape == (2, 5)
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) < cfg.vocab_size).all()


def test_greedy_generate_matches_prefill_argmax():
    """First generated token == argmax of prefill logits (consistency)."""
    import repro.configs as C
    from repro.data import synthetic_batch
    from repro.models import build_model
    cfg = C.get_smoke("florbench-100m").replace(dtype="float32",
                                                param_dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    prompt = synthetic_batch(cfg, 2, 16, 0)
    caches, logits = jax.jit(lambda p, b: m.prefill(p, b, 32))(params, prompt)
    from repro.serve.step import greedy_generate
    out = greedy_generate(cfg, params, prompt, steps=3, max_len=32)
    np.testing.assert_array_equal(np.asarray(out[:, 0]),
                                  np.asarray(jnp.argmax(logits, -1)))
