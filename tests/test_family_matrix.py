"""Cross-family record -> hindsight-replay matrix: every model family the
paper's benchmark sweeps (dense, MoE, SSM, hybrid/MLA, audio enc-dec, VLM)
must record through the full Session path and hindsight-replay to
BIT-IDENTICAL state and log rows — replay correctness is a property of the
substrate, not of one architecture's numerics."""
import jax
import numpy as np
import pytest

import repro.configs as C
import repro.flor as flor
from repro.data import synthetic_batch
from repro.train.step import build_train_step

EPOCHS, STEPS = 2, 2
BATCH, SEQ = 2, 32

# one representative arch per family
FAMILIES = [
    ("dense", "gemma-2b"),
    ("moe", "mixtral-8x7b"),
    ("ssm", "falcon-mamba-7b"),
    ("hybrid", "zamba2-7b"),
    ("audio", "seamless-m4t-large-v2"),
    ("vlm", "llava-next-mistral-7b"),
]


def _loop(sess, cfg, init_state, ts, probe=False):
    state = jax.jit(init_state)(jax.random.PRNGKey(0))
    with sess.checkpointing(state=state) as ckpt:
        for epoch in sess.loop("epochs", range(EPOCHS)):
            for s in sess.loop("train", range(STEPS)):
                b = synthetic_batch(cfg, BATCH, SEQ, epoch * STEPS + s)
                ckpt.state, m = ts(ckpt.state, b)
                if probe:
                    flor.log("probe_gnorm", m["grad_norm"])
            if sess.executed("train"):
                flor.log("loss", m["loss"])
        return ckpt.state


def _leaves_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


@pytest.mark.slow
@pytest.mark.parametrize("family,arch", FAMILIES,
                         ids=[f for f, _ in FAMILIES])
def test_family_record_replay_bit_identical(tmp_path, family, arch):
    cfg = C.get_smoke(arch)
    assert cfg.family == family
    init_state, train_step = build_train_step(cfg)
    ts = jax.jit(train_step)
    run = str(tmp_path / arch)

    with flor.Session(run, mode="record",
                      record=flor.RecordSpec(adaptive=False)) as sess:
        final = _loop(sess, cfg, init_state, ts)

    with flor.Session(run, mode="replay",
                      replay=flor.ReplaySpec(probed={"train"})) as sess:
        out = _loop(sess, cfg, init_state, ts, probe=True)

    # 1) replayed final state is bit-identical
    assert _leaves_equal(final, out), f"{arch}: state diverged in replay"
    # 2) every recorded log row is reproduced bit-identically, and the
    #    hindsight probes landed
    rec, reps = flor.run_logs(run)
    res = flor.deferred_check(rec, reps)
    assert res.ok, (arch, res.anomalies)
    assert res.compared == EPOCHS            # one loss row per epoch
    assert res.hindsight_only == EPOCHS * STEPS
    from repro.logging import FingerprintLog
    rec_loss = [r["value"] for r in FingerprintLog.read(rec)
                if r["key"] == "loss"]
    rep_loss = [r["value"] for p in reps for r in FingerprintLog.read(p)
                if r["key"] == "loss"]
    assert rec_loss == rep_loss and len(rec_loss) == EPOCHS
