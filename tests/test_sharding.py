"""Sharding resolver properties + serve/train step mesh lowering on a small
local mesh (8 fake devices, subprocess so the main process keeps 1 device)."""
import subprocess
import sys
import textwrap

import jax
import pytest

from proptest import given, st

from jax.sharding import PartitionSpec as P


def _mesh(shape=(2, 2), axes=("data", "model")):
    # build an ABSTRACT mesh: resolver only needs axis names/sizes.
    # jax >= 0.4.36 takes ((name, size), ...) pairs; older took (shape, names)
    try:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))
    except TypeError:
        return jax.sharding.AbstractMesh(shape, axes)


from repro.parallel.sharding import physical_spec  # noqa: E402


def test_divisibility_fallback():
    mesh = _mesh((2, 16), ("data", "model"))
    # kv_heads=8 does not divide 16 -> replicate that dim
    spec = physical_spec(("embed", "kv_heads", None), (64, 8, 64), mesh)
    assert spec == P(("data",), None, None) or spec == P("data", None, None)
    # heads=32 divides -> sharded
    spec = physical_spec(("embed", "heads", None), (64, 32, 64), mesh)
    assert spec[1] == "model"


def test_no_axis_reuse():
    mesh = _mesh((2, 2), ("data", "model"))
    spec = physical_spec(("heads", "mlp"), (4, 4), mesh)
    used = [s for s in spec if s is not None]
    flat = []
    for s in used:
        flat.extend(s if isinstance(s, tuple) else [s])
    assert len(flat) == len(set(flat))


def test_cache_seq_spreads_over_all_axes():
    mesh = _mesh((2, 16, 16), ("pod", "data", "model"))
    spec = physical_spec((None, "cache_seq", None, None),
                         (1, 4096, 8, 128), mesh)
    assert spec[1] == ("pod", "data", "model")


def test_batch_of_one_replicates():
    """long_500k decode: B=1 can't use 'data', so the cache sequence dim
    grabs BOTH free axes — all 256 chips still participate."""
    mesh = _mesh((16, 16), ("data", "model"))
    spec = physical_spec(("batch", "cache_seq", None), (1, 4096, 16), mesh)
    assert spec[0] is None
    assert spec[1] == ("data", "model")


@given(dims=st.lists(st.sampled_from([1, 2, 3, 8, 16, 17, 64, 4096]),
                     min_size=1, max_size=4),
       names=st.lists(st.sampled_from(["batch", "embed", "heads", "mlp",
                                       "cache_seq", "vocab", None]),
                      min_size=1, max_size=4))
def test_physical_spec_always_valid(dims, names):
    """Any (logical, shape) combination resolves to a spec that (a) divides
    every dim it shards and (b) never reuses a mesh axis."""
    n = min(len(dims), len(names))
    dims, names = dims[:n], names[:n]
    mesh = _mesh((2, 4, 4), ("pod", "data", "model"))
    sizes = dict(zip(("pod", "data", "model"), (2, 4, 4)))
    spec = physical_spec(names, dims, mesh)
    used = []
    for dim, entry in zip(dims, tuple(spec)):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        used.extend(axes)
        total = 1
        for a in axes:
            total *= sizes[a]
        assert dim % total == 0, (dims, names, spec)
    assert len(used) == len(set(used)), spec


def test_constrain_is_noop_without_mesh():
    import jax.numpy as jnp
    from repro.parallel import constrain
    x = jnp.ones((4, 4))
    assert constrain(x, ("batch", None)) is x


@pytest.mark.slow
def test_small_mesh_train_and_decode_lowering():
    """8 fake devices in a subprocess: florbench train_step + decode_step
    lower+compile with the same sharding machinery the dry-run uses."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import repro.configs as C
        from repro.launch.specs import (batch_shardings, cache_shardings,
                                        param_shardings, state_shardings)
        from repro.configs.base import ShapeSpec
        from repro.models import build_model
        from repro.parallel import use_mesh
        from repro.serve.step import build_decode_step
        from repro.train.step import build_train_step
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg = C.get_smoke("granite-3-2b")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        model = build_model(cfg)
        shape = ShapeSpec("t", "train", 64, 4)
        with mesh, use_mesh(mesh):
            init_state, train_step = build_train_step(cfg)
            st_shapes = jax.eval_shape(init_state, jax.random.PRNGKey(0))
            st_sh = state_shardings(cfg, mesh, st_shapes)
            b_sh, b_specs = batch_shardings(model, shape, mesh)
            rep = NamedSharding(mesh, P())
            c = jax.jit(train_step, in_shardings=(st_sh, b_sh),
                        out_shardings=(st_sh, rep)).lower(
                            st_shapes, b_specs).compile()
            assert c.cost_analysis() is not None
            dshape = ShapeSpec("d", "decode", 256, 8)
            p_sh, p_shapes = param_shardings(model, mesh, dtype=cfg.dtype)
            c_sh, c_specs = cache_shardings(model, dshape, mesh)
            b_sh, b_specs = batch_shardings(model, dshape, mesh)
            step = build_decode_step(cfg)
            c2 = jax.jit(step, in_shardings=(p_sh, c_sh, b_sh["tokens"], rep),
                         out_shardings=(rep, rep, c_sh)).lower(
                p_shapes, c_specs, b_specs["tokens"], b_specs["pos"]).compile()
        print("LOWERED_OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300)
    assert "LOWERED_OK" in out.stdout, out.stderr[-2000:]


@pytest.mark.slow
def test_divisibility_fallback_on_real_8dev_mesh():
    """Forced 8-device CPU mesh: physical_spec's divisibility fallback and
    respec's resharding rules hold on REAL devices — device_put under the
    resolved spec round-trips the exact bytes, and a recorded (2, 4) spec
    re-resolves on a (8,) mesh by dropping the absent axis."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.parallel.sharding import physical_spec, respec, spec_entries

        devs = jax.devices()
        assert len(devs) == 8, devs
        mesh = Mesh(np.array(devs).reshape(2, 4), ("data", "model"))

        # kv_heads=6 does not divide model=4 -> replicated dim; embed -> data
        spec = physical_spec(("embed", "kv_heads"), (16, 6), mesh)
        assert tuple(spec) == ("data", None), spec
        x = jnp.arange(16 * 6, dtype=jnp.float32).reshape(16, 6)
        xs = jax.device_put(x, NamedSharding(mesh, spec))
        assert np.array_equal(np.asarray(jax.device_get(xs)), np.asarray(x))

        # heads=8 divides model=4 -> sharded on real devices
        spec2 = physical_spec(("embed", "heads"), (16, 8), mesh)
        assert tuple(spec2) == ("data", "model"), spec2
        y = jax.device_put(jnp.full((16, 8), 1.5),
                           NamedSharding(mesh, spec2))
        assert len({d.id for d in y.devices()}) == 8

        # respec: recorded ("data","model") entries re-resolve on a 1-axis
        # replay mesh — "model" is absent so that dim replicates, and a
        # non-dividing dim falls back to its longest dividing prefix
        m8 = Mesh(np.array(devs).reshape(8), ("data",))
        r = respec(spec_entries(spec2), (16, 8), m8)
        assert tuple(r) == ("data", None), r
        r2 = respec(spec_entries(P(("data", "model"))), (12,), m8)
        assert tuple(r2) == (None,), r2   # 12 % 8 != 0 -> replicate
        print("FALLBACK_OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300)
    assert "FALLBACK_OK" in out.stdout, out.stderr[-2000:]


def test_serve_param_shardings_drop_fsdp():
    """serve_replicate_fsdp: serve-path params lose the 'embed' FSDP dim
    (weights-stationary decode) while train params keep it."""
    import repro.configs as C
    from repro.launch.specs import param_shardings
    from repro.models import build_model
    mesh = _mesh((4, 4), ("data", "model"))
    cfg = C.get_smoke("mixtral-8x7b").replace(d_model=64)
    model = build_model(cfg)
    train_sh, _ = param_shardings(model, mesh, dtype=cfg.dtype, serve=False)
    serve_sh, _ = param_shardings(model, mesh, dtype=cfg.dtype, serve=True)

    def uses_data(sh):
        found = []
        for s in jax.tree_util.tree_leaves(
                sh, is_leaf=lambda x: hasattr(x, "spec")):
            for e in s.spec:
                axes = e if isinstance(e, tuple) else (e,)
                if "data" in axes:
                    found.append(s)
        return found

    assert uses_data(train_sh)          # FSDP present in training layout
    assert not uses_data(serve_sh)      # fully weights-stationary at serve


def test_serve_param_shardings_respect_opt_out():
    import repro.configs as C
    from repro.launch.specs import param_shardings
    from repro.models import build_model
    mesh = _mesh((4, 4), ("data", "model"))
    cfg = C.get_smoke("mixtral-8x7b").replace(d_model=64,
                                              serve_replicate_fsdp=False)
    model = build_model(cfg)
    serve_sh, _ = param_shardings(model, mesh, dtype=cfg.dtype, serve=True)
    specs = [s.spec for s in jax.tree_util.tree_leaves(
        serve_sh, is_leaf=lambda x: hasattr(x, "spec"))]
    assert any(any(("data" in (e if isinstance(e, tuple) else (e,)))
                   for e in sp if e) for sp in specs)
