"""Kernel-fused checkpoint fast path: fused fingerprint+mask vs composed
oracles, gather+quantize wire format, q8 manifest round-trips across dtypes,
overlap-mode deferred accounting, structure-change fallback, and the learned
restore cost model feeding the replay planner."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointPipeline, CheckpointStore
from repro.kernels import ref
from repro.kernels.ops import (fingerprint_and_changed, fingerprint_leaf,
                               gather_quantize_blocks, q8_decode_chunk,
                               q8_encode_chunk, quantizable_dtype)


@pytest.fixture()
def store(tmp_path):
    return CheckpointStore(str(tmp_path / "store"))


def _leaves_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    return all(str(np.asarray(x).dtype) == str(np.asarray(y).dtype)
               and np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


# ------------------------------------------------------------ fused kernels
def test_fused_fingerprint_changed_matches_composed():
    """One fused pass == fingerprint then compare, digests and mask both."""
    x = jax.random.normal(jax.random.PRNGKey(0), (8 * 256,))
    prev = fingerprint_leaf(x, 256)
    x2 = x.at[100].set(x[100] + 1.0)
    digest, mask = fingerprint_and_changed(x2, prev, 256)
    np.testing.assert_array_equal(np.asarray(digest),
                                  np.asarray(fingerprint_leaf(x2, 256)))
    exp = np.any(np.asarray(digest) != np.asarray(prev), axis=1)
    np.testing.assert_array_equal(np.asarray(mask).astype(bool), exp)
    assert int(np.asarray(mask).sum()) == 1         # exactly one chunk moved


def test_fused_unchanged_leaf_all_zero_mask():
    x = jax.random.normal(jax.random.PRNGKey(1), (4096,))
    _, mask = fingerprint_and_changed(x, fingerprint_leaf(x, 512), 512)
    assert int(np.asarray(mask).sum()) == 0


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_gather_quantize_wire_roundtrip(dtype):
    """Fused gather+quantize rows encode/decode within the blockwise bound
    for every quantizable dtype."""
    x = (jax.random.normal(jax.random.PRNGKey(2), (4 * 512,)) * 3
         ).astype(dtype)
    idx = jnp.asarray([0, 2, 3], jnp.int32)
    q, s = gather_quantize_blocks(x, idx, 512, 256)
    host = np.asarray(x.astype(jnp.float32))
    for j, i in enumerate([0, 2, 3]):
        payload = q8_encode_chunk(np.asarray(q)[j], np.asarray(s)[j], 512,
                                  256)
        back = np.frombuffer(q8_decode_chunk(payload, str(np.asarray(x).dtype)),
                             dtype=np.asarray(x).dtype)
        chunk = host[i * 512:(i + 1) * 512]
        amax = np.abs(chunk).max()
        assert np.abs(back.astype(np.float32) - chunk).max() \
            <= max(amax, 1e-12) / 126


def test_quantizable_dtype_gate():
    assert quantizable_dtype("float32") and quantizable_dtype("bfloat16") \
        and quantizable_dtype("float16")
    # int/8-byte dtypes pack multiple elements or raw words per u32 word —
    # chunk rows would not align with fingerprint rows
    assert not quantizable_dtype("int32")
    assert not quantizable_dtype("float64")
    assert not quantizable_dtype("uint8")


# ------------------------------------------------------ pipeline q8 slots --
def _tree(step, dtype=jnp.float32):
    frozen = jax.random.normal(jax.random.PRNGKey(0), (64 * 256,))
    return {"frozen": frozen,
            "head": jnp.full((256,), step, jnp.float32),
            "opt": {"mu": (jnp.arange(256, dtype=jnp.float32) * step / 99
                           ).astype(dtype)}}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_q8_slot_roundtrip_over_delta_chain(store, dtype):
    """Quantized slot restores within the q8 bound through full AND delta
    manifests; exact slots stay bit-identical; per-chunk enc resolves
    through the parent chain."""
    pipe = CheckpointPipeline(store, chunk_words=256, full_every=3,
                              async_stage=False, quantize_slots=("mu",))
    trees = {}
    for i in range(7):
        trees[i] = _tree(float(i + 1), dtype)
        pipe.submit(f"ck{i}", trees[i], scope="train")
    pipe.close()
    for i in range(7):
        back = store.get_tree(f"ck{i}")
        assert np.array_equal(np.asarray(back["['frozen']"]),
                              np.asarray(trees[i]["frozen"]))
        assert np.array_equal(np.asarray(back["['head']"]),
                              np.asarray(trees[i]["head"]))
        mu_true = np.asarray(trees[i]["opt"]["mu"].astype(jnp.float32))
        mu_back = np.asarray(back["['opt']['mu']"]).astype(np.float32)
        assert str(back["['opt']['mu']"].dtype) == str(np.asarray(
            trees[i]["opt"]["mu"]).dtype)
        amax = np.abs(mu_true).max()
        assert np.abs(mu_back - mu_true).max() <= max(amax, 1e-12) / 126


def test_q8_enc_survives_resolution_and_unchanged_chunks(store):
    """A q8 chunk recorded in an ancestor manifest keeps its encoding when
    inherited by a descendant delta (enc travels with the hash)."""
    pipe = CheckpointPipeline(store, chunk_words=256, full_every=10,
                              async_stage=False, quantize_slots=("mu",))
    t0 = _tree(1.0)
    pipe.submit("ck0", t0, scope="train")
    # mu UNCHANGED in ck1: its chunks (and their q8 enc) must inherit
    t1 = {"frozen": t0["frozen"],
          "head": t0["head"] + 1.0, "opt": {"mu": t0["opt"]["mu"]}}
    pipe.submit("ck1", t1, scope="train")
    pipe.close()
    resolved = store.resolve_manifest("ck1")
    mu = next(lf for lf in resolved["leaves"]
              if lf["path"] == "['opt']['mu']")
    assert mu.get("leaf_enc") == "q8"
    assert all(e == "q8" for e in mu["enc"])
    back = store.get_tree("ck1")
    mu_true = np.asarray(t1["opt"]["mu"])
    assert np.abs(np.asarray(back["['opt']['mu']"]) - mu_true).max() \
        <= max(np.abs(mu_true).max(), 1e-12) / 126


def test_non_quantizable_dtype_slot_stays_raw(store):
    """A quantize_slots match on an int leaf is ignored (exact path)."""
    pipe = CheckpointPipeline(store, chunk_words=256, async_stage=False,
                              quantize_slots=("counts",))
    tree = {"counts": jnp.arange(1024, dtype=jnp.int32),
            "w": jnp.ones((256,), jnp.float32)}
    pipe.submit("ck0", tree, scope="train")
    pipe.close()
    back = store.get_tree("ck0", like=tree)
    assert _leaves_equal(tree, back)
    m = store.get_manifest("ck0")
    assert all("leaf_enc" not in lf for lf in m["leaves"])


def test_policy_flip_forces_full_manifest(store):
    """Turning quantization on for an existing slot changes the structure
    signature: next submit writes a FULL manifest (no silent mixed chain)."""
    pipe = CheckpointPipeline(store, chunk_words=256, full_every=100,
                              async_stage=False)
    pipe.submit("ck0", _tree(1.0), scope="train")
    pipe.submit("ck1", _tree(2.0), scope="train")
    assert store.get_manifest("ck1")["kind"] == "delta"
    pipe.close()
    pipe2 = CheckpointPipeline(store, chunk_words=256, full_every=100,
                               async_stage=False, quantize_slots=("mu",))
    pipe2.warm_start("train", "ck1", store.resolve_manifest("ck1"),
                     store.get_tree("ck1"))
    t = _tree(3.0)
    s = pipe2.submit("ck2", t, scope="train")
    pipe2.close()
    assert s["kind"] == "full"          # policy flip != silent inheritance
    back = store.get_tree("ck2")
    mu_true = np.asarray(t["opt"]["mu"])
    assert np.abs(np.asarray(back["['opt']['mu']"]) - mu_true).max() \
        <= max(np.abs(mu_true).max(), 1e-12) / 126


def test_structure_change_fallback_with_quantized_slot(store):
    """Reshaping a quantized slot mid-run falls back to a full manifest and
    still restores correctly (tracker forgets the stale digests)."""
    pipe = CheckpointPipeline(store, chunk_words=256, full_every=100,
                              async_stage=False, quantize_slots=("mu",))
    pipe.submit("ck0", _tree(1.0), scope="train")
    pipe.submit("ck1", _tree(2.0), scope="train")
    grown = _tree(3.0)
    grown["opt"]["mu"] = jnp.arange(1024, dtype=jnp.float32) / 7
    s = pipe.submit("ck2", grown, scope="train")
    pipe.close()
    assert s["kind"] == "full"
    back = store.get_tree("ck2")
    mu_true = np.asarray(grown["opt"]["mu"])
    got = np.asarray(back["['opt']['mu']"])
    assert got.shape == mu_true.shape
    assert np.abs(got - mu_true).max() \
        <= max(np.abs(mu_true).max(), 1e-12) / 126
    assert np.array_equal(np.asarray(back["['frozen']"]),
                          np.asarray(grown["frozen"]))


# ------------------------------------------------------------ overlap mode --
def test_overlap_defers_transfer_and_restores(store):
    """Overlap submits report no transfer figure (gather is deferred);
    materialized stats carry the measured bytes; restores stay correct."""
    pipe = CheckpointPipeline(store, chunk_words=256, full_every=4,
                              overlap=True, quantize_slots=("mu",))
    assert pipe.overlap
    trees = {}
    for i in range(6):
        trees[i] = _tree(float(i + 1))
        s = pipe.submit(f"ck{i}", trees[i], scope="train")
        assert s["overlap"] and s["transferred_bytes"] is None
    pipe.drain()
    mats = list(pipe.stats)
    pipe.close()
    assert len(mats) == 6
    assert all(m["transferred_bytes"] is not None and m["overlap"]
               for m in mats)
    deltas = [m for m in mats if m["kind"] == "delta"]
    assert deltas and all(m["transferred_bytes"] < m["logical_bytes"] * 0.2
                          for m in deltas)
    for i in range(6):
        back = store.get_tree(f"ck{i}")
        assert np.array_equal(np.asarray(back["['frozen']"]),
                              np.asarray(trees[i]["frozen"]))
        assert np.array_equal(np.asarray(back["['head']"]),
                              np.asarray(trees[i]["head"]))


def test_overlap_requires_async_stage(store):
    """overlap composes with the async writer only; a sync pipeline keeps
    the one-phase path."""
    pipe = CheckpointPipeline(store, async_stage=False, overlap=True)
    assert not pipe.overlap
    pipe.close()


# --------------------------------------------------- learned cost models --
def test_context_overlap_charges_foreground_only(tmp_path):
    """Overlap mode: M_i sees only the submit stall; writer-thread finalize
    lands in the controller's background accumulator; tfrac still learned
    from the deferred measured transfer."""
    from repro.core.context import FlorContext
    ctx = FlorContext(str(tmp_path / "run"), "record", adaptive=True,
                      ckpt_overlap=True, ckpt_quantize_slots=("mu",))
    try:
        st = _tree(1.0)
        for e in range(4):
            ctx.begin_epoch(e)
            st = {"frozen": st["frozen"], "head": st["head"] + 1.0,
                  "opt": {"mu": st["opt"]["mu"] + 0.5}}
            ctx.controller.observe_execution("train", 1.0)
            ctx.submit_checkpoint("train", ctx.block_key("train"), st, {})
            ctx.advance_block("train")
        ctx.pipeline.drain()
        snap = ctx.controller.snapshot()
        assert snap["bg_s"] > 0          # finalize landed off the step path
        b = ctx.controller.blocks["train"]
        assert b.M.count == 4            # every materialization observed
        assert b.pending == 0
        assert b.tfrac.count > 0 and b.tfrac.value < 1.0
    finally:
        ctx.finish()


def test_calibration_persists_read_bps(tmp_path):
    from repro.core.context import FlorContext
    ctx = FlorContext(str(tmp_path / "run"), "record", adaptive=True)
    calib = ctx.store.get_meta("store_calib")
    ctx.finish()
    assert calib["write_bps"] >= 1e7
    assert calib["read_bps"] >= 1e7


def test_restore_stats_feed_learned_model(tmp_path):
    """restore_checkpoint records bytes+hops; finish() persists a fitted
    read_bps into store calibration meta."""
    from repro.core.context import FlorContext
    ctx = FlorContext(str(tmp_path / "run"), "record", adaptive=False)
    st = _tree(1.0)
    for e in range(3):
        ctx.begin_epoch(e)
        st = {"frozen": st["frozen"], "head": st["head"] + 1.0,
              "opt": {"mu": st["opt"]["mu"]}}
        ctx.submit_checkpoint("train", ctx.block_key("train"), st, {})
        ctx.advance_block("train")
    ctx.pipeline.drain()
    _, dt = ctx.restore_checkpoint("train@2.0")
    rec = ctx.restore_stats[-1]
    assert rec["bytes"] > 0 and rec["hops"] >= 1   # delta chain walked
    ctx.finish()
    calib = CheckpointStore(str(tmp_path / "run" / "store")) \
        .get_meta("store_calib")
    assert calib["read_bps"] > 0 and calib["restore_samples"] == 1


def test_fit_restore_model_shapes():
    from repro.core.context import _fit_restore_model
    assert _fit_restore_model([]) is None
    # single sample: effective throughput only
    one = _fit_restore_model([{"restore_s": 0.5, "bytes": 5 * 10**8,
                               "hops": 0}])
    assert one == {"read_bps": pytest.approx(1e9)}
    # spanning depths: both coefficients recovered from synthetic data
    bps, hop = 2e9, 0.004
    samples = [{"restore_s": b / bps + h * hop, "bytes": b, "hops": h}
               for b, h in [(10**8, 0), (2 * 10**8, 1), (10**8, 3),
                            (4 * 10**8, 2)]]
    fit = _fit_restore_model(samples)
    assert fit["read_bps"] == pytest.approx(bps, rel=1e-3)
    assert fit["hop_s"] == pytest.approx(hop, rel=1e-3)


def test_plan_consumes_learned_calib(tmp_path):
    """build_plan prices restores from the LEARNED calibration meta: bumping
    hop_s / dropping read_bps must raise its restore-cost estimates."""
    import repro.flor as flor
    from repro.replay import build_plan
    run = str(tmp_path / "run")
    with flor.Session(run, record=flor.RecordSpec(adaptive=False)) as sess:
        state = {"x": jnp.zeros((8,), jnp.float32)}
        with sess.checkpointing(state=state) as ckpt:
            for e in sess.loop("epochs", range(4)):
                for _ in sess.loop("train", range(1)):
                    ckpt.state = {"x": ckpt.state["x"] + (e + 1)}
    store = CheckpointStore(os.path.join(run, "store"))
    base = build_plan(run, probed=set())
    calib = dict(store.get_meta("store_calib") or {})
    calib.update({"read_bps": 1e9, "hop_s": 10.0})
    store.put_meta("store_calib", calib)
    slow = build_plan(run, probed=set())
    rc_base = sum(s.restore_cost_s for s in base.segments)
    rc_slow = sum(s.restore_cost_s for s in slow.segments)
    # every priced restore now pays >= 10s of hop latency
    assert rc_slow > rc_base + 9


def test_measured_straggler_default():
    from repro.replay.scheduler import (DEFAULT_STRAGGLER_FACTOR, Task,
                                        measured_straggler_factor)
    measured = [Task(task_id=0, visits=[], est_cost_s=2.0),
                Task(task_id=1, visits=[], est_cost_s=0.5)]
    unmeasured = [Task(task_id=0, visits=[], est_cost_s=2.0),
                  Task(task_id=1, visits=[], est_cost_s=0.0)]
    assert measured_straggler_factor(measured) == DEFAULT_STRAGGLER_FACTOR
    assert measured_straggler_factor(unmeasured) == 0.0
    assert measured_straggler_factor([]) == 0.0


# --------------------------------------------------------- session surface --
def test_recordspec_fused_knobs_validation():
    from repro.core.session import RecordSpec
    spec = RecordSpec(ckpt_quantize_slots=["mu", "nu"], ckpt_overlap=True)
    assert spec.ckpt_quantize_slots == ("mu", "nu")
    kw = spec.to_kwargs()
    assert kw["ckpt_quantize_slots"] == ("mu", "nu") and kw["ckpt_overlap"]
    with pytest.raises(ValueError):
        RecordSpec(ckpt_quantize_slots="mu")        # bare string
    with pytest.raises(ValueError):
        RecordSpec(ckpt_overlap=True, async_materialize=False)


def test_session_fused_end_to_end(tmp_path):
    """RecordSpec knobs reach the pipeline through a Session; exact slots
    restore bit-identically, quantized slot within bound."""
    import repro.flor as flor
    from repro.core.session import RecordSpec
    run = str(tmp_path / "run")
    spec = RecordSpec(adaptive=False, ckpt_quantize_slots=("mu",),
                      ckpt_overlap=True)
    st = _tree(1.0)
    with flor.Session(run, record=spec):
        ctx = flor.get_context()
        assert ctx.pipeline.quantize_slots == ("mu",)
        assert ctx.pipeline.overlap
        for e in range(3):
            ctx.begin_epoch(e)
            st = {"frozen": st["frozen"], "head": st["head"] + 1.0,
                  "opt": {"mu": st["opt"]["mu"] + 0.25}}
            ctx.submit_checkpoint("train", ctx.block_key("train"), st, {})
            ctx.advance_block("train")
        ctx.pipeline.drain()
        back = ctx.store.get_tree("train@2.0")
        assert np.array_equal(np.asarray(back["['frozen']"]),
                              np.asarray(st["frozen"]))
        assert np.array_equal(np.asarray(back["['head']"]),
                              np.asarray(st["head"]))
        mu_true = np.asarray(st["opt"]["mu"])
        assert np.abs(np.asarray(back["['opt']['mu']"]) - mu_true).max() \
            <= max(np.abs(mu_true).max(), 1e-12) / 126
