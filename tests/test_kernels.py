"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.chunk_delta import changed_mask_pallas, fingerprint_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.quantize import dequantize_pallas, quantize_pallas
from repro.kernels.ops import (
    dequantize_blocks, fingerprint_leaf, quantize_blocks)

from proptest import given, st


@pytest.mark.parametrize("g,b", [(8, 128), (16, 1024), (32, 256), (64, 64)])
def test_fingerprint_matches_ref(g, b):
    x = jax.random.bits(jax.random.PRNGKey(g * b), (g, b), jnp.uint32)
    np.testing.assert_array_equal(np.asarray(fingerprint_pallas(x)),
                                  np.asarray(ref.fingerprint_ref(x)))


def test_fingerprint_detects_single_bit_flip():
    x = jax.random.bits(jax.random.PRNGKey(0), (16, 512), jnp.uint32)
    base = fingerprint_pallas(x)
    for (i, j) in [(0, 0), (7, 511), (15, 100)]:
        x2 = x.at[i, j].set(x[i, j] ^ np.uint32(1))
        mask = changed_mask_pallas(fingerprint_pallas(x2), base)
        assert int(mask[i]) == 1 and int(mask.sum()) == 1


def test_fingerprint_position_sensitivity():
    """Swapping two words within a chunk must change its digest."""
    x = jax.random.bits(jax.random.PRNGKey(3), (8, 64), jnp.uint32)
    sw = x.at[2, 0].set(x[2, 1]).at[2, 1].set(x[2, 0])
    assert int(changed_mask_pallas(fingerprint_pallas(sw),
                                   fingerprint_pallas(x))[2]) == 1


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "float16"])
@pytest.mark.parametrize("shape", [(100,), (33, 7), (5, 6, 7)])
def test_fingerprint_leaf_any_shape_dtype(dtype, shape):
    x = jax.random.normal(jax.random.PRNGKey(1), shape).astype(dtype)
    d1 = fingerprint_leaf(x, 64)
    d2 = fingerprint_leaf(x, 64)
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    x2 = x.reshape(-1).at[0].set(jnp.asarray(1.5, x.dtype)).reshape(shape)
    if float(x.reshape(-1)[0]) != 1.5:
        assert not np.array_equal(np.asarray(fingerprint_leaf(x2, 64)),
                                  np.asarray(d1))


@pytest.mark.parametrize("g,b", [(8, 256), (16, 128), (40, 512)])
@pytest.mark.parametrize("scale", [1e-4, 1.0, 1e4])
def test_quantize_matches_ref(g, b, scale):
    x = jax.random.normal(jax.random.PRNGKey(g + b), (g, b)) * scale
    qp, sp = quantize_pallas(x)
    qr, sr = ref.quantize_ref(x)
    np.testing.assert_array_equal(np.asarray(qp), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr), rtol=1e-6)
    # error bound: |x - deq| <= scale/2 per block
    deq = dequantize_pallas(qp, sp)
    err = np.abs(np.asarray(deq) - np.asarray(x))
    bound = np.asarray(sp)[:, None] * 0.5 + 1e-9
    assert (err <= bound).all()


@given(n=st.integers(1, 5000))
def test_quantize_blocks_roundtrip_any_size(n):
    x = jax.random.normal(jax.random.PRNGKey(n), (n,))
    q, s = quantize_blocks(x, block=256)
    back = dequantize_blocks(q, s, (n,), jnp.float32)
    err = np.max(np.abs(np.asarray(back) - np.asarray(x)))
    assert err <= float(s.max()) * 0.5 + 1e-9


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("cfg", [
    # B, H, KV, Sq, Sk, d, bq, bk, causal
    (1, 2, 2, 128, 128, 64, 64, 64, True),
    (2, 4, 2, 128, 128, 64, 128, 128, True),
    (1, 8, 1, 64, 256, 32, 64, 64, True),     # MQA, decode-ish Sq<Sk
    (2, 2, 2, 128, 128, 128, 64, 32, False),  # bidirectional
])
def test_flash_attention_matches_ref(dtype, cfg):
    B, H, KV, Sq, Sk, d, bq, bk, causal = cfg
    ks = jax.random.split(jax.random.PRNGKey(sum(cfg)), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, d)).astype(dtype)
    k = jax.random.normal(ks[1], (B, KV, Sk, d)).astype(dtype)
    v = jax.random.normal(ks[2], (B, KV, Sk, d)).astype(dtype)
    o_p = flash_attention_pallas(q, k, v, causal=causal, block_q=bq, block_k=bk)
    o_r = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o_p, np.float32),
                               np.asarray(o_r, np.float32), atol=tol, rtol=tol)


def test_flash_block_shape_invariance():
    """Output must not depend on the BlockSpec tiling."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    outs = [flash_attention_pallas(q, k, v, block_q=bq, block_k=bk)
            for bq, bk in [(64, 64), (128, 64), (256, 128), (128, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   atol=1e-5)


def test_gradient_compression_error_feedback():
    from repro.parallel.compression import (
        compress_grads_with_feedback, decompress_grads, init_error_state)
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (300,)),
         "b": jax.random.normal(jax.random.PRNGKey(1), (7,))}
    err = init_error_state(g)
    comp, err2 = compress_grads_with_feedback(g, err)
    deq = decompress_grads(comp, g)
    # error feedback: residual carried exactly
    resid = np.asarray(g["w"]) - np.asarray(deq["w"])
    np.testing.assert_allclose(np.asarray(err2["w"]), resid, atol=1e-6)
    # accumulated bias shrinks over repeated steps of the same gradient
    total = np.zeros(300, np.float32)
    err_state = init_error_state(g)
    for _ in range(8):
        comp, err_state = compress_grads_with_feedback(g, err_state)
        total += np.asarray(decompress_grads(comp, g)["w"])
    avg = total / 8
    assert np.abs(avg - np.asarray(g["w"])).max() < 0.02
