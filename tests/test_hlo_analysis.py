"""Trip-count-aware HLO cost analysis: validated against analytic FLOPs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze


def _analyze(fn, *args):
    return analyze(jax.jit(fn).lower(*args).compile().as_text())


def test_single_matmul_flops():
    a = jnp.zeros((128, 256))
    b = jnp.zeros((256, 64))
    r = _analyze(lambda x, y: x @ y, a, b)
    assert r["flops"] == 2 * 128 * 256 * 64


def test_scan_multiplies_trip_count():
    x = jnp.zeros((128, 128))
    def f(x, w):
        return jax.lax.scan(lambda h, ww: (h @ ww, None), x, w)[0]
    for trips in (4, 16):
        w = jnp.zeros((trips, 128, 128))
        r = _analyze(f, x, w)
        expect = trips * 2 * 128 ** 3
        assert abs(r["flops"] - expect) / expect < 0.01, (trips, r["flops"])


def test_nested_scan_multiplies_both_levels():
    x = jnp.zeros((64, 64))
    def inner(h, w):
        return jax.lax.scan(lambda hh, ww: (hh @ ww, None), h, w)[0]
    def outer(x, w):
        return jax.lax.scan(lambda h, wouter: (inner(h, wouter), None), x, w)[0]
    w = jnp.zeros((3, 5, 64, 64))
    r = _analyze(outer, x, w)
    expect = 3 * 5 * 2 * 64 ** 3
    assert abs(r["flops"] - expect) / expect < 0.01


def test_batched_dot_flops():
    a = jnp.zeros((8, 32, 64))
    b = jnp.zeros((8, 64, 16))
    r = _analyze(lambda x, y: jnp.einsum("bik,bkj->bij", x, y), a, b)
    assert r["flops"] == 2 * 8 * 32 * 64 * 16


def test_remat_sees_physical_compute():
    """The analyzer reports the flops of the OPTIMIZED module — i.e., what
    actually runs after XLA CSE/DCE — for both remat and plain autodiff.
    (XLA may CSE the recompute in trivial cases, so we only require both
    to be within the analytic fwd+bwd envelope, not an ordering.)"""
    w1 = jnp.zeros((64, 64))

    def f(w):
        def g(w):
            h = w @ w
            return (h @ h).sum()
        return jax.grad(lambda w: jax.checkpoint(g)(w))(w).sum()

    r = _analyze(f, w1)
    r2 = _analyze(lambda w: jax.grad(
        lambda w: ((w @ w) @ (w @ w)).sum())(w).sum(), w1)
    one_mm = 2 * 64 ** 3
    for rr in (r, r2):
        assert 0 < rr["flops"] <= 8 * one_mm, rr["flops"]


def test_bytes_positive_and_bounded():
    a = jnp.zeros((1024, 1024))
    r = _analyze(lambda x: (x + 1.0) * 2.0, a)
    nbytes = 1024 * 1024 * 4
    assert nbytes <= r["bytes"] <= 6 * nbytes
